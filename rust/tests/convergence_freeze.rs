//! Convergence-aware freeze/thaw contracts (ISSUE: convergence-aware
//! online adaptation):
//!
//! (a) **Replay** — freeze/thaw points and every report figure replay
//!     bit-identically across runs, for several seeds, on the adaptive
//!     virtual clock.
//! (b) **Inertness** — `tol = 0` (the default) leaves the session
//!     bit-for-bit identical to the pre-detector behavior, and an
//!     *enabled* detector that never fires is bitwise indistinguishable
//!     from a disabled one on every non-trace field.
//! (c) **Frozen pipeline parity** — with the detector freezing mid-stream,
//!     the threaded pipelined executor still matches its serial reference
//!     executor bit-for-bit (final dictionary, losses, ψ MessageStats,
//!     and the freeze/thaw event trace itself).
//! (d) **Stationarity** — on a stationary stream a frozen session never
//!     thaws; on a distribution-shift stream the post-shift loss jump
//!     thaws it at a deterministic batch boundary.

use ddl::config::experiment::{ControlConfig, InferenceConfig, ServeConfig};
use ddl::learn::ConvEvent;
use ddl::serve::pipeline::{run_pipelined, PipelineExec};
use ddl::serve::{run_service_with_dict, shift_boundaries, ServeReport};

/// Small serving config; saturated arrivals, serial executor.
fn base_cfg(seed: u64) -> ServeConfig {
    ServeConfig {
        seed,
        agents: 16,
        dim: 8,
        topology: "ring".into(),
        ring_k: 2,
        batch: 8,
        max_wait_us: 2_000,
        samples: 128,
        rate: 0.0,
        mu_w: 0.08,
        pipeline: false,
        pipeline_depth: 1,
        infer: InferenceConfig { mu: 0.4, iters: 10, gamma: 0.08, delta: 0.2, threads: 1 },
        ..ServeConfig::default()
    }
}

/// Detector knobs that guarantee an early freeze on any stream: `tol` is
/// huge, so the first drift windows all count as converged.
fn freeze_fast(cfg: &mut ServeConfig) {
    cfg.convergence.tol = 10.0;
    cfg.convergence.window = 2;
    cfg.convergence.max_no_improvement = 1;
    cfg.convergence.loss_window = 4;
}

/// Adaptive control plane on the deterministic virtual clock (same shape
/// as `tests/control_adaptive.rs`), so *every* report figure — including
/// durations and throughput — is bit-reproducible.
fn adaptive(cfg: &mut ServeConfig) {
    cfg.control = ControlConfig {
        enabled: true,
        slo_p99_ms: 5.0,
        tick_us: 1_000,
        batch_min: 1,
        batch_max: 16,
        wait_min_us: 0,
        wait_max_us: 4_000,
        window: 64,
        svc_base_us: 200,
        svc_per_sample_us: 50,
        upd_per_sample_us: 30,
        depth_min: 1,
        depth_max: 3,
        epoch_batches: 4,
        ..ControlConfig::default()
    };
}

/// Fields that are pure functions of (config, seed, stream) under *any*
/// executor — excludes wall-clock-derived figures, which only replay on
/// the adaptive virtual clock.
fn assert_deterministic_fields_equal(a: &ServeReport, b: &ServeReport, label: &str) {
    assert_eq!(a.samples, b.samples, "{label}: samples");
    assert_eq!(a.batches, b.batches, "{label}: batches");
    assert_eq!(a.shed, b.shed, "{label}: shed");
    assert_eq!(a.mean_batch.to_bits(), b.mean_batch.to_bits(), "{label}: mean batch");
    assert_eq!(
        a.loss_first_quarter.to_bits(),
        b.loss_first_quarter.to_bits(),
        "{label}: first-quarter loss"
    );
    assert_eq!(
        a.loss_last_quarter.to_bits(),
        b.loss_last_quarter.to_bits(),
        "{label}: last-quarter loss"
    );
    assert_eq!(a.stats, b.stats, "{label}: ψ MessageStats");
    assert_eq!(a.decisions, b.decisions, "{label}: controller trace");
    assert_eq!(a.depth_trace, b.depth_trace, "{label}: depth trace");
}

/// Conv-trace equality: every freeze/thaw/drift decision, with exact
/// float bits inside the drift events (`ConvEvent: PartialEq` compares
/// norms by value, which is what the replay contract promises — NaN never
/// occurs by construction).
fn assert_conv_trace_equal(a: &ServeReport, b: &ServeReport, label: &str) {
    assert_eq!(a.conv_events, b.conv_events, "{label}: conv events");
    assert_eq!(a.frozen_batches, b.frozen_batches, "{label}: frozen batches");
}

fn freeze_batch(report: &ServeReport) -> Option<usize> {
    report.conv_events.iter().find_map(|e| match e {
        ConvEvent::Freeze { batch } => Some(*batch),
        _ => None,
    })
}

fn has_thaw(report: &ServeReport) -> bool {
    report.conv_events.iter().any(|e| matches!(e, ConvEvent::Thaw { .. }))
}

// ---------------------------------------------------------------------
// (a) Replay across seeds
// ---------------------------------------------------------------------

#[test]
fn freeze_thaw_replays_bitwise_across_seeds() {
    for seed in [0xF1_01u64, 0xF1_02, 0xF1_03] {
        let mut cfg = base_cfg(seed);
        freeze_fast(&mut cfg);
        adaptive(&mut cfg);
        let (r1, d1) = run_service_with_dict(&cfg, &mut |_| {}).unwrap();
        let (r2, d2) = run_service_with_dict(&cfg, &mut |_| {}).unwrap();
        assert!(
            r1.frozen_batches > 0,
            "seed {seed:#x}: detector must freeze under tol = 10"
        );
        assert!(freeze_batch(&r1).is_some(), "seed {seed:#x}: Freeze event missing");
        assert_deterministic_fields_equal(&r1, &r2, "freeze replay");
        assert_conv_trace_equal(&r1, &r2, "freeze replay");
        // Adaptive mode: even the virtual duration replays.
        assert_eq!(r1.duration_s.to_bits(), r2.duration_s.to_bits(), "virtual duration");
        assert_eq!(d1.mat().as_slice(), d2.mat().as_slice(), "final dictionaries");
    }
}

// ---------------------------------------------------------------------
// (b) tol = 0 and the never-firing detector are inert
// ---------------------------------------------------------------------

#[test]
fn tol_zero_is_bitwise_always_adapt() {
    // Baseline: detector off (tol = 0 is the ServeConfig default).
    let off = base_cfg(0xF1_10);
    assert!(!off.convergence.enabled());
    let (r_off, d_off) = run_service_with_dict(&off, &mut |_| {}).unwrap();
    assert!(r_off.conv_events.is_empty(), "disabled detector must observe nothing");
    assert_eq!(r_off.frozen_batches, 0);

    // Enabled but never firing: tol so small that adaptation drift always
    // exceeds it. Every batch still takes the full adapt path, so all
    // non-trace fields — and the dictionary — are bit-identical to `off`.
    let mut on = base_cfg(0xF1_10);
    on.convergence.tol = 1e-12;
    on.convergence.window = 4;
    on.convergence.max_no_improvement = 2;
    let (r_on, d_on) = run_service_with_dict(&on, &mut |_| {}).unwrap();
    assert!(
        r_on.conv_events.iter().all(|e| matches!(e, ConvEvent::Drift { .. })),
        "a never-firing detector may only log drift measurements"
    );
    assert!(
        r_on.conv_events.iter().any(|e| match e {
            ConvEvent::Drift { norm, .. } => *norm > 1e-12,
            _ => false,
        }),
        "adaptation under mu_w > 0 must register drift"
    );
    assert_eq!(r_on.frozen_batches, 0, "tol = 1e-12 must never freeze here");
    assert_deterministic_fields_equal(&r_off, &r_on, "tol0 vs never-firing");
    assert_eq!(d_off.mat().as_slice(), d_on.mat().as_slice(), "final dictionaries");
}

// ---------------------------------------------------------------------
// (c) Frozen-mode pipeline parity
// ---------------------------------------------------------------------

#[test]
fn frozen_pipeline_threaded_matches_reference() {
    for &threads in &[1usize, 2] {
        let mut cfg = base_cfg(0xF1_20);
        cfg.pipeline = true;
        freeze_fast(&mut cfg);
        cfg.infer.threads = threads;
        let (r_ref, d_ref) = run_pipelined(&cfg, PipelineExec::Reference, &mut |_| {}).unwrap();
        let (r_thr, d_thr) = run_pipelined(&cfg, PipelineExec::Threaded, &mut |_| {}).unwrap();
        let label = format!("frozen pipeline t{threads}");
        assert!(r_ref.frozen_batches > 0, "{label}: freeze must fire");
        assert_deterministic_fields_equal(&r_ref, &r_thr, &label);
        assert_conv_trace_equal(&r_ref, &r_thr, &label);
        assert_eq!(
            d_ref.mat().as_slice(),
            d_thr.mat().as_slice(),
            "{label}: final dictionaries must be bit-identical"
        );
    }
}

#[test]
fn frozen_adaptive_pipeline_parity_and_replay() {
    // Adaptive + frozen: the PipeSim update-slot discount is part of the
    // shared schedule, so threaded ≡ reference including virtual timing.
    let mut cfg = base_cfg(0xF1_21);
    cfg.pipeline = true;
    freeze_fast(&mut cfg);
    adaptive(&mut cfg);
    let (r_ref, d_ref) = run_pipelined(&cfg, PipelineExec::Reference, &mut |_| {}).unwrap();
    let (r_thr, d_thr) = run_pipelined(&cfg, PipelineExec::Threaded, &mut |_| {}).unwrap();
    let (r_thr2, _) = run_pipelined(&cfg, PipelineExec::Threaded, &mut |_| {}).unwrap();
    assert!(r_ref.frozen_batches > 0, "freeze must fire under tol = 10");
    assert_deterministic_fields_equal(&r_ref, &r_thr, "frozen adaptive parity");
    assert_conv_trace_equal(&r_ref, &r_thr, "frozen adaptive parity");
    assert_eq!(r_ref.duration_s.to_bits(), r_thr.duration_s.to_bits(), "virtual duration");
    assert_eq!(d_ref.mat().as_slice(), d_thr.mat().as_slice());
    assert_deterministic_fields_equal(&r_thr, &r_thr2, "threaded replay");
    assert_conv_trace_equal(&r_thr, &r_thr2, "threaded replay");
}

// ---------------------------------------------------------------------
// (d) Stationary streams never thaw; shift streams do
// ---------------------------------------------------------------------

#[test]
fn stationary_stream_never_thaws_after_freezing() {
    let mut cfg = base_cfg(0xF1_30);
    freeze_fast(&mut cfg);
    // Default thaw_ratio 1.5: a stationary planted stream stays within a
    // 1.5x band of its freeze-time loss.
    let (report, _) = run_service_with_dict(&cfg, &mut |_| {}).unwrap();
    let froze_at = freeze_batch(&report).expect("freeze must fire under tol = 10");
    assert!(!has_thaw(&report), "stationary stream must never thaw");
    // Frozen from the batch after the freeze decision to the end.
    assert_eq!(
        report.frozen_batches,
        report.batches - froze_at - 1,
        "every batch after the freeze must be served frozen"
    );
}

#[test]
fn distribution_shift_thaws_at_deterministic_boundary() {
    let mut cfg = base_cfg(0xF1_31);
    cfg.samples = 256; // 32 batches: freeze ≈ batch 8, shift ≈ batch 12–20
    cfg.mu_w = 0.25; // adapt fast so the freeze-time loss sits well below
                     // the mismatched post-shift loss
    cfg.stream = "shift".into();
    cfg.shift_count = 1;
    cfg.convergence.tol = 10.0;
    cfg.convergence.window = 4;
    cfg.convergence.max_no_improvement = 2;
    cfg.convergence.loss_window = 4;
    cfg.convergence.thaw_ratio = 1.25;
    let bounds = shift_boundaries(&cfg).unwrap();
    assert_eq!(bounds.len(), 1, "one shift boundary for shift_count = 1");
    assert!(bounds[0] >= 96 && bounds[0] <= 160, "jitter stays within its span");
    let (r1, _) = run_service_with_dict(&cfg, &mut |_| {}).unwrap();
    let froze_at = freeze_batch(&r1).expect("freeze must fire before the shift");
    assert!(
        froze_at * cfg.batch < bounds[0],
        "freeze (batch {froze_at}) must land before the shift at sample {}",
        bounds[0]
    );
    let thawed_at = r1
        .conv_events
        .iter()
        .find_map(|e| match e {
            ConvEvent::Thaw { batch } => Some(*batch),
            _ => None,
        })
        .expect("post-shift loss jump must thaw adaptation");
    assert!(thawed_at > froze_at, "thaw follows the freeze");
    // The thaw point is itself part of the replay contract.
    let (r2, _) = run_service_with_dict(&cfg, &mut |_| {}).unwrap();
    assert_conv_trace_equal(&r1, &r2, "shift thaw replay");
    assert_deterministic_fields_equal(&r1, &r2, "shift thaw replay");
}
