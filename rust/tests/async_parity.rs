//! Async-executor equivalence and staleness properties: the safety net
//! under `net/async_exec.rs`.
//!
//! * **Degeneracy**: at `τ = 0` the async executor must reproduce the BSP
//!   executor's ν trajectories **bit-for-bit**, for zero delays and for
//!   any random delay configuration (delays move the simulated clock,
//!   never the arithmetic).
//! * **Staleness bound**: no combine may ever use a neighbor ψ older than
//!   `τ` iterations, for any topology / delay / straggler scenario.
//! * **Determinism**: a (seed, scenario) pair replays bit-identically —
//!   trajectories, traffic, and the simulated clock.
//! * **Convergence**: stale combines still drive every agent to the same
//!   O(μ)-neighborhood of the exact dual the synchronous run reaches.

use ddl::graph::{metropolis_weights, Graph, Topology};
use ddl::infer::{exact_dual, DiffusionParams};
use ddl::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use ddl::net::{
    AsyncNetwork, AsyncParams, BspNetwork, ChaosStats, CombineMode, CorruptPolicy, DelayDist,
    DetectionConfig, FaultSchedule,
};
use ddl::rng::Pcg64;

fn random_topology(rng: &mut Pcg64) -> Topology {
    match rng.next_below(3) {
        0 => Topology::Ring { k: 1 + rng.next_below(3) as usize },
        1 => Topology::Grid,
        _ => Topology::ErdosRenyi { p: 0.2 + 0.5 * rng.next_f64() },
    }
}

fn random_delays(rng: &mut Pcg64) -> (DelayDist, DelayDist) {
    let pick = |rng: &mut Pcg64| match rng.next_below(4) {
        0 => DelayDist::Zero,
        1 => DelayDist::Constant { us: 1 + rng.next_below(100) },
        2 => DelayDist::Uniform { lo_us: 10, hi_us: 10 + rng.next_below(300) },
        _ => DelayDist::Exp { mean_us: 5.0 + 100.0 * rng.next_f64() },
    };
    (pick(rng), pick(rng))
}

/// Property: τ = 0 is bit-for-bit BSP across random topologies, sizes,
/// and delay configurations — including straggler multipliers.
#[test]
fn prop_tau0_bitwise_bsp_any_delays() {
    let mut rng = Pcg64::new(0xA5_C0);
    let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
    for case in 0..12 {
        let n = 5 + rng.next_below(25) as usize;
        let m = 2 + rng.next_below(10) as usize;
        let iters = 5 + rng.next_below(40) as usize;
        let topo = random_topology(&mut rng);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &topo, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let params = DiffusionParams::new(0.3, iters);

        let mut bsp = BspNetwork::new(g.clone(), a.clone(), m, None);
        bsp.run(&dict, &task, &x, params).unwrap();

        let (compute, link) = random_delays(&mut rng);
        let mut ap = AsyncParams::default().with_delays(compute, link).with_seed(case);
        if rng.next_below(2) == 1 {
            ap = ap.with_slow_agent(rng.next_below(n as u64) as usize, 8.0);
        }
        let mut anet = AsyncNetwork::new(g, a, m, None, ap).unwrap();
        anet.run(&dict, &task, &x, params).unwrap();

        for k in 0..n {
            assert_eq!(
                anet.nu(k),
                bsp.nu(k),
                "case {case} ({topo:?}, n={n}, m={m}, iters={iters}): agent {k}"
            );
        }
        assert_eq!(anet.stats(), bsp.stats(), "case {case}: traffic accounting");
        assert_eq!(anet.max_staleness_observed(), 0, "case {case}");
    }
}

/// Property: the staleness bound holds as a hard invariant across random
/// scenarios, and every agent completes the full iteration target.
#[test]
fn prop_staleness_bounded_and_live() {
    let mut rng = Pcg64::new(0xA5_C1);
    let task = TaskSpec::SparseCoding { gamma: 0.15, delta: 0.5 };
    for case in 0..10 {
        let n = 6 + rng.next_below(20) as usize;
        let m = 3 + rng.next_below(8) as usize;
        let iters = 10 + rng.next_below(50) as usize;
        let tau = rng.next_below(6) as usize;
        let topo = random_topology(&mut rng);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &topo, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let (compute, link) = random_delays(&mut rng);
        let mut ap =
            AsyncParams::default().with_tau(tau).with_delays(compute, link).with_seed(1000 + case);
        if rng.next_below(2) == 1 {
            ap = ap.with_slow_agent(rng.next_below(n as u64) as usize, 12.0);
        }
        let mut anet = AsyncNetwork::new(g, a, m, None, ap).unwrap();
        anet.run(&dict, &task, &x, DiffusionParams::new(0.25, iters)).unwrap();
        assert!(
            anet.max_staleness_observed() <= tau,
            "case {case}: staleness {} > tau {tau}",
            anet.max_staleness_observed()
        );
        for k in 0..n {
            assert_eq!(anet.iters_done(k), iters, "case {case}: agent {k} incomplete");
        }
        // Traffic is iteration-count-determined, independent of τ/delays.
        assert_eq!(anet.stats().rounds, iters, "case {case}");
    }
}

/// The acceptance-criterion shape at test scale: a 10×-slow agent on a
/// ring, async at τ = 4 clamped to the sync executor's simulated
/// completion time, MSD within 1e-3 of sync against the exact dual.
#[test]
fn straggler_ring_msd_matches_sync_at_equal_sim_time() {
    let (n, m, iters) = (40, 10, 800);
    let mut rng = Pcg64::new(0xA5_C2);
    let dict =
        DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
    let g = Graph::generate(n, &Topology::Ring { k: 2 }, &mut rng);
    let a = metropolis_weights(&g);
    let x = rng.normal_vec(m);
    let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
    let params = DiffusionParams::new(0.5, iters);
    let exact = exact_dual(&dict, &task, &x, 1e-6, 20_000).unwrap();

    let scenario = |tau: usize| {
        AsyncParams::default()
            .with_tau(tau)
            .with_delays(DelayDist::Exp { mean_us: 100.0 }, DelayDist::Exp { mean_us: 20.0 })
            .with_slow_agent(0, 10.0)
            .with_seed(0xBEEF)
    };
    let mut sync = AsyncNetwork::new(g.clone(), a.clone(), m, None, scenario(0)).unwrap();
    sync.run(&dict, &task, &x, params).unwrap();
    let mut anet = AsyncNetwork::new(g, a, m, None, scenario(4)).unwrap();
    anet.run_clamped(&dict, &task, &x, params, sync.sim_time_us()).unwrap();

    let msd_sync = sync.msd_vs(&exact.nu);
    let msd_async = anet.msd_vs(&exact.nu);
    assert!(
        (msd_async - msd_sync).abs() <= 1e-3,
        "MSD gap too large: sync {msd_sync:.3e} vs async {msd_async:.3e}"
    );
    // The async run must genuinely have used stale information to get
    // there (otherwise this test proves nothing).
    assert!(anet.max_staleness_observed() >= 1, "scenario produced no staleness");
}

/// Determinism across the full executor surface: same seed ⇒ identical
/// trajectories, stats, staleness, and clock; different seed ⇒ different
/// clock (the delay model actually randomizes).
#[test]
fn replay_is_bit_identical_per_seed() {
    let (n, m, iters) = (14, 6, 60);
    let mut rng = Pcg64::new(0xA5_C3);
    let dict =
        DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
    let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.4 }, &mut rng);
    let a = metropolis_weights(&g);
    let x = rng.normal_vec(m);
    let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
    let params = DiffusionParams::new(0.3, iters);
    let scenario = |seed: u64| {
        AsyncParams::default()
            .with_tau(3)
            .with_delays(DelayDist::Exp { mean_us: 70.0 }, DelayDist::Exp { mean_us: 30.0 })
            .with_slow_agent(2, 5.0)
            .with_seed(seed)
    };

    let run = |ap: AsyncParams| {
        let mut net = AsyncNetwork::new(g.clone(), a.clone(), m, None, ap).unwrap();
        net.run(&dict, &task, &x, params).unwrap();
        net
    };
    let r1 = run(scenario(7));
    let r2 = run(scenario(7));
    let r3 = run(scenario(8));
    for k in 0..n {
        assert_eq!(r1.nu(k), r2.nu(k), "agent {k}");
    }
    assert_eq!(r1.stats(), r2.stats());
    assert_eq!(r1.sim_time_us(), r2.sim_time_us());
    assert_eq!(r1.max_staleness_observed(), r2.max_staleness_observed());
    assert_ne!(r1.sim_time_us(), r3.sim_time_us(), "seed must move the clock");
}

/// Property: attaching an **empty** (but seeded) `FaultSchedule` is a
/// bitwise no-op across random topologies, delay models, and stragglers —
/// the chaos layer's degeneracy contract, beyond the single fixed case
/// covered in the unit tests.
#[test]
fn prop_empty_fault_schedule_bitwise_parity() {
    let mut rng = Pcg64::new(0xC4_A0);
    let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
    for case in 0..8 {
        let n = 5 + rng.next_below(18) as usize;
        let m = 2 + rng.next_below(8) as usize;
        let iters = 5 + rng.next_below(30) as usize;
        let topo = random_topology(&mut rng);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &topo, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let params = DiffusionParams::new(0.3, iters);
        let (compute, link) = random_delays(&mut rng);
        let mut ap = AsyncParams::default()
            .with_tau(rng.next_below(5) as usize)
            .with_delays(compute, link)
            .with_seed(2000 + case);
        if rng.next_below(2) == 1 {
            ap = ap.with_slow_agent(rng.next_below(n as u64) as usize, 6.0);
        }
        let chaos_seed = rng.next_u64();

        let mut plain = AsyncNetwork::new(g.clone(), a.clone(), m, None, ap.clone()).unwrap();
        plain.run(&dict, &task, &x, params).unwrap();
        let mut with_layer = AsyncNetwork::new(
            g,
            a,
            m,
            None,
            ap.with_chaos(FaultSchedule::new(chaos_seed)),
        )
        .unwrap();
        with_layer.run(&dict, &task, &x, params).unwrap();

        for k in 0..n {
            assert_eq!(plain.nu(k), with_layer.nu(k), "case {case} ({topo:?}): agent {k}");
        }
        assert_eq!(plain.stats(), with_layer.stats(), "case {case}: traffic");
        assert_eq!(plain.sim_time_us(), with_layer.sim_time_us(), "case {case}: clock");
        assert_eq!(with_layer.chaos_stats(), ChaosStats::default(), "case {case}: counters");
        assert_eq!(with_layer.combine_mode(), CombineMode::Metropolis, "case {case}: auto");
    }
}

/// Build a randomized-but-deterministic fault schedule: any subset of
/// {healing partition, crash/recovery, directed outage, edge churn,
/// drop window}, windows inside `[0, horizon]`.
fn random_schedule(g: &Graph, n: usize, horizon: u64, rng: &mut Pcg64) -> FaultSchedule {
    let mut s = FaultSchedule::new(rng.next_u64());
    if rng.next_below(2) == 1 {
        let from = rng.next_below(horizon / 2);
        let len = 1 + rng.next_below(horizon / 2);
        s = s.with_partition(
            FaultSchedule::split_side(n, 0.2 + 0.5 * rng.next_f64()),
            from,
            from + len,
        );
    }
    if rng.next_below(2) == 1 {
        let from = rng.next_below(horizon);
        s = s.with_crash(rng.next_below(n as u64) as usize, from, from + 1 + rng.next_below(horizon));
    }
    if rng.next_below(2) == 1 {
        let k = rng.next_below(n as u64) as usize;
        if let Some(&nb) = g.neighbors(k).first() {
            let from = rng.next_below(horizon);
            s = s.with_link_down(k, nb, from, from + 1 + rng.next_below(horizon));
        }
    }
    s = s.with_edge_churn(g, rng.next_below(5) as usize, horizon / 10, horizon, rng.next_u64());
    if rng.next_below(2) == 1 {
        s = s.with_drops(0.3 * rng.next_f64(), 0, horizon);
    }
    s
}

/// Property (graceful degradation): under randomized fault schedules —
/// partitions, crashes, directed outages, churn, drops, in any
/// combination, under any combine mode — the executor never panics and
/// never stalls: every agent completes its full iteration target, the
/// gated-staleness invariant holds, and same-schedule replays are
/// bit-identical.
#[test]
fn prop_randomized_fault_schedules_never_panic_or_stall() {
    let mut rng = Pcg64::new(0xC4_A1);
    let task = TaskSpec::SparseCoding { gamma: 0.15, delta: 0.5 };
    for case in 0..10 {
        let n = 6 + rng.next_below(12) as usize;
        let m = 3 + rng.next_below(6) as usize;
        let iters = 15 + rng.next_below(30) as usize;
        let tau = rng.next_below(5) as usize;
        let topo = random_topology(&mut rng);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &topo, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let params = DiffusionParams::new(0.25, iters);
        let schedule = random_schedule(&g, n, 20_000, &mut rng);
        let combine = match rng.next_below(3) {
            0 => CombineMode::Auto,
            1 => CombineMode::Metropolis,
            _ => CombineMode::PushSum,
        };
        let ap = AsyncParams::default()
            .with_tau(tau)
            .with_delays(DelayDist::Constant { us: 100 }, DelayDist::Constant { us: 15 })
            .with_seed(3000 + case)
            .with_chaos(schedule)
            .with_combine(combine);

        let run = || {
            let mut net = AsyncNetwork::new(g.clone(), a.clone(), m, None, ap.clone()).unwrap();
            net.run(&dict, &task, &x, params).unwrap();
            net
        };
        let net = run();
        for k in 0..n {
            assert_eq!(
                net.iters_done(k),
                iters,
                "case {case} ({topo:?}, {combine:?}): agent {k} stalled"
            );
        }
        assert!(
            net.max_staleness_observed() <= tau,
            "case {case}: gated staleness {} > tau {tau}",
            net.max_staleness_observed()
        );
        if case % 3 == 0 {
            let again = run();
            assert_eq!(net.stats(), again.stats(), "case {case}: replay traffic");
            assert_eq!(net.sim_time_us(), again.sim_time_us(), "case {case}: replay clock");
            assert_eq!(net.chaos_stats(), again.chaos_stats(), "case {case}: replay stats");
            for k in 0..n {
                assert_eq!(net.nu(k), again.nu(k), "case {case}: replay agent {k}");
            }
        }
    }
}

/// Property (resilient-combine degeneracy): with **zero** Byzantine
/// agents, `Median` and `TrimmedMean(f)` are plain deterministic combine
/// rules — every agent finishes, the τ invariant holds, the chaos
/// corruption counter stays zero, and same-seed replays are bitwise.
#[test]
fn prop_resilient_combine_deterministic_without_attackers() {
    let mut rng = Pcg64::new(0xC4_A3);
    let task = TaskSpec::SparseCoding { gamma: 0.15, delta: 0.5 };
    for case in 0..8 {
        let n = 6 + rng.next_below(12) as usize;
        let m = 3 + rng.next_below(6) as usize;
        let iters = 10 + rng.next_below(30) as usize;
        let tau = rng.next_below(4) as usize;
        let topo = random_topology(&mut rng);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &topo, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let params = DiffusionParams::new(0.25, iters);
        let combine =
            if case % 2 == 0 { CombineMode::Median } else { CombineMode::TrimmedMean(1 + case % 3) };
        // Empty-but-seeded schedule: the Byzantine machinery is armed but
        // nobody attacks, so the resilient combine is the only change.
        let ap = AsyncParams::default()
            .with_tau(tau)
            .with_delays(DelayDist::Constant { us: 60 }, DelayDist::Constant { us: 10 })
            .with_seed(5000 + case as u64)
            .with_chaos(FaultSchedule::new(rng.next_u64()))
            .with_combine(combine);
        let run = || {
            let mut net = AsyncNetwork::new(g.clone(), a.clone(), m, None, ap.clone()).unwrap();
            net.run(&dict, &task, &x, params).unwrap();
            net
        };
        let net = run();
        let again = run();
        for k in 0..n {
            assert_eq!(net.iters_done(k), iters, "case {case} ({combine:?}): agent {k} stalled");
            assert_eq!(net.nu(k), again.nu(k), "case {case} ({combine:?}): replay agent {k}");
            assert!(net.nu(k).iter().all(|v| v.is_finite()), "case {case}: non-finite ν");
        }
        assert!(net.max_staleness_observed() <= tau, "case {case}: τ invariant");
        assert_eq!(net.chaos_stats().corrupted, 0, "case {case}: nobody attacked");
        assert_eq!(net.stats(), again.stats(), "case {case}: replay traffic");
        assert_eq!(net.sim_time_us(), again.sim_time_us(), "case {case}: replay clock");
    }
}

/// Property (defended attack): one corrupted agent per case (each policy
/// in rotation) against `TrimmedMean(f ≥ 1)` — the executor never panics
/// or stalls, the gated-staleness invariant survives the attack, every ν
/// stays finite, corruption is actually happening (counter > 0), and the
/// attacked run replays bit-identically.
#[test]
fn prop_trimmed_defense_survives_corrupted_neighbor() {
    let mut rng = Pcg64::new(0xC4_A4);
    let task = TaskSpec::SparseCoding { gamma: 0.15, delta: 0.5 };
    let policies = [
        CorruptPolicy::SignFlip,
        CorruptPolicy::ScaledNoise { sigma: 5.0 },
        CorruptPolicy::ConstantPsi { value: 3.0 },
        CorruptPolicy::ColludingOffset { magnitude: 2.0 },
    ];
    for case in 0..8 {
        let n = 8 + rng.next_below(12) as usize;
        let m = 3 + rng.next_below(6) as usize;
        let iters = 15 + rng.next_below(30) as usize;
        let tau = rng.next_below(4) as usize;
        let topo = random_topology(&mut rng);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &topo, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let params = DiffusionParams::new(0.25, iters);
        let attacker = rng.next_below(n as u64) as usize;
        let policy = policies[case % policies.len()];
        let schedule =
            FaultSchedule::new(rng.next_u64()).with_byzantine(attacker, policy, 0, u64::MAX);
        let ap = AsyncParams::default()
            .with_tau(tau)
            .with_delays(DelayDist::Constant { us: 60 }, DelayDist::Constant { us: 10 })
            .with_seed(6000 + case as u64)
            .with_chaos(schedule)
            .with_combine(CombineMode::TrimmedMean(1));
        let run = || {
            let mut net = AsyncNetwork::new(g.clone(), a.clone(), m, None, ap.clone()).unwrap();
            net.run(&dict, &task, &x, params).unwrap();
            net
        };
        let net = run();
        for k in 0..n {
            assert_eq!(net.iters_done(k), iters, "case {case} ({policy:?}): agent {k} stalled");
            assert!(
                net.nu(k).iter().all(|v| v.is_finite()),
                "case {case} ({policy:?}): agent {k} blew up under attack"
            );
        }
        assert!(
            net.max_staleness_observed() <= tau,
            "case {case}: attack broke the τ invariant ({} > {tau})",
            net.max_staleness_observed()
        );
        assert!(net.chaos_stats().corrupted > 0, "case {case}: attack never fired");
        let again = run();
        assert_eq!(net.chaos_stats(), again.chaos_stats(), "case {case}: replay counters");
        assert_eq!(net.sim_time_us(), again.sim_time_us(), "case {case}: replay clock");
        for k in 0..n {
            assert_eq!(net.nu(k), again.nu(k), "case {case}: replay agent {k}");
        }
    }
}

/// Property (detection zero false positives): arming the reputation
/// layer on a run with **zero attackers** is a bitwise no-op — same ν
/// bits, traffic, and clock as the same run without detection, and no
/// agent is ever flagged or excluded — across random topologies, delay
/// models, resilient combine modes, and stragglers.
#[test]
fn prop_detection_zero_false_positives_fault_free() {
    let mut rng = Pcg64::new(0xC4_A5);
    let task = TaskSpec::SparseCoding { gamma: 0.15, delta: 0.5 };
    for case in 0..8 {
        let n = 6 + rng.next_below(14) as usize;
        let m = 3 + rng.next_below(6) as usize;
        let iters = 20 + rng.next_below(40) as usize;
        let tau = rng.next_below(4) as usize;
        let topo = random_topology(&mut rng);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &topo, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let params = DiffusionParams::new(0.25, iters);
        let (compute, link) = random_delays(&mut rng);
        let combine =
            if case % 2 == 0 { CombineMode::Median } else { CombineMode::TrimmedMean(1) };
        let mut ap = AsyncParams::default()
            .with_tau(tau)
            .with_delays(compute, link)
            .with_seed(7000 + case)
            .with_chaos(FaultSchedule::new(rng.next_u64()))
            .with_combine(combine);
        if rng.next_below(2) == 1 {
            ap = ap.with_slow_agent(rng.next_below(n as u64) as usize, 6.0);
        }
        let run = |ap: AsyncParams| {
            let mut net = AsyncNetwork::new(g.clone(), a.clone(), m, None, ap).unwrap();
            net.run(&dict, &task, &x, params).unwrap();
            net
        };
        let plain = run(ap.clone());
        let armed = run(ap.with_detect(DetectionConfig { enabled: true, ..Default::default() }));
        for k in 0..n {
            assert_eq!(
                plain.nu(k),
                armed.nu(k),
                "case {case} ({topo:?}, {combine:?}): detection perturbed agent {k}"
            );
        }
        assert_eq!(plain.stats(), armed.stats(), "case {case}: traffic");
        assert_eq!(plain.sim_time_us(), armed.sim_time_us(), "case {case}: clock");
        assert!(
            armed.flagged_suspects().is_empty() && armed.excluded_suspects().is_empty(),
            "case {case} ({topo:?}): false positive on a fault-free run: flagged {:?} \
             excluded {:?}",
            armed.flagged_suspects(),
            armed.excluded_suspects()
        );
        let cs = armed.chaos_stats();
        assert_eq!((cs.flagged, cs.detect_excluded, cs.readmitted), (0, 0, 0), "case {case}");
    }
}

/// Property (detection replay): a sign-flip attacker against
/// `TrimmedMean(1)` with detection armed is flagged and excluded, and a
/// second run under the identical configuration reproduces the entire
/// detection trajectory bit-for-bit — ν bits, clocks, stats, and the
/// exact flagged/excluded sets — across random ring sizes and delays.
#[test]
fn prop_detection_exclusion_replays_bit_identical() {
    let mut rng = Pcg64::new(0xC4_A6);
    let task = TaskSpec::SparseCoding { gamma: 0.15, delta: 0.5 };
    for case in 0..6 {
        let n = 8 + rng.next_below(12) as usize;
        let m = 4 + rng.next_below(6) as usize;
        let iters = 60 + rng.next_below(40) as usize;
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::Ring { k: 1 + rng.next_below(2) as usize }, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let params = DiffusionParams::new(0.3, iters);
        let attacker = rng.next_below(n as u64) as usize;
        let (compute, link) = random_delays(&mut rng);
        let schedule = FaultSchedule::new(rng.next_u64()).with_byzantine(
            attacker,
            CorruptPolicy::SignFlip,
            0,
            u64::MAX,
        );
        let ap = AsyncParams::default()
            .with_tau(rng.next_below(4) as usize)
            .with_delays(compute, link)
            .with_seed(8000 + case)
            .with_chaos(schedule)
            .with_combine(CombineMode::TrimmedMean(1))
            .with_detect(DetectionConfig { enabled: true, ..Default::default() });
        let run = || {
            let mut net = AsyncNetwork::new(g.clone(), a.clone(), m, None, ap.clone()).unwrap();
            net.run(&dict, &task, &x, params).unwrap();
            net
        };
        let net = run();
        assert_eq!(
            net.excluded_suspects(),
            vec![attacker],
            "case {case} (n={n}): detection must exclude exactly the attacker"
        );
        assert_eq!(net.flagged_suspects(), vec![attacker], "case {case}: flag set");
        assert!(net.chaos_stats().detect_excluded > 0, "case {case}: counter");
        let again = run();
        assert_eq!(net.excluded_suspects(), again.excluded_suspects(), "case {case}: replay set");
        assert_eq!(net.flagged_suspects(), again.flagged_suspects(), "case {case}: replay flags");
        assert_eq!(net.chaos_stats(), again.chaos_stats(), "case {case}: replay counters");
        assert_eq!(net.stats(), again.stats(), "case {case}: replay traffic");
        assert_eq!(net.sim_time_us(), again.sim_time_us(), "case {case}: replay clock");
        for k in 0..n {
            assert_eq!(net.nu(k), again.nu(k), "case {case}: replay agent {k}");
        }
    }
}

/// The f = 2 collusion acceptance shape at test scale: two *adjacent*
/// sign-flip colluders on a k = 2 ring, so the honest judges between
/// them see both colluders at once. `TrimmedMean(1)` masking alone can
/// trim only the more extreme colluder per coordinate — the other leaks
/// into every combine and holds the trajectory off its clean fixed
/// point — while masking + detection excludes the pair (the leaker
/// cascades once its partner is gone) and recovers to within 1e-3 of
/// the clean defended trajectory.
#[test]
fn detection_survives_colluding_pair_where_masking_stays_biased() {
    let (n, m, iters) = (20, 8, 800);
    let mut rng = Pcg64::new(0xC4_A7);
    let dict =
        DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
    let g = Graph::generate(n, &Topology::Ring { k: 2 }, &mut rng);
    let a = metropolis_weights(&g);
    let x = rng.normal_vec(m);
    let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
    let params = DiffusionParams::new(0.4, iters);
    let exact = exact_dual(&dict, &task, &x, 1e-6, 20_000).unwrap();

    let colluders = [5usize, 6usize];
    let attacked = FaultSchedule::new(0xD00D).with_colluders(
        &colluders,
        CorruptPolicy::SignFlip,
        0,
        u64::MAX,
    );
    let scenario = |chaos: FaultSchedule, detect: bool| {
        let ap = AsyncParams::default()
            .with_tau(2)
            .with_delays(DelayDist::Constant { us: 50 }, DelayDist::Constant { us: 10 })
            .with_seed(0xFEED)
            .with_chaos(chaos)
            .with_combine(CombineMode::TrimmedMean(1));
        if detect {
            ap.with_detect(DetectionConfig { enabled: true, ..Default::default() })
        } else {
            ap
        }
    };
    let run = |ap: AsyncParams| {
        let mut net = AsyncNetwork::new(g.clone(), a.clone(), m, None, ap).unwrap();
        net.run(&dict, &task, &x, params).unwrap();
        net
    };
    let clean = run(scenario(FaultSchedule::new(0xD00D), false));
    let masked = run(scenario(attacked.clone(), false));
    let detected = run(scenario(attacked, true));

    let msd_clean = clean.msd_vs(&exact.nu);
    let masking_gap = (masked.msd_vs(&exact.nu) - msd_clean).abs();
    let detect_gap = (detected.msd_vs(&exact.nu) - msd_clean).abs();
    let excluded = detected.excluded_suspects();
    assert!(
        excluded.contains(&colluders[0]) && excluded.contains(&colluders[1]),
        "detection must exclude both colluders: {excluded:?}"
    );
    assert!(
        detect_gap <= 1e-3,
        "detection must recover to the clean defended trajectory: gap {detect_gap:.3e}"
    );
    assert!(
        masking_gap > 1e-3,
        "premise broken: TrimmedMean(1) masking alone should stay biased under f = 2 \
         collusion (gap {masking_gap:.3e})"
    );
    assert!(
        detect_gap < masking_gap,
        "detection ({detect_gap:.3e}) must beat masking alone ({masking_gap:.3e})"
    );
    // Corruption really fired in both attacked runs.
    assert!(masked.chaos_stats().corrupted > 0 && detected.chaos_stats().corrupted > 0);
}

/// Property (satellite of the τ-invariant): edge churn — links flapping
/// up and down mid-iteration — never lets a *gated* combine use
/// information older than τ.
#[test]
fn prop_staleness_bound_survives_edge_churn() {
    let mut rng = Pcg64::new(0xC4_A2);
    let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
    for case in 0..6 {
        let n = 6 + rng.next_below(12) as usize;
        let m = 3 + rng.next_below(5) as usize;
        let iters = 20 + rng.next_below(30) as usize;
        let tau = rng.next_below(4) as usize;
        let topo = random_topology(&mut rng);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &topo, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let schedule = FaultSchedule::new(rng.next_u64()).with_edge_churn(
            &g,
            4 + rng.next_below(8) as usize,
            2_000,
            30_000,
            rng.next_u64(),
        );
        let ap = AsyncParams::default()
            .with_tau(tau)
            .with_delays(DelayDist::Constant { us: 80 }, DelayDist::Constant { us: 10 })
            .with_seed(4000 + case)
            .with_chaos(schedule);
        let mut net = AsyncNetwork::new(g, a, m, None, ap).unwrap();
        net.run(&dict, &task, &x, DiffusionParams::new(0.25, iters)).unwrap();
        assert!(
            net.max_staleness_observed() <= tau,
            "case {case}: churn broke the τ invariant ({} > {tau})",
            net.max_staleness_observed()
        );
        for k in 0..n {
            assert_eq!(net.iters_done(k), iters, "case {case}: agent {k} incomplete");
        }
    }
}
