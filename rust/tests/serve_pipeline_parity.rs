//! Pipelined-serving parity: the three-stage concurrent executor must be
//! **bit-identical** to its serial reference executor — same final
//! dictionary, sample/batch counts, per-batch losses, and ψ-traffic
//! `MessageStats` — for any pipeline depth and thread count, on full and
//! partial batches, saturated and paced arrivals. Wall-clock figures
//! (throughput, latency percentiles) are the only thing allowed to differ:
//! the speedup is pure overlap, not a silently different algorithm.
//!
//! Plus the admission property the pipeline is built on: the shared
//! micro-batching queue never blocks admission while a batch is in flight.

use ddl::config::experiment::{InferenceConfig, ServeConfig};
use ddl::serve::pipeline::{run_pipelined, PipelineExec};
use ddl::serve::{BatchPolicy, SharedQueue};

/// Ring N = 100 serving config scaled for test runtime (M and iters small;
/// the schedule logic under test is size-independent).
fn ring_cfg(samples: usize, threads: usize, depth: usize, rate: f64) -> ServeConfig {
    let base = ServeConfig::default();
    ServeConfig {
        seed: 0x9A21,
        agents: 100,
        dim: 10,
        topology: "ring".into(),
        ring_k: 2,
        batch: 8,
        max_wait_us: 400,
        samples,
        rate,
        mu_w: 0.08,
        pipeline: true,
        pipeline_depth: depth,
        infer: InferenceConfig { mu: 0.4, iters: 10, gamma: 0.08, delta: 0.2, threads },
        ..base
    }
}

fn assert_parity(cfg: &ServeConfig, label: &str) {
    let (r_ref, d_ref) =
        run_pipelined(cfg, PipelineExec::Reference, &mut |_| {}).expect("reference executor");
    let (r_thr, d_thr) =
        run_pipelined(cfg, PipelineExec::Threaded, &mut |_| {}).expect("threaded executor");

    assert_eq!(
        d_ref.mat().as_slice(),
        d_thr.mat().as_slice(),
        "{label}: final dictionaries must be bit-identical"
    );
    assert_eq!(r_ref.samples, r_thr.samples, "{label}: sample counts");
    assert_eq!(r_ref.batches, r_thr.batches, "{label}: batch counts");
    assert_eq!(r_ref.mean_batch, r_thr.mean_batch, "{label}: mean batch size");
    assert_eq!(r_ref.stats, r_thr.stats, "{label}: ψ-traffic MessageStats");
    assert_eq!(
        r_ref.loss_first_quarter.to_bits(),
        r_thr.loss_first_quarter.to_bits(),
        "{label}: first-quarter loss"
    );
    assert_eq!(
        r_ref.loss_last_quarter.to_bits(),
        r_thr.loss_last_quarter.to_bits(),
        "{label}: last-quarter loss"
    );
    assert_eq!(r_ref.shed, r_thr.shed, "{label}: shed counts");
    assert_eq!(r_ref.combine_path, r_thr.combine_path);
    assert_eq!(r_thr.mode, "pipelined");
    assert_eq!(r_ref.mode, "pipelined-reference");
    assert_eq!(r_thr.samples, cfg.samples, "{label}: every request served exactly once");
}

/// Saturated ring N = 100 stream, sweeping depth × threads, with the
/// stream length chosen so the final batch is partial (44 = 5·8 + 4) —
/// the engine re-shapes between full and partial batches mid-pipeline.
#[test]
fn pipelined_matches_reference_saturated() {
    for &depth in &[1usize, 2] {
        for &threads in &[1usize, 2] {
            let cfg = ring_cfg(44, threads, depth, 0.0);
            assert_parity(&cfg, &format!("saturated depth={depth} threads={threads}"));
        }
    }
}

/// Deeper pipeline than batches (depth > batch count) and exact-multiple
/// stream lengths are schedule edge cases.
#[test]
fn pipelined_matches_reference_edge_depths() {
    let cfg = ring_cfg(16, 2, 4, 0.0); // 2 batches, depth 4
    assert_parity(&cfg, "depth exceeds batch count");
    let cfg = ring_cfg(32, 1, 2, 0.0); // exact multiple, serial inference
    assert_parity(&cfg, "exact-multiple stream");
}

/// Paced arrivals: formation is service-independent in pipeline mode (the
/// virtual clock jumps only to arrival/deadline events), so the batch
/// sequence — deadline-released partial batches included — is identical
/// across executors, and so is everything downstream.
#[test]
fn pipelined_matches_reference_paced() {
    // ~2k req/s against a 400 µs max-wait: a mix of full and
    // deadline-released partial batches.
    let cfg = ring_cfg(40, 2, 2, 2_000.0);
    let (r_ref, _) =
        run_pipelined(&cfg, PipelineExec::Reference, &mut |_| {}).expect("reference executor");
    assert!(
        r_ref.batches > cfg.samples / cfg.batch,
        "pacing should release some partial batches (got {} batches)",
        r_ref.batches
    );
    assert_parity(&cfg, "paced arrivals");
}

/// The pipelined session still realizes the paper's online-learning
/// property: the representation loss falls while serving (bounded
/// staleness of `depth` batches does not break adaptation).
#[test]
fn pipelined_session_adapts_online() {
    let mut cfg = ring_cfg(192, 2, 2, 0.0);
    cfg.infer.iters = 60;
    cfg.infer.mu = 0.3;
    cfg.mu_w = 0.08;
    let (report, _) =
        run_pipelined(&cfg, PipelineExec::Threaded, &mut |_| {}).expect("threaded executor");
    assert!(
        report.loss_last_quarter < report.loss_first_quarter,
        "online adaptation should reduce loss under the pipeline: {} -> {}",
        report.loss_first_quarter,
        report.loss_last_quarter
    );
}

/// Worker death mid-batch (`[serve] kill_slot`): the victim slot dies on
/// its first batch with index ≥ `kill_at_batch`, the dispatcher
/// re-dispatches the lost batch to the surviving slot, and the session
/// stays bit-identical to the (kill-ignoring) reference executor AND to
/// a no-kill threaded run — a death loses no batch and changes no bit.
#[test]
fn worker_death_redispatch_preserves_parity() {
    let mut cfg = ring_cfg(44, 1, 2, 0.0);
    cfg.kill_slot = Some(1);
    cfg.kill_at_batch = 2;
    assert_parity(&cfg, "worker death at batch >= 2");
    let (r_kill, d_kill) =
        run_pipelined(&cfg, PipelineExec::Threaded, &mut |_| {}).expect("killed run");
    let mut calm = cfg.clone();
    calm.kill_slot = None;
    let (r_calm, d_calm) =
        run_pipelined(&calm, PipelineExec::Threaded, &mut |_| {}).expect("calm run");
    assert_eq!(
        d_kill.mat().as_slice(),
        d_calm.mat().as_slice(),
        "worker death must not change the final dictionary"
    );
    assert_eq!(r_kill.stats, r_calm.stats, "worker death must not change ψ-traffic");
    assert_eq!(r_kill.batches, r_calm.batches);
    // A kill_slot beyond the slot count is inert.
    let mut inert = cfg.clone();
    inert.kill_slot = Some(99);
    let (r_inert, d_inert) =
        run_pipelined(&inert, PipelineExec::Threaded, &mut |_| {}).expect("inert kill");
    assert_eq!(d_inert.mat().as_slice(), d_calm.mat().as_slice());
    assert_eq!(r_inert.samples, cfg.samples);
}

/// Killing the only inference worker is unrecoverable and must surface a
/// typed runtime error, not a hang; the reference executor (no workers)
/// treats the knob as inert.
#[test]
fn killing_the_last_worker_errors() {
    let mut cfg = ring_cfg(16, 1, 1, 0.0);
    cfg.kill_slot = Some(0);
    cfg.kill_at_batch = 0;
    assert!(run_pipelined(&cfg, PipelineExec::Threaded, &mut |_| {}).is_err());
    assert!(run_pipelined(&cfg, PipelineExec::Reference, &mut |_| {}).is_ok());
}

/// Bounded admission (`[serve] queue_capacity`) sheds the saturated
/// overflow identically in both executors: same shed count, same served
/// samples, same final dictionary.
#[test]
fn bounded_admission_sheds_identically_across_executors() {
    let mut cfg = ring_cfg(44, 1, 2, 0.0);
    cfg.queue_capacity = 16;
    let (r_ref, d_ref) =
        run_pipelined(&cfg, PipelineExec::Reference, &mut |_| {}).expect("reference executor");
    let (r_thr, d_thr) =
        run_pipelined(&cfg, PipelineExec::Threaded, &mut |_| {}).expect("threaded executor");
    assert!(r_ref.shed > 0, "saturated arrivals over capacity 16 must shed");
    assert_eq!(r_ref.shed, r_thr.shed, "shed counts must match across executors");
    assert_eq!(r_ref.samples, r_thr.samples);
    assert_eq!(r_ref.samples + r_ref.shed, cfg.samples, "every request served or shed");
    assert_eq!(d_ref.mat().as_slice(), d_thr.mat().as_slice());
}

/// `run_service` dispatches on `cfg.pipeline` and reports the mode.
#[test]
fn run_service_dispatches_to_pipeline() {
    let cfg = ring_cfg(16, 1, 2, 0.0);
    let report = ddl::serve::run_service(&cfg, &mut |_| {}).unwrap();
    assert_eq!(report.mode, "pipelined");
    assert_eq!(report.pipeline_depth, 2);
    assert_eq!(report.samples, 16);
    let mut serial = cfg.clone();
    serial.pipeline = false;
    let report = ddl::serve::run_service(&serial, &mut |_| {}).unwrap();
    assert_eq!(report.mode, "serial");
    assert_eq!(report.pipeline_depth, 0);
}

/// Admission is never blocked while a batch is in flight: a popped batch
/// is moved out of the queue's lock before inference starts, so concurrent
/// producers always make immediate progress.
#[test]
fn admission_never_blocks_while_batch_in_flight() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let q = Arc::new(SharedQueue::new(BatchPolicy::new(4, 1_000)));
    for i in 0..4 {
        q.push(vec![i as f32], 0);
    }
    // Take a batch "into flight" — the queue lock is released the moment
    // the batch is moved out.
    let in_flight = q.pop_batch(0).expect("full batch ready");
    assert_eq!(in_flight.len(), 4);
    assert!(q.is_empty());

    // While the batch is still in flight (not dropped, "processing"), a
    // producer thread admits a burst; it must complete on its own — no
    // dependence on batch completion.
    let done = Arc::new(AtomicBool::new(false));
    let producer = {
        let q = Arc::clone(&q);
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            for i in 0..32 {
                q.push(vec![i as f32], 10 + i as u64);
            }
            done.store(true, Ordering::SeqCst);
        })
    };
    producer.join().expect("producer must finish while the batch is in flight");
    assert!(done.load(Ordering::SeqCst));
    assert_eq!(q.len(), 32, "all admissions landed while the batch was in flight");
    // The in-flight batch is untouched by the new admissions.
    assert_eq!(in_flight.len(), 4);
    drop(in_flight);
    // The backlog drains in policy-sized chunks afterwards.
    assert_eq!(q.pop_batch(10).expect("backlog ready").len(), 4);
}
