//! Cross-module property tests (seeded generators + shrinking from
//! `ddl::testutil`). These pin down the mathematical invariants the whole
//! reproduction rests on.

use ddl::graph::{is_doubly_stochastic, metropolis_weights, Graph, Topology};
use ddl::infer::cost::dual_cost_sum;
use ddl::math::Mat;
use ddl::metrics::auc;
use ddl::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use ddl::ops::{project_l1_ball, project_nonneg_unit_ball, project_unit_ball};
use ddl::rng::Pcg64;
use ddl::testutil::{check, F32Range, VecF32};

/// Metropolis weights are doubly stochastic for any connected G(n, p).
#[test]
fn prop_metropolis_doubly_stochastic() {
    let mut rng = Pcg64::new(0xA1);
    for trial in 0..40 {
        let n = 3 + (rng.next_below(30) as usize);
        let p = 0.15 + 0.8 * rng.next_f64();
        let g = Graph::generate(n, &Topology::ErdosRenyi { p }, &mut rng);
        let a = metropolis_weights(&g);
        assert!(is_doubly_stochastic(&a, 1e-4), "trial {trial}: n={n}, p={p:.2}");
    }
}

/// Euclidean projections are idempotent and non-expansive toward the set.
#[test]
fn prop_projections_idempotent() {
    let gen = VecF32 { min_len: 1, max_len: 40, lo: -5.0, hi: 5.0 };
    check(0xB2, 120, &gen, |v| {
        let mut a = v.clone();
        project_unit_ball(&mut a);
        let mut b = a.clone();
        project_unit_ball(&mut b);
        if ddl::math::vector::dist_sq(&a, &b) > 1e-10 {
            return Err("unit-ball projection not idempotent".into());
        }
        let mut c = v.clone();
        project_nonneg_unit_ball(&mut c);
        if c.iter().any(|&x| x < 0.0) || ddl::math::vector::norm2(&c) > 1.0 + 1e-5 {
            return Err(format!("nonneg ball violated: {c:?}"));
        }
        let mut d = v.clone();
        project_l1_ball(&mut d, 1.0);
        if ddl::math::vector::norm1(&d) > 1.0 + 1e-4 {
            return Err(format!("l1 ball violated: norm {}", ddl::math::vector::norm1(&d)));
        }
        let mut e = d.clone();
        project_l1_ball(&mut e, 1.0);
        if ddl::math::vector::dist_sq(&d, &e) > 1e-8 {
            return Err("l1 projection not idempotent".into());
        }
        Ok(())
    });
}

/// Fenchel–Young: h(y) + h*(Wᵀν) ≥ (Wᵀν)ᵀ y for the elastic net (feasible
/// y only for the non-negative variant).
#[test]
fn prop_fenchel_young_elastic_net() {
    let mut rng = Pcg64::new(0xC3);
    for _ in 0..200 {
        let k = 1 + rng.next_below(6) as usize;
        let gamma = 0.05 + rng.next_f32();
        let delta = 0.05 + rng.next_f32();
        let a: Vec<f32> = (0..k).map(|_| 3.0 * (rng.next_f32() - 0.5)).collect();
        for task in [
            TaskSpec::SparseCoding { gamma, delta },
            TaskSpec::Nmf { gamma, delta },
        ] {
            let y: Vec<f32> = (0..k)
                .map(|_| {
                    let v = 2.0 * (rng.next_f32() - 0.5);
                    if matches!(task, TaskSpec::Nmf { .. }) {
                        v.abs()
                    } else {
                        v
                    }
                })
                .collect();
            let h = task.h_reg(&y);
            let hstar = task.h_conj(&a);
            let inner = ddl::math::blas::dot(&a, &y);
            assert!(
                h + hstar >= inner - 1e-3 * (1.0 + inner.abs()),
                "{task:?}: FY violated: h {h} + h* {hstar} < {inner}"
            );
        }
    }
}

/// Weak duality: for every ν and every feasible y,
/// g(ν) = −Σ J_k(ν) ≤ f(x − Wy) + h(y).
#[test]
fn prop_weak_duality() {
    let mut rng = Pcg64::new(0xD4);
    for trial in 0..60 {
        let m = 4 + rng.next_below(10) as usize;
        let k = 2 + rng.next_below(6) as usize;
        let dict =
            DistributedDictionary::random(m, k, k, AtomConstraint::UnitBall, &mut rng).unwrap();
        let x = rng.normal_vec(m);
        let gamma = 0.05 + 0.5 * rng.next_f32();
        let delta = 0.1 + 0.5 * rng.next_f32();
        let task = TaskSpec::SparseCoding { gamma, delta };
        let nu = rng.normal_vec(m);
        let y = rng.normal_vec(k);
        let g = -dual_cost_sum(&dict, &task, &nu, &x);
        let wy = dict.mat().matvec(&y).unwrap();
        let resid = ddl::math::vector::sub(&x, &wy);
        let primal = task.f_loss(&resid) + task.h_reg(&y);
        assert!(
            g <= primal + 1e-3 * (1.0 + primal.abs()),
            "trial {trial}: weak duality violated: g {g} > primal {primal}"
        );
    }
}

/// Huber weak duality with the ℓ∞ dual-domain constraint.
#[test]
fn prop_weak_duality_huber() {
    let mut rng = Pcg64::new(0xE5);
    for _ in 0..60 {
        let m = 4 + rng.next_below(8) as usize;
        let k = 2 + rng.next_below(4) as usize;
        let dict =
            DistributedDictionary::random(m, k, k, AtomConstraint::NonNegUnitBall, &mut rng)
                .unwrap();
        let x = rng.normal_vec(m);
        let task = TaskSpec::HuberNmf { gamma: 0.2, delta: 0.3, eta: 0.2 };
        // ν must lie in V_f.
        let mut nu = rng.normal_vec(m);
        ddl::ops::clip_linf(&mut nu, 1.0);
        let y: Vec<f32> = rng.normal_vec(k).iter().map(|v| v.abs()).collect();
        let g = -dual_cost_sum(&dict, &task, &nu, &x);
        let wy = dict.mat().matvec(&y).unwrap();
        let resid = ddl::math::vector::sub(&x, &wy);
        let primal = task.f_loss(&resid) + task.h_reg(&y);
        assert!(g <= primal + 1e-3 * (1.0 + primal.abs()), "g {g} > primal {primal}");
    }
}

/// AUC is invariant under strictly monotone transforms of the scores.
#[test]
fn prop_auc_monotone_invariant() {
    let mut rng = Pcg64::new(0xF6);
    for _ in 0..30 {
        let n = 20 + rng.next_below(200) as usize;
        let scores: Vec<f64> = (0..n).map(|_| rng.next_f64() * 4.0 - 2.0).collect();
        let labels: Vec<bool> = (0..n).map(|_| rng.next_f64() < 0.4).collect();
        if labels.iter().all(|&l| l) || labels.iter().all(|&l| !l) {
            continue;
        }
        let base = auc(&scores, &labels);
        let warped: Vec<f64> = scores.iter().map(|&s| (s * 1.7).exp()).collect();
        let a2 = auc(&warped, &labels);
        assert!((base - a2).abs() < 1e-12, "{base} vs {a2}");
    }
}

/// The diffusion fixed point scales correctly: scaling x scales ν° for the
/// (unregularized-path) linear regime γ = 0 where the dual is linear.
#[test]
fn prop_dual_linearity_gamma_zero() {
    let mut rng = Pcg64::new(0x17);
    let m = 8;
    let k = 5;
    let dict = DistributedDictionary::random(m, k, k, AtomConstraint::UnitBall, &mut rng).unwrap();
    let task = TaskSpec::SparseCoding { gamma: 0.0, delta: 0.5 };
    let x = rng.normal_vec(m);
    let sol1 = ddl::infer::exact_dual(&dict, &task, &x, 1e-9, 20000).unwrap();
    let x2: Vec<f32> = x.iter().map(|v| 2.0 * v).collect();
    let sol2 = ddl::infer::exact_dual(&dict, &task, &x2, 1e-9, 20000).unwrap();
    for i in 0..m {
        assert!(
            (2.0 * sol1.nu[i] - sol2.nu[i]).abs() < 1e-3 * (1.0 + sol2.nu[i].abs()),
            "i={i}: {} vs {}",
            2.0 * sol1.nu[i],
            sol2.nu[i]
        );
    }
}

/// Dictionary expansion never disturbs previously learned atoms, across
/// random sizes.
#[test]
fn prop_expand_preserves_prefix() {
    let mut rng = Pcg64::new(0x28);
    for _ in 0..25 {
        let m = 4 + rng.next_below(12) as usize;
        let k = 2 + rng.next_below(6) as usize;
        let extra = 1 + rng.next_below(5) as usize;
        let mut d =
            DistributedDictionary::random(m, k, k, AtomConstraint::NonNegUnitBall, &mut rng)
                .unwrap();
        let before: Vec<Vec<f32>> = (0..k).map(|q| d.atom(q)).collect();
        d.expand(extra, extra, AtomConstraint::NonNegUnitBall, &mut rng).unwrap();
        for (q, b) in before.iter().enumerate() {
            let after = d.atom(q);
            assert_eq!(&after, b, "atom {q} changed by expansion");
        }
        assert_eq!(d.k(), k + extra);
    }
}

/// The trainer must reject malformed inputs instead of corrupting state.
#[test]
fn failure_injection_shape_mismatches() {
    let mut rng = Pcg64::new(0x39);
    let dict =
        DistributedDictionary::random(8, 4, 4, AtomConstraint::UnitBall, &mut rng).unwrap();
    let a = ddl::graph::uniform_weights(4);
    let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
    let mut eng = ddl::infer::DiffusionEngine::new(&a, 8, None).unwrap();
    // Wrong x length.
    assert!(eng
        .run(&dict, &task, &[0.0; 7], ddl::infer::DiffusionParams::new(0.1, 1))
        .is_err());
    // Wrong dictionary dimension.
    let dict_bad =
        DistributedDictionary::random(9, 4, 4, AtomConstraint::UnitBall, &mut rng).unwrap();
    assert!(eng
        .run(&dict_bad, &task, &[0.0; 8], ddl::infer::DiffusionParams::new(0.1, 1))
        .is_err());
    // Wrong agent count.
    let dict_n =
        DistributedDictionary::random(8, 6, 6, AtomConstraint::UnitBall, &mut rng).unwrap();
    assert!(eng
        .run(&dict_n, &task, &[0.0; 8], ddl::infer::DiffusionParams::new(0.1, 1))
        .is_err());
    // Non-square combination matrix.
    assert!(ddl::infer::DiffusionEngine::new(&Mat::zeros(3, 4), 8, None).is_err());
}

/// gemm must agree with the naive triple loop on adversarial shapes
/// (shrinking finds minimal failing dims if the microkernel breaks).
#[test]
fn prop_gemm_matches_naive() {
    let shape_gen = VecF32 { min_len: 3, max_len: 3, lo: 1.0, hi: 40.0 };
    check(0x4A, 40, &shape_gen, |dims| {
        let (m, n, k) = (dims[0] as usize, dims[1] as usize, dims[2] as usize);
        let mut rng = Pcg64::new((m * 1000 + n * 100 + k) as u64);
        let a: Vec<f32> = (0..m * k).map(|_| rng.next_normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.next_normal()).collect();
        let mut c = vec![0.0f32; m * n];
        ddl::math::blas::gemm(m, n, k, 1.0, &a, &b, 0.0, &mut c);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for p in 0..k {
                    acc += a[i * k + p] * b[p * n + j];
                }
                if (c[i * n + j] - acc).abs() > 1e-3 * (1.0 + acc.abs()) {
                    return Err(format!("({m},{n},{k}) at [{i},{j}]: {} vs {acc}", c[i * n + j]));
                }
            }
        }
        Ok(())
    });
    let _ = F32Range { lo: 0.0, hi: 1.0 }; // keep the generator API exercised
}
