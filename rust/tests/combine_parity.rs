//! Combine-path equivalence properties: the CSR spmm path, the dense gemm
//! path, and the uniform fast path must compute the same diffusion, and
//! the thread count must never change a trajectory.
//!
//! These are the safety net for the sparse + parallel inference substrate:
//! every optimization the engine picks (`uniform` / `sparse` / `dense`,
//! `threads = T`) is proven interchangeable here across random Metropolis
//! topologies and agent counts.

use ddl::graph::{metropolis_csr, metropolis_weights, Graph, Topology};
use ddl::infer::{DiffusionEngine, DiffusionParams};
use ddl::math::{blas, CsrMat, Mat};
use ddl::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use ddl::rng::Pcg64;

/// One random (topology, Ψ) combine instance: CSR spmm vs dense gemm.
fn combine_pair(n: usize, m: usize, topo: &Topology, rng: &mut Pcg64) -> (Vec<f32>, Vec<f32>) {
    let g = Graph::generate(n, topo, rng);
    let a = metropolis_weights(&g);
    let at_csr = metropolis_csr(&g);
    let psi = Mat::from_fn(n, m, |_, _| rng.next_normal());
    let mut v_sparse = vec![0.0f32; n * m];
    at_csr.spmm(psi.as_slice(), m, &mut v_sparse);
    let at = a.transpose();
    let mut v_dense = vec![0.0f32; n * m];
    blas::gemm(n, m, n, 1.0, at.as_slice(), psi.as_slice(), 0.0, &mut v_dense);
    (v_sparse, v_dense)
}

/// Property: CSR-spmm combine matches the dense gemm combine to ≤ 1e-6
/// across random Metropolis topologies and agent counts.
#[test]
fn prop_csr_combine_matches_dense_combine() {
    let mut rng = Pcg64::new(0xC5_01);
    for case in 0..30 {
        let n = 5 + (rng.next_below(60) as usize);
        let m = 1 + (rng.next_below(24) as usize);
        let topo = match rng.next_below(3) {
            0 => Topology::Ring { k: 1 + rng.next_below(4) as usize },
            1 => Topology::Grid,
            _ => Topology::ErdosRenyi { p: 0.15 + 0.5 * rng.next_f64() },
        };
        let (sparse, dense) = combine_pair(n, m, &topo, &mut rng);
        for (i, (&s, &d)) in sparse.iter().zip(&dense).enumerate() {
            assert!(
                (s - d).abs() <= 1e-6 + 1e-6 * d.abs(),
                "case {case} ({topo:?}, n={n}, m={m}): index {i}: {s} vs {d}"
            );
        }
    }
}

/// Property: compressing the dense Metropolis matrix gives the same CSR the
/// direct builder produces (values and structure both).
#[test]
fn prop_direct_csr_equals_compressed_dense() {
    let mut rng = Pcg64::new(0xC5_02);
    for _ in 0..20 {
        let n = 4 + (rng.next_below(40) as usize);
        let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.3 }, &mut rng);
        let direct = metropolis_csr(&g);
        let compressed = CsrMat::from_dense_transposed(&metropolis_weights(&g), 0.0);
        // The diagonal can be an exact 0.0 in degenerate cases (and would
        // then be dropped by compression), so compare via densification.
        assert_eq!(direct.to_dense(), compressed.to_dense());
    }
}

fn random_problem(
    n: usize,
    m: usize,
    rng: &mut Pcg64,
) -> (DistributedDictionary, Graph, Vec<f32>) {
    let dict = DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, rng).unwrap();
    let g = Graph::generate(n, &Topology::Ring { k: 2 }, rng);
    let x = rng.normal_vec(m);
    (dict, g, x)
}

/// Property: full engine runs agree between the auto-selected sparse path
/// and the forced dense path, across sizes.
#[test]
fn prop_engine_sparse_path_equals_dense_path() {
    let mut rng = Pcg64::new(0xC5_03);
    let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
    // Ring k=2 rows hold 5 entries, so density 5/N ≤ 0.25 needs N ≥ 20.
    for &(n, m) in &[(24usize, 6usize), (36, 10), (57, 17)] {
        let (dict, g, x) = random_problem(n, m, &mut rng);
        let a = metropolis_weights(&g);
        let params = DiffusionParams::new(0.25, 60);

        let mut sparse = DiffusionEngine::new(&a, m, None).unwrap();
        assert_eq!(sparse.combine_path(), "sparse", "ring k=2 at n={n} must be sparse");
        sparse.run(&dict, &task, &x, params).unwrap();

        let mut dense = DiffusionEngine::new(&a, m, None).unwrap();
        dense.set_combination_dense(&a).unwrap();
        dense.run(&dict, &task, &x, params).unwrap();

        for k in 0..n {
            for (i, (&s, &d)) in sparse.nu(k).iter().zip(dense.nu(k)).enumerate() {
                assert!(
                    (s - d).abs() <= 1e-5 + 1e-4 * d.abs(),
                    "n={n}, agent {k}, dim {i}: sparse {s} vs dense {d}"
                );
            }
        }
    }
}

/// The uniform fast path must be reproduced bit-for-bit by the threaded
/// variant (worker 0 runs the identical serial reduction).
#[test]
fn uniform_fast_path_threading_is_bit_identical() {
    let mut rng = Pcg64::new(0xC5_04);
    let (n, m) = (15, 9);
    let dict = DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
    let a = ddl::graph::uniform_weights(n);
    let x = rng.normal_vec(m);
    let task = TaskSpec::SparseCoding { gamma: 0.15, delta: 0.4 };
    let mut serial = DiffusionEngine::new(&a, m, None).unwrap();
    assert_eq!(serial.combine_path(), "uniform");
    serial.run(&dict, &task, &x, DiffusionParams::new(0.3, 64)).unwrap();
    let mut threaded = DiffusionEngine::new(&a, m, None).unwrap();
    threaded.run(&dict, &task, &x, DiffusionParams::new(0.3, 64).with_threads(4)).unwrap();
    for k in 0..n {
        assert_eq!(serial.nu(k), threaded.nu(k), "agent {k}");
    }
}

/// Determinism: `threads = 1` and `threads = 4` produce identical ν
/// trajectories — checked at several intermediate horizons, not just the
/// final iterate, on both sparse and dense paths.
#[test]
fn thread_determinism_across_horizons() {
    let mut rng = Pcg64::new(0xC5_05);
    let (n, m) = (26, 11);
    let (dict, g, x) = random_problem(n, m, &mut rng);
    let a = metropolis_weights(&g);
    let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };

    for force_dense in [false, true] {
        for iters in [1usize, 7, 33] {
            let make = |threads: usize| {
                let mut e = DiffusionEngine::new(&a, m, None).unwrap();
                if force_dense {
                    e.set_combination_dense(&a).unwrap();
                }
                e.run(&dict, &task, &x, DiffusionParams::new(0.3, iters).with_threads(threads))
                    .unwrap();
                e
            };
            let serial = make(1);
            let threaded = make(4);
            for k in 0..n {
                assert_eq!(
                    serial.nu(k),
                    threaded.nu(k),
                    "force_dense={force_dense}, iters={iters}, agent {k}"
                );
            }
        }
    }
}

/// Property: the batched path with `B ∈ {1, 2, 8}` produces per-sample ν
/// trajectories **bit-identical** to the sequential one-sample engine, for
/// every combine path and for any thread count — the safety net under the
/// `serve/` streaming subsystem (`OnlineTrainer::step` and the session
/// loop both ride `run_batch`).
#[test]
fn prop_batched_trajectories_match_sequential() {
    let mut rng = Pcg64::new(0xC5_07);
    let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
    let (n, m) = (26, 9); // ring k=2 at N=26 → density 5/26 < 0.25 (sparse)
    let (dict, g, _) = random_problem(n, m, &mut rng);
    let a = metropolis_weights(&g);

    for &batch in &[1usize, 2, 8] {
        let xs: Vec<Vec<f32>> = (0..batch).map(|_| rng.normal_vec(m)).collect();
        let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        // Check at several horizons so intermediate iterates are covered,
        // not just the fixed point.
        for &iters in &[1usize, 9, 40] {
            for force_dense in [false, true] {
                // Sequential references, one engine run per sample.
                let seq: Vec<DiffusionEngine> = refs
                    .iter()
                    .map(|x| {
                        let mut e = DiffusionEngine::new(&a, m, None).unwrap();
                        if force_dense {
                            e.set_combination_dense(&a).unwrap();
                        }
                        e.run(&dict, &task, x, DiffusionParams::new(0.3, iters)).unwrap();
                        e
                    })
                    .collect();
                for threads in [1usize, 4] {
                    let mut eng = DiffusionEngine::new(&a, m, None).unwrap();
                    if force_dense {
                        eng.set_combination_dense(&a).unwrap();
                    }
                    eng.run_batch(
                        &dict,
                        &task,
                        &refs,
                        DiffusionParams::new(0.3, iters).with_threads(threads),
                    )
                    .unwrap();
                    for (s, reference) in seq.iter().enumerate() {
                        for k in 0..n {
                            assert_eq!(
                                eng.nu_sample(k, s),
                                reference.nu(k),
                                "B={batch}, iters={iters}, dense={force_dense}, \
                                 threads={threads}, sample {s}, agent {k}"
                            );
                        }
                        assert_eq!(
                            eng.recover_y_sample(&dict, &task, s),
                            reference.recover_y(&dict, &task),
                            "B={batch}, iters={iters}, dense={force_dense}, \
                             threads={threads}, sample {s}: recovered y"
                        );
                    }
                }
            }
        }
    }
}

/// The batched trainer step must leave the dictionary in exactly the state
/// the historical per-sample-inference step produced: run the same stream
/// through a batched trainer (B = 4) and a per-sample reference
/// implementation, comparing dictionaries bit-for-bit.
#[test]
fn batched_trainer_step_matches_per_sample_reference() {
    use ddl::learn::{OnlineTrainer, TrainerOptions};
    use ddl::ops::prox::DictProx;

    let (n, m) = (24, 8);
    let mut rng = Pcg64::new(0xC5_08);
    let dict0 = DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
    let g = Graph::generate(n, &Topology::Ring { k: 2 }, &mut rng);
    let a = metropolis_weights(&g);
    let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.3 };
    let params = DiffusionParams::new(0.3, 40);
    let mu_w = 0.05f32;
    let xs: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(m)).collect();
    let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();

    // Batched trainer, two minibatches of 4.
    let mut dict_batched = dict0.clone();
    let mut trainer = OnlineTrainer::new(
        &a,
        m,
        None,
        TrainerOptions { infer: params, prox: DictProx::None },
    )
    .unwrap();
    for chunk in refs.chunks(4) {
        trainer.step(&mut dict_batched, &task, chunk, mu_w).unwrap();
    }

    // Reference: per-sample inference with a fresh engine per sample, then
    // the minibatch-averaged Eq. 51 update (the pre-batching trainer).
    let mut dict_ref = dict0.clone();
    for chunk in refs.chunks(4) {
        let mut batch: Vec<(Vec<Vec<f32>>, Vec<f32>)> = Vec::new();
        for &x in chunk {
            let mut eng = DiffusionEngine::new(&a, m, None).unwrap();
            eng.run(&dict_ref, &task, x, params).unwrap();
            let nus: Vec<Vec<f32>> = (0..n).map(|k| eng.nu(k).to_vec()).collect();
            let y = eng.recover_y(&dict_ref, &task);
            batch.push((nus, y));
        }
        let constraint = task.atom_constraint();
        let scale = mu_w / chunk.len() as f32;
        for k in 0..n {
            for (nus, y) in &batch {
                dict_ref.block_gradient_step(k, scale, &nus[k], y);
            }
            dict_ref.project_block(k, constraint);
        }
    }
    assert_eq!(dict_batched.mat().as_slice(), dict_ref.mat().as_slice());
}

/// The engine built straight from a CSR (no dense materialization) matches
/// the dense-constructed engine bit-for-bit on the same topology.
#[test]
fn csr_constructed_engine_is_exact() {
    let mut rng = Pcg64::new(0xC5_06);
    let (n, m) = (40, 8);
    let (dict, g, x) = random_problem(n, m, &mut rng);
    let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
    let params = DiffusionParams::new(0.25, 50);
    let mut from_dense = DiffusionEngine::new(&metropolis_weights(&g), m, None).unwrap();
    assert_eq!(from_dense.combine_path(), "sparse");
    from_dense.run(&dict, &task, &x, params).unwrap();
    let mut from_csr = DiffusionEngine::new_csr(metropolis_csr(&g), m, None).unwrap();
    from_csr.run(&dict, &task, &x, params).unwrap();
    for k in 0..n {
        assert_eq!(from_dense.nu(k), from_csr.nu(k), "agent {k}");
    }
}
