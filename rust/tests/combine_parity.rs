//! Combine-path equivalence properties: the CSR spmm path, the dense gemm
//! path, and the uniform fast path must compute the same diffusion, and
//! the thread count must never change a trajectory.
//!
//! These are the safety net for the sparse + parallel inference substrate:
//! every optimization the engine picks (`uniform` / `sparse` / `dense`,
//! `threads = T`) is proven interchangeable here across random Metropolis
//! topologies and agent counts.

use ddl::graph::{metropolis_csr, metropolis_weights, Graph, Topology};
use ddl::infer::{DiffusionEngine, DiffusionParams};
use ddl::math::{blas, CsrMat, Mat};
use ddl::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use ddl::rng::Pcg64;

/// One random (topology, Ψ) combine instance: CSR spmm vs dense gemm.
fn combine_pair(n: usize, m: usize, topo: &Topology, rng: &mut Pcg64) -> (Vec<f32>, Vec<f32>) {
    let g = Graph::generate(n, topo, rng);
    let a = metropolis_weights(&g);
    let at_csr = metropolis_csr(&g);
    let psi = Mat::from_fn(n, m, |_, _| rng.next_normal());
    let mut v_sparse = vec![0.0f32; n * m];
    at_csr.spmm(psi.as_slice(), m, &mut v_sparse);
    let at = a.transpose();
    let mut v_dense = vec![0.0f32; n * m];
    blas::gemm(n, m, n, 1.0, at.as_slice(), psi.as_slice(), 0.0, &mut v_dense);
    (v_sparse, v_dense)
}

/// Property: CSR-spmm combine matches the dense gemm combine to ≤ 1e-6
/// across random Metropolis topologies and agent counts.
#[test]
fn prop_csr_combine_matches_dense_combine() {
    let mut rng = Pcg64::new(0xC5_01);
    for case in 0..30 {
        let n = 5 + (rng.next_below(60) as usize);
        let m = 1 + (rng.next_below(24) as usize);
        let topo = match rng.next_below(3) {
            0 => Topology::Ring { k: 1 + rng.next_below(4) as usize },
            1 => Topology::Grid,
            _ => Topology::ErdosRenyi { p: 0.15 + 0.5 * rng.next_f64() },
        };
        let (sparse, dense) = combine_pair(n, m, &topo, &mut rng);
        for (i, (&s, &d)) in sparse.iter().zip(&dense).enumerate() {
            assert!(
                (s - d).abs() <= 1e-6 + 1e-6 * d.abs(),
                "case {case} ({topo:?}, n={n}, m={m}): index {i}: {s} vs {d}"
            );
        }
    }
}

/// Property: compressing the dense Metropolis matrix gives the same CSR the
/// direct builder produces (values and structure both).
#[test]
fn prop_direct_csr_equals_compressed_dense() {
    let mut rng = Pcg64::new(0xC5_02);
    for _ in 0..20 {
        let n = 4 + (rng.next_below(40) as usize);
        let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.3 }, &mut rng);
        let direct = metropolis_csr(&g);
        let compressed = CsrMat::from_dense_transposed(&metropolis_weights(&g), 0.0);
        // The diagonal can be an exact 0.0 in degenerate cases (and would
        // then be dropped by compression), so compare via densification.
        assert_eq!(direct.to_dense(), compressed.to_dense());
    }
}

fn random_problem(
    n: usize,
    m: usize,
    rng: &mut Pcg64,
) -> (DistributedDictionary, Graph, Vec<f32>) {
    let dict = DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, rng).unwrap();
    let g = Graph::generate(n, &Topology::Ring { k: 2 }, rng);
    let x = rng.normal_vec(m);
    (dict, g, x)
}

/// Property: full engine runs agree between the auto-selected sparse path
/// and the forced dense path, across sizes.
#[test]
fn prop_engine_sparse_path_equals_dense_path() {
    let mut rng = Pcg64::new(0xC5_03);
    let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
    // Ring k=2 rows hold 5 entries, so density 5/N ≤ 0.25 needs N ≥ 20.
    for &(n, m) in &[(24usize, 6usize), (36, 10), (57, 17)] {
        let (dict, g, x) = random_problem(n, m, &mut rng);
        let a = metropolis_weights(&g);
        let params = DiffusionParams::new(0.25, 60);

        let mut sparse = DiffusionEngine::new(&a, m, None).unwrap();
        assert_eq!(sparse.combine_path(), "sparse", "ring k=2 at n={n} must be sparse");
        sparse.run(&dict, &task, &x, params).unwrap();

        let mut dense = DiffusionEngine::new(&a, m, None).unwrap();
        dense.set_combination_dense(&a).unwrap();
        dense.run(&dict, &task, &x, params).unwrap();

        for k in 0..n {
            for (i, (&s, &d)) in sparse.nu(k).iter().zip(dense.nu(k)).enumerate() {
                assert!(
                    (s - d).abs() <= 1e-5 + 1e-4 * d.abs(),
                    "n={n}, agent {k}, dim {i}: sparse {s} vs dense {d}"
                );
            }
        }
    }
}

/// The uniform fast path must be reproduced bit-for-bit by the threaded
/// variant (worker 0 runs the identical serial reduction).
#[test]
fn uniform_fast_path_threading_is_bit_identical() {
    let mut rng = Pcg64::new(0xC5_04);
    let (n, m) = (15, 9);
    let dict = DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
    let a = ddl::graph::uniform_weights(n);
    let x = rng.normal_vec(m);
    let task = TaskSpec::SparseCoding { gamma: 0.15, delta: 0.4 };
    let mut serial = DiffusionEngine::new(&a, m, None).unwrap();
    assert_eq!(serial.combine_path(), "uniform");
    serial.run(&dict, &task, &x, DiffusionParams::new(0.3, 64)).unwrap();
    let mut threaded = DiffusionEngine::new(&a, m, None).unwrap();
    threaded.run(&dict, &task, &x, DiffusionParams::new(0.3, 64).with_threads(4)).unwrap();
    for k in 0..n {
        assert_eq!(serial.nu(k), threaded.nu(k), "agent {k}");
    }
}

/// Determinism: `threads = 1` and `threads = 4` produce identical ν
/// trajectories — checked at several intermediate horizons, not just the
/// final iterate, on both sparse and dense paths.
#[test]
fn thread_determinism_across_horizons() {
    let mut rng = Pcg64::new(0xC5_05);
    let (n, m) = (26, 11);
    let (dict, g, x) = random_problem(n, m, &mut rng);
    let a = metropolis_weights(&g);
    let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };

    for force_dense in [false, true] {
        for iters in [1usize, 7, 33] {
            let make = |threads: usize| {
                let mut e = DiffusionEngine::new(&a, m, None).unwrap();
                if force_dense {
                    e.set_combination_dense(&a).unwrap();
                }
                e.run(&dict, &task, &x, DiffusionParams::new(0.3, iters).with_threads(threads))
                    .unwrap();
                e
            };
            let serial = make(1);
            let threaded = make(4);
            for k in 0..n {
                assert_eq!(
                    serial.nu(k),
                    threaded.nu(k),
                    "force_dense={force_dense}, iters={iters}, agent {k}"
                );
            }
        }
    }
}

/// The engine built straight from a CSR (no dense materialization) matches
/// the dense-constructed engine bit-for-bit on the same topology.
#[test]
fn csr_constructed_engine_is_exact() {
    let mut rng = Pcg64::new(0xC5_06);
    let (n, m) = (40, 8);
    let (dict, g, x) = random_problem(n, m, &mut rng);
    let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
    let params = DiffusionParams::new(0.25, 50);
    let mut from_dense = DiffusionEngine::new(&metropolis_weights(&g), m, None).unwrap();
    assert_eq!(from_dense.combine_path(), "sparse");
    from_dense.run(&dict, &task, &x, params).unwrap();
    let mut from_csr = DiffusionEngine::new_csr(metropolis_csr(&g), m, None).unwrap();
    from_csr.run(&dict, &task, &x, params).unwrap();
    for k in 0..n {
        assert_eq!(from_dense.nu(k), from_csr.nu(k), "agent {k}");
    }
}
