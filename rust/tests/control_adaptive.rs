//! Control-plane invariants (ISSUE 5):
//!
//! 1. **Queue edge semantics** — property tests over random push/pop
//!    interleavings: `max_wait_us = 0` releases on every poll, FIFO order
//!    is preserved, `next_deadline_us` is exactly the earliest time
//!    `ready` holds, and `SharedQueue` mirrors `MicroBatchQueue` op for
//!    op.
//! 2. **Adaptive replay determinism** — two runs of an adaptive session
//!    (serial and pipelined) or an adaptive-τ experiment with the same
//!    config produce bit-identical dictionaries, reports, and controller
//!    decision traces: every decision is a pure function of (config,
//!    seed, stream) on the virtual clocks.
//! 3. **Adaptive pipeline parity** — the threaded executor under the
//!    control plane stays bit-identical to the serial reference executor
//!    of the same token schedule (policy swaps and depth re-plans
//!    included), extending `serve_pipeline_parity.rs` to adaptive mode.

use ddl::config::experiment::{ControlConfig, InferenceConfig, ServeConfig};
use ddl::rng::Pcg64;
use ddl::serve::pipeline::{run_pipelined, PipelineExec};
use ddl::serve::{run_service_with_dict, BatchPolicy, MicroBatchQueue, ServeReport, SharedQueue};
use ddl::testutil::{check, Gen};

// ---------------------------------------------------------------------
// 1. Queue property tests
// ---------------------------------------------------------------------

/// One randomized queue scenario: policy knobs plus an interleaved
/// push/pop script with clock increments.
#[derive(Clone, Debug)]
struct Scenario {
    max_batch: usize,
    max_wait_us: u64,
    /// `(is_push, clock_increment_us)` per step.
    ops: Vec<(bool, u64)>,
}

struct ScenarioGen;

impl Gen for ScenarioGen {
    type Value = Scenario;
    fn gen(&self, rng: &mut Pcg64) -> Scenario {
        let max_batch = 1 + rng.next_below(8) as usize;
        let max_wait_us = rng.next_below(4) * 200; // 0, 200, 400, 600
        let n = 1 + rng.next_below(48) as usize;
        let ops = (0..n)
            .map(|_| (rng.next_below(3) > 0, rng.next_below(250)))
            .collect();
        Scenario { max_batch, max_wait_us, ops }
    }
    fn shrink(&self, v: &Scenario) -> Vec<Scenario> {
        let mut out = Vec::new();
        if v.ops.len() > 1 {
            out.push(Scenario { ops: v.ops[..v.ops.len() / 2].to_vec(), ..v.clone() });
            out.push(Scenario { ops: v.ops[1..].to_vec(), ..v.clone() });
        }
        if v.max_wait_us > 0 {
            out.push(Scenario { max_wait_us: 0, ..v.clone() });
        }
        out
    }
}

/// Replay a scenario against both queue flavors, checking every invariant
/// at every step.
fn run_scenario(s: &Scenario) -> Result<(), String> {
    let policy = BatchPolicy::new(s.max_batch, s.max_wait_us);
    let mut q = MicroBatchQueue::new(policy);
    let shared = SharedQueue::new(policy);
    let mut now = 0u64;
    let mut next_expected_id = 0u64;
    for &(is_push, dt) in &s.ops {
        now += dt;
        if is_push {
            let a = q.push(vec![now as f32], now);
            let b = shared.push(vec![now as f32], now);
            if a != b {
                return Err(format!("id divergence: {a} vs {b}"));
            }
        } else {
            let popped = q.pop_batch(now);
            let popped_shared = shared.pop_batch(now);
            match (&popped, &popped_shared) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    if x.len() != y.len() {
                        return Err("shared/plain batch size divergence".into());
                    }
                    if x.len() > s.max_batch.max(1) {
                        return Err(format!("batch of {} exceeds cap", x.len()));
                    }
                    // FIFO: ids are globally consecutive across batches.
                    for r in x {
                        if r.id != next_expected_id {
                            return Err(format!(
                                "FIFO violated: got id {}, expected {next_expected_id}",
                                r.id
                            ));
                        }
                        next_expected_id += 1;
                    }
                }
                _ => return Err("shared/plain pop divergence".into()),
            }
        }
        // next_deadline_us is exactly the earliest time ready() holds.
        let deadline = q.next_deadline_us();
        let ready_now = q.ready(now);
        let expect_ready = deadline.map(|d| d <= now).unwrap_or(false);
        if ready_now != expect_ready {
            return Err(format!(
                "ready({now}) = {ready_now} inconsistent with deadline {deadline:?}"
            ));
        }
        if let Some(d) = deadline {
            if d > now && q.ready(d.saturating_sub(1)) && d.saturating_sub(1) >= now {
                return Err(format!("queue ready before its own deadline {d}"));
            }
            if !q.ready(d) {
                return Err(format!("queue not ready at its own deadline {d}"));
            }
        }
        // max_wait = 0 releases on every poll with anything queued.
        if s.max_wait_us == 0 && !q.is_empty() && !q.ready(now) {
            return Err("max_wait 0 must release on every poll".into());
        }
        if q.len() != shared.len() {
            return Err("shared/plain length divergence".into());
        }
    }
    Ok(())
}

#[test]
fn prop_queue_edge_semantics() {
    check(0xC0_57, 200, &ScenarioGen, run_scenario);
}

#[test]
fn prop_zero_wait_releases_every_poll() {
    // Focused corner: max_wait 0, pushes only, then drain.
    check(0xC0_58, 100, &ScenarioGen, |s| {
        let mut q = MicroBatchQueue::new(BatchPolicy::new(s.max_batch, 0));
        for (i, &(_, dt)) in s.ops.iter().enumerate() {
            q.push(vec![i as f32], dt);
            if !q.ready(dt) {
                return Err("non-empty zero-wait queue not ready".into());
            }
        }
        let mut total = 0;
        while let Some(b) = q.pop_batch(u64::MAX) {
            total += b.len();
        }
        if total != s.ops.len() {
            return Err(format!("drained {total} of {}", s.ops.len()));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// 2 + 3. Adaptive sessions: determinism and pipeline parity
// ---------------------------------------------------------------------

/// Small adaptive serving config on the virtual service clock. Paced,
/// bursty arrivals so the batch controller has something to chase.
fn adaptive_cfg(pipeline: bool, threads: usize) -> ServeConfig {
    ServeConfig {
        seed: 0xAD_47,
        agents: 24,
        dim: 8,
        topology: "ring".into(),
        ring_k: 2,
        batch: 4,
        max_wait_us: 2_000,
        samples: 96,
        rate: 4_000.0,
        burst: 8,
        mu_w: 0.08,
        pipeline,
        pipeline_depth: 1,
        infer: InferenceConfig { mu: 0.4, iters: 10, gamma: 0.08, delta: 0.2, threads },
        control: ControlConfig {
            enabled: true,
            slo_p99_ms: 5.0,
            tick_us: 1_000,
            batch_min: 1,
            batch_max: 16,
            wait_min_us: 0,
            wait_max_us: 4_000,
            window: 64,
            svc_base_us: 200,
            svc_per_sample_us: 50,
            upd_per_sample_us: 30,
            depth_min: 1,
            depth_max: 3,
            epoch_batches: 4,
            ..ControlConfig::default()
        },
        ..ServeConfig::default()
    }
}

fn assert_reports_bitwise_equal(a: &ServeReport, b: &ServeReport, label: &str) {
    assert_eq!(a.samples, b.samples, "{label}: samples");
    assert_eq!(a.batches, b.batches, "{label}: batches");
    assert_eq!(a.mean_batch.to_bits(), b.mean_batch.to_bits(), "{label}: mean batch");
    assert_eq!(a.duration_s.to_bits(), b.duration_s.to_bits(), "{label}: virtual duration");
    assert_eq!(
        a.throughput_rps.to_bits(),
        b.throughput_rps.to_bits(),
        "{label}: virtual throughput"
    );
    assert_eq!(a.latency_p50_ms.to_bits(), b.latency_p50_ms.to_bits(), "{label}: p50");
    assert_eq!(a.latency_p99_ms.to_bits(), b.latency_p99_ms.to_bits(), "{label}: p99");
    assert_eq!(a.latency_max_ms.to_bits(), b.latency_max_ms.to_bits(), "{label}: max");
    assert_eq!(
        a.slo_violation_frac.to_bits(),
        b.slo_violation_frac.to_bits(),
        "{label}: SLO violations"
    );
    assert_eq!(a.stats, b.stats, "{label}: MessageStats");
    assert_eq!(a.decisions, b.decisions, "{label}: batch-controller trace");
    assert_eq!(a.depth_trace, b.depth_trace, "{label}: depth-controller trace");
    assert_eq!(
        a.loss_first_quarter.to_bits(),
        b.loss_first_quarter.to_bits(),
        "{label}: first-quarter loss"
    );
    assert_eq!(
        a.loss_last_quarter.to_bits(),
        b.loss_last_quarter.to_bits(),
        "{label}: last-quarter loss"
    );
}

/// Two adaptive *serial* runs replay bit-identically: dictionary, report
/// figures, and the controller decision trace.
#[test]
fn adaptive_serial_replays_bitwise() {
    let cfg = adaptive_cfg(false, 1);
    let (r1, d1) = run_service_with_dict(&cfg, &mut |_| {}).unwrap();
    let (r2, d2) = run_service_with_dict(&cfg, &mut |_| {}).unwrap();
    assert_eq!(r1.mode, "serial-adaptive");
    assert!(r1.adaptive);
    assert_eq!(r1.samples, cfg.samples);
    assert!(!r1.decisions.is_empty(), "the controller must have ticked");
    assert_reports_bitwise_equal(&r1, &r2, "serial adaptive replay");
    assert_eq!(d1.mat().as_slice(), d2.mat().as_slice(), "final dictionaries");
}

/// Two adaptive *pipelined* runs replay bit-identically, and the threaded
/// executor matches the serial reference executor of the same token
/// schedule — policy swaps and depth re-plans included.
#[test]
fn adaptive_pipeline_parity_and_replay() {
    for &threads in &[1usize, 2] {
        let cfg = adaptive_cfg(true, threads);
        let (r_ref, d_ref) = run_pipelined(&cfg, PipelineExec::Reference, &mut |_| {}).unwrap();
        let (r_thr, d_thr) = run_pipelined(&cfg, PipelineExec::Threaded, &mut |_| {}).unwrap();
        let (r_thr2, d_thr2) = run_pipelined(&cfg, PipelineExec::Threaded, &mut |_| {}).unwrap();
        assert_eq!(r_thr.mode, "pipelined-adaptive");
        assert_eq!(r_ref.mode, "pipelined-adaptive-reference");
        assert_eq!(r_thr.samples, cfg.samples, "every request served exactly once");
        let label = format!("threaded-vs-reference t{threads}");
        assert_eq!(
            d_ref.mat().as_slice(),
            d_thr.mat().as_slice(),
            "{label}: final dictionaries must be bit-identical"
        );
        assert_reports_bitwise_equal(&r_ref, &r_thr, &label);
        assert_reports_bitwise_equal(&r_thr, &r_thr2, "threaded replay");
        assert_eq!(d_thr.mat().as_slice(), d_thr2.mat().as_slice());
    }
}

/// The depth controller actually re-plans under saturation: starting at
/// depth 1 with cheap updates, tokens bind and the depth climbs — and the
/// threaded executor still matches the reference bitwise (the re-plans
/// are part of the shared schedule).
#[test]
fn adaptive_depth_replans_under_saturation() {
    let mut cfg = adaptive_cfg(true, 1);
    cfg.rate = 0.0; // saturated: formation is instant, tokens always bind
    cfg.samples = 128;
    let (r_ref, d_ref) = run_pipelined(&cfg, PipelineExec::Reference, &mut |_| {}).unwrap();
    let (r_thr, d_thr) = run_pipelined(&cfg, PipelineExec::Threaded, &mut |_| {}).unwrap();
    assert!(
        !r_ref.depth_trace.is_empty(),
        "saturated token-bound pipeline must deepen at some epoch boundary"
    );
    assert!(r_ref.depth_trace.iter().all(|d| d.depth <= cfg.control.depth_max));
    assert_eq!(r_ref.depth_trace, r_thr.depth_trace);
    assert_eq!(d_ref.mat().as_slice(), d_thr.mat().as_slice());
}

/// With the control plane *disabled*, the pipeline produces the same
/// result as an adaptive run whose controllers are pinned to the static
/// knobs by degenerate bounds — the "pinning" escape hatch the bench's
/// static grid uses.
#[test]
fn pinned_bounds_match_static_schedule() {
    // Static run (control disabled): PR 3 code path, wall-clock timing.
    let mut static_cfg = adaptive_cfg(true, 1);
    static_cfg.control.enabled = false;
    let (r_static, d_static) =
        run_pipelined(&static_cfg, PipelineExec::Reference, &mut |_| {}).unwrap();
    assert_eq!(r_static.mode, "pipelined-reference");
    assert!(r_static.decisions.is_empty());

    // Adaptive run pinned to the same knobs: identical batch sequence and
    // schedule, so identical dictionary and losses (timing figures differ
    // by design: virtual vs wall clock).
    let mut pinned = adaptive_cfg(true, 1);
    pinned.control.batch_min = pinned.batch;
    pinned.control.batch_max = pinned.batch;
    pinned.control.wait_min_us = pinned.max_wait_us;
    pinned.control.wait_max_us = pinned.max_wait_us;
    pinned.control.depth_min = pinned.pipeline_depth;
    pinned.control.depth_max = pinned.pipeline_depth;
    let (r_pin, d_pin) = run_pipelined(&pinned, PipelineExec::Reference, &mut |_| {}).unwrap();
    assert_eq!(r_pin.batches, r_static.batches, "pinned bounds must not change formation");
    assert_eq!(r_pin.mean_batch.to_bits(), r_static.mean_batch.to_bits());
    assert_eq!(
        r_pin.loss_first_quarter.to_bits(),
        r_static.loss_first_quarter.to_bits(),
        "pinned controller must not perturb the schedule"
    );
    assert_eq!(
        r_pin.loss_last_quarter.to_bits(),
        r_static.loss_last_quarter.to_bits()
    );
    assert_eq!(r_pin.stats, r_static.stats);
    assert_eq!(d_pin.mat().as_slice(), d_static.mat().as_slice());
    assert!(r_pin.depth_trace.is_empty(), "pinned depth bounds cannot re-plan");
}
