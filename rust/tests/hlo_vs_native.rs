//! Integration: the AOT HLO path must match the native rust engine.
//!
//! This is the three-layer contract test — L1 Pallas kernels lowered
//! through L2 into `artifacts/*.hlo.txt`, executed via PJRT from L3, are
//! compared against the pure-rust `DiffusionEngine` on identical inputs.
//!
//! Requires `make artifacts` (skips with a message when absent, so plain
//! `cargo test` works before the python step) and the `xla` feature (the
//! PJRT bridge is optional; the default build is pure rust).
#![cfg(feature = "xla")]

use ddl::graph::{metropolis_weights, Graph, Topology};
use ddl::infer::{DiffusionEngine, DiffusionParams};
use ddl::math::Mat;
use ddl::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use ddl::rng::Pcg64;
use ddl::runtime::exec::ParamPack;
use ddl::runtime::Runtime;
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/manifest.json missing (run `make artifacts`)");
        None
    }
}

/// Build a problem matching an infer artifact's (n, m).
fn problem(n: usize, m: usize, seed: u64, nonneg: bool) -> (DistributedDictionary, Mat, Vec<f32>, Graph) {
    let mut rng = Pcg64::new(seed);
    let constraint = if nonneg { AtomConstraint::NonNegUnitBall } else { AtomConstraint::UnitBall };
    let dict = DistributedDictionary::random(m, n, n, constraint, &mut rng).unwrap();
    let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
    let a = metropolis_weights(&g);
    let x = rng.normal_vec(m);
    (dict, a, x, g)
}

/// Transposed-dictionary view for the HLO path (row k = atom k).
fn wt_of(dict: &DistributedDictionary) -> Mat {
    dict.mat().transpose()
}

#[test]
fn quickstart_infer_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let infer = rt.load_infer("quickstart_infer").unwrap();
    let (n, m) = (infer.info.n, infer.info.m);
    let iters = infer.info.iters.unwrap();
    let (dict, a, x, _) = problem(n, m, 42, false);
    let task = TaskSpec::SparseCoding { gamma: 0.3, delta: 0.4 };
    let mu = 0.25f32;

    // HLO path.
    let theta = vec![1.0 / n as f32; n];
    let out = infer
        .run(&wt_of(&dict), &x, &a.transpose(), &theta, ParamPack::from_task(&task, n, mu))
        .unwrap();

    // Native path.
    let mut eng = DiffusionEngine::new(&a, m, None).unwrap();
    eng.run(&dict, &task, &x, DiffusionParams::new(mu, iters)).unwrap();

    for k in 0..n {
        for i in 0..m {
            let h = out.v.get(k, i);
            let r = eng.nu(k)[i];
            assert!(
                (h - r).abs() <= 1e-4 + 1e-3 * r.abs(),
                "V[{k},{i}]: hlo {h} vs native {r}"
            );
        }
    }
    let y_native = eng.recover_y(&dict, &task);
    for k in 0..n {
        assert!(
            (out.y[k] - y_native[k]).abs() <= 1e-4 + 1e-3 * y_native[k].abs(),
            "y[{k}]: hlo {} vs native {}",
            out.y[k],
            y_native[k]
        );
    }
}

#[test]
fn novelty_huber_infer_matches_native_and_scores() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let infer = rt.load_infer("novelty_huber_infer").unwrap();
    let (n, m) = (infer.info.n, infer.info.m);
    let iters = infer.info.iters.unwrap();
    let (dict, a, mut x, _) = problem(n, m, 7, true);
    for v in &mut x {
        *v = v.abs();
    }
    ddl::math::vector::normalize(&mut x);
    let task = TaskSpec::HuberNmf { gamma: 0.2, delta: 0.1, eta: 0.2 };
    let mu = 0.1f32;

    let theta = vec![1.0 / n as f32; n];
    let out = infer
        .run(&wt_of(&dict), &x, &a.transpose(), &theta, ParamPack::from_task(&task, n, mu))
        .unwrap();

    let mut eng = DiffusionEngine::new(&a, m, None).unwrap();
    eng.run(&dict, &task, &x, DiffusionParams::new(mu, iters)).unwrap();

    // Dual iterates match.
    for k in 0..n {
        for i in 0..m {
            let h = out.v.get(k, i);
            let r = eng.nu(k)[i];
            assert!((h - r).abs() <= 1e-4 + 1e-3 * r.abs(), "V[{k},{i}]: {h} vs {r}");
        }
    }
    // Box respected.
    assert!(out.v.max_abs() <= 1.0 + 1e-5);
    // Cost matches the native novelty score g = −Σ J_k evaluated on the
    // same iterates.
    let cost = out.cost.expect("huber artifact exports cost");
    let nu_bar = eng.consensus_nu();
    // Native: f*(ν̄) − ν̄ᵀx + Σ_k h*_k(own rows).
    let mut hsum = 0.0f32;
    let mut s = vec![0.0f32; dict.k()];
    for k in 0..n {
        dict.block_correlations(k, eng.nu(k), &mut s);
        let (start, len) = dict.block(k);
        hsum += task.h_conj(&s[start..start + len]);
    }
    let native_cost =
        -(task.f_conj(&nu_bar) - ddl::math::blas::dot(&nu_bar, &x) + hsum);
    assert!(
        (cost - native_cost).abs() <= 1e-3 + 1e-2 * native_cost.abs(),
        "cost: hlo {cost} vs native {native_cost}"
    );
}

#[test]
fn dict_update_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let update = rt.load_update("denoise_update").unwrap();
    let (n, m) = (update.info.n, update.info.m);
    let mut rng = Pcg64::new(9);
    let mut dict =
        DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
    let nu = rng.normal_vec(m);
    let y = rng.normal_vec(n);
    let mu_w = 0.3f32;

    let wt_new = update.run(&wt_of(&dict), &nu, &y, mu_w).unwrap();

    // Native Eq. 51 with the same consensus nu at every agent.
    let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.1 };
    for k in 0..n {
        dict.block_gradient_step(k, mu_w, &nu, &y);
        dict.project_block(k, task.atom_constraint());
    }
    let native_wt = wt_of(&dict);
    for k in 0..n {
        for i in 0..m {
            let h = wt_new.get(k, i);
            let r = native_wt.get(k, i);
            assert!((h - r).abs() <= 1e-5 + 1e-4 * r.abs(), "Wt[{k},{i}]: {h} vs {r}");
        }
    }
}

#[test]
fn informed_subset_via_theta_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let infer = rt.load_infer("quickstart_infer").unwrap();
    let (n, m) = (infer.info.n, infer.info.m);
    let iters = infer.info.iters.unwrap();
    let (dict, a, x, _) = problem(n, m, 11, false);
    let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.4 };
    let mu = 0.2f32;

    // Only agent 0 informed: theta = e0 (|N_I| = 1).
    let mut theta = vec![0.0f32; n];
    theta[0] = 1.0;
    let out = infer
        .run(&wt_of(&dict), &x, &a.transpose(), &theta, ParamPack::from_task(&task, n, mu))
        .unwrap();

    let mut eng = DiffusionEngine::new(&a, m, Some(&[0])).unwrap();
    eng.run(&dict, &task, &x, DiffusionParams::new(mu, iters)).unwrap();
    for k in 0..n {
        for i in 0..m {
            let h = out.v.get(k, i);
            let r = eng.nu(k)[i];
            assert!((h - r).abs() <= 1e-4 + 1e-3 * r.abs(), "V[{k},{i}]: {h} vs {r}");
        }
    }
}
