//! End-to-end integration: the full stack composes.
//!
//! * golden trajectories (always compiled): final-dictionary checksums for
//!   a fixed ring-of-50 problem across the BSP, async τ=2, and
//!   serve-batched paths, pinned against `tests/golden/end_to_end.golden`.
//!   Any change to RNG draw order, combine arithmetic, update order, or
//!   stream generation shows up as a checksum mismatch here before it
//!   shows up as a silently different "reproduction" of the paper;
//! * HLO path (`--features xla` only): artifacts load, PJRT inference
//!   matches native, and an HLO-driven training loop reduces loss.

use ddl::graph::{metropolis_weights, Graph, Topology};
use ddl::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use ddl::rng::Pcg64;
use std::path::PathBuf;

// ---------------------------------------------------------------------
// Golden trajectories (pure-rust build)
// ---------------------------------------------------------------------

/// FNV-1a 64 over the f32 bit patterns, in matrix order. One flipped
/// mantissa bit anywhere in the final dictionary changes the digest.
fn fnv1a64(data: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in data {
        for b in v.to_bits().to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/end_to_end.golden")
}

/// Load the committed golden digests (`key value-in-hex` per line).
fn load_golden() -> Option<Vec<(String, u64)>> {
    let text = std::fs::read_to_string(golden_path()).ok()?;
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (key, hex) = line.split_once(' ')?;
        out.push((key.to_string(), u64::from_str_radix(hex.trim(), 16).ok()?));
    }
    Some(out)
}

const N: usize = 50; // ring of 50 agents, one atom each
const M: usize = 16;
const SEED: u64 = 0x601D;
const MU_W: f32 = 0.05;
const TRAIN_SAMPLES: usize = 20;

/// Fixed planted-dictionary sampler shared by the BSP and async paths:
/// every draw count is constant per sample, so the three paths consume
/// their own RNGs independently of inference internals.
struct PlantedSampler {
    planted: DistributedDictionary,
    rng: Pcg64,
}

impl PlantedSampler {
    fn new(seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let planted =
            DistributedDictionary::random(M, N, N, AtomConstraint::UnitBall, &mut rng).unwrap();
        PlantedSampler { planted, rng }
    }

    fn next(&mut self) -> Vec<f32> {
        let mut x = vec![0.0f32; M];
        for _ in 0..2 {
            let q = self.rng.next_below(N as u64) as usize;
            ddl::math::vector::axpy(0.5 + self.rng.next_f32(), &self.planted.atom(q), &mut x);
        }
        x
    }
}

/// Primal recovery from per-agent duals (Eq. 37 / Table II), mirroring
/// `infer::diffusion::recover_y_into` for executors that expose `nu(k)`
/// directly instead of a `NuView`.
fn recover_y(dict: &DistributedDictionary, task: &TaskSpec, nu_of: &dyn Fn(usize) -> Vec<f32>) -> Vec<f32> {
    let mut y = vec![0.0f32; dict.k()];
    let mut scratch = vec![0.0f32; dict.k()];
    let inv_delta = 1.0 / task.delta();
    for k in 0..dict.agents() {
        let nu = nu_of(k);
        dict.block_correlations(k, &nu, &mut scratch);
        let (start, len) = dict.block(k);
        for q in start..start + len {
            y[q] = task.threshold(scratch[q]) * inv_delta;
        }
    }
    y
}

/// Eq. 51 block update + projection for one sample's duals.
fn update_dict(
    dict: &mut DistributedDictionary,
    task: &TaskSpec,
    nu_of: &dyn Fn(usize) -> Vec<f32>,
) {
    let y = recover_y(dict, task, nu_of);
    let constraint = task.atom_constraint();
    for k in 0..dict.agents() {
        let nu = nu_of(k);
        dict.block_gradient_step(k, MU_W, &nu, &y);
        dict.project_block(k, constraint);
    }
}

fn ring_problem() -> (Graph, ddl::math::Mat, DistributedDictionary, TaskSpec) {
    let mut rng = Pcg64::new(SEED);
    let graph = Graph::generate(N, &Topology::Ring { k: 2 }, &mut rng);
    let weights = metropolis_weights(&graph);
    let dict =
        DistributedDictionary::random(M, N, N, AtomConstraint::UnitBall, &mut rng).unwrap();
    let task = TaskSpec::SparseCoding { gamma: 0.05, delta: 0.2 };
    (graph, weights, dict, task)
}

/// BSP online training: fresh synchronous rounds per sample.
fn bsp_trajectory() -> u64 {
    use ddl::infer::DiffusionParams;
    use ddl::net::BspNetwork;
    let (graph, weights, mut dict, task) = ring_problem();
    let mut sampler = PlantedSampler::new(SEED ^ 0xB59);
    for _ in 0..TRAIN_SAMPLES {
        let x = sampler.next();
        let mut net = BspNetwork::new(graph.clone(), weights.clone(), M, None);
        net.run(&dict, &task, &x, DiffusionParams::new(0.5, 30)).unwrap();
        update_dict(&mut dict, &task, &|k| net.nu(k).to_vec());
    }
    fnv1a64(dict.mat().as_slice())
}

/// Async τ=2 online training under a constant-delay model: the bounded
/// staleness gate and the event schedule are part of the pinned bits.
fn async_tau2_trajectory() -> u64 {
    use ddl::infer::DiffusionParams;
    use ddl::net::{AsyncNetwork, AsyncParams, DelayDist};
    let (graph, weights, mut dict, task) = ring_problem();
    let mut sampler = PlantedSampler::new(SEED ^ 0xA54);
    for t in 0..TRAIN_SAMPLES {
        let x = sampler.next();
        let ap = AsyncParams::default()
            .with_tau(2)
            .with_delays(DelayDist::Constant { us: 80 }, DelayDist::Constant { us: 15 })
            .with_seed(SEED + t as u64);
        let mut net =
            AsyncNetwork::new(graph.clone(), weights.clone(), M, None, ap).unwrap();
        net.run(&dict, &task, &x, DiffusionParams::new(0.5, 30)).unwrap();
        update_dict(&mut dict, &task, &|k| net.nu(k).to_vec());
    }
    fnv1a64(dict.mat().as_slice())
}

/// Serve-batched path: the streaming session's final dictionary (serial
/// executor, planted stream, saturated arrivals).
fn serve_trajectory() -> u64 {
    use ddl::config::experiment::{InferenceConfig, ServeConfig};
    let cfg = ServeConfig {
        seed: SEED,
        agents: N,
        dim: M,
        topology: "ring".into(),
        ring_k: 2,
        batch: 8,
        max_wait_us: 2_000,
        samples: 64,
        rate: 0.0,
        mu_w: MU_W,
        pipeline: false,
        infer: InferenceConfig { mu: 0.5, iters: 30, gamma: 0.05, delta: 0.2, threads: 1 },
        ..ServeConfig::default()
    };
    let (_, dict) = ddl::serve::run_service_with_dict(&cfg, &mut |_| {}).unwrap();
    fnv1a64(dict.mat().as_slice())
}

/// Pin the three trajectories against the committed golden file. On first
/// run (no toolchain had produced the file yet) the digests are written
/// out for committing — see `tests/golden/README.md`.
#[test]
fn golden_trajectories_ring50() {
    let current = vec![
        ("bsp".to_string(), bsp_trajectory()),
        ("async_tau2".to_string(), async_tau2_trajectory()),
        ("serve_batched".to_string(), serve_trajectory()),
    ];
    match load_golden() {
        Some(golden) => {
            for (key, digest) in &current {
                let pinned = golden.iter().find(|(k, _)| k == key).map(|(_, v)| *v);
                assert_eq!(
                    Some(*digest),
                    pinned,
                    "golden trajectory '{key}' diverged: got {digest:016x}, pinned \
                     {pinned:?} — if the change is intentional, delete \
                     tests/golden/end_to_end.golden, re-run, and commit the reseeded file"
                );
            }
            assert_eq!(golden.len(), current.len(), "golden file has stale extra entries");
        }
        None => {
            let mut text = String::from(
                "# FNV-1a-64 digests of final dictionaries (ring N=50, fixed seed).\n\
                 # Self-seeded by tests/end_to_end.rs::golden_trajectories_ring50 —\n\
                 # commit this file; see tests/golden/README.md.\n",
            );
            for (key, digest) in &current {
                text.push_str(&format!("{key} {digest:016x}\n"));
            }
            std::fs::create_dir_all(golden_path().parent().unwrap()).unwrap();
            std::fs::write(golden_path(), text).unwrap();
            eprintln!(
                "SEEDED {}: commit it to pin the trajectories",
                golden_path().display()
            );
        }
    }
}

/// The two sample-level paths really are different executors (staleness
/// changes the duals), yet each replays itself bitwise.
#[test]
fn golden_trajectories_replay_and_differ() {
    assert_eq!(bsp_trajectory(), bsp_trajectory(), "BSP trajectory must replay");
    assert_eq!(
        async_tau2_trajectory(),
        async_tau2_trajectory(),
        "async trajectory must replay"
    );
    assert_ne!(
        bsp_trajectory(),
        async_tau2_trajectory(),
        "τ=2 staleness must perturb the trajectory relative to BSP"
    );
}

// ---------------------------------------------------------------------
// HLO path (PJRT bridge; compiled only with the `xla` feature)
// ---------------------------------------------------------------------

#[cfg(feature = "xla")]
mod hlo {
    use super::*;
    use ddl::runtime::exec::ParamPack;
    use ddl::runtime::Runtime;
    use std::path::Path;

    fn artifacts_dir() -> Option<&'static Path> {
        let dir = Path::new("artifacts");
        if dir.join("manifest.json").exists() {
            Some(dir)
        } else {
            eprintln!("SKIP: artifacts missing (run `make artifacts`)");
            None
        }
    }

    #[test]
    fn quickstart_runs() {
        let Some(dir) = artifacts_dir() else { return };
        let mut lines = Vec::new();
        ddl::coordinator::quickstart::run_quickstart(dir, &mut |s| lines.push(s.to_string()))
            .expect("quickstart should succeed");
        assert!(lines.iter().any(|l| l.contains("quickstart OK")));
    }

    /// Train on planted-dictionary data with inference + update both on the
    /// HLO path; the representation loss must drop.
    #[test]
    fn hlo_training_loop_reduces_loss() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::new(dir).unwrap();
        let infer = rt.load_infer("quickstart_infer").unwrap();
        let (n, m) = (infer.info.n, infer.info.m);

        // The update artifact shapes must match quickstart's; otherwise use the
        // native update (still an end-to-end inference test).
        let update =
            rt.load_update("denoise_update").ok().filter(|u| u.info.n == n && u.info.m == m);

        let mut rng = Pcg64::new(0xE2E);
        let planted =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let sample = |rng: &mut Pcg64| -> Vec<f32> {
            let mut x = vec![0.0f32; m];
            for _ in 0..2 {
                let q = rng.next_below(n as u64) as usize;
                ddl::math::vector::axpy(0.5 + rng.next_f32(), &planted.atom(q), &mut x);
            }
            x
        };

        let mut dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
        let a = metropolis_weights(&g);
        let at = a.transpose();
        let theta = vec![1.0 / n as f32; n];
        let task = TaskSpec::SparseCoding { gamma: 0.05, delta: 0.2 };
        let pack = ParamPack::from_task(&task, n, 0.3);
        let mu_w = 0.05f32;

        let loss = |dict: &DistributedDictionary, xs: &[Vec<f32>]| -> f32 {
            xs.iter()
                .map(|x| {
                    let out = infer
                        .run(&dict.mat().transpose(), x, &at, &theta, pack)
                        .unwrap();
                    let wy = dict.mat().matvec(&out.y).unwrap();
                    let r = ddl::math::vector::sub(x, &wy);
                    task.f_loss(&r)
                })
                .sum::<f32>()
        };

        let probe: Vec<Vec<f32>> = (0..8).map(|_| sample(&mut rng)).collect();
        let before = loss(&dict, &probe);

        for _ in 0..120 {
            let x = sample(&mut rng);
            let out = infer.run(&dict.mat().transpose(), &x, &at, &theta, pack).unwrap();
            let nu = out.v.row(0).to_vec(); // any agent's estimate post-consensus
            match &update {
                Some(u) => {
                    let wt2 = u.run(&dict.mat().transpose(), &nu, &out.y, mu_w).unwrap();
                    *dict.mat_mut() = wt2.transpose();
                }
                None => {
                    for k in 0..n {
                        dict.block_gradient_step(k, mu_w, &nu, &out.y);
                        dict.project_block(k, task.atom_constraint());
                    }
                }
            }
        }
        let after = loss(&dict, &probe);
        assert!(
            after < 0.8 * before,
            "HLO training loop did not reduce loss: {before} → {after}"
        );
    }
}
