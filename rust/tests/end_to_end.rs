//! End-to-end integration: the full stack composes.
//!
//! * quickstart: artifacts load, HLO inference matches native, update runs;
//! * HLO-driven training: a short online training loop where *inference
//!   runs through the PJRT executable* and the dictionary update runs
//!   through the update artifact — Python never appears on this path.
//!
//! Compiled only with the `xla` feature (the PJRT bridge is optional).
#![cfg(feature = "xla")]

use ddl::graph::{metropolis_weights, Graph, Topology};
use ddl::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use ddl::rng::Pcg64;
use ddl::runtime::exec::ParamPack;
use ddl::runtime::Runtime;
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        None
    }
}

#[test]
fn quickstart_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let mut lines = Vec::new();
    ddl::coordinator::quickstart::run_quickstart(dir, &mut |s| lines.push(s.to_string()))
        .expect("quickstart should succeed");
    assert!(lines.iter().any(|l| l.contains("quickstart OK")));
}

/// Train on planted-dictionary data with inference + update both on the
/// HLO path; the representation loss must drop.
#[test]
fn hlo_training_loop_reduces_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).unwrap();
    let infer = rt.load_infer("quickstart_infer").unwrap();
    let (n, m) = (infer.info.n, infer.info.m);

    // The update artifact shapes must match quickstart's; otherwise use the
    // native update (still an end-to-end inference test).
    let update = rt.load_update("denoise_update").ok().filter(|u| u.info.n == n && u.info.m == m);

    let mut rng = Pcg64::new(0xE2E);
    let planted = DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
    let sample = |rng: &mut Pcg64| -> Vec<f32> {
        let mut x = vec![0.0f32; m];
        for _ in 0..2 {
            let q = rng.next_below(n as u64) as usize;
            ddl::math::vector::axpy(0.5 + rng.next_f32(), &planted.atom(q), &mut x);
        }
        x
    };

    let mut dict = DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
    let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
    let a = metropolis_weights(&g);
    let at = a.transpose();
    let theta = vec![1.0 / n as f32; n];
    let task = TaskSpec::SparseCoding { gamma: 0.05, delta: 0.2 };
    let pack = ParamPack::from_task(&task, n, 0.3);
    let mu_w = 0.05f32;

    let loss = |dict: &DistributedDictionary, xs: &[Vec<f32>]| -> f32 {
        xs.iter()
            .map(|x| {
                let out = infer
                    .run(&dict.mat().transpose(), x, &at, &theta, pack)
                    .unwrap();
                let wy = dict.mat().matvec(&out.y).unwrap();
                let r = ddl::math::vector::sub(x, &wy);
                task.f_loss(&r)
            })
            .sum::<f32>()
    };

    let probe: Vec<Vec<f32>> = (0..8).map(|_| sample(&mut rng)).collect();
    let before = loss(&dict, &probe);

    for _ in 0..120 {
        let x = sample(&mut rng);
        let out = infer.run(&dict.mat().transpose(), &x, &at, &theta, pack).unwrap();
        let nu = out.v.row(0).to_vec(); // any agent's estimate post-consensus
        match &update {
            Some(u) => {
                let wt2 = u.run(&dict.mat().transpose(), &nu, &out.y, mu_w).unwrap();
                *dict.mat_mut() = wt2.transpose();
            }
            None => {
                for k in 0..n {
                    dict.block_gradient_step(k, mu_w, &nu, &out.y);
                    dict.project_block(k, task.atom_constraint());
                }
            }
        }
    }
    let after = loss(&dict, &probe);
    assert!(
        after < 0.8 * before,
        "HLO training loop did not reduce loss: {before} → {after}"
    );
}
