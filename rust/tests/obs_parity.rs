//! Observer-effect parity: tracing must not perturb the run. For every
//! instrumented executor — BSP, async discrete-event, chaos
//! (fault-injected async), and the serve sessions (serial and pipelined,
//! static and adaptive) — a run with a recording [`ObsHandle`] attached
//! must be **bit-identical** to the same run untraced: same ν dual
//! trajectories, same `MessageStats` / `ChaosStats`, same simulated
//! clocks, same final dictionary, same controller decisions.
//!
//! The contract this proves is the one `obs/` is built on: emitting an
//! event consumes no RNG draws and advances no clock. The null path is a
//! single `Option::is_some` branch, and the recording path only copies
//! values the executor already computed. Since the executors are
//! deterministic functions of (problem, seed, schedule), bitwise equality
//! of traced vs untraced output is exactly the statement that the
//! recorder had zero observable effect — including zero RNG consumption
//! (one stolen draw would shift every delay sample after it).
//!
//! Cases are randomized over topology, delay distributions, and fault
//! schedules, following the `tests/async_parity.rs` idiom.

use ddl::config::experiment::{ControlConfig, InferenceConfig, ServeConfig};
use ddl::graph::{metropolis_weights, Graph, Topology};
use ddl::infer::DiffusionParams;
use ddl::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use ddl::net::{AsyncNetwork, AsyncParams, BspNetwork, DelayDist, FaultSchedule};
use ddl::obs::ObsHandle;
use ddl::rng::Pcg64;
use ddl::serve::run_service_with_dict;

const M: usize = 12;
const RING_CAP: usize = 1 << 14;

fn random_topology(rng: &mut Pcg64) -> Topology {
    match rng.next_below(3) {
        0 => Topology::Ring { k: 1 + rng.next_below(3) as usize },
        1 => Topology::Grid,
        _ => Topology::ErdosRenyi { p: 0.2 + 0.5 * rng.next_f64() },
    }
}

fn random_delays(rng: &mut Pcg64) -> (DelayDist, DelayDist) {
    let pick = |rng: &mut Pcg64| match rng.next_below(4) {
        0 => DelayDist::Zero,
        1 => DelayDist::Constant { us: 50 + rng.next_below(200) },
        2 => {
            let lo = 20 + rng.next_below(100);
            DelayDist::Uniform { lo_us: lo, hi_us: lo + 1 + rng.next_below(300) }
        }
        _ => DelayDist::Exp { mean_us: 30.0 + 120.0 * rng.next_f64() },
    };
    (pick(rng), pick(rng))
}

fn problem(
    n: usize,
    seed: u64,
) -> (Graph, ddl::math::Mat, DistributedDictionary, Vec<f32>, TaskSpec) {
    let mut rng = Pcg64::new(seed);
    let topo = random_topology(&mut rng);
    let graph = Graph::generate(n, &topo, &mut rng);
    let weights = metropolis_weights(&graph);
    let dict =
        DistributedDictionary::random(M, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
    let x = rng.normal_vec(M);
    let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
    (graph, weights, dict, x, task)
}

/// BSP: traced ≡ untraced, and the registry view round-trips the stats.
#[test]
fn bsp_traced_matches_untraced() {
    for case in 0u64..4 {
        let n = 20 + 5 * case as usize;
        let (graph, weights, dict, x, task) = problem(n, 0x0B5_0000 + case);
        let params = DiffusionParams::new(0.5, 60);

        let mut plain = BspNetwork::new(graph.clone(), weights.clone(), M, None);
        plain.run(&dict, &task, &x, params).unwrap();

        let mut traced = BspNetwork::new(graph, weights, M, None);
        let obs = ObsHandle::recording(RING_CAP);
        traced.attach_obs(obs.clone());
        traced.run(&dict, &task, &x, params).unwrap();

        for k in 0..n {
            assert_eq!(traced.nu(k), plain.nu(k), "case {case}: ν[{k}] must be bit-identical");
        }
        assert_eq!(traced.stats(), plain.stats(), "case {case}: MessageStats");
        assert!(!obs.snapshot().is_empty(), "case {case}: traced run recorded events");
        assert_eq!(
            traced.metrics().message_stats("net"),
            traced.stats(),
            "case {case}: registry round-trips MessageStats"
        );
    }
}

/// Async DES under random delays, bounded staleness, and a straggler:
/// traced ≡ untraced on ν, traffic, clock, and staleness accounting.
#[test]
fn async_traced_matches_untraced() {
    for case in 0u64..4 {
        let n = 24;
        let (graph, weights, dict, x, task) = problem(n, 0xA5_0000 + case);
        let mut seeder = Pcg64::new(0xA5_1000 + case);
        let (compute, link) = random_delays(&mut seeder);
        let mut ap = AsyncParams::default()
            .with_tau(2)
            .with_delays(compute, link)
            .with_seed(0xA5_2000 + case);
        if case % 2 == 0 {
            ap = ap.with_slow_agent(seeder.next_below(n as u64) as usize, 8.0);
        }
        let params = DiffusionParams::new(0.5, 80);

        let mut plain =
            AsyncNetwork::new(graph.clone(), weights.clone(), M, None, ap.clone()).unwrap();
        plain.run(&dict, &task, &x, params).unwrap();

        let mut traced = AsyncNetwork::new(graph, weights, M, None, ap).unwrap();
        let obs = ObsHandle::recording(RING_CAP);
        traced.attach_obs(obs.clone());
        traced.run(&dict, &task, &x, params).unwrap();

        for k in 0..n {
            assert_eq!(traced.nu(k), plain.nu(k), "case {case}: ν[{k}] must be bit-identical");
        }
        assert_eq!(traced.stats(), plain.stats(), "case {case}: MessageStats");
        assert_eq!(traced.sim_time_us(), plain.sim_time_us(), "case {case}: simulated clock");
        assert_eq!(
            traced.max_staleness_observed(),
            plain.max_staleness_observed(),
            "case {case}: staleness accounting"
        );
        assert!(!obs.snapshot().is_empty(), "case {case}: traced run recorded events");
        assert_eq!(
            traced.metrics().message_stats("net"),
            traced.stats(),
            "case {case}: registry round-trips MessageStats"
        );
    }
}

/// Chaos: partitions, crashes, and random drops — the executor branches
/// on fault state constantly, so this exercises every instrumented seam
/// (fault windows, crash deferral, forced combines, drop instants).
#[test]
fn chaos_traced_matches_untraced() {
    for case in 0u64..3 {
        let n = 24;
        let (graph, weights, dict, x, task) = problem(n, 0xC4A0_0000 + case);
        let mut seeder = Pcg64::new(0xC4A0_1000 + case);
        let crash_k = seeder.next_below(n as u64) as usize;
        let schedule = FaultSchedule::new(0xC4A0_2000 + case)
            .with_partition(FaultSchedule::split_side(n, 0.25), 4_000, 12_000)
            .with_crash(crash_k, 2_000, 6_000)
            .with_drops(0.1, 8_000, 16_000);
        let ap = AsyncParams::default()
            .with_tau(2)
            .with_delays(
                DelayDist::Exp { mean_us: 100.0 },
                DelayDist::Exp { mean_us: 20.0 },
            )
            .with_seed(0xC4A0_3000 + case)
            .with_chaos(schedule);
        let params = DiffusionParams::new(0.5, 80);

        let mut plain =
            AsyncNetwork::new(graph.clone(), weights.clone(), M, None, ap.clone()).unwrap();
        plain.run(&dict, &task, &x, params).unwrap();

        let mut traced = AsyncNetwork::new(graph, weights, M, None, ap).unwrap();
        let obs = ObsHandle::recording(RING_CAP);
        traced.attach_obs(obs.clone());
        traced.run(&dict, &task, &x, params).unwrap();

        for k in 0..n {
            assert_eq!(traced.nu(k), plain.nu(k), "case {case}: ν[{k}] must be bit-identical");
        }
        assert_eq!(traced.stats(), plain.stats(), "case {case}: MessageStats");
        assert_eq!(traced.chaos_stats(), plain.chaos_stats(), "case {case}: ChaosStats");
        assert_eq!(traced.sim_time_us(), plain.sim_time_us(), "case {case}: simulated clock");
        assert!(!obs.snapshot().is_empty(), "case {case}: traced run recorded events");
        assert_eq!(
            traced.metrics().chaos_stats(),
            traced.chaos_stats(),
            "case {case}: registry round-trips ChaosStats"
        );
    }
}

/// Byzantine chaos: a corrupted-ψ attacker under the trimmed-mean
/// defense — the corruption hook and the resilient combine are both
/// instrumented, so this pins the observer-effect contract on the two
/// new seams (`psi_corrupt`, `combine_trimmed`) and on the corruption
/// counter.
#[test]
fn byzantine_traced_matches_untraced() {
    use ddl::net::{CombineMode, CorruptPolicy};
    let policies = [
        CorruptPolicy::SignFlip,
        CorruptPolicy::ScaledNoise { sigma: 4.0 },
        CorruptPolicy::ColludingOffset { magnitude: 2.0 },
    ];
    for case in 0u64..3 {
        let n = 24;
        let (graph, weights, dict, x, task) = problem(n, 0xB12A_0000 + case);
        let mut seeder = Pcg64::new(0xB12A_1000 + case);
        let attacker = seeder.next_below(n as u64) as usize;
        let schedule = FaultSchedule::new(0xB12A_2000 + case).with_byzantine(
            attacker,
            policies[case as usize % policies.len()],
            0,
            u64::MAX,
        );
        let ap = AsyncParams::default()
            .with_tau(2)
            .with_delays(DelayDist::Exp { mean_us: 80.0 }, DelayDist::Exp { mean_us: 15.0 })
            .with_seed(0xB12A_3000 + case)
            .with_chaos(schedule)
            .with_combine(CombineMode::TrimmedMean(1));
        let params = DiffusionParams::new(0.5, 80);

        let mut plain =
            AsyncNetwork::new(graph.clone(), weights.clone(), M, None, ap.clone()).unwrap();
        plain.run(&dict, &task, &x, params).unwrap();

        let mut traced = AsyncNetwork::new(graph, weights, M, None, ap).unwrap();
        let obs = ObsHandle::recording(RING_CAP);
        traced.attach_obs(obs.clone());
        traced.run(&dict, &task, &x, params).unwrap();

        for k in 0..n {
            assert_eq!(traced.nu(k), plain.nu(k), "case {case}: ν[{k}] must be bit-identical");
        }
        assert_eq!(traced.stats(), plain.stats(), "case {case}: MessageStats");
        assert_eq!(traced.chaos_stats(), plain.chaos_stats(), "case {case}: ChaosStats");
        assert!(traced.chaos_stats().corrupted > 0, "case {case}: attack never fired");
        assert_eq!(traced.sim_time_us(), plain.sim_time_us(), "case {case}: simulated clock");
        let events = obs.snapshot();
        assert!(
            events.iter().any(|e| e.name == "psi_corrupt"),
            "case {case}: corruption instants recorded"
        );
        assert!(
            events.iter().any(|e| e.name == "combine_trimmed"),
            "case {case}: resilient-combine instants recorded"
        );
    }
}

/// Serve sessions: `cfg.obs.enabled = true` (recorder attached, nothing
/// written — no trace path) vs the default. Covers the serial loop, the
/// static pipeline, and the adaptive pipeline with the batch/depth
/// controllers making live decisions.
#[test]
fn serve_traced_matches_untraced() {
    let base = |pipeline: bool, adaptive: bool| ServeConfig {
        seed: 0x0B5E,
        agents: 30,
        dim: 10,
        topology: "ring".into(),
        ring_k: 2,
        batch: 4,
        max_wait_us: 500,
        samples: 36,
        rate: if adaptive { 1_500.0 } else { 0.0 },
        burst: if adaptive { 4 } else { 1 },
        mu_w: 0.05,
        pipeline,
        pipeline_depth: 2,
        infer: InferenceConfig { mu: 0.4, iters: 8, gamma: 0.08, delta: 0.2, threads: 1 },
        control: if adaptive {
            ControlConfig {
                enabled: true,
                slo_p99_ms: 10.0,
                tick_us: 2_000,
                batch_min: 1,
                batch_max: 8,
                wait_min_us: 0,
                wait_max_us: 5_000,
                window: 64,
                svc_base_us: 800,
                svc_per_sample_us: 150,
                ..ControlConfig::default()
            }
        } else {
            ControlConfig::default()
        },
        ..ServeConfig::default()
    };

    for (label, pipeline, adaptive) in
        [("serial", false, false), ("pipelined", true, false), ("adaptive", true, true)]
    {
        let cfg = base(pipeline, adaptive);
        let (r_plain, d_plain) = run_service_with_dict(&cfg, &mut |_| {}).unwrap();

        let mut traced_cfg = cfg.clone();
        traced_cfg.obs.enabled = true; // recorder on, no trace path → no IO
        let (r_obs, d_obs) = run_service_with_dict(&traced_cfg, &mut |_| {}).unwrap();

        assert_eq!(
            d_plain.mat().as_slice(),
            d_obs.mat().as_slice(),
            "{label}: final dictionary must be bit-identical"
        );
        assert_eq!(r_plain.samples, r_obs.samples, "{label}: samples");
        assert_eq!(r_plain.batches, r_obs.batches, "{label}: batches");
        assert_eq!(r_plain.stats, r_obs.stats, "{label}: ψ-traffic MessageStats");
        assert_eq!(
            r_plain.loss_first_quarter.to_bits(),
            r_obs.loss_first_quarter.to_bits(),
            "{label}: first-quarter loss"
        );
        assert_eq!(
            r_plain.loss_last_quarter.to_bits(),
            r_obs.loss_last_quarter.to_bits(),
            "{label}: last-quarter loss"
        );
        assert_eq!(r_plain.decisions, r_obs.decisions, "{label}: controller decision trace");
        assert_eq!(r_plain.depth_trace, r_obs.depth_trace, "{label}: depth replans");
        if adaptive {
            // Adaptive sessions run on the deterministic virtual clock, so
            // even the latency/throughput figures must match bitwise.
            // (Static sessions report measured wall time there — the one
            // thing allowed to differ between any two runs.)
            assert_eq!(
                r_plain.latency_p99_ms.to_bits(),
                r_obs.latency_p99_ms.to_bits(),
                "{label}: virtual p99 latency"
            );
            assert_eq!(
                r_plain.throughput_rps.to_bits(),
                r_obs.throughput_rps.to_bits(),
                "{label}: virtual throughput"
            );
        }
    }
}

/// Convergence freeze/thaw instrumentation: with the detector freezing
/// mid-stream, tracing must not perturb the freeze point, the frozen-batch
/// count, or any downstream bit — on the serial loop, the static pipeline,
/// and the adaptive pipeline (where the frozen update-slot discount feeds
/// the virtual clock the controllers read). Also pins that the
/// `freeze` / `thaw` / `drift_norm` instants actually reach an exported
/// trace.
#[test]
fn convergence_traced_matches_untraced() {
    let base = |pipeline: bool, adaptive: bool| {
        let mut cfg = ServeConfig {
            seed: 0x0B60,
            agents: 30,
            dim: 10,
            topology: "ring".into(),
            ring_k: 2,
            batch: 4,
            max_wait_us: 500,
            samples: 48,
            rate: 0.0,
            mu_w: 0.05,
            pipeline,
            pipeline_depth: 2,
            infer: InferenceConfig { mu: 0.4, iters: 8, gamma: 0.08, delta: 0.2, threads: 1 },
            control: if adaptive {
                ControlConfig {
                    enabled: true,
                    slo_p99_ms: 10.0,
                    tick_us: 2_000,
                    batch_min: 1,
                    batch_max: 8,
                    wait_min_us: 0,
                    wait_max_us: 5_000,
                    window: 64,
                    svc_base_us: 800,
                    svc_per_sample_us: 150,
                    ..ControlConfig::default()
                }
            } else {
                ControlConfig::default()
            },
            ..ServeConfig::default()
        };
        // Freeze early and reliably: any drift counts as converged.
        cfg.convergence.tol = 10.0;
        cfg.convergence.window = 2;
        cfg.convergence.max_no_improvement = 1;
        cfg
    };

    for (label, pipeline, adaptive) in
        [("serial", false, false), ("pipelined", true, false), ("adaptive", true, true)]
    {
        let cfg = base(pipeline, adaptive);
        let (r_plain, d_plain) = run_service_with_dict(&cfg, &mut |_| {}).unwrap();
        assert!(r_plain.frozen_batches > 0, "{label}: freeze must fire under tol = 10");

        let mut traced_cfg = cfg.clone();
        traced_cfg.obs.enabled = true; // recorder on, no trace path → no IO
        let (r_obs, d_obs) = run_service_with_dict(&traced_cfg, &mut |_| {}).unwrap();

        assert_eq!(
            d_plain.mat().as_slice(),
            d_obs.mat().as_slice(),
            "{label}: final dictionary must be bit-identical"
        );
        assert_eq!(r_plain.conv_events, r_obs.conv_events, "{label}: freeze/thaw trace");
        assert_eq!(r_plain.frozen_batches, r_obs.frozen_batches, "{label}: frozen batches");
        assert_eq!(r_plain.batches, r_obs.batches, "{label}: batches");
        assert_eq!(r_plain.stats, r_obs.stats, "{label}: ψ-traffic MessageStats");
        assert_eq!(
            r_plain.loss_last_quarter.to_bits(),
            r_obs.loss_last_quarter.to_bits(),
            "{label}: last-quarter loss"
        );
        assert_eq!(r_plain.decisions, r_obs.decisions, "{label}: controller decision trace");
        if adaptive {
            assert_eq!(
                r_plain.throughput_rps.to_bits(),
                r_obs.throughput_rps.to_bits(),
                "{label}: virtual throughput (frozen slots discount the same way)"
            );
        }
    }

    // The instants land in an exported trace under their contract names.
    let mut cfg = base(false, false);
    cfg.obs.enabled = true;
    let path = std::env::temp_dir().join("ddl_conv_obs_parity.jsonl");
    cfg.obs.trace_path = Some(path.to_string_lossy().into_owned());
    cfg.obs.format = "jsonl".into();
    run_service_with_dict(&cfg, &mut |_| {}).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("\"freeze\""), "freeze instant missing from trace");
    assert!(text.contains("\"drift_norm\""), "drift_norm instants missing from trace");
    ddl::obs::check_jsonl(&path).unwrap();
    let _ = std::fs::remove_file(&path);
}

/// Serve fault paths: bounded admission (overflow sheds, `queue_shed`
/// instants) and a mid-stream worker death (`worker_death` /
/// `batch_redispatch` instants) — tracing must not perturb the shed
/// accounting, the re-dispatch schedule, or any downstream bit.
#[test]
fn serve_faults_traced_match_untraced() {
    let base = || ServeConfig {
        seed: 0x0B5F,
        agents: 30,
        dim: 10,
        topology: "ring".into(),
        ring_k: 2,
        batch: 4,
        max_wait_us: 500,
        samples: 36,
        rate: 0.0,
        mu_w: 0.05,
        pipeline: true,
        pipeline_depth: 2,
        infer: InferenceConfig { mu: 0.4, iters: 8, gamma: 0.08, delta: 0.2, threads: 1 },
        ..ServeConfig::default()
    };
    let shedding = || ServeConfig { queue_capacity: 16, ..base() };
    let killing =
        || ServeConfig { kill_slot: Some(1), kill_at_batch: 2, ..base() };
    for (label, cfg) in [("shedding", shedding()), ("worker-death", killing())] {
        let (r_plain, d_plain) = run_service_with_dict(&cfg, &mut |_| {}).unwrap();

        let mut traced_cfg = cfg.clone();
        traced_cfg.obs.enabled = true; // recorder on, no trace path → no IO
        let (r_obs, d_obs) = run_service_with_dict(&traced_cfg, &mut |_| {}).unwrap();

        assert_eq!(
            d_plain.mat().as_slice(),
            d_obs.mat().as_slice(),
            "{label}: final dictionary must be bit-identical"
        );
        assert_eq!(r_plain.samples, r_obs.samples, "{label}: samples");
        assert_eq!(r_plain.batches, r_obs.batches, "{label}: batches");
        assert_eq!(r_plain.shed, r_obs.shed, "{label}: shed accounting");
        assert_eq!(r_plain.stats, r_obs.stats, "{label}: ψ-traffic MessageStats");
        assert_eq!(
            r_plain.loss_last_quarter.to_bits(),
            r_obs.loss_last_quarter.to_bits(),
            "{label}: last-quarter loss"
        );
        if label == "shedding" {
            assert!(r_plain.shed > 0, "{label}: capacity 16 under 36 saturated arrivals sheds");
        }
    }
}
