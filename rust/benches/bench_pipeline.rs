//! End-to-end pipeline throughput + design-choice ablations.
//!
//! * training samples/s for the Fig. 5 denoise configuration (the
//!   system's "serving" rate);
//! * minibatch-size ablation (paper footnote 4 uses 4);
//! * topology ablation: iterations-to-consensus vs spectral gap;
//! * per-sample denoising latency.

use ddl::bench::Bencher;
use ddl::config::experiment::DenoiseConfig;
use ddl::data::{synth_scene, PatchSampler};
use ddl::graph::{laplacian::spectral_gap, metropolis_weights, Graph, Topology};
use ddl::infer::{DiffusionEngine, DiffusionParams};
use ddl::learn::{OnlineTrainer, TrainerOptions};
use ddl::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use ddl::ops::prox::DictProx;
use ddl::rng::Pcg64;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Pcg64::new(3);
    let cfg = DenoiseConfig::default();
    let m = cfg.patch * cfg.patch;
    let n = cfg.agents;
    let task = TaskSpec::SparseCoding { gamma: cfg.train_infer.gamma, delta: cfg.train_infer.delta };

    let images = vec![synth_scene(96, &mut rng)];
    let mut sampler = PatchSampler::new(images, cfg.patch, 11);
    let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
    let a = metropolis_weights(&g);

    // --- minibatch ablation: samples/s at batch 1, 4, 16 ---
    for &batch in &[1usize, 4, 16] {
        let mut dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let mut tr = OnlineTrainer::new(
            &a,
            m,
            None,
            TrainerOptions {
                infer: DiffusionParams::new(cfg.train_infer.mu, cfg.train_infer.iters),
                prox: DictProx::None,
            },
        )
        .unwrap();
        let samples: Vec<Vec<f32>> = (0..batch).map(|_| sampler.sample().0).collect();
        let refs: Vec<&[f32]> = samples.iter().map(|v| v.as_slice()).collect();
        b.bench_work(&format!("train step, minibatch {batch}"), batch as f64, || {
            tr.step(&mut dict, &task, &refs, cfg.mu_w).unwrap();
        });
    }

    // --- denoise latency per patch (inference + recovery) ---
    {
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let mut eng = DiffusionEngine::new(&a, m, None).unwrap();
        let (patch, _) = sampler.sample();
        b.bench(&format!("denoise patch ({n},{m})x{}", cfg.denoise_infer.iters), || {
            eng.reset();
            eng.run(
                &dict,
                &task,
                &patch,
                DiffusionParams::new(cfg.denoise_infer.mu, cfg.denoise_infer.iters),
            )
            .unwrap();
            std::hint::black_box(eng.recover_y(&dict, &task));
        });
    }

    // --- topology ablation: fixed iteration budget, report disagreement ---
    println!("\ntopology ablation (iterations to reach the same budget):");
    for (label, topo) in [
        ("ring", Topology::Ring { k: 1 }),
        ("er_p02", Topology::ErdosRenyi { p: 0.2 }),
        ("er_p05", Topology::ErdosRenyi { p: 0.5 }),
        ("complete", Topology::FullyConnected),
    ] {
        let g = Graph::generate(n, &topo, &mut rng);
        let a = metropolis_weights(&g);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let x = rng.normal_vec(m);
        let mut eng = DiffusionEngine::new(&a, m, None).unwrap();
        eng.run(&dict, &task, &x, DiffusionParams::new(0.1, 300)).unwrap();
        println!(
            "  {label:<9} gap {:.3} → disagreement {:.3e} after 300 iters",
            spectral_gap(&a),
            eng.disagreement()
        );
    }

    b.write_csv(std::path::Path::new("results/bench_pipeline.csv")).unwrap();
    println!("\nwrote results/bench_pipeline.csv");
}
