//! Control-plane figures (ISSUE 5): static-vs-adaptive serving under a
//! bursty arrival stream, and adaptive-τ vs the best static τ under a
//! drifting straggler. Methodology: EXPERIMENTS.md §Control.
//!
//! Everything in this file runs on the deterministic virtual clocks
//! (the serve sessions on the `[control]` service model, the async runs
//! on the discrete-event clock), so every derived figure is **exact and
//! machine-independent** — the gate keys are noise-free by construction.
//! Static comparison points are produced by the *same* adaptive
//! machinery with the controller bounds pinned to a single grid point
//! (`batch_min = batch_max`, `wait_min_us = wait_max_us`,
//! `tau_min = tau_max`), so adaptive and static runs share one code
//! path, one workload, and one clock.
//!
//! Derived keys written to `BENCH_control.json` (gated by
//! `ddl bench-gate` against `bench/baselines/BENCH_control.json`):
//!
//! * `control_batch_dominates_static_grid` — **1.0** when no fixed
//!   `(max_batch, max_wait_us)` grid point beats the adaptive batch
//!   controller on virtual throughput (by more than a 2% tie margin)
//!   while matching its SLO-violation fraction — i.e. the adaptive
//!   session sits on the throughput/compliance Pareto front of the grid
//!   it never saw;
//! * `control_batch_throughput_ratio_adaptive_vs_best_compliant_static`
//!   — adaptive virtual throughput over the best static grid point whose
//!   SLO-violation fraction is no worse than the adaptive one's (2.0 when
//!   no grid point is that compliant);
//! * `control_tau_within_5pct_of_best_static_drift` — **1.0** when the
//!   adaptive-τ time-to-target-MSD lands within 5% of the best static τ
//!   in the grid, under a drifting straggler the controller does not know
//!   in advance (the ISSUE 5 acceptance bar);
//! * `control_tau_time_ratio_best_static_vs_adaptive` — the underlying
//!   ratio (≥ 0.95 when the bar holds; > 1 when adaptive wins outright);
//! * `control_replay_bitwise` — **1.0** when a second adaptive serve run
//!   reproduces the first bit-for-bit (p99, decision trace, dictionary)
//!   and a second adaptive-τ run reproduces its decision trace and
//!   clocks — the determinism contract, kept visible in the artifact.
//!
//! Pass `--fast` (or `BENCH_FAST=1`) for the CI smoke configuration.

use ddl::bench::Bencher;
use ddl::config::experiment::{AsyncConfig, ControlConfig, InferenceConfig, ServeConfig};
use ddl::coordinator::run_adaptive_tau;
use ddl::serve::run_service_with_dict;

/// Bursty serving scenario: clumps of 8 requests at 1500 req/s mean rate
/// against a B = 1 virtual capacity of ~1052 req/s — batching is
/// mandatory for stability, waiting trades latency for efficiency, and
/// the 10 ms p99 SLO arbitrates.
fn serve_cfg(fast: bool) -> ServeConfig {
    ServeConfig {
        seed: 0xC0_51,
        agents: 50,
        dim: 32,
        topology: "ring".into(),
        ring_k: 2,
        batch: 8,
        max_wait_us: 2_000,
        samples: if fast { 256 } else { 768 },
        rate: 1_500.0,
        burst: 8,
        mu_w: 0.05,
        infer: InferenceConfig { mu: 0.4, iters: if fast { 30 } else { 60 }, gamma: 0.08, delta: 0.2, threads: 1 },
        control: ControlConfig {
            enabled: true,
            slo_p99_ms: 10.0,
            tick_us: 2_000,
            batch_min: 1,
            batch_max: 32,
            wait_min_us: 0,
            wait_max_us: 20_000,
            window: 256,
            svc_base_us: 800,
            svc_per_sample_us: 150,
            ..ControlConfig::default()
        },
        ..ServeConfig::default()
    }
}

/// Pin the controller bounds to one `(max_batch, max_wait_us)` grid
/// point: same code path and clock as the adaptive run, zero freedom.
fn pinned(cfg: &ServeConfig, max_batch: usize, max_wait_us: u64) -> ServeConfig {
    let mut c = cfg.clone();
    c.batch = max_batch;
    c.max_wait_us = max_wait_us;
    c.control.batch_min = max_batch;
    c.control.batch_max = max_batch;
    c.control.wait_min_us = max_wait_us;
    c.control.wait_max_us = max_wait_us;
    c
}

/// Drifting-straggler async scenario: the 10x-slow identity rotates every
/// 20 ms, so no static τ is chosen with knowledge of the schedule.
fn tau_cfg(fast: bool) -> AsyncConfig {
    AsyncConfig {
        seed: 0xC0_52,
        agents: 50,
        dim: 16,
        topology: "ring".into(),
        ring_k: 2,
        tau: 4, // adaptive starting point (clamped into the bounds)
        compute_dist: "exp".into(),
        compute_us: 100,
        link_dist: "exp".into(),
        link_us: 20,
        slow_agent: None,
        slow_factor: 10.0,
        drift_period_us: 20_000,
        infer: InferenceConfig {
            mu: 0.5,
            iters: if fast { 800 } else { 1200 },
            gamma: 0.1,
            delta: 0.5,
            threads: 1,
        },
        control: ControlConfig {
            adaptive_tau: true,
            tau_min: 0,
            tau_max: 8,
            tau_epoch_us: 2_000,
            gate_wait_hi: 0.25,
            msd_drift_bound: 0.5,
            ..ControlConfig::default()
        },
        ..AsyncConfig::default()
    }
}

/// Pin the τ bounds to one static value (the grid comparator).
fn tau_pinned(cfg: &AsyncConfig, tau: usize) -> AsyncConfig {
    let mut c = cfg.clone();
    c.tau = tau;
    c.control.tau_min = tau;
    c.control.tau_max = tau;
    c
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast")
        || std::env::var("BENCH_FAST").map(|v| v != "0").unwrap_or(false);
    let mut b = if fast { Bencher::quick() } else { Bencher::new() };
    let mut derived: Vec<(String, f64)> = Vec::new();
    let mut replay_ok = true;

    // ------------------------------------------------------------------
    // Batch controller: adaptive vs the static (max_batch, max_wait) grid
    // under the bursty stream, all on the virtual service clock.
    // ------------------------------------------------------------------
    let cfg = serve_cfg(fast);
    let (adaptive, dict_a) = run_service_with_dict(&cfg, &mut |_| {}).unwrap();
    println!(
        "adaptive: {:.1} rps, p99 {:.2} ms, SLO violations {:.2}%, {} decisions",
        adaptive.throughput_rps,
        adaptive.latency_p99_ms,
        100.0 * adaptive.slo_violation_frac,
        adaptive.decisions.len()
    );
    // Replay check: bit-identical second run.
    let (adaptive2, dict_a2) = run_service_with_dict(&cfg, &mut |_| {}).unwrap();
    replay_ok &= adaptive.latency_p99_ms.to_bits() == adaptive2.latency_p99_ms.to_bits()
        && adaptive.throughput_rps.to_bits() == adaptive2.throughput_rps.to_bits()
        && adaptive.decisions == adaptive2.decisions
        && dict_a.mat().as_slice() == dict_a2.mat().as_slice();

    let grid: &[(usize, u64)] =
        &[(1, 0), (1, 20_000), (4, 0), (4, 20_000), (32, 0), (32, 3_000), (32, 20_000)];
    let mut dominated = false;
    let mut best_compliant_rps: Option<f64> = None;
    for &(mb, mw) in grid {
        let (r, _) = run_service_with_dict(&pinned(&cfg, mb, mw), &mut |_| {}).unwrap();
        println!(
            "static B={mb:>2} wait={mw:>6}: {:.1} rps, p99 {:.2} ms, violations {:.2}%",
            r.throughput_rps,
            r.latency_p99_ms,
            100.0 * r.slo_violation_frac
        );
        let as_compliant = r.slo_violation_frac <= adaptive.slo_violation_frac + 1e-9;
        if as_compliant {
            // A grid point must beat adaptive by > 2% (virtual-clock tie
            // margin) at equal-or-better compliance to dominate it.
            if r.throughput_rps > adaptive.throughput_rps * 1.02 {
                dominated = true;
            }
            best_compliant_rps = Some(
                best_compliant_rps.map_or(r.throughput_rps, |best| best.max(r.throughput_rps)),
            );
        }
    }
    derived.push((
        "control_batch_dominates_static_grid".to_string(),
        if dominated { 0.0 } else { 1.0 },
    ));
    derived.push((
        "control_batch_throughput_ratio_adaptive_vs_best_compliant_static".to_string(),
        match best_compliant_rps {
            Some(best) => adaptive.throughput_rps / best.max(1e-12),
            None => 2.0,
        },
    ));

    // ------------------------------------------------------------------
    // τ controller: time-to-target MSD vs the static τ grid under the
    // drifting straggler, shared epoch granularity.
    // ------------------------------------------------------------------
    let acfg = tau_cfg(fast);
    let adaptive_tau = run_adaptive_tau(&acfg, &mut |_| {}).unwrap();
    let adaptive_tau2 = run_adaptive_tau(&acfg, &mut |_| {}).unwrap();
    replay_ok &= adaptive_tau.trace == adaptive_tau2.trace
        && adaptive_tau.completion_us == adaptive_tau2.completion_us;

    // Each pinned run re-simulates its own τ = 0 probe (redundant DES
    // work, ~2x) — accepted so every grid point goes through the exact
    // adaptive code path and epoch grid it is compared against.
    let tau_grid = [0usize, 1, 2, 4, 8];
    let statics: Vec<_> = tau_grid
        .iter()
        .map(|&t| run_adaptive_tau(&tau_pinned(&acfg, t), &mut |_| {}).unwrap())
        .collect();
    // Target MSD every run provably reaches: 1.25x the worst final MSD
    // across all candidates (each run's last epoch row is its final
    // state, so time_to_msd(target) is always Some).
    let worst_final = statics
        .iter()
        .map(|r| r.rows.last().unwrap().msd_adaptive)
        .chain([adaptive_tau.rows.last().unwrap().msd_adaptive])
        .fold(0.0f64, f64::max);
    let target = worst_final * 1.25;
    let t_adaptive = adaptive_tau.time_to_msd(target).expect("target reached by construction");
    let mut t_best_static = u64::MAX;
    for (r, &t) in statics.iter().zip(&tau_grid) {
        let tt = r.time_to_msd(target).expect("target reached by construction");
        println!(
            "static tau={t}: time-to-MSD {:.4} s (completes {:.4} s)",
            tt as f64 / 1e6,
            r.completion_us as f64 / 1e6
        );
        t_best_static = t_best_static.min(tt);
    }
    println!(
        "adaptive tau: time-to-MSD {:.4} s, final tau {}, trace {} epochs",
        t_adaptive as f64 / 1e6,
        adaptive_tau.final_tau,
        adaptive_tau.trace.len()
    );
    let ratio = t_best_static as f64 / t_adaptive.max(1) as f64;
    derived.push((
        "control_tau_within_5pct_of_best_static_drift".to_string(),
        if t_adaptive as f64 <= 1.05 * t_best_static as f64 { 1.0 } else { 0.0 },
    ));
    derived.push(("control_tau_time_ratio_best_static_vs_adaptive".to_string(), ratio));
    derived.push(("control_replay_bitwise".to_string(), if replay_ok { 1.0 } else { 0.0 }));

    // Wall-clock cost of one adaptive serve session (the only
    // machine-dependent row; informational, not gated).
    let mut tiny = serve_cfg(true);
    tiny.samples = 96;
    b.bench_work("adaptive serve session (96 samples)", 96.0, || {
        let (r, _) = run_service_with_dict(&tiny, &mut |_| {}).unwrap();
        std::hint::black_box(r.throughput_rps);
    });

    ddl::bench::write_report(&b, "control", &derived);
}
