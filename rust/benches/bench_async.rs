//! Asynchronous-diffusion figures: the acceptance scenario of ISSUE 4 and
//! the tracked straggler numbers (methodology: EXPERIMENTS.md §Async).
//!
//! Scenario of record — **one 10×-slow agent on the ring, N = 100**
//! (ring k = 2, exponential compute/link delays): the sync comparator is
//! the async executor at τ = 0 (bit-for-bit the BSP trajectory, with the
//! same delay model pricing its barriers), the async executor runs at
//! τ = 4 clamped to the sync run's simulated completion time, and MSD is
//! measured against the exact dual ν° ([`ddl::infer::exact_dual`]). The
//! iteration count (2000; 1200 in `--fast`) is chosen so both executors
//! are deep in the geometric tail — the cold-start magnitude build-up
//! takes ~N/μ iterations — which is what "completes" means in the
//! acceptance criterion.
//!
//! Derived figures written to `BENCH_async.json` (gated by
//! `ddl bench-gate` against `bench/baselines/BENCH_async.json`):
//!
//! * `async_msd_parity_ring_n100_slow10x` — **1.0** when the async MSD at
//!   equal simulated time sits within 1e-3 of sync (the acceptance bar),
//!   else 0.0; the gate (min-frac 0.5) therefore fails on any violation;
//! * `async_bsp_bitwise_parity` — 1.0 when τ = 0 under random delays
//!   reproduces the `BspNetwork` ν trajectories bit-for-bit (redundant
//!   with `tests/async_parity.rs`, but keeps the invariant visible in the
//!   tracked bench artifact);
//! * `async_time_speedup_to_equal_iters_ring_n100_slow10x` — sync
//!   simulated completion time over async simulated completion time at
//!   the same iteration target: the straggler stops charging the rest of
//!   the network its round-trip, but bounded staleness still chains
//!   long-run progress to it, so this is a modest, honest ratio;
//! * `async_time_speedup_jitter_ring_n100` — the same ratio in the
//!   *homogeneous jitter* scenario (no straggler, exponential compute and
//!   link delays): here the barrier pays the max of every neighborhood's
//!   draws each round while τ = 4 absorbs the jitter, the classic
//!   asynchronous win.
//!
//! Wall-clock cost of the simulation itself (agent-iterations/s of the
//! discrete-event core) is also timed, as `async DES ring N=100`.
//!
//! Pass `--fast` (or `BENCH_FAST=1`) for the CI smoke configuration.

use ddl::bench::Bencher;
use ddl::graph::{metropolis_weights, Graph, Topology};
use ddl::infer::{exact_dual, DiffusionParams};
use ddl::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use ddl::net::{AsyncNetwork, AsyncParams, BspNetwork, DelayDist};
use ddl::rng::Pcg64;

const N: usize = 100;
const TAU: usize = 4;

fn jitter(tau: usize) -> AsyncParams {
    AsyncParams::default()
        .with_tau(tau)
        .with_delays(DelayDist::Exp { mean_us: 100.0 }, DelayDist::Exp { mean_us: 20.0 })
        .with_seed(0xA5_BE)
}

fn straggler(tau: usize) -> AsyncParams {
    jitter(tau).with_slow_agent(0, 10.0)
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast")
        || std::env::var("BENCH_FAST").map(|v| v != "0").unwrap_or(false);
    let mut b = if fast { Bencher::quick() } else { Bencher::new() };
    let mut derived: Vec<(String, f64)> = Vec::new();

    let m = if fast { 16 } else { 24 };
    let iters = if fast { 1200 } else { 2000 };

    // One problem instance for every figure in this file.
    let mut rng = Pcg64::new(0xA51);
    let dict =
        DistributedDictionary::random(m, N, N, AtomConstraint::UnitBall, &mut rng).unwrap();
    let graph = Graph::generate(N, &Topology::Ring { k: 2 }, &mut rng);
    let weights = metropolis_weights(&graph);
    let x = rng.normal_vec(m);
    let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
    let params = DiffusionParams::new(0.5, iters);
    let exact = exact_dual(&dict, &task, &x, 1e-6, 20_000).unwrap();

    // τ = 0 under the straggler's random delays must be bitwise the BSP
    // run — the executor's correctness anchor, kept visible in the
    // tracked artifact.
    let mut bsp = BspNetwork::new(graph.clone(), weights.clone(), m, None);
    bsp.run(&dict, &task, &x, params).unwrap();
    let mut sync =
        AsyncNetwork::new(graph.clone(), weights.clone(), m, None, straggler(0)).unwrap();
    sync.run(&dict, &task, &x, params).unwrap();
    let bitwise_ok = (0..N).all(|k| sync.nu(k) == bsp.nu(k)) && sync.stats() == bsp.stats();
    derived.push(("async_bsp_bitwise_parity".to_string(), if bitwise_ok { 1.0 } else { 0.0 }));
    let t_sync = sync.sim_time_us();
    let msd_sync = sync.msd_vs(&exact.nu);
    println!(
        "straggler sync (tau=0): T = {:.4} s, MSD = {:.3e}, bitwise BSP parity: {bitwise_ok}",
        t_sync as f64 / 1e6,
        msd_sync,
    );

    // Async at τ = TAU, same iteration target: MSD at the sync time
    // budget (the acceptance comparison), then completion time.
    let mut anet =
        AsyncNetwork::new(graph.clone(), weights.clone(), m, None, straggler(TAU)).unwrap();
    let finished = anet.run_clamped(&dict, &task, &x, params, t_sync).unwrap();
    let msd_async = anet.msd_vs(&exact.nu);
    let msd_gap = (msd_async - msd_sync).abs();
    anet.run(&dict, &task, &x, params).unwrap();
    let t_async = anet.sim_time_us();
    println!(
        "straggler async (tau={TAU}): finished within T_sync: {finished}, T = {:.4} s, \
         MSD at T_sync = {:.3e} (gap {:.3e}), max staleness {}",
        t_async as f64 / 1e6,
        msd_async,
        msd_gap,
        anet.max_staleness_observed()
    );
    derived.push((
        "async_msd_parity_ring_n100_slow10x".to_string(),
        if msd_gap <= 1e-3 { 1.0 } else { 0.0 },
    ));
    derived.push((
        "async_time_speedup_to_equal_iters_ring_n100_slow10x".to_string(),
        t_sync as f64 / (t_async as f64).max(1.0),
    ));

    // Homogeneous-jitter scenario: no straggler, the barrier pays the
    // neighborhood max every round while τ absorbs it.
    let mut jsync =
        AsyncNetwork::new(graph.clone(), weights.clone(), m, None, jitter(0)).unwrap();
    jsync.run(&dict, &task, &x, params).unwrap();
    let mut jasync =
        AsyncNetwork::new(graph.clone(), weights.clone(), m, None, jitter(TAU)).unwrap();
    jasync.run(&dict, &task, &x, params).unwrap();
    println!(
        "jitter: sync T = {:.4} s, async T = {:.4} s ({:.2}x), traffic identical: {}",
        jsync.sim_time_us() as f64 / 1e6,
        jasync.sim_time_us() as f64 / 1e6,
        jsync.sim_time_us() as f64 / (jasync.sim_time_us() as f64).max(1.0),
        jsync.stats().messages == jasync.stats().messages,
    );
    derived.push((
        "async_time_speedup_jitter_ring_n100".to_string(),
        jsync.sim_time_us() as f64 / (jasync.sim_time_us() as f64).max(1.0),
    ));

    // Cost of the simulation machinery itself.
    let des_iters = if fast { 200 } else { 500 };
    let des_params = DiffusionParams::new(0.5, des_iters);
    b.bench_work(
        &format!("async DES ring N={N} ({des_iters} iters)"),
        (N * des_iters) as f64,
        || {
            let mut net =
                AsyncNetwork::new(graph.clone(), weights.clone(), m, None, straggler(TAU))
                    .unwrap();
            net.run(&dict, &task, &x, des_params).unwrap();
            std::hint::black_box(net.nu(0)[0]);
        },
    );

    ddl::bench::write_report(&b, "async", &derived);
}
