//! Chaos-harness figures: the acceptance scenario of ISSUE 6 and the
//! tracked robustness numbers (methodology: EXPERIMENTS.md §Chaos).
//!
//! Scenario of record — **healing partition on the ring, N = 100**
//! (ring k = 2, exponential compute/link delays, no straggler): a
//! partition cutting 20% of the agents opens at 40% of the fault-free
//! horizon and heals after 20% of it. The chaos driver
//! ([`ddl::coordinator::run_chaos`]) runs the fault-free baseline, the
//! chaos run, a bitwise replay check, and an empty-schedule parity check
//! in one call; this bench re-exports its contract booleans as gated
//! indicators so the invariants stay visible in the tracked artifact.
//!
//! Derived figures written to `BENCH_chaos.json` (gated by
//! `ddl bench-gate` against `bench/baselines/BENCH_chaos.json`):
//!
//! * `chaos_empty_schedule_bitwise_parity` — **1.0** when a run with an
//!   empty-but-seeded `FaultSchedule` reproduces the fault-free
//!   trajectory bit-for-bit (clock, traffic, ν), else 0.0;
//! * `chaos_replay_bitwise` — **1.0** when a second run under the
//!   identical schedule reproduces the chaos run bit-for-bit;
//! * `chaos_partition_recovery_gap_ok` — **1.0** when
//!   `|MSD_chaos − MSD_clean|` at equal simulated time `t = T` (after
//!   the partition healed) is below 1e-3, the ISSUE 6 acceptance bar;
//! * `chaos_pushsum_vs_metropolis_bias_ratio` — converged-MSD ratio
//!   Metropolis/push-sum under a persistent directed outage
//!   (`run_pushsum_bias`): > 1 means the push-sum correction removes
//!   bias Metropolis keeps. Tracked as a ratio with the default gate
//!   slack (min-frac 0.5), not pinned — the exact magnitude depends on
//!   scenario scale.
//! * `chaos_byzantine_defense_recovers` — **1.0** when, under an f = 1
//!   sign-flip attacker on the ring, the `TrimmedMean(1)` defense lands
//!   within 1e-3 MSD of its own attack-free run while undefended
//!   Metropolis is biased > 10× (or diverges) — the ISSUE 8 acceptance
//!   bar ([`ddl::coordinator::run_byzantine`]);
//! * `chaos_byzantine_replay_bitwise` — **1.0** when both attacked runs
//!   replay bit-identically under the identical Byzantine schedule.
//! * `chaos_detection_excludes_colluders` — **1.0** when, under f = 2
//!   *adjacent colluding* sign-flip attackers on the k = 2 ring
//!   (`--byzantine-agents --detect`), the reputation layer flags and
//!   excludes both colluders, the detection-defended run lands within
//!   1e-3 MSD of its own clean defended trajectory (where `TrimmedMean(1)`
//!   masking alone stays biased), and the detection pass replays
//!   bit-identically — flagged/excluded sets included (PR 10 acceptance);
//! * `chaos_detection_zero_false_positives` — **1.0** when the clean run
//!   with detection armed is bitwise the clean defended run and records
//!   zero flags and zero exclusions;
//! * `serve_poison_quarantine_recovers` — **1.0** when a poisoned serve
//!   session (`ddl serve --poison`) quarantines the corrupted samples
//!   before the Eq. 51 update and its tail loss stays well below the
//!   unscreened run, a zero-poison stream is never quarantined, and the
//!   poisoned defended session replays bit-identically.
//!
//! Wall-clock cost of the fault-injected discrete-event core is timed as
//! `chaos DES ring (churn)` — agent-iterations/s with an 8-window churn
//! schedule active, comparable to the `async DES` row of
//! `BENCH_async.json` (the fault layer should cost ~nothing).
//!
//! Pass `--fast` (or `BENCH_FAST=1`) for the CI smoke configuration.

use ddl::bench::Bencher;
use ddl::config::experiment::{AsyncConfig, ServeConfig};
use ddl::coordinator::{run_byzantine, run_chaos, run_pushsum_bias};
use ddl::graph::{metropolis_weights, Graph, Topology};
use ddl::infer::DiffusionParams;
use ddl::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use ddl::net::{AsyncNetwork, AsyncParams, DelayDist, FaultSchedule};
use ddl::rng::Pcg64;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast")
        || std::env::var("BENCH_FAST").map(|v| v != "0").unwrap_or(false);
    let mut b = if fast { Bencher::quick() } else { Bencher::new() };
    let mut derived: Vec<(String, f64)> = Vec::new();

    // Scenario of record. The `[chaos]` defaults already encode the
    // acceptance partition (20% of agents, open at 40% of T for 20% of
    // T); `--fast` shrinks the network, not the scenario shape.
    let mut cfg = AsyncConfig {
        agents: if fast { 40 } else { 100 },
        dim: if fast { 16 } else { 24 },
        slow_agent: None, // isolate faults from the straggler study
        checkpoints: 6,
        ..AsyncConfig::default()
    };
    cfg.infer.iters = if fast { 800 } else { 1500 };
    cfg.chaos.enabled = true;
    let report = run_chaos(&cfg, &mut |s| println!("{s}")).unwrap();
    println!("{}", report.summary(cfg.agents));
    derived.push((
        "chaos_empty_schedule_bitwise_parity".to_string(),
        if report.empty_parity { 1.0 } else { 0.0 },
    ));
    derived.push((
        "chaos_replay_bitwise".to_string(),
        if report.replay_bitwise { 1.0 } else { 0.0 },
    ));
    derived.push((
        "chaos_partition_recovery_gap_ok".to_string(),
        if report.recovery_gap < 1e-3 { 1.0 } else { 0.0 },
    ));

    // Push-sum bias probe: persistent directed outage, converged MSD
    // under forced Metropolis vs forced push-sum on one scenario.
    let mut bias_cfg = cfg.clone();
    bias_cfg.agents = if fast { 30 } else { 60 };
    bias_cfg.infer.iters = if fast { 600 } else { 1200 };
    let probe = run_pushsum_bias(&bias_cfg, &mut |s| println!("{s}")).unwrap();
    println!(
        "bias probe: outage from {} µs cut {} links, metropolis {:.3e} vs push-sum {:.3e} \
         ({:.2}x)",
        probe.outage_from_us,
        probe.links_cut,
        probe.msd_metropolis,
        probe.msd_pushsum,
        probe.bias_ratio(),
    );
    derived.push(("chaos_pushsum_vs_metropolis_bias_ratio".to_string(), probe.bias_ratio()));

    // Byzantine probe: f = 1 sign-flip attacker on the ring, undefended
    // Metropolis vs the TrimmedMean(1) defense (defaults of `[chaos]`
    // byzantine_agent/byzantine_policy once an attacker is named).
    let mut byz_cfg = cfg.clone();
    byz_cfg.infer.iters = if fast { 500 } else { 1000 };
    byz_cfg.chaos.byzantine_agent = Some(0);
    byz_cfg.chaos.byzantine_policy = "sign-flip".to_string();
    let byz = run_byzantine(&byz_cfg, &mut |s| println!("{s}")).unwrap();
    println!("{}", byz.summary());
    derived.push((
        "chaos_byzantine_defense_recovers".to_string(),
        if byz.undefended_diverged() && byz.defense_gap <= 1e-3 { 1.0 } else { 0.0 },
    ));
    derived.push((
        "chaos_byzantine_replay_bitwise".to_string(),
        if byz.replay_bitwise { 1.0 } else { 0.0 },
    ));

    // Detection probe (PR 10 acceptance): f = 2 adjacent colluding
    // sign-flip attackers on the k = 2 ring, detection armed on top of
    // TrimmedMean(1). Honest judges between the colluders see both at
    // once, so masking alone leaks one of them into every combine;
    // detection excludes the pair and recovers.
    let mut det_cfg = cfg.clone();
    det_cfg.agents = if fast { 24 } else { 50 };
    det_cfg.ring_k = 2;
    det_cfg.infer.iters = if fast { 600 } else { 1000 };
    det_cfg.chaos.byzantine_agents = "5,6".to_string();
    det_cfg.chaos.byzantine_policy = "sign-flip".to_string();
    det_cfg.chaos.detect = true;
    let det = run_byzantine(&det_cfg, &mut |s| println!("{s}")).unwrap();
    println!("{}", det.summary());
    let colluders_out = det.flagged.contains(&5)
        && det.flagged.contains(&6)
        && det.excluded.contains(&5)
        && det.excluded.contains(&6);
    derived.push((
        "chaos_detection_excludes_colluders".to_string(),
        if colluders_out && det.detect_gap <= 1e-3 && det.detect_replay_bitwise {
            1.0
        } else {
            0.0
        },
    ));
    derived.push((
        "chaos_detection_zero_false_positives".to_string(),
        if det.detect_zero_fp { 1.0 } else { 0.0 },
    ));

    // Serve data-poisoning probe (`ddl serve --poison`): the robust
    // norm-outlier screen quarantines the corrupted samples before the
    // Eq. 51 update; the unscreened run's tail loss shows what they
    // would have done; a zero-poison stream is never quarantined and the
    // defended session replays bit-identically.
    let mut sp = ServeConfig {
        samples: if fast { 96 } else { 240 },
        rate: 0.0,
        ..ServeConfig::default()
    };
    sp.infer.iters = if fast { 30 } else { 60 };
    sp.mu_w = 0.08;
    sp.poison = true;
    sp.poison_frac = 0.2;
    let defended = ddl::serve::run_service(&sp, &mut |s| println!("{s}")).unwrap();
    let mut unscreened = sp.clone();
    unscreened.poison_screen = false;
    let undefended = ddl::serve::run_service(&unscreened, &mut |_| {}).unwrap();
    let mut zero = sp.clone();
    zero.poison_frac = 0.0;
    let zfp = ddl::serve::run_service(&zero, &mut |_| {}).unwrap();
    let replayed = ddl::serve::run_service(&sp, &mut |_| {}).unwrap();
    println!(
        "poison probe: defended quarantined {} (tail loss {:.3e}) vs unscreened {:.3e}; \
         zero-poison quarantined {}",
        defended.quarantined,
        defended.loss_last_quarter,
        undefended.loss_last_quarter,
        zfp.quarantined,
    );
    let poison_ok = defended.quarantined > 0
        && undefended.loss_last_quarter > 2.0 * defended.loss_last_quarter
        && zfp.quarantined == 0
        && replayed.quarantined == defended.quarantined
        && replayed.loss_last_quarter.to_bits() == defended.loss_last_quarter.to_bits();
    derived.push((
        "serve_poison_quarantine_recovers".to_string(),
        if poison_ok { 1.0 } else { 0.0 },
    ));

    // Cost of the fault-injected DES machinery itself: same shape as the
    // `async DES` row of bench_async, with a churn schedule active.
    let n = if fast { 40 } else { 100 };
    let m = if fast { 16 } else { 24 };
    let des_iters = if fast { 200 } else { 500 };
    let mut rng = Pcg64::new(0xC4A0);
    let dict = DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
    let graph = Graph::generate(n, &Topology::Ring { k: 2 }, &mut rng);
    let weights = metropolis_weights(&graph);
    let x = rng.normal_vec(m);
    let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
    let des_params = DiffusionParams::new(0.5, des_iters);
    let schedule = FaultSchedule::new(0xC4A0_55ED).with_edge_churn(&graph, 8, 2_000, 40_000, 7);
    let ap = AsyncParams::default()
        .with_tau(4)
        .with_delays(DelayDist::Exp { mean_us: 100.0 }, DelayDist::Exp { mean_us: 20.0 })
        .with_seed(0xC4_BE)
        .with_chaos(schedule);
    b.bench_work(
        &format!("chaos DES ring N={n} churn ({des_iters} iters)"),
        (n * des_iters) as f64,
        || {
            let mut net =
                AsyncNetwork::new(graph.clone(), weights.clone(), m, None, ap.clone()).unwrap();
            net.run(&dict, &task, &x, des_params).unwrap();
            std::hint::black_box(net.nu(0)[0]);
        },
    );

    ddl::bench::write_report(&b, "chaos", &derived);
}
