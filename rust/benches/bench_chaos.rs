//! Chaos-harness figures: the acceptance scenario of ISSUE 6 and the
//! tracked robustness numbers (methodology: EXPERIMENTS.md §Chaos).
//!
//! Scenario of record — **healing partition on the ring, N = 100**
//! (ring k = 2, exponential compute/link delays, no straggler): a
//! partition cutting 20% of the agents opens at 40% of the fault-free
//! horizon and heals after 20% of it. The chaos driver
//! ([`ddl::coordinator::run_chaos`]) runs the fault-free baseline, the
//! chaos run, a bitwise replay check, and an empty-schedule parity check
//! in one call; this bench re-exports its contract booleans as gated
//! indicators so the invariants stay visible in the tracked artifact.
//!
//! Derived figures written to `BENCH_chaos.json` (gated by
//! `ddl bench-gate` against `bench/baselines/BENCH_chaos.json`):
//!
//! * `chaos_empty_schedule_bitwise_parity` — **1.0** when a run with an
//!   empty-but-seeded `FaultSchedule` reproduces the fault-free
//!   trajectory bit-for-bit (clock, traffic, ν), else 0.0;
//! * `chaos_replay_bitwise` — **1.0** when a second run under the
//!   identical schedule reproduces the chaos run bit-for-bit;
//! * `chaos_partition_recovery_gap_ok` — **1.0** when
//!   `|MSD_chaos − MSD_clean|` at equal simulated time `t = T` (after
//!   the partition healed) is below 1e-3, the ISSUE 6 acceptance bar;
//! * `chaos_pushsum_vs_metropolis_bias_ratio` — converged-MSD ratio
//!   Metropolis/push-sum under a persistent directed outage
//!   (`run_pushsum_bias`): > 1 means the push-sum correction removes
//!   bias Metropolis keeps. Tracked as a ratio with the default gate
//!   slack (min-frac 0.5), not pinned — the exact magnitude depends on
//!   scenario scale.
//! * `chaos_byzantine_defense_recovers` — **1.0** when, under an f = 1
//!   sign-flip attacker on the ring, the `TrimmedMean(1)` defense lands
//!   within 1e-3 MSD of its own attack-free run while undefended
//!   Metropolis is biased > 10× (or diverges) — the ISSUE 8 acceptance
//!   bar ([`ddl::coordinator::run_byzantine`]);
//! * `chaos_byzantine_replay_bitwise` — **1.0** when both attacked runs
//!   replay bit-identically under the identical Byzantine schedule.
//!
//! Wall-clock cost of the fault-injected discrete-event core is timed as
//! `chaos DES ring (churn)` — agent-iterations/s with an 8-window churn
//! schedule active, comparable to the `async DES` row of
//! `BENCH_async.json` (the fault layer should cost ~nothing).
//!
//! Pass `--fast` (or `BENCH_FAST=1`) for the CI smoke configuration.

use ddl::bench::Bencher;
use ddl::config::experiment::AsyncConfig;
use ddl::coordinator::{run_byzantine, run_chaos, run_pushsum_bias};
use ddl::graph::{metropolis_weights, Graph, Topology};
use ddl::infer::DiffusionParams;
use ddl::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use ddl::net::{AsyncNetwork, AsyncParams, DelayDist, FaultSchedule};
use ddl::rng::Pcg64;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast")
        || std::env::var("BENCH_FAST").map(|v| v != "0").unwrap_or(false);
    let mut b = if fast { Bencher::quick() } else { Bencher::new() };
    let mut derived: Vec<(String, f64)> = Vec::new();

    // Scenario of record. The `[chaos]` defaults already encode the
    // acceptance partition (20% of agents, open at 40% of T for 20% of
    // T); `--fast` shrinks the network, not the scenario shape.
    let mut cfg = AsyncConfig {
        agents: if fast { 40 } else { 100 },
        dim: if fast { 16 } else { 24 },
        slow_agent: None, // isolate faults from the straggler study
        checkpoints: 6,
        ..AsyncConfig::default()
    };
    cfg.infer.iters = if fast { 800 } else { 1500 };
    cfg.chaos.enabled = true;
    let report = run_chaos(&cfg, &mut |s| println!("{s}")).unwrap();
    println!("{}", report.summary(cfg.agents));
    derived.push((
        "chaos_empty_schedule_bitwise_parity".to_string(),
        if report.empty_parity { 1.0 } else { 0.0 },
    ));
    derived.push((
        "chaos_replay_bitwise".to_string(),
        if report.replay_bitwise { 1.0 } else { 0.0 },
    ));
    derived.push((
        "chaos_partition_recovery_gap_ok".to_string(),
        if report.recovery_gap < 1e-3 { 1.0 } else { 0.0 },
    ));

    // Push-sum bias probe: persistent directed outage, converged MSD
    // under forced Metropolis vs forced push-sum on one scenario.
    let mut bias_cfg = cfg.clone();
    bias_cfg.agents = if fast { 30 } else { 60 };
    bias_cfg.infer.iters = if fast { 600 } else { 1200 };
    let probe = run_pushsum_bias(&bias_cfg, &mut |s| println!("{s}")).unwrap();
    println!(
        "bias probe: outage from {} µs cut {} links, metropolis {:.3e} vs push-sum {:.3e} \
         ({:.2}x)",
        probe.outage_from_us,
        probe.links_cut,
        probe.msd_metropolis,
        probe.msd_pushsum,
        probe.bias_ratio(),
    );
    derived.push(("chaos_pushsum_vs_metropolis_bias_ratio".to_string(), probe.bias_ratio()));

    // Byzantine probe: f = 1 sign-flip attacker on the ring, undefended
    // Metropolis vs the TrimmedMean(1) defense (defaults of `[chaos]`
    // byzantine_agent/byzantine_policy once an attacker is named).
    let mut byz_cfg = cfg.clone();
    byz_cfg.infer.iters = if fast { 500 } else { 1000 };
    byz_cfg.chaos.byzantine_agent = Some(0);
    byz_cfg.chaos.byzantine_policy = "sign-flip".to_string();
    let byz = run_byzantine(&byz_cfg, &mut |s| println!("{s}")).unwrap();
    println!("{}", byz.summary());
    derived.push((
        "chaos_byzantine_defense_recovers".to_string(),
        if byz.undefended_diverged() && byz.defense_gap <= 1e-3 { 1.0 } else { 0.0 },
    ));
    derived.push((
        "chaos_byzantine_replay_bitwise".to_string(),
        if byz.replay_bitwise { 1.0 } else { 0.0 },
    ));

    // Cost of the fault-injected DES machinery itself: same shape as the
    // `async DES` row of bench_async, with a churn schedule active.
    let n = if fast { 40 } else { 100 };
    let m = if fast { 16 } else { 24 };
    let des_iters = if fast { 200 } else { 500 };
    let mut rng = Pcg64::new(0xC4A0);
    let dict = DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
    let graph = Graph::generate(n, &Topology::Ring { k: 2 }, &mut rng);
    let weights = metropolis_weights(&graph);
    let x = rng.normal_vec(m);
    let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
    let des_params = DiffusionParams::new(0.5, des_iters);
    let schedule = FaultSchedule::new(0xC4A0_55ED).with_edge_churn(&graph, 8, 2_000, 40_000, 7);
    let ap = AsyncParams::default()
        .with_tau(4)
        .with_delays(DelayDist::Exp { mean_us: 100.0 }, DelayDist::Exp { mean_us: 20.0 })
        .with_seed(0xC4_BE)
        .with_chaos(schedule);
    b.bench_work(
        &format!("chaos DES ring N={n} churn ({des_iters} iters)"),
        (n * des_iters) as f64,
        || {
            let mut net =
                AsyncNetwork::new(graph.clone(), weights.clone(), m, None, ap.clone()).unwrap();
            net.run(&dict, &task, &x, des_params).unwrap();
            std::hint::black_box(net.nu(0)[0]);
        },
    );

    ddl::bench::write_report(&b, "chaos", &derived);
}
