//! Baseline solver costs: the centralized comparators' per-sample work,
//! for the efficiency discussion in EXPERIMENTS.md §Perf.

use ddl::baselines::{AdmmDictLearner, AdmmOptions, MairalLearner, MairalOptions};
use ddl::bench::Bencher;
use ddl::math::Mat;
use ddl::rng::Pcg64;

fn rand_dict(m: usize, k: usize, rng: &mut Pcg64, nonneg: bool) -> Mat {
    let mut w = Mat::from_fn(m, k, |_, _| if nonneg { rng.next_normal().abs() } else { rng.next_normal() });
    ddl::model::dictionary::normalize_columns(&mut w);
    w
}

fn main() {
    let mut b = Bencher::new();
    let mut rng = Pcg64::new(4);

    // Mairal at denoise scale (M=100, K=64) and novelty scale (M=800, K=40).
    for &(m, k, label) in &[
        (100usize, 64usize, "mairal step (100,64)"),
        (800, 40, "mairal step (800,40)"),
    ] {
        let w = rand_dict(m, k, &mut rng, false);
        let mut learner = MairalLearner::new(w, MairalOptions::denoising());
        let x = rng.normal_vec(m);
        b.bench(label, || {
            learner.step(&x).unwrap();
        });
        b.bench(&format!("{label} [code only]"), || {
            std::hint::black_box(learner.code(&x));
        });
    }

    // ADMM at novelty scale.
    {
        let (m, k) = (800usize, 40usize);
        let w = rand_dict(m, k, &mut rng, true);
        let learner = AdmmDictLearner::new(w, AdmmOptions::default());
        let mut x: Vec<f32> = rng.normal_vec(m).iter().map(|v| v.abs()).collect();
        let n1 = ddl::math::vector::norm1(&x);
        ddl::math::vector::scale(1.0 / n1, &mut x);
        b.bench("admm code (800,40), 35 iters", || {
            std::hint::black_box(learner.code(&x));
        });
        b.bench("admm objective (800,40)", || {
            std::hint::black_box(learner.objective(&x));
        });
    }

    // Exact dual solve (the CVX stand-in) at tuning scale.
    {
        let (m, k) = (400usize, 10usize);
        let mut rng2 = Pcg64::new(5);
        let dict = ddl::model::DistributedDictionary::random(
            m,
            k,
            k,
            ddl::model::AtomConstraint::NonNegUnitBall,
            &mut rng2,
        )
        .unwrap();
        let task = ddl::model::TaskSpec::HuberNmf { gamma: 1.0, delta: 0.1, eta: 0.2 };
        let x: Vec<f32> = rng2.normal_vec(m).iter().map(|v| v.abs() * 0.05).collect();
        b.bench("exact dual FISTA (400,10) huber", || {
            std::hint::black_box(
                ddl::infer::exact_dual(&dict, &task, &x, 1e-7, 5000).unwrap().iters,
            );
        });
    }

    b.write_csv(std::path::Path::new("results/bench_baselines.csv")).unwrap();
    println!("\nwrote results/bench_baselines.csv");
}
