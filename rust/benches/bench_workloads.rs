//! Workload suite: convergence-aware freeze/thaw and the diversified
//! streams (distribution shift, sensor-network field).
//!
//! Unlike the wall-clock benches, the headline figures here come off the
//! **deterministic virtual service clock** (adaptive mode), so they are
//! bit-reproducible across machines and can be gated tightly:
//!
//! * `workloads_frozen_throughput_ratio` — virtual throughput of an
//!   adaptive session whose detector freezes early (update slots released
//!   to pure inference) over the same session with the detector off.
//!   Must exceed 1.0: a frozen batch charges `service − update` µs;
//! * `workloads_freeze_replay_bitwise` — 1.0 iff two frozen sessions
//!   replay bit-identically (conv events, dictionary, virtual duration);
//! * `workloads_tol0_matches_baseline` — 1.0 iff a `tol = 0` session is
//!   bit-identical to the pre-detector behavior (inert by construction);
//! * `workloads_shift_thaws` — 1.0 iff the piecewise-stationary shift
//!   stream freezes before its boundary and thaws after it;
//! * `workloads_field_adaptation_gain` — first/last-quarter loss ratio on
//!   the spatially-correlated field stream (> 1: the dictionary learned
//!   the field's smooth modes while serving).
//!
//! Pass `--fast` (or `BENCH_FAST=1`) for the CI smoke configuration.

use ddl::bench::Bencher;
use ddl::config::experiment::{ControlConfig, InferenceConfig, ServeConfig};
use ddl::learn::ConvEvent;
use ddl::serve::run_service_with_dict;

const N: usize = 50;
const M: usize = 16;

fn adaptive_cfg(samples: usize, iters: usize) -> ServeConfig {
    ServeConfig {
        seed: 0x0BE7,
        agents: N,
        dim: M,
        topology: "ring".into(),
        ring_k: 2,
        batch: 8,
        max_wait_us: 2_000,
        samples,
        rate: 0.0,
        mu_w: 0.08,
        pipeline: false,
        infer: InferenceConfig { mu: 0.4, iters, gamma: 0.08, delta: 0.2, threads: 1 },
        control: ControlConfig {
            enabled: true,
            slo_p99_ms: 5.0,
            tick_us: 1_000,
            batch_min: 8,
            batch_max: 8,
            wait_min_us: 2_000,
            wait_max_us: 2_000,
            window: 64,
            svc_base_us: 200,
            svc_per_sample_us: 50,
            upd_per_sample_us: 30,
            ..ControlConfig::default()
        },
        ..ServeConfig::default()
    }
}

fn freeze_fast(cfg: &mut ServeConfig) {
    cfg.convergence.tol = 10.0;
    cfg.convergence.window = 2;
    cfg.convergence.max_no_improvement = 1;
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast")
        || std::env::var("BENCH_FAST").map(|v| v != "0").unwrap_or(false);
    let b = if fast { Bencher::quick() } else { Bencher::new() };
    let mut derived: Vec<(String, f64)> = Vec::new();

    let samples = if fast { 96 } else { 256 };
    let iters = if fast { 10 } else { 30 };

    // Frozen-phase throughput: virtual clock, so the ratio is exact.
    {
        let mut frozen_cfg = adaptive_cfg(samples, iters);
        freeze_fast(&mut frozen_cfg);
        let (frozen, d1) = run_service_with_dict(&frozen_cfg, &mut |_| {}).unwrap();
        let (frozen2, d2) = run_service_with_dict(&frozen_cfg, &mut |_| {}).unwrap();
        let baseline_cfg = adaptive_cfg(samples, iters); // tol = 0: detector off
        let (baseline, _) = run_service_with_dict(&baseline_cfg, &mut |_| {}).unwrap();
        println!(
            "frozen session: {} of {} batches frozen, {:.1} rps (virtual) vs baseline {:.1}",
            frozen.frozen_batches, frozen.batches, frozen.throughput_rps, baseline.throughput_rps
        );
        assert!(frozen.frozen_batches > 0, "detector must freeze under tol = 10");
        derived.push((
            "workloads_frozen_throughput_ratio".to_string(),
            frozen.throughput_rps / baseline.throughput_rps.max(1e-12),
        ));
        let replay_ok = frozen.conv_events == frozen2.conv_events
            && frozen.frozen_batches == frozen2.frozen_batches
            && frozen.duration_s.to_bits() == frozen2.duration_s.to_bits()
            && d1.mat().as_slice() == d2.mat().as_slice();
        derived.push((
            "workloads_freeze_replay_bitwise".to_string(),
            if replay_ok { 1.0 } else { 0.0 },
        ));
        derived.push((
            "workloads_tol0_matches_baseline".to_string(),
            if baseline.conv_events.is_empty() && baseline.frozen_batches == 0 {
                1.0
            } else {
                0.0
            },
        ));
    }

    // Distribution-shift stream: freeze on the first segment, thaw on the
    // post-shift loss jump.
    {
        let mut cfg = adaptive_cfg(samples.max(256), iters);
        cfg.stream = "shift".into();
        cfg.shift_count = 1;
        cfg.mu_w = 0.25;
        cfg.convergence.tol = 10.0;
        cfg.convergence.window = 4;
        cfg.convergence.max_no_improvement = 2;
        cfg.convergence.loss_window = 4;
        cfg.convergence.thaw_ratio = 1.25;
        let (report, _) = run_service_with_dict(&cfg, &mut |_| {}).unwrap();
        let froze = report.conv_events.iter().any(|e| matches!(e, ConvEvent::Freeze { .. }));
        let thawed = report.conv_events.iter().any(|e| matches!(e, ConvEvent::Thaw { .. }));
        println!(
            "shift session: froze = {froze}, thawed = {thawed}, {} frozen batches",
            report.frozen_batches
        );
        derived.push((
            "workloads_shift_thaws".to_string(),
            if froze && thawed { 1.0 } else { 0.0 },
        ));
    }

    // Field workload: spatially-correlated sensor snapshots; adaptation
    // gain is the first/last-quarter loss ratio.
    {
        let mut cfg = adaptive_cfg(samples, iters);
        cfg.stream = "field".into();
        let (report, _) = run_service_with_dict(&cfg, &mut |_| {}).unwrap();
        let gain = report.loss_first_quarter / report.loss_last_quarter.max(1e-12);
        println!(
            "field session: loss {:.4} -> {:.4} (gain {gain:.2}x)",
            report.loss_first_quarter, report.loss_last_quarter
        );
        derived.push(("workloads_field_adaptation_gain".to_string(), gain));
    }

    ddl::bench::write_report(&b, "workloads", &derived);
}
