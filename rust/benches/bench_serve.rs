//! Serving throughput: batched streaming path vs the sequential
//! one-sample-per-`run()` loop the repo used before the `serve/`
//! subsystem.
//!
//! Operating point (ISSUE/EXPERIMENTS §Serving): N = 100 agents on the 4-
//! connected grid, M = 100 (10×10 patches), one atom per agent, sparse-
//! coding task, online dictionary update after every minibatch (each
//! sample presented once, Alg. 1). Both paths do identical end-to-end
//! work per sample — inference, coefficient recovery, stats, Eq. 51
//! update — and produce identical per-sample trajectories (see
//! `tests/combine_parity.rs`); only the batching differs:
//!
//! * **seq**  — `OnlineTrainer::step` once per sample (`B = 1`);
//! * **batch8** — `OnlineTrainer::step` once per 8 samples
//!   (`DiffusionEngine::run_batch`, one combine + one worker-pool region
//!   amortized across the minibatch).
//!
//! Headline figures written to `BENCH_serve.json`:
//!
//! * `serve_throughput_speedup_b8_vs_seq_n100_grid` — batched vs
//!   sequential samples/s at the serving thread count (t = 2);
//! * `serve_throughput_speedup_b8_vs_seq_n100_grid_t1` — same at t = 1
//!   (pure adapt/combine amortization, no barrier effects).
//!
//! A full service-loop session (`serve::run_service`, saturated arrivals)
//! is also timed so queueing overhead shows up in the tracked numbers.
//!
//! **Pipelined serving** (PR 3): a second pair of full sessions compares
//! the serial single-server loop against the three-stage concurrent
//! pipeline (`--pipeline`, depth 2) at N = 100 on the ring, B = 8, t = 2 —
//! identical per-batch arithmetic (the parity tests prove bit-equality
//! against the reference executor), so the throughput ratio
//! `serve_throughput_speedup_pipelined_vs_serial_n100_ring_b8_t2` isolates
//! the overlap win: batch formation, inference, and the Eq. 51 update on
//! separate threads, with consecutive inference sweeps overlapping at
//! depth 2. The p99-latency ratio is tracked alongside it (direction-aware:
//! lower is better).
//!
//! Pass `--fast` (or `BENCH_FAST=1`) for the CI smoke configuration.

use ddl::bench::Bencher;
use ddl::config::experiment::{InferenceConfig, ServeConfig};
use ddl::graph::{metropolis_csr, Graph, Topology};
use ddl::infer::{DiffusionEngine, DiffusionParams};
use ddl::learn::{OnlineTrainer, TrainerOptions};
use ddl::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use ddl::ops::prox::DictProx;
use ddl::rng::Pcg64;

const N: usize = 100;
const M: usize = 100;

fn grid_engine() -> DiffusionEngine {
    let mut rng = Pcg64::new(7);
    let g = Graph::generate(N, &Topology::Grid, &mut rng);
    DiffusionEngine::new_csr(metropolis_csr(&g), M, None).unwrap()
}

/// Deterministic patch stream — the session's own workload definition
/// (`serve::generate_stream`), saturated arrivals, so the bench measures
/// exactly what the service serves.
fn stream(samples: usize, seed: u64) -> Vec<Vec<f32>> {
    let cfg =
        ServeConfig { agents: N, dim: M, samples, rate: 0.0, seed, ..ServeConfig::default() };
    let mut rng = Pcg64::new(seed);
    ddl::serve::generate_stream(&cfg, &mut rng)
        .unwrap()
        .into_iter()
        .map(|(_, x)| x)
        .collect()
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast")
        || std::env::var("BENCH_FAST").map(|v| v != "0").unwrap_or(false);
    let mut b = if fast { Bencher::quick() } else { Bencher::new() };
    let mut derived: Vec<(String, f64)> = Vec::new();

    let iters = if fast { 30 } else { 120 };
    let samples = if fast { 24 } else { 64 };
    let task = TaskSpec::SparseCoding { gamma: 0.08, delta: 0.2 };
    let mu_w = 0.05f32;
    let xs = stream(samples, 11);
    let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
    let mut rng = Pcg64::new(13);
    let dict0 =
        DistributedDictionary::random(M, N, N, AtomConstraint::UnitBall, &mut rng).unwrap();

    let mut medians: Vec<(String, f64)> = Vec::new();
    for &threads in &[1usize, 2] {
        let params = DiffusionParams::new(0.4, iters).with_threads(threads);
        for &(label, batch) in &[("seq", 1usize), ("batch8", 8usize)] {
            let mut trainer = OnlineTrainer::from_engine(
                grid_engine(),
                TrainerOptions { infer: params, prox: DictProx::None },
            );
            let name = format!("serve {label} t{threads} grid N={N} ({samples} samples)");
            let r = b.bench_work(&name, samples as f64, || {
                // Fresh dictionary per pass so every iteration does the
                // same work (adaptation drifts sparsity otherwise).
                let mut dict = dict0.clone();
                for chunk in refs.chunks(batch) {
                    trainer.step(&mut dict, &task, chunk, mu_w).unwrap();
                }
                std::hint::black_box(dict.mat().as_slice()[0]);
            });
            medians.push((format!("{label}_t{threads}"), r.median_s()));
        }
    }
    let med = |k: &str| medians.iter().find(|(n, _)| n == k).map(|(_, v)| *v).unwrap();
    derived.push((
        "serve_throughput_speedup_b8_vs_seq_n100_grid".to_string(),
        med("seq_t2") / med("batch8_t2").max(1e-12),
    ));
    derived.push((
        "serve_throughput_speedup_b8_vs_seq_n100_grid_t1".to_string(),
        med("seq_t1") / med("batch8_t1").max(1e-12),
    ));

    // Full service loop (queue + session + adaptation), saturated arrivals.
    {
        let base = ServeConfig::default();
        let cfg = ServeConfig {
            seed: 21,
            agents: N,
            dim: M,
            topology: "grid".into(),
            batch: 8,
            max_wait_us: 2_000,
            samples,
            rate: 0.0,
            mu_w,
            infer: InferenceConfig {
                mu: 0.4,
                iters,
                gamma: 0.08,
                delta: 0.2,
                threads: 2,
            },
            ..base
        };
        let report = ddl::serve::run_service(&cfg, &mut |_| {}).unwrap();
        println!(
            "service loop: {:.1} samples/s, p50 {:.2} ms, p99 {:.2} ms, loss {:.4} -> {:.4}",
            report.throughput_rps,
            report.latency_p50_ms,
            report.latency_p99_ms,
            report.loss_first_quarter,
            report.loss_last_quarter
        );
        derived.push(("serve_session_throughput_rps_b8_t2".to_string(), report.throughput_rps));
        derived.push(("serve_session_p99_latency_ms_b8_t2".to_string(), report.latency_p99_ms));
    }

    // Pipelined vs serial full sessions: N = 100 ring (k = 2), B = 8,
    // t = 2, saturated arrivals. Identical stream, dictionary, and
    // per-batch arithmetic — only the execution schedule differs. Each
    // session runs twice and the better throughput counts (single-shot
    // session timing is the noisiest figure in this file).
    {
        let svc_samples = if fast { 48 } else { 192 };
        let mk = |pipeline: bool, depth: usize| ServeConfig {
            seed: 29,
            agents: N,
            dim: M,
            topology: "ring".into(),
            ring_k: 2,
            batch: 8,
            max_wait_us: 2_000,
            samples: svc_samples,
            rate: 0.0,
            mu_w,
            pipeline,
            pipeline_depth: depth,
            infer: InferenceConfig { mu: 0.4, iters, gamma: 0.08, delta: 0.2, threads: 2 },
            ..ServeConfig::default()
        };
        let session = |cfg: &ServeConfig| {
            let a = ddl::serve::run_service(cfg, &mut |_| {}).unwrap();
            let b = ddl::serve::run_service(cfg, &mut |_| {}).unwrap();
            if a.throughput_rps >= b.throughput_rps {
                a
            } else {
                b
            }
        };
        let serial = session(&mk(false, 0));
        let pipe_d2 = session(&mk(true, 2));
        let pipe_d1 = session(&mk(true, 1));
        println!(
            "pipeline sessions (ring N={N}, B=8, t=2): serial {:.1} rps, depth-1 {:.1} rps, \
             depth-2 {:.1} rps",
            serial.throughput_rps, pipe_d1.throughput_rps, pipe_d2.throughput_rps
        );
        derived.push((
            "serve_throughput_speedup_pipelined_vs_serial_n100_ring_b8_t2".to_string(),
            pipe_d2.throughput_rps / serial.throughput_rps.max(1e-12),
        ));
        derived.push((
            "serve_throughput_speedup_pipelined_d1_vs_serial_n100_ring_b8_t2".to_string(),
            pipe_d1.throughput_rps / serial.throughput_rps.max(1e-12),
        ));
        derived.push((
            "serve_p99_latency_ratio_pipelined_vs_serial_n100_ring_b8_t2".to_string(),
            pipe_d2.latency_p99_ms / serial.latency_p99_ms.max(1e-12),
        ));
    }

    ddl::bench::write_report(&b, "serve", &derived);
}
