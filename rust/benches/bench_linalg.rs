//! Linalg roofline: gemm/gemv/dot at the experiment shapes.
//!
//! The combine step `V ← AᵀΨ` is `2·N²·M` flops per diffusion iteration —
//! the inference hot spot. This bench establishes the achievable GFLOP/s
//! for the gemm microkernel so `bench_inference` can report efficiency
//! against it (EXPERIMENTS.md §Perf).

use ddl::bench::Bencher;
use ddl::math::{blas, CsrMat, Mat};
use ddl::rng::Pcg64;

fn rand_mat(r: usize, c: usize, rng: &mut Pcg64) -> Mat {
    Mat::from_fn(r, c, |_, _| rng.next_normal())
}

fn main() {
    let mut b = Bencher::new();
    let mut rng = Pcg64::new(1);

    // Square gemm sweep (roofline trend).
    for &n in &[32usize, 64, 128, 256] {
        let a = rand_mat(n, n, &mut rng);
        let x = rand_mat(n, n, &mut rng);
        let mut c = Mat::zeros(n, n);
        let flops = 2.0 * (n as f64).powi(3);
        b.bench_work(&format!("gemm {n}x{n}x{n}"), flops, || {
            blas::gemm(n, n, n, 1.0, a.as_slice(), x.as_slice(), 0.0, c.as_mut_slice());
            std::hint::black_box(&c);
        });
    }

    // Experiment shapes: combine at denoise (N=64, M=100), denoise
    // paper-scale (N=196, M=100), novelty (N=80, M=800).
    for &(n, m, label) in &[
        (64usize, 100usize, "combine denoise (64,100)"),
        (196, 100, "combine paper (196,100)"),
        (80, 800, "combine novelty (80,800)"),
    ] {
        let at = rand_mat(n, n, &mut rng);
        let psi = rand_mat(n, m, &mut rng);
        let mut v = Mat::zeros(n, m);
        let flops = 2.0 * (n * n * m) as f64;
        b.bench_work(label, flops, || {
            blas::gemm(n, m, n, 1.0, at.as_slice(), psi.as_slice(), 0.0, v.as_mut_slice());
            std::hint::black_box(&v);
        });
    }

    // CSR spmm at combine shapes: degree-8 sparsity vs the dense gemm
    // above (the sparse-combine roofline; EXPERIMENTS.md §Perf).
    for &(n, m, label) in &[
        (196usize, 100usize, "spmm deg8 (196,100)"),
        (400, 100, "spmm deg8 (400,100)"),
    ] {
        let a = Mat::from_fn(n, n, |r, c| {
            let d = (r as i64 - c as i64).rem_euclid(n as i64);
            if d <= 4 || d >= n as i64 - 4 {
                0.11
            } else {
                0.0
            }
        });
        let at = CsrMat::from_dense_transposed(&a, 0.0);
        let psi = rand_mat(n, m, &mut rng);
        let mut v = Mat::zeros(n, m);
        let flops = 2.0 * (at.nnz() * m) as f64;
        b.bench_work(label, flops, || {
            at.spmm(psi.as_slice(), m, v.as_mut_slice());
            std::hint::black_box(&v);
        });
    }

    // gemv and dot at adapt-step shapes.
    let a = rand_mat(100, 100, &mut rng);
    let x: Vec<f32> = rng.normal_vec(100);
    let mut y = vec![0.0f32; 100];
    b.bench_work("gemv 100x100", 2.0 * 100.0 * 100.0, || {
        blas::gemv(100, 100, a.as_slice(), &x, &mut y);
        std::hint::black_box(&y);
    });
    let u: Vec<f32> = rng.normal_vec(800);
    let w: Vec<f32> = rng.normal_vec(800);
    b.bench_work("dot 800", 2.0 * 800.0, || {
        std::hint::black_box(blas::dot(&u, &w));
    });

    b.write_csv(std::path::Path::new("results/bench_linalg.csv")).unwrap();
    println!("\nwrote results/bench_linalg.csv");
}
