//! Inference hot path: combine-path (dense gemm vs CSR spmm) and
//! thread-scaling sweeps across topologies and network sizes, plus the
//! native-vs-AOT/PJRT and BSP comparisons.
//!
//! The sweep covers ring / grid / Erdős–Rényi topologies at
//! N ∈ {50, 100, 200, 400} (M = 100), timing the combine step in isolation
//! (CSR spmm vs the dense gemm the seed engine used) and the full `run()`
//! end-to-end at 1 and 4 worker threads. Headline figures are written to
//! `BENCH_inference.json` (tracked across PRs; see EXPERIMENTS.md §Perf):
//!
//! * `combine_speedup_csr_vs_dense_n200_deg8` — sparse-combine win at the
//!   degree-≈8, N = 200 operating point;
//! * `e2e_speedup_sparse_t4_vs_dense_t1_n200_deg8` — full-run win of the
//!   sparse 4-thread path over the single-threaded dense seed path.
//!
//! Pass `--fast` (or set `BENCH_FAST=1`) for the CI smoke configuration.

use ddl::bench::Bencher;
use ddl::graph::{metropolis_csr, metropolis_weights, Graph, Topology};
use ddl::infer::{DiffusionEngine, DiffusionParams};
use ddl::math::Mat;
use ddl::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use ddl::net::BspNetwork;
use ddl::rng::Pcg64;
#[cfg(feature = "xla")]
use ddl::runtime::exec::ParamPack;
#[cfg(feature = "xla")]
use ddl::runtime::Runtime;
#[cfg(feature = "xla")]
use std::path::Path;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast")
        || std::env::var("BENCH_FAST").map(|v| v != "0").unwrap_or(false);
    let mut b = if fast { Bencher::quick() } else { Bencher::new() };
    let mut rng = Pcg64::new(2);
    let mut derived: Vec<(String, f64)> = Vec::new();

    // --- native engine across paper experiment shapes ---
    for &(n, m, iters, label) in &[
        (64usize, 100usize, 200usize, "native denoise (64,100)x200"),
        (196, 100, 300, "native paper (196,100)x300"),
        (80, 800, 150, "native novelty (80,800)x150"),
    ] {
        let iters = if fast { iters / 10 } else { iters };
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.3 };
        let mut eng = DiffusionEngine::new(&a, m, None).unwrap();
        let flops = iters as f64 * (2.0 * (n * n * m) as f64 + 8.0 * (n * m) as f64);
        b.bench_work(label, flops, || {
            eng.reset();
            eng.run(&dict, &task, &x, DiffusionParams::new(0.1, iters)).unwrap();
            std::hint::black_box(eng.nu(0));
        });
    }

    // --- combine-step and end-to-end sweep over sparse topologies ---
    let ns: &[usize] = if fast { &[50, 100] } else { &[50, 100, 200, 400] };
    let m = 100usize;
    let iters = if fast { 20 } else { 100 };
    let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.3 };
    for &n in ns {
        let topologies: Vec<(&str, Topology)> = vec![
            // Degree ≈ 8 everywhere: ring with 4 neighbors a side, 4-conn
            // grid, and G(N, p) with expected degree 8.
            ("ring_k4", Topology::Ring { k: 4 }),
            ("grid", Topology::Grid),
            ("er_deg8", Topology::ErdosRenyi { p: (8.0 / (n as f64 - 1.0)).min(1.0) }),
        ];
        for (tname, topo) in topologies {
            let g = Graph::generate(n, &topo, &mut rng);
            let a = metropolis_weights(&g);
            let at_csr = metropolis_csr(&g);
            let at_dense = a.transpose();
            let psi = Mat::from_fn(n, m, |_, _| rng.next_normal());
            let mut v = Mat::zeros(n, m);
            let dense_flops = 2.0 * (n * n * m) as f64;
            let sparse_flops = 2.0 * (at_csr.nnz() * m) as f64;

            let dense_med = {
                let r = b.bench_work(&format!("combine dense {tname} N={n}"), dense_flops, || {
                    ddl::math::blas::gemm(
                        n,
                        m,
                        n,
                        1.0,
                        at_dense.as_slice(),
                        psi.as_slice(),
                        0.0,
                        v.as_mut_slice(),
                    );
                    std::hint::black_box(&v);
                });
                r.median_s()
            };
            let csr_med = {
                let r = b.bench_work(&format!("combine csr {tname} N={n}"), sparse_flops, || {
                    at_csr.spmm(psi.as_slice(), m, v.as_mut_slice());
                    std::hint::black_box(&v);
                });
                r.median_s()
            };
            if tname == "ring_k4" {
                derived.push((
                    format!("combine_speedup_csr_vs_dense_n{n}_deg8"),
                    dense_med / csr_med.max(1e-12),
                ));
            }

            // End-to-end run() on the degree-8 ring only (one topology is
            // enough for the trend; the combine micro covers the rest).
            if tname == "ring_k4" {
                let dict =
                    DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng)
                        .unwrap();
                let x = rng.normal_vec(m);
                let flops = iters as f64 * (2.0 * (n * n * m) as f64 + 8.0 * (n * m) as f64);

                // Seed path: dense gemm combine, single thread.
                let mut eng_dense = DiffusionEngine::new(&a, m, None).unwrap();
                eng_dense.set_combination_dense(&a).unwrap();
                let dense_run = {
                    let r = b.bench_work(
                        &format!("run dense t1 {tname} N={n}x{iters}"),
                        flops,
                        || {
                            eng_dense.reset();
                            eng_dense
                                .run(&dict, &task, &x, DiffusionParams::new(0.1, iters))
                                .unwrap();
                            std::hint::black_box(eng_dense.nu(0));
                        },
                    );
                    r.median_s()
                };

                // Sparse combine, single thread.
                let mut eng_sparse =
                    DiffusionEngine::new_csr(metropolis_csr(&g), m, None).unwrap();
                assert_eq!(eng_sparse.combine_path(), "sparse");
                let sparse_run = {
                    let r = b.bench_work(
                        &format!("run sparse t1 {tname} N={n}x{iters}"),
                        flops,
                        || {
                            eng_sparse.reset();
                            eng_sparse
                                .run(&dict, &task, &x, DiffusionParams::new(0.1, iters))
                                .unwrap();
                            std::hint::black_box(eng_sparse.nu(0));
                        },
                    );
                    r.median_s()
                };

                // Sparse combine, 4 worker threads.
                let sparse_t4_run = {
                    let r = b.bench_work(
                        &format!("run sparse t4 {tname} N={n}x{iters}"),
                        flops,
                        || {
                            eng_sparse.reset();
                            eng_sparse
                                .run(
                                    &dict,
                                    &task,
                                    &x,
                                    DiffusionParams::new(0.1, iters).with_threads(4),
                                )
                                .unwrap();
                            std::hint::black_box(eng_sparse.nu(0));
                        },
                    );
                    r.median_s()
                };

                derived.push((
                    format!("e2e_speedup_sparse_t1_vs_dense_t1_n{n}_deg8"),
                    dense_run / sparse_run.max(1e-12),
                ));
                derived.push((
                    format!("e2e_speedup_sparse_t4_vs_dense_t1_n{n}_deg8"),
                    dense_run / sparse_t4_run.max(1e-12),
                ));
            }
        }
    }

    // --- BSP message-passing executor (distribution overhead) ---
    {
        let (n, m, iters) = (64usize, 100usize, if fast { 20 } else { 200 });
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.3 };
        let flops = iters as f64 * (2.0 * (n * n * m) as f64 + 8.0 * (n * m) as f64);
        b.bench_work(&format!("bsp denoise (64,100)x{iters}"), flops, || {
            let mut net = BspNetwork::new(g.clone(), a.clone(), m, None);
            net.run(&dict, &task, &x, DiffusionParams::new(0.1, iters)).unwrap();
            std::hint::black_box(net.nu(0));
        });
    }

    // --- HLO/PJRT path at artifact shapes (feature `xla` only) ---
    #[cfg(feature = "xla")]
    match Runtime::new(Path::new("artifacts")) {
        Err(e) => println!("(skipping HLO benches: {e})"),
        Ok(rt) => {
            for name in ["denoise_infer", "novelty_sq_infer", "quickstart_infer"] {
                let Ok(infer) = rt.load_infer(name) else { continue };
                let (n, m) = (infer.info.n, infer.info.m);
                let iters = infer.info.iters.unwrap_or(1);
                let dict =
                    DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng)
                        .unwrap();
                let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
                let at = metropolis_weights(&g).transpose();
                let wt = dict.mat().transpose();
                let x = rng.normal_vec(m);
                let theta = vec![1.0 / n as f32; n];
                let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.3 };
                let pack = ParamPack::from_task(&task, n, 0.1);
                let flops = iters as f64 * (2.0 * (n * n * m) as f64 + 8.0 * (n * m) as f64);
                b.bench_work(&format!("hlo {name} ({n},{m})x{iters}"), flops, || {
                    let out = infer.run(&wt, &x, &at, &theta, pack).unwrap();
                    std::hint::black_box(out.y.len());
                });
            }
        }
    }

    ddl::bench::write_report(&b, "inference", &derived);
}
