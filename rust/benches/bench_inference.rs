//! Inference hot path: native diffusion vs the AOT/PJRT executable, per
//! paper experiment shape, plus the BSP message-passing executor for the
//! distribution-overhead view.
//!
//! Reported as time per full inference (all iterations) and per-iteration
//! effective GFLOP/s ≈ (2·N²·M + ~8·N·M) / t_iter. Compare against the
//! gemm roofline from `bench_linalg` (EXPERIMENTS.md §Perf).

use ddl::bench::Bencher;
use ddl::graph::{metropolis_weights, Graph, Topology};
use ddl::infer::{DiffusionEngine, DiffusionParams};
use ddl::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use ddl::net::BspNetwork;
use ddl::rng::Pcg64;
use ddl::runtime::exec::ParamPack;
use ddl::runtime::Runtime;
use std::path::Path;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Pcg64::new(2);

    // --- native engine across experiment shapes ---
    for &(n, m, iters, label) in &[
        (64usize, 100usize, 200usize, "native denoise (64,100)x200"),
        (196, 100, 300, "native paper (196,100)x300"),
        (80, 800, 150, "native novelty (80,800)x150"),
    ] {
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.3 };
        let mut eng = DiffusionEngine::new(&a, m, None).unwrap();
        let flops = iters as f64 * (2.0 * (n * n * m) as f64 + 8.0 * (n * m) as f64);
        b.bench_work(label, flops, || {
            eng.reset();
            eng.run(&dict, &task, &x, DiffusionParams { mu: 0.1, iters }).unwrap();
            std::hint::black_box(eng.nu(0));
        });
    }

    // --- BSP message-passing executor (distribution overhead) ---
    {
        let (n, m, iters) = (64usize, 100usize, 200usize);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.3 };
        let flops = iters as f64 * (2.0 * (n * n * m) as f64 + 8.0 * (n * m) as f64);
        b.bench_work("bsp denoise (64,100)x200", flops, || {
            let mut net = BspNetwork::new(g.clone(), a.clone(), m, None);
            net.run(&dict, &task, &x, DiffusionParams { mu: 0.1, iters }).unwrap();
            std::hint::black_box(net.nu(0));
        });
    }

    // --- HLO/PJRT path at artifact shapes ---
    match Runtime::new(Path::new("artifacts")) {
        Err(e) => println!("(skipping HLO benches: {e})"),
        Ok(rt) => {
            for name in ["denoise_infer", "novelty_sq_infer", "quickstart_infer"] {
                let Ok(infer) = rt.load_infer(name) else { continue };
                let (n, m) = (infer.info.n, infer.info.m);
                let iters = infer.info.iters.unwrap_or(1);
                let dict =
                    DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng)
                        .unwrap();
                let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
                let at = metropolis_weights(&g).transpose();
                let wt = dict.mat().transpose();
                let x = rng.normal_vec(m);
                let theta = vec![1.0 / n as f32; n];
                let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.3 };
                let pack = ParamPack::from_task(&task, n, 0.1);
                let flops = iters as f64 * (2.0 * (n * n * m) as f64 + 8.0 * (n * m) as f64);
                b.bench_work(&format!("hlo {name} ({n},{m})x{iters}"), flops, || {
                    let out = infer.run(&wt, &x, &at, &theta, pack).unwrap();
                    std::hint::black_box(out.y.len());
                });
            }
        }
    }

    b.write_csv(Path::new("results/bench_inference.csv")).unwrap();
    println!("\nwrote results/bench_inference.csv");
}
