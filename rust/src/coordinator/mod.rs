//! Experiment coordinators: full pipelines behind the paper's figures.
//!
//! * [`denoise`] — Fig. 5: train a model-distributed dictionary on natural
//!   scene patches, denoise a corrupted image, compare to centralized [6];
//! * [`novelty`] — Figs. 6–7 / Tables III–IV: streaming novel-document
//!   detection with dictionary/network expansion per time-step;
//! * [`straggler`] — `ddl async`: sync-vs-async diffusion under a delay
//!   model (MSD vs simulated time, straggler scenarios), plus the
//!   adaptive-τ driver (`--adaptive-tau`: the τ controller stepped
//!   against a τ = 0 probe through shared sim-time epochs);
//! * [`chaos`] — `ddl chaos`: deterministic fault injection over the async
//!   executor (healing partitions, Gilbert–Elliott bursty links, crashes,
//!   drops, Byzantine corruption) with MSD-vs-sim-time sensitivity
//!   curves, replay/parity checks, and the `--byzantine` attack/defense
//!   probe;
//! * [`field`] — `ddl field`: sensor-network field-monitoring scenario —
//!   the streaming service over a spatially-correlated field workload,
//!   reporting spatial structure and adaptation gain (and, with
//!   `[convergence]` enabled, the frozen-mode share of the stream);
//! * [`csv`] — tiny CSV writer for `results/`.

pub mod chaos;
pub mod csv;
pub mod denoise;
pub mod field;
pub mod novelty;
#[cfg(feature = "xla")]
pub mod quickstart;
pub mod straggler;
pub mod tuning;

pub use chaos::{
    run_byzantine, run_chaos, run_pushsum_bias, ByzantineReport, ChaosReport, ChaosRow,
    PushSumBias,
};
pub use denoise::{run_denoise, DenoiseReport};
pub use field::{run_field, FieldReport};
pub use novelty::{run_novelty, NoveltyAlgo, NoveltyReport, StepResult};
pub use straggler::{
    run_adaptive_tau, run_straggler, AdaptiveTauReport, AsyncRow, StragglerReport, TauRow,
};
