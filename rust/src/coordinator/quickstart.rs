//! Quickstart: a tiny end-to-end pass over the full three-layer stack.
//!
//! Loads the `quickstart_infer` artifact (L1 Pallas kernels fused by the
//! L2 graph, AOT-lowered to HLO), runs it via PJRT, cross-checks against
//! the native engine, and performs one dictionary update — everything a
//! user needs to verify their installation.

use crate::error::Result;
use crate::graph::{metropolis_weights, Graph, Topology};
use crate::infer::{DiffusionEngine, DiffusionParams};
use crate::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use crate::rng::Pcg64;
use crate::runtime::exec::ParamPack;
use crate::runtime::Runtime;
use std::path::Path;

/// Run the quickstart; `log` receives progress lines.
pub fn run_quickstart(artifacts: &Path, log: &mut dyn FnMut(&str)) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    log(&format!("PJRT platform: {}", rt.platform()));
    let infer = rt.load_infer("quickstart_infer")?;
    let (n, m) = (infer.info.n, infer.info.m);
    let iters = infer.info.iters.unwrap_or(60);
    log(&format!("artifact quickstart_infer: N={n} agents, M={m}, {iters} iterations"));

    // Problem setup.
    let mut rng = Pcg64::new(0xDD1);
    let dict = DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng)?;
    let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
    let a = metropolis_weights(&g);
    let x = rng.normal_vec(m);
    let task = TaskSpec::SparseCoding { gamma: 0.3, delta: 0.4 };
    let mu = 0.25;

    // HLO path.
    let theta = vec![1.0 / n as f32; n];
    let out = infer.run(
        &dict.mat().transpose(),
        &x,
        &a.transpose(),
        &theta,
        ParamPack::from_task(&task, n, mu),
    )?;
    log("HLO inference done");

    // Native cross-check.
    let mut eng = DiffusionEngine::new(&a, m, None)?;
    eng.run(&dict, &task, &x, DiffusionParams::new(mu, iters))?;
    let y_native = eng.recover_y(&dict, &task);
    let max_diff = out
        .y
        .iter()
        .zip(&y_native)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    log(&format!("HLO vs native max |Δy| = {max_diff:.2e}"));
    if max_diff > 1e-3 {
        return Err(crate::DdlError::Runtime(format!(
            "HLO/native mismatch: {max_diff}"
        )));
    }

    // One dictionary update through the update artifact.
    let update = rt.load_update("denoise_update");
    match update {
        Ok(u) if u.info.n == n && u.info.m == m => {
            let wt2 = u.run(&dict.mat().transpose(), eng.nu(0), &y_native, 1e-3)?;
            log(&format!(
                "dictionary update artifact applied (‖ΔWt‖ = {:.2e})",
                wt2.sub(&dict.mat().transpose())?.frob_norm()
            ));
        }
        _ => log("(denoise_update artifact has different shapes; skipping update demo)"),
    }
    log("quickstart OK — all three layers compose");
    Ok(())
}
