//! Straggler experiment driver (`ddl async`): sync-vs-async diffusion on
//! the same problem, same delay model, same simulated clock.
//!
//! The comparison is the one EXPERIMENTS.md §Async prescribes:
//!
//! 1. build one problem (topology, dictionary, sample) and one delay
//!    scenario from [`AsyncConfig`];
//! 2. run the **sync comparator** — the async executor at `τ = 0`, which
//!    is bit-for-bit the BSP trajectory with the same delay model pricing
//!    its barriers — to completion, yielding `T_sync`;
//! 3. run the **async executor** (`τ` from the config) on fresh state,
//!    stepping both through shared simulated-time checkpoints up to
//!    `T_sync` and recording MSD against the exact dual ν°
//!    ([`crate::infer::exact_dual`]) at each checkpoint.
//!
//! The headline numbers: the MSD gap at equal simulated time (acceptance:
//! within 1e-3 for the one-10×-slow-agent ring), the wall-clock speedup to
//! equal iterations, and the ψ-traffic [`MessageStats`] of both runs.

use crate::config::experiment::AsyncConfig;
use crate::error::{DdlError, Result};
use crate::graph::{metropolis_weights, Graph, Topology};
use crate::infer::{exact_dual, DiffusionParams};
use crate::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use crate::net::{AsyncNetwork, AsyncParams, MessageStats, TauController, TauDecision};
use crate::obs::{ArgValue, Track};
use crate::rng::Pcg64;

/// One simulated-time checkpoint of the sync-vs-async comparison.
#[derive(Clone, Debug)]
pub struct AsyncRow {
    /// Checkpoint on the simulated clock (µs).
    pub t_us: u64,
    /// Sync (τ = 0) MSD vs the exact dual at this time.
    pub msd_sync: f64,
    /// Async (τ from config) MSD vs the exact dual at this time.
    pub msd_async: f64,
    /// Completed network-wide waves, sync executor.
    pub sync_min_iters: usize,
    /// Completed network-wide waves, async executor.
    pub async_min_iters: usize,
    /// Mean per-agent completed iterations, async executor.
    pub async_mean_iters: f64,
}

/// Outcome of one straggler experiment.
#[derive(Clone, Debug)]
pub struct StragglerReport {
    pub rows: Vec<AsyncRow>,
    /// Simulated completion time of the sync comparator.
    pub sync_time_us: u64,
    /// Simulated completion time of the async executor (its own full run).
    pub async_time_us: u64,
    /// |MSD_async − MSD_sync| at `t = sync_time_us` (equal simulated time).
    pub msd_gap: f64,
    /// `sync_time_us / async_time_us`: wall-clock speedup to equal
    /// iteration counts from relaxing the barrier.
    pub time_speedup: f64,
    pub sync_stats: MessageStats,
    pub async_stats: MessageStats,
    /// Largest staleness any async combine actually used (≤ τ).
    pub max_staleness: usize,
}

impl StragglerReport {
    /// Multi-line human-readable summary (the `ddl async` output body).
    pub fn summary(&self, agents: usize) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:>12} {:>12} {:>12} {:>10} {:>10} {:>10}\n",
            "sim time s", "msd sync", "msd async", "waves sync", "waves asyn", "mean iters"
        ));
        for r in &self.rows {
            out.push_str(&format!(
                "{:>12.4} {:>12.3e} {:>12.3e} {:>10} {:>10} {:>10.1}\n",
                r.t_us as f64 / 1e6,
                r.msd_sync,
                r.msd_async,
                r.sync_min_iters,
                r.async_min_iters,
                r.async_mean_iters,
            ));
        }
        out.push_str(&format!(
            "msd gap at equal simulated time: {:.3e}\n\
             completion: sync {:.4} s, async {:.4} s (speedup {:.2}x), max staleness used {}\n\
             traffic sync:  {} msgs, {:.2} MB, {} rounds, {:.1} B/agent/round\n\
             traffic async: {} msgs, {:.2} MB, {} rounds, {:.1} B/agent/round",
            self.msd_gap,
            self.sync_time_us as f64 / 1e6,
            self.async_time_us as f64 / 1e6,
            self.time_speedup,
            self.max_staleness,
            self.sync_stats.messages,
            self.sync_stats.bytes as f64 / 1e6,
            self.sync_stats.rounds,
            self.sync_stats.bytes_per_agent_round(agents),
            self.async_stats.messages,
            self.async_stats.bytes as f64 / 1e6,
            self.async_stats.rounds,
            self.async_stats.bytes_per_agent_round(agents),
        ));
        out
    }
}

/// Build the experiment topology named by the config (shared with the
/// chaos driver, which studies the identical problem instance).
pub(crate) fn build_topology(cfg: &AsyncConfig, rng: &mut Pcg64) -> Result<Graph> {
    let topo = match cfg.topology.as_str() {
        "ring" => Topology::Ring { k: cfg.ring_k.max(1) },
        "grid" => Topology::Grid,
        "er" | "erdos" => Topology::ErdosRenyi { p: cfg.edge_prob },
        "full" => Topology::FullyConnected,
        other => {
            return Err(DdlError::Config(format!(
                "async: unknown topology '{other}' (ring|grid|er|full)"
            )))
        }
    };
    Ok(Graph::generate(cfg.agents, &topo, rng))
}

/// Run the sync-vs-async straggler comparison; `log` receives progress
/// lines. See the module docs for the protocol.
pub fn run_straggler(
    cfg: &AsyncConfig,
    log: &mut dyn FnMut(&str),
) -> Result<StragglerReport> {
    let mut rng = Pcg64::new(cfg.seed);
    let graph = build_topology(cfg, &mut rng)?;
    let weights = metropolis_weights(&graph);
    let dict = DistributedDictionary::random(
        cfg.dim,
        cfg.agents,
        cfg.agents,
        AtomConstraint::UnitBall,
        &mut rng,
    )?;
    let x = rng.normal_vec(cfg.dim);
    let task = TaskSpec::SparseCoding { gamma: cfg.infer.gamma, delta: cfg.infer.delta };
    let params = DiffusionParams::new(cfg.infer.mu, cfg.infer.iters);
    let async_params = cfg.async_params()?;
    let sync_params = AsyncParams { tau: 0, ..async_params.clone() };

    log(&format!(
        "async: N={} M={} topology={} ({} directed edges), iters={}, tau={}, \
         compute {} ~{}us{}, link {} ~{}us",
        cfg.agents,
        cfg.dim,
        cfg.topology,
        2 * graph.edge_count(),
        cfg.infer.iters,
        cfg.tau,
        cfg.compute_dist,
        cfg.compute_us,
        match cfg.slow_agent {
            Some(k) => format!(", agent {k} {:.0}x slow", cfg.slow_factor),
            None => String::new(),
        },
        cfg.link_dist,
        cfg.link_us,
    ));

    // Ground truth for MSD.
    let exact = exact_dual(&dict, &task, &x, 1e-6, 20_000)?;
    log(&format!(
        "exact dual: {} FISTA iters, grad norm {:.2e}",
        exact.iters, exact.grad_norm
    ));

    // One full sync run pins the time axis (T_sync); the checkpointed
    // instances below then replay/resume — same seeds, identical
    // trajectories, so nothing is simulated twice on the async side.
    let mut sync_full =
        AsyncNetwork::new(graph.clone(), weights.clone(), cfg.dim, None, sync_params.clone())?;
    sync_full.run(&dict, &task, &x, params)?;
    let sync_time_us = sync_full.sim_time_us();

    let mut sync_net =
        AsyncNetwork::new(graph.clone(), weights.clone(), cfg.dim, None, sync_params)?;
    let mut async_net = AsyncNetwork::new(graph, weights, cfg.dim, None, async_params)?;
    // Trace only the async instance (the figure of interest); the sync
    // comparator and the time-pinning run stay untraced. The parity test
    // holds the traced ≡ untraced contract, so attaching here cannot
    // change any number in the report.
    let obs = crate::obs::handle_for(&cfg.obs);
    async_net.attach_obs(obs.clone());
    let checkpoints = cfg.checkpoints.max(1);
    let mut rows = Vec::with_capacity(checkpoints);
    for c in 1..=checkpoints {
        let t_us = (sync_time_us as u128 * c as u128 / checkpoints as u128) as u64;
        sync_net.run_clamped(&dict, &task, &x, params, t_us)?;
        async_net.run_clamped(&dict, &task, &x, params, t_us)?;
        rows.push(AsyncRow {
            t_us,
            msd_sync: sync_net.msd_vs(&exact.nu),
            msd_async: async_net.msd_vs(&exact.nu),
            sync_min_iters: sync_net.min_iters_done(),
            async_min_iters: async_net.min_iters_done(),
            async_mean_iters: async_net.mean_iters_done(),
        });
    }
    let last = rows.last().expect("checkpoints >= 1");
    let msd_gap = (last.msd_async - last.msd_sync).abs();
    // Resume the async instance to completion for its own clock/traffic
    // figures (run_clamped resumes exactly; no second simulation needed).
    async_net.run(&dict, &task, &x, params)?;
    let async_time_us = async_net.sim_time_us();
    if let Some(n) = crate::obs::export(&cfg.obs, &obs)? {
        log(&format!(
            "trace: wrote {n} events to {}",
            cfg.obs.trace_path.as_deref().unwrap_or("?")
        ));
    }

    Ok(StragglerReport {
        rows,
        sync_time_us,
        async_time_us,
        msd_gap,
        time_speedup: sync_time_us as f64 / (async_time_us as f64).max(1.0),
        sync_stats: sync_full.stats(),
        async_stats: async_net.stats(),
        max_staleness: async_net.max_staleness_observed(),
    })
}

/// One control epoch of the adaptive-τ run.
#[derive(Clone, Debug)]
pub struct TauRow {
    /// Epoch boundary on the simulated clock (µs).
    pub t_us: u64,
    /// τ in effect *during* the epoch.
    pub tau: usize,
    /// Gate-wait fraction of the epoch (per agent).
    pub gate_wait_frac: f64,
    /// Adaptive executor's MSD vs the exact dual at the boundary.
    pub msd_adaptive: f64,
    /// τ = 0 probe's MSD at the same boundary.
    pub msd_probe: f64,
    /// Completed network-wide waves of the adaptive executor.
    pub adaptive_min_iters: usize,
}

/// Outcome of one adaptive-τ run (`ddl async --adaptive-tau`).
#[derive(Clone, Debug)]
pub struct AdaptiveTauReport {
    pub rows: Vec<TauRow>,
    /// The controller's decision trace (one entry per epoch; the
    /// replay-determinism test compares it bitwise).
    ///
    /// Deprecated alias: the same decisions now also flow into the trace
    /// subsystem as `tau_decision` instants on the `tau` controller lane
    /// (`ddl async --adaptive-tau --trace`, see [`crate::obs`]). The
    /// field stays for one release; prefer the trace events.
    pub trace: Vec<TauDecision>,
    /// Simulated completion time of the adaptive executor.
    pub completion_us: u64,
    /// τ in effect when the run completed.
    pub final_tau: usize,
    /// Largest staleness any combine used (≤ the widest τ in effect).
    pub max_staleness: usize,
    pub stats: MessageStats,
}

impl AdaptiveTauReport {
    /// First epoch boundary at which the adaptive run's MSD reached
    /// `target` (the time-to-target figure `bench_control.rs` compares
    /// against the static-τ grid, on the same epoch granularity).
    pub fn time_to_msd(&self, target: f64) -> Option<u64> {
        self.rows.iter().find(|r| r.msd_adaptive <= target).map(|r| r.t_us)
    }

    /// Multi-line human-readable summary (the `ddl async --adaptive-tau`
    /// output body).
    pub fn summary(&self, agents: usize) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:>12} {:>5} {:>10} {:>12} {:>12} {:>10}\n",
            "sim time s", "tau", "gate frac", "msd adapt", "msd probe", "waves"
        ));
        for r in &self.rows {
            out.push_str(&format!(
                "{:>12.4} {:>5} {:>10.3} {:>12.3e} {:>12.3e} {:>10}\n",
                r.t_us as f64 / 1e6,
                r.tau,
                r.gate_wait_frac,
                r.msd_adaptive,
                r.msd_probe,
                r.adaptive_min_iters,
            ));
        }
        out.push_str(&format!(
            "completed in {:.4} s at final tau {}, max staleness used {}\n\
             traffic: {} msgs, {:.2} MB, {} rounds, {:.1} B/agent/round",
            self.completion_us as f64 / 1e6,
            self.final_tau,
            self.max_staleness,
            self.stats.messages,
            self.stats.bytes as f64 / 1e6,
            self.stats.rounds,
            self.stats.bytes_per_agent_round(agents),
        ));
        out
    }
}

/// Run the adaptive-τ experiment: the τ controller steps the adaptive
/// executor and a τ = 0 probe through shared simulated-time epochs
/// (`[control] tau_epoch_us`), widening τ when gate-wait dominates the
/// epoch and narrowing it when the adaptive MSD drifts behind the
/// probe's. Problem setup consumes the RNG in the same order as
/// [`run_straggler`], so both drivers study the identical instance.
/// Deterministic end to end: two runs with the same config replay
/// bit-identically (trace, rows, clocks — `tests/control_adaptive.rs`).
pub fn run_adaptive_tau(
    cfg: &AsyncConfig,
    log: &mut dyn FnMut(&str),
) -> Result<AdaptiveTauReport> {
    let mut rng = Pcg64::new(cfg.seed);
    let graph = build_topology(cfg, &mut rng)?;
    let weights = metropolis_weights(&graph);
    let dict = DistributedDictionary::random(
        cfg.dim,
        cfg.agents,
        cfg.agents,
        AtomConstraint::UnitBall,
        &mut rng,
    )?;
    let x = rng.normal_vec(cfg.dim);
    let task = TaskSpec::SparseCoding { gamma: cfg.infer.gamma, delta: cfg.infer.delta };
    let params = DiffusionParams::new(cfg.infer.mu, cfg.infer.iters);
    let base = cfg.async_params()?;

    let mut controller = TauController::new(&cfg.control);
    let tau0 = controller.initial_tau(cfg.tau);
    let mut adaptive = AsyncNetwork::new(
        graph.clone(),
        weights.clone(),
        cfg.dim,
        None,
        AsyncParams { tau: tau0, ..base.clone() },
    )?;
    let mut probe =
        AsyncNetwork::new(graph, weights, cfg.dim, None, AsyncParams { tau: 0, ..base })?;
    // Trace the adaptive executor only (the probe is a comparator).
    let obs = crate::obs::handle_for(&cfg.obs);
    adaptive.attach_obs(obs.clone());

    log(&format!(
        "adaptive-tau: N={} M={} topology={}, iters={}, tau0={} in [{}, {}], epoch {} µs{}",
        cfg.agents,
        cfg.dim,
        cfg.topology,
        cfg.infer.iters,
        tau0,
        cfg.control.tau_min,
        cfg.control.tau_max,
        cfg.control.tau_epoch_us,
        if cfg.drift_period_us > 0 {
            format!(", drifting straggler every {} µs", cfg.drift_period_us)
        } else {
            String::new()
        },
    ));

    let exact = exact_dual(&dict, &task, &x, 1e-6, 20_000)?;
    let epoch_us = cfg.control.tau_epoch_us.max(1);
    let mut rows = Vec::new();
    let mut tau = tau0;
    let mut t = epoch_us;
    loop {
        let done = adaptive.run_clamped(&dict, &task, &x, params, t)?;
        probe.run_clamped(&dict, &task, &x, params, t)?;
        let msd_adaptive = adaptive.msd_vs(&exact.nu);
        let msd_probe = probe.msd_vs(&exact.nu);
        // gate_wait_us_at includes the in-progress waits of still-gated
        // agents, so an epoch spent entirely blocked (no combine landed)
        // still shows its full wait to the controller.
        let next_tau = controller.decide(
            t,
            cfg.agents,
            adaptive.gate_wait_us_at(t),
            msd_adaptive,
            msd_probe,
            tau,
        );
        let decided = controller.trace().last().expect("decide() just pushed");
        if obs.enabled() {
            // The controller's epoch decision as a trace instant — the
            // same payload [`TauDecision`] carries.
            obs.instant(
                t,
                "tau_decision",
                Track::Controller("tau"),
                vec![
                    ("tau", ArgValue::U(next_tau as u64)),
                    ("prev", ArgValue::U(tau as u64)),
                    ("gate_wait_frac", ArgValue::F(decided.gate_wait_frac)),
                    ("msd_drift", ArgValue::F(decided.msd_drift)),
                    ("partition", ArgValue::B(decided.partition)),
                ],
            );
        }
        rows.push(TauRow {
            t_us: t,
            tau,
            gate_wait_frac: decided.gate_wait_frac,
            msd_adaptive,
            msd_probe,
            adaptive_min_iters: adaptive.min_iters_done(),
        });
        if rows.len() % 16 == 0 {
            log(&format!(
                "  [{:>8.3} s] tau {} -> {}, msd {:.3e} (probe {:.3e})",
                t as f64 / 1e6,
                tau,
                next_tau,
                msd_adaptive,
                msd_probe
            ));
        }
        if done {
            break;
        }
        if next_tau != tau {
            adaptive.set_tau(next_tau, &task, t);
            tau = next_tau;
        }
        t += epoch_us;
    }
    if let Some(n) = crate::obs::export(&cfg.obs, &obs)? {
        log(&format!(
            "trace: wrote {n} events to {}",
            cfg.obs.trace_path.as_deref().unwrap_or("?")
        ));
    }

    Ok(AdaptiveTauReport {
        rows,
        completion_us: adaptive.sim_time_us(),
        final_tau: tau,
        max_staleness: adaptive.max_staleness_observed(),
        stats: adaptive.stats(),
        trace: controller.into_trace(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> AsyncConfig {
        AsyncConfig {
            agents: 12,
            dim: 8,
            ring_k: 1,
            tau: 2,
            compute_us: 50,
            link_us: 10,
            infer: crate::config::experiment::InferenceConfig {
                mu: 0.3,
                iters: 60,
                gamma: 0.1,
                delta: 0.5,
                threads: 1,
            },
            checkpoints: 3,
            ..AsyncConfig::default()
        }
    }

    #[test]
    fn straggler_report_is_consistent() {
        let cfg = tiny_cfg();
        let mut lines = Vec::new();
        let r = run_straggler(&cfg, &mut |s| lines.push(s.to_string())).unwrap();
        assert_eq!(r.rows.len(), 3);
        // Checkpoints are monotone in time and the last sits at T_sync.
        assert!(r.rows.windows(2).all(|w| w[0].t_us <= w[1].t_us));
        assert_eq!(r.rows.last().unwrap().t_us, r.sync_time_us);
        // At T_sync the sync executor has finished all its waves.
        assert_eq!(r.rows.last().unwrap().sync_min_iters, cfg.infer.iters);
        // MSD decreases toward the exact dual over the run.
        assert!(r.rows.last().unwrap().msd_sync < r.rows[0].msd_sync);
        assert!(r.max_staleness <= cfg.tau);
        assert!(r.sync_stats.messages > 0 && r.async_stats.messages > 0);
        assert!(r.time_speedup > 0.0);
        assert!(!r.summary(cfg.agents).is_empty());
        assert!(!lines.is_empty());
    }

    #[test]
    fn homogeneous_zero_delay_gap_is_zero() {
        // With zero delays and τ = 0 both executors are the same BSP
        // trajectory: the gap must be exactly zero.
        let cfg = AsyncConfig {
            tau: 0,
            compute_dist: "zero".into(),
            link_dist: "zero".into(),
            slow_agent: None,
            ..tiny_cfg()
        };
        let r = run_straggler(&cfg, &mut |_| {}).unwrap();
        assert_eq!(r.msd_gap, 0.0);
        assert_eq!(r.sync_time_us, 0);
    }

    #[test]
    fn unknown_topology_rejected() {
        let cfg = AsyncConfig { topology: "torus".into(), ..tiny_cfg() };
        assert!(run_straggler(&cfg, &mut |_| {}).is_err());
    }

    fn adaptive_cfg() -> AsyncConfig {
        let mut cfg = tiny_cfg();
        cfg.control.adaptive_tau = true;
        cfg.control.tau_min = 0;
        cfg.control.tau_max = 6;
        cfg.control.tau_epoch_us = 2_000;
        cfg.tau = 0; // start at the barrier; the controller must widen
        cfg
    }

    #[test]
    fn adaptive_tau_report_is_consistent() {
        let cfg = adaptive_cfg();
        let mut lines = Vec::new();
        let r = run_adaptive_tau(&cfg, &mut |s| lines.push(s.to_string())).unwrap();
        assert!(!r.rows.is_empty());
        assert_eq!(r.rows.len(), r.trace.len());
        // Epoch boundaries are monotone; τ stays inside the bounds and
        // moves by at most 1 per epoch.
        assert!(r.rows.windows(2).all(|w| w[0].t_us < w[1].t_us));
        for w in r.rows.windows(2) {
            let (a, b) = (w[0].tau as i64, w[1].tau as i64);
            assert!((a - b).abs() <= 1, "tau moved by more than 1: {a} -> {b}");
        }
        assert!(r.rows.iter().all(|row| row.tau <= cfg.control.tau_max));
        assert!(r.final_tau <= cfg.control.tau_max);
        assert!(r.max_staleness <= cfg.control.tau_max);
        // The 10x straggler at τ = 0 forces gate waits: the controller
        // must have widened off the barrier at some point.
        assert!(r.rows.iter().any(|row| row.tau > 0), "controller never widened");
        assert!(r.completion_us > 0);
        assert!(r.stats.messages > 0);
        // time_to_msd is monotone-consistent with the rows.
        let loose = r.time_to_msd(f64::MAX).unwrap();
        assert_eq!(loose, r.rows[0].t_us);
        assert_eq!(r.time_to_msd(-1.0), None);
        assert!(!r.summary(cfg.agents).is_empty());
        assert!(!lines.is_empty());
    }

    /// Two adaptive-τ runs with one config replay bit-identically:
    /// decision traces, epoch rows, and clocks.
    #[test]
    fn adaptive_tau_replays_bitwise() {
        let cfg = adaptive_cfg();
        let a = run_adaptive_tau(&cfg, &mut |_| {}).unwrap();
        let b = run_adaptive_tau(&cfg, &mut |_| {}).unwrap();
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.completion_us, b.completion_us);
        assert_eq!(a.final_tau, b.final_tau);
        assert_eq!(a.stats, b.stats);
        assert_eq!(a.rows.len(), b.rows.len());
        for (ra, rb) in a.rows.iter().zip(&b.rows) {
            assert_eq!(ra.t_us, rb.t_us);
            assert_eq!(ra.tau, rb.tau);
            assert_eq!(ra.msd_adaptive.to_bits(), rb.msd_adaptive.to_bits());
            assert_eq!(ra.msd_probe.to_bits(), rb.msd_probe.to_bits());
        }
    }

    /// Pinned bounds (`tau_min == tau_max`) reduce the adaptive driver to
    /// a static-τ run on the same epoch grid — the comparator
    /// `bench_control.rs` sweeps.
    #[test]
    fn pinned_bounds_hold_tau_static() {
        let mut cfg = adaptive_cfg();
        cfg.control.tau_min = 2;
        cfg.control.tau_max = 2;
        cfg.tau = 0; // clamped up to 2 by initial_tau
        let r = run_adaptive_tau(&cfg, &mut |_| {}).unwrap();
        assert!(r.rows.iter().all(|row| row.tau == 2));
        assert_eq!(r.final_tau, 2);
    }
}
