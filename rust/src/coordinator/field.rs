//! `ddl field` — sensor-network field-monitoring scenario.
//!
//! The original motivation for diffusion dictionary learning is a sensor
//! network compressing observations of a shared physical field
//! (arXiv:1304.3568-style). This coordinator runs the streaming service
//! over the spatially-correlated [`crate::data::FieldModel`] workload:
//! each request is one network-wide snapshot (`M` = sensor count), and
//! the agents cooperatively learn the field's smooth spatial modes while
//! serving.
//!
//! Beyond the ordinary serve report it measures two workload-specific
//! figures:
//!
//! * **spatial structure** — mean Pearson correlation of near vs far
//!   sensor pairs in the stream itself (sanity: the workload actually is
//!   spatially correlated);
//! * **adaptation gain** — first-quarter over last-quarter mean batch
//!   loss; > 1 means the dictionary learned the field's modes while
//!   serving.
//!
//! With `[convergence] tol > 0` the session freezes adaptation once the
//! dictionary stops drifting, so the report also shows how much of the
//! stream was served in the cheaper frozen mode.

use crate::config::experiment::ServeConfig;
use crate::data::field::{spatial_correlation, FieldModel};
use crate::rng::Pcg64;
use crate::serve::ServeReport;
use crate::Result;

/// Everything `ddl field` prints: the underlying serve report plus the
/// field-specific figures.
#[derive(Clone, Debug)]
pub struct FieldReport {
    /// The streaming-service report for the field workload.
    pub serve: ServeReport,
    /// Mean Pearson correlation over sensor pairs closer than the median
    /// pair distance (probe stream, same generator parameters).
    pub near_corr: f64,
    /// Mean Pearson correlation over sensor pairs farther than the median
    /// pair distance.
    pub far_corr: f64,
    /// First-quarter over last-quarter mean batch loss; > 1 means the
    /// dictionary adapted to the field while serving.
    pub adaptation_gain: f64,
}

impl FieldReport {
    /// Human-readable block appended to the serve summary.
    pub fn summary(&self, agents: usize) -> String {
        format!(
            "{}\nfield: near-pair corr {:.3} vs far-pair {:.3}, adaptation gain {:.2}x",
            self.serve.summary(agents),
            self.near_corr,
            self.far_corr,
            self.adaptation_gain,
        )
    }
}

/// Probe-stream sample count for the spatial-correlation figures: enough
/// for stable Pearson estimates, small enough to stay off the critical
/// path.
const CORR_PROBE_SAMPLES: usize = 200;

/// Run the field-monitoring scenario: force the `field` stream, serve it,
/// and report spatial structure + adaptation gain alongside the ordinary
/// serve figures.
pub fn run_field(cfg: &ServeConfig, log: &mut dyn FnMut(&str)) -> Result<FieldReport> {
    let mut cfg = cfg.clone();
    cfg.stream = "field".to_string();
    log(&format!(
        "field: {} sensors, {} sources, width {:.3}, noise σ {:.3}",
        cfg.dim, cfg.field_sources, cfg.field_width, cfg.field_noise
    ));
    let serve = crate::serve::run_service(&cfg, log)?;
    // Spatial-structure probe on an independent stream with the same
    // generator parameters (offset by a fixed lane so it never aliases the
    // served stream's draws).
    let model = FieldModel::new(cfg.dim, cfg.field_sources, cfg.field_width, cfg.field_noise);
    let mut rng = Pcg64::new(cfg.seed ^ 0xF1E1D);
    let near_corr = spatial_correlation(&model, &mut rng, CORR_PROBE_SAMPLES, true);
    let mut rng = Pcg64::new(cfg.seed ^ 0xF1E1D);
    let far_corr = spatial_correlation(&model, &mut rng, CORR_PROBE_SAMPLES, false);
    let (first, last) = (serve.loss_first_quarter, serve.loss_last_quarter);
    let adaptation_gain = if last > 0.0 { first / last } else { 1.0 };
    Ok(FieldReport { serve, near_corr, far_corr, adaptation_gain })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::experiment::ServeConfig;

    fn field_cfg() -> ServeConfig {
        let mut cfg = ServeConfig::default();
        cfg.samples = 96;
        cfg.batch = 8;
        cfg.agents = 8;
        cfg.dim = 16;
        cfg.pipeline = false;
        cfg
    }

    #[test]
    fn field_scenario_reports_spatial_structure_and_gain() {
        let cfg = field_cfg();
        let report = run_field(&cfg, &mut |_| {}).expect("field run");
        assert_eq!(report.serve.samples, 96);
        assert!(
            report.near_corr > report.far_corr,
            "near {:.3} should exceed far {:.3}",
            report.near_corr,
            report.far_corr
        );
        assert!(report.adaptation_gain.is_finite() && report.adaptation_gain > 0.0);
        assert!(report.summary(cfg.agents).contains("field: near-pair corr"));
    }

    #[test]
    fn field_scenario_replays_bitwise() {
        let cfg = field_cfg();
        let a = run_field(&cfg, &mut |_| {}).expect("run a");
        let b = run_field(&cfg, &mut |_| {}).expect("run b");
        assert_eq!(a.serve.loss_first_quarter.to_bits(), b.serve.loss_first_quarter.to_bits());
        assert_eq!(a.serve.loss_last_quarter.to_bits(), b.serve.loss_last_quarter.to_bits());
        assert_eq!(a.serve.stats, b.serve.stats, "ψ traffic must replay");
        assert_eq!(a.near_corr.to_bits(), b.near_corr.to_bits());
        assert_eq!(a.adaptation_gain.to_bits(), b.adaptation_gain.to_bits());
    }

    #[test]
    fn field_forces_stream_kind() {
        // Even a config pointing at another stream serves the field
        // workload under this coordinator.
        let mut cfg = field_cfg();
        cfg.stream = "planted".to_string();
        let forced = run_field(&cfg, &mut |_| {}).expect("forced run");
        cfg.stream = "field".to_string();
        let native = run_field(&cfg, &mut |_| {}).expect("native run");
        assert_eq!(
            forced.serve.loss_first_quarter.to_bits(),
            native.serve.loss_first_quarter.to_bits(),
        );
        assert_eq!(
            forced.serve.loss_last_quarter.to_bits(),
            native.serve.loss_last_quarter.to_bits(),
        );
    }
}
