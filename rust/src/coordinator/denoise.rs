//! Image-denoising pipeline (paper §IV-B, Fig. 5).
//!
//! 1. Train the model-distributed dictionary online on DC-removed patches
//!    from synthetic natural scenes (Alg. 2), in minibatches of 4 with
//!    gradient averaging (footnote 4);
//! 2. Corrupt a held-out scene with σ = 50 AWGN (14.1 dB);
//! 3. Denoise: for every sliding patch, infer the dual ν° and reconstruct
//!    `z° = x − ν°` (Table II), add the DC back, overlap-add;
//! 4. Score PSNR — optionally per agent (Fig. 5g), where each agent
//!    reconstructs from its **own** dual iterate.
//!
//! The centralized comparator [6] trains on the same patch stream and
//! denoises with its own elastic-net coding.

use crate::baselines::{MairalLearner, MairalOptions};
use crate::config::experiment::DenoiseConfig;
use crate::data::{add_awgn, synth_scene, Image, PatchSampler, Reconstructor};
use crate::error::Result;
use crate::graph::{metropolis_weights, Graph, Topology};
use crate::infer::{DiffusionEngine, DiffusionParams};
use crate::learn::{OnlineTrainer, TrainerOptions};
use crate::math::Mat;
use crate::metrics::psnr;
use crate::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use crate::ops::prox::DictProx;
use crate::rng::Pcg64;

/// Results of a full denoising run.
#[derive(Clone, Debug)]
pub struct DenoiseReport {
    pub psnr_noisy: f64,
    /// Distributed method, consensus reconstruction.
    pub psnr_distributed: f64,
    /// Centralized [6] comparator (None if skipped).
    pub psnr_centralized: Option<f64>,
    /// Per-agent PSNR (Fig. 5g), when requested.
    pub per_agent_psnr: Vec<f64>,
    /// Final training loss (diagnostics).
    pub final_train_loss: f64,
    /// The learned dictionary (for atom visualization).
    pub dictionary: Mat,
    /// Images for optional PGM export: (clean, noisy, denoised).
    pub images: (Image, Image, Image),
}

/// Run the experiment. `informed`: `None` = all agents see the data;
/// `Some(k)` = only the first `k` agents do (Fig. 5e/f uses `Some(1)`).
/// `with_baseline` additionally trains and scores the centralized [6]
/// learner. `per_agent` computes the Fig. 5g per-agent PSNR sweep.
pub fn run_denoise(
    cfg: &DenoiseConfig,
    with_baseline: bool,
    per_agent: bool,
    mut progress: impl FnMut(&str),
) -> Result<DenoiseReport> {
    let mut rng = Pcg64::new(cfg.seed);
    let m = cfg.patch * cfg.patch;
    let n = cfg.agents;
    let task = TaskSpec::SparseCoding {
        gamma: cfg.train_infer.gamma,
        delta: cfg.train_infer.delta,
    };

    // --- data ---
    let train_images: Vec<Image> =
        (0..6).map(|_| synth_scene(cfg.image_side, &mut rng)).collect();
    // Reject near-flat training patches: at γ = 45 they code to y = 0 and
    // contribute no dictionary gradient (Eq. 51 with y° = 0).
    let mut sampler =
        PatchSampler::new(train_images, cfg.patch, rng.next_u64()).with_min_std(35.0);
    let clean = synth_scene(cfg.image_side, &mut rng);
    let noisy = add_awgn(&clean, cfg.noise_sigma, &mut rng);
    let psnr_noisy = psnr(&clean.pixels, &noisy.pixels, 255.0);
    progress(&format!("corrupted image PSNR: {psnr_noisy:.2} dB"));

    // --- network ---
    let g = Graph::generate(n, &Topology::ErdosRenyi { p: cfg.edge_prob }, &mut rng);
    let a = metropolis_weights(&g);
    let informed_idx: Option<Vec<usize>> = cfg.informed.map(|k| (0..k).collect());

    // --- distributed training (Alg. 2) ---
    let mut dict =
        DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng)?;
    let mut trainer = OnlineTrainer::new(
        &a,
        m,
        informed_idx.as_deref(),
        TrainerOptions {
            infer: DiffusionParams::new(cfg.train_infer.mu, cfg.train_infer.iters)
                .with_threads(cfg.train_infer.threads),
            prox: DictProx::None,
        },
    )?;
    let steps = cfg.train_samples / cfg.minibatch.max(1);
    let mut final_loss = 0.0;
    let mut baseline = with_baseline.then(|| {
        MairalLearner::new(
            dict.mat().clone(),
            MairalOptions {
                gamma: cfg.train_infer.gamma,
                delta: cfg.train_infer.delta,
                ..MairalOptions::denoising()
            },
        )
    });

    for step in 0..steps {
        let batch: Vec<(Vec<f32>, f32)> = (0..cfg.minibatch).map(|_| sampler.sample()).collect();
        let refs: Vec<&[f32]> = batch.iter().map(|(p, _)| p.as_slice()).collect();
        let stats = trainer.step(&mut dict, &task, &refs, cfg.mu_w)?;
        final_loss = stats.mean_loss;
        if let Some(b) = baseline.as_mut() {
            for (p, _) in &batch {
                b.step(p)?;
            }
        }
        if step % (steps / 10).max(1) == 0 {
            progress(&format!(
                "train step {step}/{steps}: loss {:.1}, sparsity {:.2}, disagreement {:.2e}",
                stats.mean_loss, stats.mean_sparsity, stats.mean_disagreement
            ));
        }
    }

    // --- denoising pass ---
    progress("denoising with the distributed dictionary...");
    let infer = DiffusionParams::new(cfg.denoise_infer.mu, cfg.denoise_infer.iters)
        .with_threads(cfg.denoise_infer.threads);
    let mut engine = DiffusionEngine::new(&a, m, informed_idx.as_deref())?;
    engine.reserve_atoms(dict.k());
    let corners =
        Reconstructor::corners(noisy.width, noisy.height, cfg.patch, cfg.denoise_stride);
    let mut rec = Reconstructor::new(noisy.width, noisy.height, cfg.patch);
    let mut per_agent_rec: Vec<Reconstructor> = if per_agent {
        (0..n).map(|_| Reconstructor::new(noisy.width, noisy.height, cfg.patch)).collect()
    } else {
        Vec::new()
    };
    let mut patch = vec![0.0f32; m];
    // Reused across patches — the streaming denoise loop allocates only for
    // per-agent reconstruction (`consensus_nu_into` is allocation-free).
    let mut nu = vec![0.0f32; m];
    let mut z = vec![0.0f32; m];
    for &(r, c) in &corners {
        crate::data::patches::extract_patch(&noisy, r, c, cfg.patch, &mut patch);
        let dc = crate::math::vector::mean(&patch);
        for v in &mut patch {
            *v -= dc;
        }
        engine.reset();
        engine.run(&dict, &task, &patch, infer)?;
        // z° = x − ν° (Table II, squared-ℓ2 residual), DC restored.
        engine.consensus_nu_into(&mut nu);
        for ((zi, &x), &v) in z.iter_mut().zip(&patch).zip(&nu) {
            *zi = x - v + dc;
        }
        rec.add_patch(r, c, &z);
        if per_agent {
            for (k, prec) in per_agent_rec.iter_mut().enumerate() {
                let nu_k = engine.nu(k);
                let zk: Vec<f32> =
                    patch.iter().zip(nu_k).map(|(&x, &v)| x - v + dc).collect();
                prec.add_patch(r, c, &zk);
            }
        }
    }
    let denoised = rec.finish(&noisy);
    let psnr_distributed = psnr(&clean.pixels, &denoised.pixels, 255.0);
    progress(&format!("distributed PSNR: {psnr_distributed:.2} dB"));

    let per_agent_psnr: Vec<f64> = per_agent_rec
        .into_iter()
        .map(|prec| psnr(&clean.pixels, &prec.finish(&noisy).pixels, 255.0))
        .collect();

    // --- centralized comparator ---
    let psnr_centralized = match baseline {
        None => None,
        Some(b) => {
            progress("denoising with the centralized [6] dictionary...");
            let mut rec = Reconstructor::new(noisy.width, noisy.height, cfg.patch);
            for &(r, c) in &corners {
                crate::data::patches::extract_patch(&noisy, r, c, cfg.patch, &mut patch);
                let dc = crate::math::vector::mean(&patch);
                for v in &mut patch {
                    *v -= dc;
                }
                let y = b.code(&patch);
                let wy = b.w.matvec(&y)?;
                let z: Vec<f32> = wy.iter().map(|&v| v + dc).collect();
                rec.add_patch(r, c, &z);
            }
            let img = rec.finish(&noisy);
            let p = psnr(&clean.pixels, &img.pixels, 255.0);
            progress(&format!("centralized PSNR: {p:.2} dB"));
            Some(p)
        }
    };

    Ok(DenoiseReport {
        psnr_noisy,
        psnr_distributed,
        psnr_centralized,
        per_agent_psnr,
        final_train_loss: final_loss,
        dictionary: dict.mat().clone(),
        images: (clean, noisy, denoised),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::experiment::InferenceConfig;

    /// Miniature end-to-end smoke: the full pipeline runs and denoising
    /// improves over the corrupted image.
    #[test]
    fn mini_denoise_improves_psnr() {
        let cfg = DenoiseConfig {
            seed: 3,
            agents: 16,
            patch: 6,
            train_samples: 240,
            minibatch: 4,
            mu_w: 2e-4,
            train_infer: InferenceConfig { mu: 0.5, iters: 60, gamma: 30.0, delta: 0.1, threads: 1 },
            denoise_infer: InferenceConfig { mu: 0.8, iters: 80, gamma: 30.0, delta: 0.1, threads: 2 },
            image_side: 48,
            noise_sigma: 50.0,
            denoise_stride: 3,
            informed: None,
            edge_prob: 0.5,
        };
        let report = run_denoise(&cfg, false, false, |_| {}).unwrap();
        assert!(
            report.psnr_distributed > report.psnr_noisy + 1.0,
            "denoise {:.2} dB should beat noisy {:.2} dB",
            report.psnr_distributed,
            report.psnr_noisy
        );
    }
}
