//! Minimal CSV writer for `results/`.

use std::io::Write;
use std::path::Path;

/// Write rows of f64 columns with a header line.
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<f64>]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        let cells: Vec<String> = row.iter().map(|v| format!("{v:.6}")).collect();
        writeln!(f, "{}", cells.join(","))?;
    }
    Ok(())
}

/// Write labeled rows (first column is a string label).
pub fn write_labeled_csv(
    path: &Path,
    header: &[&str],
    rows: &[(String, Vec<f64>)],
) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", header.join(","))?;
    for (label, row) in rows {
        let cells: Vec<String> = row.iter().map(|v| format!("{v:.6}")).collect();
        writeln!(f, "{label},{}", cells.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_rows() {
        let p = std::env::temp_dir().join("ddl_csv_test.csv");
        write_csv(&p, &["a", "b"], &[vec![1.0, 2.0], vec![3.0, 4.5]]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("a,b\n1.000000,2.000000\n"));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn writes_labeled_rows() {
        let p = std::env::temp_dir().join("ddl_csv_label_test.csv");
        write_labeled_csv(&p, &["algo", "auc"], &[("diffusion".into(), vec![0.93])]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("diffusion,0.930000"));
        std::fs::remove_file(&p).ok();
    }
}
