//! Step-size tuning procedure (§IV-A, Fig. 4).
//!
//! For a chosen μ and iteration budget: compute the exact `(y°, ν°)` with
//! the FISTA solver (the CVX stand-in), run the distributed diffusion, and
//! record per-iteration SNR of both the primal `y_i` (Eq. 54) and the dual
//! `ν_{k,i}` against the exact solutions. The chosen μ must drive both
//! curves to an acceptable SNR (40–50 dB in the paper's example) within
//! the iteration budget.

use crate::config::experiment::NoveltyConfig;
use crate::data::{CorpusConfig, CorpusStream};
use crate::error::Result;
use crate::graph::{metropolis_weights, Graph, Topology};
use crate::infer::{exact_dual, DiffusionParams};
use crate::math::Mat;
use crate::metrics::snr_db;
use crate::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use crate::rng::Pcg64;

/// One point on the Fig. 4 learning curves.
#[derive(Clone, Copy, Debug)]
pub struct TuningPoint {
    pub iter: usize,
    /// `10·log10(‖y°‖²/‖y_i − y°‖²)` at agent-local recovery (Eq. 54).
    pub y_snr_db: f64,
    /// `10·log10(‖ν°‖²/‖ν_{k,i} − ν°‖²)` at a fixed probe agent.
    pub nu_snr_db: f64,
}

/// Reproduce the Fig. 4 setup: the Huber novelty configuration on one
/// corpus sample, measuring SNR trajectories for the given μ.
pub fn tuning_curves(mu: f32, iters: usize, seed: u64) -> Result<Vec<TuningPoint>> {
    let cfg = NoveltyConfig::huber();
    let mut rng = Pcg64::new(seed);
    let task = TaskSpec::HuberNmf { gamma: cfg.gamma, delta: cfg.delta, eta: 0.2 };

    // One document from the corpus.
    let schedule = CorpusStream::huber_schedule(cfg.topics, cfg.time_steps);
    let mut corpus = CorpusStream::new(
        CorpusConfig { vocab: 400, topics: cfg.topics, seed, ..Default::default() },
        schedule,
    );
    let mut docs = corpus.batch(0, 2 * 10 + 12);
    // Probe sample: a fresh document whose topic one of the atoms covers.
    let atom_topics: Vec<usize> = docs.iter().take(10).map(|d| d.topic).collect();
    let pos = (10..docs.len())
        .find(|&i| atom_topics.contains(&docs[i].topic))
        .expect("corpus cycles topics, so a matching probe doc exists");
    let doc = docs.swap_remove(pos);
    let m = doc.features.len();

    // Dictionary at the initial scale (10 atoms/agents), *warm-started*
    // from corpus documents — the paper's Fig. 4 probes the tuned system
    // mid-training, where atoms already correlate with the data (a cold
    // random dictionary would make the primal degenerately zero under
    // γ = 1). Each agent's atom is a (feasible) normalized document.
    let n = 10; // paper: 10 initial atoms/agents
    let mut dict =
        DistributedDictionary::random(m, n, n, AtomConstraint::NonNegUnitBall, &mut rng)?;
    for (k, d) in docs.iter().take(n).enumerate() {
        let mut atom = d.features.clone();
        crate::math::vector::normalize(&mut atom);
        dict.mat_mut().set_col(k, &atom);
    }
    let g = Graph::generate(n, &Topology::ErdosRenyi { p: cfg.edge_prob }, &mut rng);
    let a = metropolis_weights(&g);

    // Scale the sample so the elastic-net correlations sit a few γ above
    // threshold (the trained-system operating point).
    let mut x = doc.features;
    let s = dict.mat().matvec_t(&x)?;
    let smax = s.iter().fold(0.0f32, |m, &v| m.max(v));
    if smax > 0.0 {
        crate::math::vector::scale(3.0 * task.gamma() / smax, &mut x);
    }

    // Ground truth from the exact solver.
    let exact = exact_dual(&dict, &task, &x, 1e-9, 50_000)?;

    curves_against_exact(&dict, &task, &x, &a, mu, iters, &exact.nu, &exact.y)
}

/// SNR trajectories of diffusion against a supplied exact solution,
/// probing agent 0 (any agent works after convergence).
pub fn curves_against_exact(
    dict: &DistributedDictionary,
    task: &TaskSpec,
    x: &[f32],
    a: &Mat,
    mu: f32,
    iters: usize,
    nu_exact: &[f32],
    y_exact: &[f32],
) -> Result<Vec<TuningPoint>> {
    let m = dict.m();
    let mut engine = crate::infer::DiffusionEngine::new(a, m, None)?;
    engine.reserve_atoms(dict.k());
    let mut points = Vec::with_capacity(iters);
    for it in 1..=iters {
        engine.run(dict, task, x, DiffusionParams::new(mu, 1))?;
        let y_i = engine.recover_y(dict, task);
        points.push(TuningPoint {
            iter: it,
            y_snr_db: snr_db(y_exact, &y_i),
            nu_snr_db: snr_db(nu_exact, engine.nu(0)),
        });
    }
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snr_curves_increase_with_iterations() {
        // μ = 0.3 converges smoothly for every seed; μ = 0.5 can sit at
        // the edge of a period-2 oscillation on some problem draws (the
        // exact behaviour Fig. 4's tuning procedure is designed to spot).
        let pts = tuning_curves(0.3, 600, 3).unwrap();
        assert_eq!(pts.len(), 600);
        let early = pts[9].nu_snr_db;
        let late = pts[599].nu_snr_db;
        assert!(late > early, "dual SNR should improve: {early} → {late}");
        // Both curves clearly positive at the plateau (max over the tail
        // tolerates residual oscillation).
        let y_tail = pts[590..].iter().map(|p| p.y_snr_db).fold(f64::MIN, f64::max);
        assert!(y_tail > 10.0, "y SNR tail {y_tail}");
    }
}
