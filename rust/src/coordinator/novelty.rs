//! Novel-document-detection pipeline (paper §IV-C, Figs. 6–7,
//! Tables III–IV).
//!
//! Streaming protocol: an initialization batch trains the starting
//! dictionary; then at every time-step `s` the incoming batch is scored
//! for novelty (ROC/AUC against ground-truth novel topics), becomes the
//! new training set (single epoch), and the dictionary + network grow by
//! `atoms_per_step` atoms/agents.

use crate::baselines::{AdmmDictLearner, AdmmOptions, MairalLearner, MairalOptions};
use crate::config::experiment::{NoveltyConfig, ResidualKind};
use crate::data::{CorpusConfig, CorpusStream, Document};
use crate::error::Result;
use crate::graph::{metropolis_weights, uniform_weights, Graph, Topology};
use crate::infer::{scalar_consensus_threaded, DiffusionEngine, DiffusionParams};
use crate::learn::StepSchedule;
use crate::math::Mat;
use crate::metrics::{auc, roc_curve, RocPoint};
use crate::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use crate::rng::Pcg64;


/// Seed dictionary columns `start..` from (normalized) documents — the
/// unit-ball-feasible equivalent of the paper's *unnormalized* random
/// non-negative initialization, whose large scale is what bootstraps
/// coding at γ ≥ 1 (a cold unit-norm random atom never crosses the
/// threshold and Eq. 51 then has zero gradient). Standard NMF practice.
fn seed_atoms_into(
    w: &mut Mat,
    start: usize,
    seeds: &[&Document],
    rng: &mut Pcg64,
) {
    if seeds.is_empty() {
        return;
    }
    let k = w.cols();
    for q in start..k {
        let d = seeds[rng.next_below(seeds.len() as u64) as usize];
        let mut atom = d.features.clone();
        crate::math::vector::normalize(&mut atom);
        w.set_col(q, &atom);
    }
}

/// Re-impose the ADMM learner's atom constraint (`‖w‖₁ ≤ 1, w ⪰ 0`) on
/// columns `start..` after document seeding.
fn l1_feasible_columns(w: &mut Mat, start: usize) {
    let k = w.cols();
    let mut col = vec![0.0f32; w.rows()];
    for q in start..k {
        w.col_into(q, &mut col);
        for v in &mut col {
            *v = v.max(0.0);
        }
        crate::ops::project_l1_ball(&mut col, 1.0);
        w.set_col(q, &col);
    }
}

/// Algorithms compared in the novelty experiments.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NoveltyAlgo {
    /// Sparsely-connected diffusion (random `G(N, p)`, Metropolis).
    Diffusion,
    /// Fully-connected diffusion (`A = 11ᵀ/N`, larger μ, fewer iters).
    DiffusionFullyConnected,
    /// Centralized online dictionary learning [6] (sq-Euclid experiment).
    CentralizedMairal,
    /// Centralized ADMM ℓ1 learner [11] (Huber experiment).
    CentralizedAdmm,
}

impl NoveltyAlgo {
    pub fn label(&self) -> &'static str {
        match self {
            NoveltyAlgo::Diffusion => "diffusion",
            NoveltyAlgo::DiffusionFullyConnected => "diffusion_fc",
            NoveltyAlgo::CentralizedMairal => "mairal",
            NoveltyAlgo::CentralizedAdmm => "admm",
        }
    }
}

/// Per-time-step outcome for one algorithm.
#[derive(Clone, Debug)]
pub struct StepResult {
    pub step: usize,
    pub algo: &'static str,
    pub auc: f64,
    pub roc: Vec<RocPoint>,
    /// Number of genuinely novel documents in the evaluation batch.
    pub novel_count: usize,
}

/// Full experiment report.
#[derive(Clone, Debug)]
pub struct NoveltyReport {
    pub steps: Vec<StepResult>,
}

impl NoveltyReport {
    /// AUC table rows: (step, algo, auc) — the Tables III/IV content.
    pub fn auc_rows(&self) -> Vec<(usize, &'static str, f64)> {
        self.steps.iter().map(|s| (s.step, s.algo, s.auc)).collect()
    }
}

/// State for one diffusion configuration (sparse or FC).
struct DiffusionState {
    dict: DistributedDictionary,
    graph: Option<Graph>, // None = fully connected
    a: Mat,
    mu: f32,
    iters: usize,
    threads: usize,
}

impl DiffusionState {
    fn engine(&self, m: usize) -> Result<DiffusionEngine> {
        DiffusionEngine::new(&self.a, m, None)
    }

    /// Novelty score: run inference, evaluate local costs, average them
    /// with the scalar cost-consensus diffusion (Eq. 65); the paper's
    /// score is `g° = −(1/N)ΣJ_k` read at agent 0.
    fn score(
        &self,
        engine: &mut DiffusionEngine,
        task: &TaskSpec,
        x: &[f32],
    ) -> Result<f64> {
        engine.reset_warm(x, 1.0 / task.conj_grad_scale());
        engine.run(
            &self.dict,
            task,
            x,
            DiffusionParams::new(self.mu, self.iters).with_threads(self.threads),
        )?;
        let n = self.dict.agents();
        let mut local = vec![0.0f32; n];
        let mut s = vec![0.0f32; self.dict.k()];
        for k in 0..n {
            let nu = engine.nu(k);
            self.dict.block_correlations(k, nu, &mut s);
            let (start, len) = self.dict.block(k);
            let h = task.h_conj(&s[start..start + len]);
            local[k] = task.f_conj(nu) / n as f32
                - crate::math::blas::dot(nu, x) / n as f32
                + h;
        }
        // Scalar consensus; all agents converge to −mean(J) = g°/N·N⁻¹...
        // the 1/N scaling is absorbed into the ROC threshold sweep.
        let g = scalar_consensus_threaded(&self.a, &local, 0.05, 400, self.threads);
        Ok(g[0] as f64)
    }

    fn train_batch(
        &mut self,
        task: &TaskSpec,
        docs: &[Document],
        mu_w: f32,
    ) -> Result<()> {
        let m = docs[0].features.len();
        let mut engine = self.engine(m)?;
        engine.reserve_atoms(self.dict.k());
        let params = DiffusionParams::new(self.mu, self.iters).with_threads(self.threads);
        for d in docs {
            engine.reset_warm(&d.features, 1.0 / task.conj_grad_scale());
            engine.run(&self.dict, task, &d.features, params)?;
            let y = engine.recover_y(&self.dict, task);
            let constraint = task.atom_constraint();
            for k in 0..self.dict.agents() {
                let nu = engine.nu(k).to_vec();
                self.dict.block_gradient_step(k, mu_w, &nu, &y);
                self.dict.project_block(k, constraint);
            }
        }
        Ok(())
    }

    /// Expand dictionary + topology by `extra` agents/atoms, seeding the
    /// new atoms from documents of the just-processed batch (see
    /// `seed_atoms`).
    fn expand(
        &mut self,
        extra: usize,
        constraint: AtomConstraint,
        p: f64,
        seeds: &[&Document],
        rng: &mut Pcg64,
    ) -> Result<()> {
        let old_k = self.dict.k();
        self.dict.expand(extra, extra, constraint, rng)?;
        seed_atoms_into(self.dict.mat_mut(), old_k, seeds, rng);
        match &mut self.graph {
            Some(g) => {
                // Paper: "a random topology is generated at each time step".
                let n = self.dict.agents();
                let g2 = Graph::generate(n, &Topology::ErdosRenyi { p }, rng);
                self.a = metropolis_weights(&g2);
                *g = g2;
            }
            None => {
                self.a = uniform_weights(self.dict.agents());
            }
        }
        Ok(())
    }
}

/// Run the novelty experiment for the given algorithms.
///
/// The squared-ℓ2 protocol (Fig. 6) scores a **fixed** held-out test set
/// each step; the Huber protocol (Fig. 7) scores each **incoming** batch
/// (only at steps where novel topics appear). Both then train on the
/// incoming batch and expand.
pub fn run_novelty(
    cfg: &NoveltyConfig,
    algos: &[NoveltyAlgo],
    mut progress: impl FnMut(&str),
) -> Result<NoveltyReport> {
    let mut rng = Pcg64::new(cfg.seed ^ 0xA11A);
    let task = match cfg.residual {
        ResidualKind::SquaredL2 => TaskSpec::Nmf { gamma: cfg.gamma, delta: cfg.delta },
        ResidualKind::Huber { eta } => {
            TaskSpec::HuberNmf { gamma: cfg.gamma, delta: cfg.delta, eta }
        }
    };
    let constraint = task.atom_constraint();
    let is_huber = matches!(cfg.residual, ResidualKind::Huber { .. });

    // --- corpus (two normalizations share one RNG path: identical docs) ---
    let schedule = if is_huber {
        CorpusStream::huber_schedule(cfg.topics, cfg.time_steps)
    } else {
        CorpusStream::spread_schedule(cfg.topics, cfg.time_steps)
    };
    let corpus_cfg = CorpusConfig {
        vocab: cfg.vocab,
        topics: cfg.topics,
        seed: cfg.seed,
        l1_normalize: false,
        ..Default::default()
    };
    let mut corpus = CorpusStream::new(corpus_cfg.clone(), schedule.clone());
    let mut corpus_l1 = CorpusStream::new(
        CorpusConfig { l1_normalize: true, ..corpus_cfg },
        schedule.clone(),
    );

    // --- initial state per algorithm ---
    let k0 = cfg.init_atoms;
    let m = cfg.vocab;
    let mut diff_state: Option<DiffusionState> = None;
    let mut fc_state: Option<DiffusionState> = None;
    let mut mairal: Option<MairalLearner> = None;
    let mut admm: Option<AdmmDictLearner> = None;

    for algo in algos {
        match algo {
            NoveltyAlgo::Diffusion => {
                let dict =
                    DistributedDictionary::random(m, k0, k0, constraint, &mut rng)?;
                let g = Graph::generate(k0, &Topology::ErdosRenyi { p: cfg.edge_prob }, &mut rng);
                let a = metropolis_weights(&g);
                diff_state = Some(DiffusionState {
                    dict,
                    graph: Some(g),
                    a,
                    mu: cfg.dist_mu,
                    iters: cfg.dist_iters,
                    threads: cfg.threads,
                });
            }
            NoveltyAlgo::DiffusionFullyConnected => {
                let dict =
                    DistributedDictionary::random(m, k0, k0, constraint, &mut rng)?;
                let a = uniform_weights(k0);
                fc_state = Some(DiffusionState {
                    dict,
                    graph: None,
                    a,
                    mu: cfg.fc_mu,
                    iters: cfg.fc_iters,
                    threads: cfg.threads,
                });
            }
            NoveltyAlgo::CentralizedMairal => {
                let mut w0 = Mat::from_fn(m, k0, |_, _| rng.next_normal().abs());
                crate::model::dictionary::normalize_columns(&mut w0);
                mairal = Some(MairalLearner::new(
                    w0,
                    MairalOptions {
                        gamma: cfg.gamma,
                        delta: cfg.delta,
                        ..MairalOptions::novelty()
                    },
                ));
            }
            NoveltyAlgo::CentralizedAdmm => {
                let mut w0 = Mat::from_fn(m, k0, |_, _| rng.next_normal().abs());
                for q in 0..k0 {
                    let mut col = w0.col(q);
                    let n1 = crate::math::vector::norm1(&col);
                    crate::math::vector::scale(1.0 / n1, &mut col);
                    w0.set_col(q, &col);
                }
                admm = Some(AdmmDictLearner::new(w0, AdmmOptions::default()));
            }
        }
    }

    // --- initialization batch (step 0) ---
    let init = corpus.batch(0, cfg.batch_docs);
    let init_l1 = corpus_l1.batch(0, cfg.batch_docs);
    progress(&format!("initializing on {} documents...", init.len()));
    // Seed every learner's initial atoms from initialization documents
    // (see `seed_atoms_into` for why this replaces the paper's
    // unnormalized random init).
    {
        let seeds: Vec<&Document> = init.iter().collect();
        let seeds_l1: Vec<&Document> = init_l1.iter().collect();
        if let Some(st) = diff_state.as_mut() {
            seed_atoms_into(st.dict.mat_mut(), 0, &seeds, &mut rng);
        }
        if let Some(st) = fc_state.as_mut() {
            seed_atoms_into(st.dict.mat_mut(), 0, &seeds, &mut rng);
        }
        if let Some(b) = mairal.as_mut() {
            seed_atoms_into(&mut b.w, 0, &seeds, &mut rng);
        }
        if let Some(b) = admm.as_mut() {
            seed_atoms_into(&mut b.w, 0, &seeds_l1, &mut rng);
            l1_feasible_columns(&mut b.w, 0);
            b.refresh_lipschitz_pub();
        }
    }
    let mu_w0 = StepSchedule::InverseTime { num: cfg.mu_w_num }.at(1);
    if let Some(st) = diff_state.as_mut() {
        st.train_batch(&task, &init, mu_w0)?;
    }
    if let Some(st) = fc_state.as_mut() {
        st.train_batch(&task, &init, mu_w0)?;
    }
    if let Some(b) = mairal.as_mut() {
        for d in &init {
            b.step(&d.features)?;
        }
    }
    if let Some(b) = admm.as_mut() {
        let refs: Vec<&[f32]> = init_l1.iter().map(|d| d.features.as_slice()).collect();
        b.fit_batch(&refs, 35);
    }

    // Fixed test set for the sq-Euclid protocol.
    let test_set: Vec<Document> = if is_huber { Vec::new() } else { corpus.test_set(cfg.batch_docs) };

    let mut steps = Vec::new();
    for s in 1..=cfg.time_steps {
        let seen = corpus.seen_through(s - 1);
        let batch = corpus.batch(s, cfg.batch_docs);
        let batch_l1 = corpus_l1.batch(s, cfg.batch_docs);
        let has_novel = !corpus.new_topics_at(s).is_empty();

        // --- evaluation ---
        let eval_docs: &[Document] = if is_huber { &batch } else { &test_set };
        let eval_docs_l1: &[Document] = if is_huber { &batch_l1 } else { &test_set };
        let labels: Vec<bool> = eval_docs.iter().map(|d| !seen.contains(&d.topic)).collect();
        let novel_count = labels.iter().filter(|&&l| l).count();
        let do_eval = novel_count > 0 && novel_count < eval_docs.len();

        if do_eval {
            if let Some(st) = diff_state.as_mut() {
                let mut engine = st.engine(m)?;
                let scores: Vec<f64> = eval_docs
                    .iter()
                    .map(|d| st.score(&mut engine, &task, &d.features))
                    .collect::<Result<_>>()?;
                let a = auc(&scores, &labels);
                progress(&format!("step {s}: diffusion AUC = {a:.3} ({novel_count} novel)"));
                steps.push(StepResult {
                    step: s,
                    algo: "diffusion",
                    auc: a,
                    roc: roc_curve(&scores, &labels),
                    novel_count,
                });
            }
            if let Some(st) = fc_state.as_mut() {
                let mut engine = st.engine(m)?;
                let scores: Vec<f64> = eval_docs
                    .iter()
                    .map(|d| st.score(&mut engine, &task, &d.features))
                    .collect::<Result<_>>()?;
                let a = auc(&scores, &labels);
                progress(&format!("step {s}: diffusion-FC AUC = {a:.3}"));
                steps.push(StepResult {
                    step: s,
                    algo: "diffusion_fc",
                    auc: a,
                    roc: roc_curve(&scores, &labels),
                    novel_count,
                });
            }
            if let Some(b) = mairal.as_ref() {
                let scores: Vec<f64> =
                    eval_docs.iter().map(|d| b.objective(&d.features) as f64).collect();
                let a = auc(&scores, &labels);
                progress(&format!("step {s}: mairal AUC = {a:.3}"));
                steps.push(StepResult {
                    step: s,
                    algo: "mairal",
                    auc: a,
                    roc: roc_curve(&scores, &labels),
                    novel_count,
                });
            }
            if let Some(b) = admm.as_ref() {
                let scores: Vec<f64> =
                    eval_docs_l1.iter().map(|d| b.objective(&d.features) as f64).collect();
                let a = auc(&scores, &labels);
                progress(&format!("step {s}: admm AUC = {a:.3}"));
                steps.push(StepResult {
                    step: s,
                    algo: "admm",
                    auc: a,
                    roc: roc_curve(&scores, &labels),
                    novel_count,
                });
            }
        } else {
            progress(&format!(
                "step {s}: no ROC ({} novel docs of {})",
                novel_count,
                eval_docs.len()
            ));
        }

        // --- training on the incoming batch, then expansion ---
        let mu_w = StepSchedule::InverseTime { num: cfg.mu_w_num }.at(s);
        let batch_seeds: Vec<&Document> = batch.iter().collect();
        let batch_seeds_l1: Vec<&Document> = batch_l1.iter().collect();
        if let Some(st) = diff_state.as_mut() {
            st.train_batch(&task, &batch, mu_w)?;
            st.expand(cfg.atoms_per_step, constraint, cfg.edge_prob, &batch_seeds, &mut rng)?;
        }
        if let Some(st) = fc_state.as_mut() {
            st.train_batch(&task, &batch, mu_w)?;
            st.expand(cfg.atoms_per_step, constraint, cfg.edge_prob, &batch_seeds, &mut rng)?;
        }
        if let Some(b) = mairal.as_mut() {
            for d in &batch {
                b.step(&d.features)?;
            }
            let old_k = b.w.cols();
            b.expand(cfg.atoms_per_step, &mut rng);
            seed_atoms_into(&mut b.w, old_k, &batch_seeds, &mut rng);
        }
        if let Some(b) = admm.as_mut() {
            let refs: Vec<&[f32]> = batch_l1.iter().map(|d| d.features.as_slice()).collect();
            b.fit_batch(&refs, 1);
            let old_k = b.w.cols();
            b.expand(cfg.atoms_per_step, &mut rng);
            seed_atoms_into(&mut b.w, old_k, &batch_seeds_l1, &mut rng);
            l1_feasible_columns(&mut b.w, old_k);
            b.refresh_lipschitz_pub();
        }
        let _ = has_novel;
    }

    Ok(NoveltyReport { steps })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Miniature end-to-end novelty run: diffusion detects novel topics
    /// clearly better than chance.
    #[test]
    fn mini_novelty_beats_chance() {
        let cfg = NoveltyConfig {
            seed: 11,
            vocab: 120,
            topics: 8,
            batch_docs: 60,
            time_steps: 2,
            init_atoms: 6,
            atoms_per_step: 4,
            dist_mu: 0.2,
            dist_iters: 120,
            fc_mu: 0.5,
            fc_iters: 60,
            ..NoveltyConfig::squared_l2()
        };
        let report = run_novelty(
            &cfg,
            &[NoveltyAlgo::DiffusionFullyConnected],
            |_| {},
        )
        .unwrap();
        assert!(!report.steps.is_empty());
        for s in &report.steps {
            assert!(s.auc > 0.6, "step {} AUC {} not better than chance", s.step, s.auc);
        }
    }
}
