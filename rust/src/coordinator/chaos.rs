//! Chaos experiment driver (`ddl chaos`): the async executor under a
//! deterministic [`FaultSchedule`], compared against its own fault-free
//! trajectory on the same problem, same delay model, same simulated clock.
//!
//! Protocol (EXPERIMENTS.md §Chaos):
//!
//! 1. build one problem instance from [`AsyncConfig`] — RNG consumption
//!    order matches [`super::straggler::run_straggler`], so `ddl async`
//!    and `ddl chaos` study the identical dictionary/topology/sample;
//! 2. run the **fault-free baseline** to completion, pinning the horizon
//!    `T` that the `[chaos]` window fractions scale to;
//! 3. build the [`FaultSchedule`] from [`ChaosConfig`] and run the
//!    **chaos executor**, stepping a fresh fault-free comparator through
//!    shared simulated-time checkpoints and recording MSD against the
//!    exact dual ν° at each (the MSD-vs-sim-time sensitivity curve);
//! 4. verify the two contracts that make this a *testing* harness rather
//!    than a demo: the chaos run **replays bit-identically** (same
//!    schedule → same trajectory, clocks, stats), and an **empty schedule
//!    is bitwise fault-free** (same final state as the baseline).
//!
//! The headline number is the **recovery gap**: `|MSD_chaos − MSD_clean|`
//! at `t = T`, i.e. at equal simulated time after every configured fault
//! window has healed (acceptance: within 1e-3 for the healing-partition
//! ring). [`run_pushsum_bias`] isolates the combine-correction story:
//! under a persistent *directed* outage the Metropolis combine loses
//! double stochasticity and converges off-target, while the push-sum
//! combine ([`crate::graph::pushsum`]) stays unbiased. [`run_byzantine`]
//! (`ddl chaos --byzantine`) is the corrupted-ψ analogue: one persistent
//! Byzantine attacker biases (or diverges) the undefended Metropolis
//! combine, while the trimmed-mean defense recovers to within the
//! defense gap of its own clean trajectory — both attacked runs
//! replaying bit-identically per seed.
//!
//! With `[control] adaptive_tau = true` the τ controller rides along,
//! fed by the chaos run's gate waits and the clean comparator as its
//! probe, with [`TauController::observe_partition`] suppressing the
//! narrow branch while the graph is cut.

use crate::config::experiment::{AsyncConfig, ChaosConfig};
use crate::error::{DdlError, Result};
use crate::graph::{metropolis_weights, Graph};
use crate::infer::{exact_dual, DiffusionParams};
use crate::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use crate::net::{
    AsyncNetwork, AsyncParams, ChaosStats, CombineMode, CorruptPolicy, Fault, FaultSchedule,
    MessageStats, TauController, TauDecision,
};
use crate::obs::{ArgValue, Track};
use crate::rng::Pcg64;

use super::straggler::build_topology;

/// One simulated-time checkpoint of the chaos-vs-clean comparison.
#[derive(Clone, Debug)]
pub struct ChaosRow {
    /// Checkpoint on the simulated clock (µs).
    pub t_us: u64,
    /// Chaos run's MSD vs the exact dual at this time.
    pub msd_faulty: f64,
    /// Fault-free comparator's MSD at the same time.
    pub msd_clean: f64,
    /// Whether a partition window overlapped this checkpoint interval.
    pub partition: bool,
    /// Staleness bound τ in effect during the interval (moves only when
    /// the adaptive-τ controller is enabled).
    pub tau: usize,
    /// Completed network-wide waves of the chaos executor.
    pub min_iters: usize,
}

/// Outcome of one chaos experiment (`ddl chaos`).
#[derive(Clone, Debug)]
pub struct ChaosReport {
    pub rows: Vec<ChaosRow>,
    /// Simulated completion time of the fault-free baseline (= the
    /// horizon `T` the schedule windows were scaled to).
    pub clean_time_us: u64,
    /// Simulated completion time of the chaos run (its own full run).
    pub chaos_time_us: u64,
    /// `|MSD_chaos − MSD_clean|` at `t = T`: equal simulated time, after
    /// every window-scaled fault has healed.
    pub recovery_gap: f64,
    /// Did a second run under the identical schedule reproduce the chaos
    /// trajectory bit-for-bit (clocks, traffic, fault stats, final MSD)?
    pub replay_bitwise: bool,
    /// Did an empty-but-seeded schedule reproduce the fault-free baseline
    /// bit-for-bit?
    pub empty_parity: bool,
    /// Combine actually used by the chaos run.
    pub combine: CombineMode,
    /// Whether `auto` selected push-sum because of directed faults.
    pub auto_pushsum: bool,
    /// Number of fault windows in the scaled schedule.
    pub schedule_faults: usize,
    /// Degradation counters of the chaos run.
    pub chaos_stats: ChaosStats,
    /// ψ-traffic of the chaos run.
    pub stats: MessageStats,
    /// Largest *gated* staleness any combine used (≤ τ; stale-fallback
    /// staleness is accounted separately in [`Self::chaos_stats`]).
    pub max_staleness: usize,
    /// τ-controller decision trace when `[control] adaptive_tau` rode
    /// along (`None` otherwise).
    ///
    /// Deprecated alias: the same decisions now also flow into the trace
    /// subsystem as `tau_decision` instants on the `tau` controller lane
    /// (`ddl chaos --trace`, see [`crate::obs`]). The field stays for one
    /// release; prefer the trace events.
    pub tau_trace: Option<Vec<TauDecision>>,
}

impl ChaosReport {
    /// Multi-line human-readable summary (the `ddl chaos` output body).
    pub fn summary(&self, agents: usize) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:>12} {:>12} {:>12} {:>5} {:>5} {:>10}\n",
            "sim time s", "msd faulty", "msd clean", "part", "tau", "waves"
        ));
        for r in &self.rows {
            out.push_str(&format!(
                "{:>12.4} {:>12.3e} {:>12.3e} {:>5} {:>5} {:>10}\n",
                r.t_us as f64 / 1e6,
                r.msd_faulty,
                r.msd_clean,
                if r.partition { "cut" } else { "-" },
                r.tau,
                r.min_iters,
            ));
        }
        out.push_str(&format!(
            "recovery gap at equal simulated time: {:.3e}\n\
             completion: clean {:.4} s, chaos {:.4} s; combine {:?}{}; {} fault windows\n\
             degradation: {} dropped, {} retries, {} abandoned, {} crash deferrals, \
             {} forced combines, {} stale fallbacks, {} exclusions, {} corrupted\n\
             detection: {} flagged, {} excluded, {} readmitted\n\
             replay bit-identical: {}; empty schedule bitwise fault-free: {}\n\
             traffic: {} msgs, {:.2} MB, {} rounds, {:.1} B/agent/round, max staleness {}",
            self.recovery_gap,
            self.clean_time_us as f64 / 1e6,
            self.chaos_time_us as f64 / 1e6,
            self.combine,
            if self.auto_pushsum { " (auto: directed faults)" } else { "" },
            self.schedule_faults,
            self.chaos_stats.dropped,
            self.chaos_stats.retries,
            self.chaos_stats.abandoned,
            self.chaos_stats.crash_deferrals,
            self.chaos_stats.forced_combines,
            self.chaos_stats.stale_fallbacks,
            self.chaos_stats.excluded_neighbors,
            self.chaos_stats.corrupted,
            self.chaos_stats.flagged,
            self.chaos_stats.detect_excluded,
            self.chaos_stats.readmitted,
            self.replay_bitwise,
            self.empty_parity,
            self.stats.messages,
            self.stats.bytes as f64 / 1e6,
            self.stats.rounds,
            self.stats.bytes_per_agent_round(agents),
            self.max_staleness,
        ));
        out
    }
}

/// Scale the `[chaos]` window fractions to a concrete horizon and emit
/// the executor-facing schedule. Pure: same (config, graph, horizon) →
/// same schedule.
fn build_schedule(c: &ChaosConfig, graph: &Graph, horizon_us: u64) -> Result<FaultSchedule> {
    let n = graph.n();
    let t = horizon_us.max(1);
    let at = |f: f64| (f.max(0.0) * t as f64).round() as u64;
    let mut s = FaultSchedule::new(c.seed);
    let (p_from, p_until) =
        (at(c.partition_start_frac), at(c.partition_start_frac + c.partition_len_frac));
    if c.partition_frac > 0.0 && c.partition_len_frac > 0.0 && p_until > p_from && n >= 2 {
        s = s.with_partition(FaultSchedule::split_side(n, c.partition_frac), p_from, p_until);
    }
    if let Some(k) = c.crash_agent {
        if k >= n {
            return Err(DdlError::Config(format!(
                "chaos.crash_agent = {k} out of range for N = {n}"
            )));
        }
        // The crash rides the same window fractions as the partition, so
        // one pair of knobs positions every "big" fault.
        if p_until > p_from {
            s = s.with_crash(k, p_from, p_until);
        }
    }
    if c.churn_windows > 0 {
        // Bursty Gilbert–Elliott links: long good states (mean T/5)
        // punctuated by short correlated bad bursts (mean T/20), replacing
        // the independent up/down windows of the first churn model.
        s = s.with_bursty_links(graph, c.churn_windows, (t / 5).max(1), (t / 20).max(1), t, c.seed);
    }
    if c.drop_prob > 0.0 {
        s = s.with_drops(c.drop_prob, 0, t);
    }
    let byz = c.byzantine_set()?;
    if let Some(&k) = byz.iter().find(|&&k| k >= n) {
        return Err(DdlError::Config(format!(
            "chaos byzantine agent {k} out of range for N = {n}"
        )));
    }
    if !byz.is_empty() {
        s = s.with_colluders(&byz, c.corrupt_policy()?, 0, t);
    }
    s.validate(n)?;
    Ok(s)
}

/// Does any partition window overlap the half-open interval `(a, b]`?
fn partition_overlaps(s: &FaultSchedule, a: u64, b: u64) -> bool {
    s.faults().iter().any(|f| match f {
        Fault::Partition { from_us, until_us, .. } => *from_us <= b && *until_us > a,
        _ => false,
    })
}

/// Run the chaos experiment; `log` receives progress lines. See the
/// module docs for the protocol.
pub fn run_chaos(cfg: &AsyncConfig, log: &mut dyn FnMut(&str)) -> Result<ChaosReport> {
    let mut rng = Pcg64::new(cfg.seed);
    let graph = build_topology(cfg, &mut rng)?;
    let weights = metropolis_weights(&graph);
    let dict = DistributedDictionary::random(
        cfg.dim,
        cfg.agents,
        cfg.agents,
        AtomConstraint::UnitBall,
        &mut rng,
    )?;
    let x = rng.normal_vec(cfg.dim);
    let task = TaskSpec::SparseCoding { gamma: cfg.infer.gamma, delta: cfg.infer.delta };
    let params = DiffusionParams::new(cfg.infer.mu, cfg.infer.iters);
    let base = cfg.async_params()?;
    let mode = cfg.chaos.combine_mode()?;

    let exact = exact_dual(&dict, &task, &x, 1e-6, 20_000)?;

    // 1. Fault-free baseline pins the horizon T the windows scale to.
    let mut clean_full =
        AsyncNetwork::new(graph.clone(), weights.clone(), cfg.dim, None, base.clone())?;
    clean_full.run(&dict, &task, &x, params)?;
    let clean_time_us = clean_full.sim_time_us();
    log(&format!(
        "chaos: N={} M={} topology={}, iters={}, tau={}; fault-free horizon T = {:.4} s",
        cfg.agents,
        cfg.dim,
        cfg.topology,
        cfg.infer.iters,
        cfg.tau,
        clean_time_us as f64 / 1e6,
    ));

    // 2. Schedule scaled to T.
    let schedule = build_schedule(&cfg.chaos, &graph, clean_time_us)?;
    log(&format!(
        "chaos schedule (seed {}): {} fault windows{}",
        cfg.chaos.seed,
        schedule.faults().len(),
        if schedule.has_directed_faults() { ", directed" } else { "" },
    ));

    // 3. Chaos run vs a fresh fault-free comparator through shared
    // checkpoints. With adaptive τ the controller rides along, the
    // comparator doubling as its MSD probe.
    let adaptive = cfg.control.adaptive_tau;
    let mut controller = adaptive.then(|| TauController::new(&cfg.control));
    let tau0 = controller.as_ref().map_or(cfg.tau, |c| c.initial_tau(cfg.tau));
    let chaos_params = AsyncParams {
        tau: tau0,
        chaos: schedule.clone(),
        combine: mode,
        ..base.clone()
    }
    .with_detect(cfg.chaos.detection());
    let mut chaos_net =
        AsyncNetwork::new(graph.clone(), weights.clone(), cfg.dim, None, chaos_params.clone())?;
    let mut clean_net =
        AsyncNetwork::new(graph.clone(), weights.clone(), cfg.dim, None, base.clone())?;
    // Trace the chaos instance only — never the replay or empty-schedule
    // instances, whose job is proving bitwise contracts that must hold
    // with or without a recorder attached.
    let obs = crate::obs::handle_for(&cfg.obs);
    chaos_net.attach_obs(obs.clone());

    let checkpoints = cfg.checkpoints.max(1);
    let mut rows = Vec::with_capacity(checkpoints);
    // τ applied for the segment *after* each checkpoint, replayed in
    // step 4 (decisions are pure functions of replayed measurements, so
    // re-applying the recorded moves reproduces the adaptive run too).
    let mut taus_after = Vec::with_capacity(checkpoints);
    let mut tau = tau0;
    let mut prev_t = 0u64;
    for c in 1..=checkpoints {
        let t_us = (clean_time_us as u128 * c as u128 / checkpoints as u128) as u64;
        let done = chaos_net.run_clamped(&dict, &task, &x, params, t_us)?;
        clean_net.run_clamped(&dict, &task, &x, params, t_us)?;
        let msd_faulty = chaos_net.msd_vs(&exact.nu);
        let msd_clean = clean_net.msd_vs(&exact.nu);
        let cut = partition_overlaps(&schedule, prev_t, t_us);
        rows.push(ChaosRow {
            t_us,
            msd_faulty,
            msd_clean,
            partition: cut,
            tau,
            min_iters: chaos_net.min_iters_done(),
        });
        if let Some(ctl) = controller.as_mut() {
            ctl.observe_partition(cut);
            let next = ctl.decide(
                t_us,
                cfg.agents,
                chaos_net.gate_wait_us_at(t_us),
                msd_faulty,
                msd_clean,
                tau,
            );
            if obs.enabled() {
                let decided = ctl.trace().last().expect("decide() just pushed");
                obs.instant(
                    t_us,
                    "tau_decision",
                    Track::Controller("tau"),
                    vec![
                        ("tau", ArgValue::U(next as u64)),
                        ("prev", ArgValue::U(tau as u64)),
                        ("gate_wait_frac", ArgValue::F(decided.gate_wait_frac)),
                        ("msd_drift", ArgValue::F(decided.msd_drift)),
                        ("partition", ArgValue::B(decided.partition)),
                    ],
                );
            }
            if next != tau && !done {
                chaos_net.set_tau(next, &task, t_us);
                tau = next;
            }
        }
        taus_after.push(tau);
        prev_t = t_us;
    }
    let last = rows.last().expect("checkpoints >= 1");
    let recovery_gap = (last.msd_faulty - last.msd_clean).abs();
    let final_msd = last.msd_faulty;
    chaos_net.run(&dict, &task, &x, params)?;
    let chaos_time_us = chaos_net.sim_time_us();
    log(&format!(
        "chaos run complete at {:.4} s (clean {:.4} s), recovery gap {:.3e}",
        chaos_time_us as f64 / 1e6,
        clean_time_us as f64 / 1e6,
        recovery_gap,
    ));

    // 4. Replay contract: the identical schedule (and τ moves) must
    // reproduce the trajectory bit-for-bit.
    let mut replay =
        AsyncNetwork::new(graph.clone(), weights.clone(), cfg.dim, None, chaos_params)?;
    let mut replay_msd = f64::NAN;
    let mut rtau = tau0;
    for c in 1..=checkpoints {
        let t_us = (clean_time_us as u128 * c as u128 / checkpoints as u128) as u64;
        let done = replay.run_clamped(&dict, &task, &x, params, t_us)?;
        if c == checkpoints {
            replay_msd = replay.msd_vs(&exact.nu);
        }
        let next = taus_after[c - 1];
        if next != rtau && !done {
            replay.set_tau(next, &task, t_us);
            rtau = next;
        }
    }
    replay.run(&dict, &task, &x, params)?;
    let replay_bitwise = replay.sim_time_us() == chaos_time_us
        && replay.stats() == chaos_net.stats()
        && replay.chaos_stats() == chaos_net.chaos_stats()
        && replay_msd.to_bits() == final_msd.to_bits();

    // 5. Empty-schedule parity: a seeded-but-empty schedule must be
    // bitwise the fault-free baseline (the chaos layer's no-op proof).
    let mut empty_net = AsyncNetwork::new(
        graph,
        weights,
        cfg.dim,
        None,
        AsyncParams { chaos: FaultSchedule::new(cfg.chaos.seed), ..base },
    )?;
    empty_net.run(&dict, &task, &x, params)?;
    let empty_parity = empty_net.sim_time_us() == clean_time_us
        && empty_net.stats() == clean_full.stats()
        && empty_net.msd_vs(&exact.nu).to_bits() == clean_full.msd_vs(&exact.nu).to_bits();

    if let Some(n) = crate::obs::export(&cfg.obs, &obs)? {
        log(&format!(
            "trace: wrote {n} events to {}",
            cfg.obs.trace_path.as_deref().unwrap_or("?")
        ));
    }

    Ok(ChaosReport {
        rows,
        clean_time_us,
        chaos_time_us,
        recovery_gap,
        replay_bitwise,
        empty_parity,
        combine: chaos_net.combine_mode(),
        auto_pushsum: chaos_net.auto_pushsum(),
        schedule_faults: schedule.faults().len(),
        chaos_stats: chaos_net.chaos_stats(),
        stats: chaos_net.stats(),
        max_staleness: chaos_net.max_staleness_observed(),
        tau_trace: controller.map(TauController::into_trace),
    })
}

/// Outcome of the combine-correction probe ([`run_pushsum_bias`]).
#[derive(Clone, Copy, Debug)]
pub struct PushSumBias {
    /// Onset of the persistent directed outage (µs).
    pub outage_from_us: u64,
    /// Directed links cut for the rest of the run.
    pub links_cut: usize,
    /// Converged MSD of the Metropolis combine under the outage.
    pub msd_metropolis: f64,
    /// Converged MSD of the push-sum combine under the same outage.
    pub msd_pushsum: f64,
}

impl PushSumBias {
    /// `msd_metropolis / msd_pushsum` — how much of the Metropolis error
    /// the push-sum correction removes (> 1 when the correction helps).
    pub fn bias_ratio(&self) -> f64 {
        self.msd_metropolis / self.msd_pushsum.max(f64::MIN_POSITIVE)
    }
}

/// Isolate the push-sum correction: one persistent *directed* outage
/// (every third agent loses its first outgoing link from `0.25·T`
/// onward), run once with the Metropolis combine forced and once with
/// push-sum forced, and compare converged MSD against the exact dual.
/// Row-stochastic-only averaging converges to a Perron-weighted (biased)
/// objective on the live digraph; the ratio-of-sums correction does not
/// — the `bench_chaos.rs` regression indicator.
pub fn run_pushsum_bias(cfg: &AsyncConfig, log: &mut dyn FnMut(&str)) -> Result<PushSumBias> {
    let mut rng = Pcg64::new(cfg.seed);
    let graph = build_topology(cfg, &mut rng)?;
    let weights = metropolis_weights(&graph);
    let dict = DistributedDictionary::random(
        cfg.dim,
        cfg.agents,
        cfg.agents,
        AtomConstraint::UnitBall,
        &mut rng,
    )?;
    let x = rng.normal_vec(cfg.dim);
    let task = TaskSpec::SparseCoding { gamma: cfg.infer.gamma, delta: cfg.infer.delta };
    let params = DiffusionParams::new(cfg.infer.mu, cfg.infer.iters);
    let base = cfg.async_params()?;
    let exact = exact_dual(&dict, &task, &x, 1e-6, 20_000)?;

    let mut clean = AsyncNetwork::new(graph.clone(), weights.clone(), cfg.dim, None, base.clone())?;
    clean.run(&dict, &task, &x, params)?;
    let from = clean.sim_time_us() / 4;

    let mut schedule = FaultSchedule::new(cfg.chaos.seed);
    let mut links_cut = 0usize;
    for k in (0..graph.n()).step_by(3) {
        if let Some(&nb) = graph.neighbors(k).first() {
            schedule = schedule.with_link_down(k, nb, from, u64::MAX);
            links_cut += 1;
        }
    }
    log(&format!(
        "pushsum-bias probe: {links_cut} directed links down from {:.4} s onward",
        from as f64 / 1e6
    ));

    let mut run = |combine: CombineMode| -> Result<f64> {
        let mut net = AsyncNetwork::new(
            graph.clone(),
            weights.clone(),
            cfg.dim,
            None,
            AsyncParams { chaos: schedule.clone(), combine, ..base.clone() },
        )?;
        net.run(&dict, &task, &x, params)?;
        Ok(net.msd_vs(&exact.nu))
    };
    let msd_metropolis = run(CombineMode::Metropolis)?;
    let msd_pushsum = run(CombineMode::PushSum)?;
    log(&format!(
        "pushsum-bias probe: metropolis {msd_metropolis:.3e}, push-sum {msd_pushsum:.3e}"
    ));
    Ok(PushSumBias { outage_from_us: from, links_cut, msd_metropolis, msd_pushsum })
}

/// Outcome of the Byzantine attack/defense probe ([`run_byzantine`]).
#[derive(Clone, Debug)]
pub struct ByzantineReport {
    /// First attacker of the colluding set (legacy single-attacker view).
    pub attacker: usize,
    /// Full colluding set whose *outbound* ψ messages are corrupted
    /// (`[chaos] byzantine_agent` ∪ `byzantine_agents`).
    pub attackers: Vec<usize>,
    /// Corruption policy every colluder applies.
    pub policy: CorruptPolicy,
    /// Resilient combine used by the defended runs.
    pub defense: CombineMode,
    /// Converged MSD of the fault-free Metropolis run (the clean anchor
    /// for the bias ratio).
    pub msd_clean: f64,
    /// Converged MSD of the fault-free run under the *defense* combine
    /// (the clean anchor for the defense gap — same combine, no attack,
    /// so trimming-rate artifacts cancel).
    pub msd_clean_defended: f64,
    /// Converged MSD of the undefended Metropolis run under attack.
    pub msd_undefended: f64,
    /// Converged MSD of the defended run under the same attack.
    pub msd_defended: f64,
    /// `|msd_defended − msd_clean_defended|` — how far the attack moves
    /// the defended trajectory from its own clean fixed point.
    pub defense_gap: f64,
    /// Did both attacked runs replay bit-identically (MSD bits, clocks,
    /// fault stats, traffic) under the identical schedule?
    pub replay_bitwise: bool,
    /// Corrupted ψ messages the defended run absorbed.
    pub corrupted: usize,
    /// Was the detection layer armed (`[chaos] detect = true`)?
    pub detect: bool,
    /// Converged MSD of the detection-defended run under attack (NaN
    /// when detection is off).
    pub msd_detected: f64,
    /// `|msd_detected − msd_clean_defended|` — how far the attack moves
    /// the *detection-defended* trajectory from the clean defended fixed
    /// point (NaN when detection is off).
    pub detect_gap: f64,
    /// Suspects flagged by at least one honest judge in the detection
    /// pass (empty when detection is off).
    pub flagged: Vec<usize>,
    /// Suspects excluded by at least one judge in the detection pass.
    pub excluded: Vec<usize>,
    /// Zero-false-positive contract: the clean run with detection armed
    /// is bitwise the clean defended run and records no flags or
    /// exclusions. Vacuously true when detection is off.
    pub detect_zero_fp: bool,
    /// Did the detection pass replay bit-identically — same MSD bits,
    /// clocks, stats, and the same flagged/excluded sets? Vacuously true
    /// when detection is off.
    pub detect_replay_bitwise: bool,
}

impl ByzantineReport {
    /// `msd_undefended / msd_clean` — how much the attack inflates the
    /// undefended combine's error (≫ 1 when the attack lands).
    pub fn bias_ratio(&self) -> f64 {
        self.msd_undefended / self.msd_clean.max(f64::MIN_POSITIVE)
    }

    /// The acceptance notion of "undefended failure": the Metropolis run
    /// diverged outright, or its error is > 10× the clean baseline.
    pub fn undefended_diverged(&self) -> bool {
        !self.msd_undefended.is_finite() || self.bias_ratio() > 10.0
    }

    /// Multi-line human-readable summary (the `ddl chaos --byzantine`
    /// output body).
    pub fn summary(&self) -> String {
        let mut out = format!(
            "byzantine probe: attackers {:?} ({}), defense {:?}\n\
             clean: metropolis {:.3e}, defended {:.3e}\n\
             under attack: metropolis {:.3e} ({}), defended {:.3e}\n\
             defense gap vs clean defended: {:.3e}; {} corrupted messages\n\
             replay bit-identical: {}",
            self.attackers,
            self.policy.name(),
            self.defense,
            self.msd_clean,
            self.msd_clean_defended,
            self.msd_undefended,
            if self.undefended_diverged() {
                "diverged/biased > 10x"
            } else {
                "within 10x of clean"
            },
            self.msd_defended,
            self.defense_gap,
            self.corrupted,
            self.replay_bitwise,
        );
        if self.detect {
            out.push_str(&format!(
                "\ndetection: flagged {:?}, excluded {:?}; detected msd {:.3e}, \
                 gap vs clean defended {:.3e}\n\
                 detection zero false positives on clean run: {}; \
                 detection replay bit-identical: {}",
                self.flagged,
                self.excluded,
                self.msd_detected,
                self.detect_gap,
                self.detect_zero_fp,
                self.detect_replay_bitwise,
            ));
        }
        out
    }
}

/// Isolate the corrupted-ψ defense (`ddl chaos --byzantine`): one
/// persistent Byzantine attacker (from `[chaos] byzantine_agent` /
/// `byzantine_policy`, defaulting to a sign-flip attacker at agent 0)
/// corrupts every outbound ψ clone, and the same problem is run four
/// ways — clean and attacked, each with the undefended Metropolis
/// combine and with the resilient defense. The defense combine comes
/// from `[chaos] pushsum = "median" | "trimmed:<f>"` when set, else
/// defaults to `TrimmedMean(1)` (one attacker ⇒ trim one each side).
/// Both attacked runs are then re-run to prove bitwise replay.
pub fn run_byzantine(cfg: &AsyncConfig, log: &mut dyn FnMut(&str)) -> Result<ByzantineReport> {
    let mut rng = Pcg64::new(cfg.seed);
    let graph = build_topology(cfg, &mut rng)?;
    let weights = metropolis_weights(&graph);
    let dict = DistributedDictionary::random(
        cfg.dim,
        cfg.agents,
        cfg.agents,
        AtomConstraint::UnitBall,
        &mut rng,
    )?;
    let x = rng.normal_vec(cfg.dim);
    let task = TaskSpec::SparseCoding { gamma: cfg.infer.gamma, delta: cfg.infer.delta };
    let params = DiffusionParams::new(cfg.infer.mu, cfg.infer.iters);
    let base = cfg.async_params()?;
    let exact = exact_dual(&dict, &task, &x, 1e-6, 20_000)?;

    let n = graph.n();
    let attackers = {
        let mut a = cfg.chaos.byzantine_set()?;
        if a.is_empty() {
            a.push(0);
        }
        a
    };
    if let Some(&k) = attackers.iter().find(|&&k| k >= n) {
        return Err(DdlError::Config(format!(
            "chaos byzantine agent {k} out of range for N = {n}"
        )));
    }
    let policy = cfg.chaos.corrupt_policy()?;
    let defense = match cfg.chaos.combine_mode()? {
        m @ (CombineMode::Median | CombineMode::TrimmedMean(_)) => m,
        _ => CombineMode::TrimmedMean(1),
    };
    let det = cfg.chaos.detection();
    let schedule =
        FaultSchedule::new(cfg.chaos.seed).with_colluders(&attackers, policy, 0, u64::MAX);
    log(&format!(
        "byzantine probe: attackers {attackers:?} apply {} for the whole run; defense \
         {defense:?}{}",
        policy.name(),
        if det.enabled { ", detection armed" } else { "" },
    ));

    // Trace only the attacked run whose events tell the story: the
    // detection pass when armed (agent_flagged / agent_excluded), else
    // the masking-only defended pass (psi_corrupt / combine_trimmed).
    // Replay instances stay untraced (traced ≡ untraced is proven
    // elsewhere).
    let obs = crate::obs::handle_for(&cfg.obs);
    type Pass = (f64, u64, ChaosStats, MessageStats, Vec<usize>, Vec<usize>);
    let mut run =
        |combine: CombineMode, chaos: FaultSchedule, detect: bool, trace: bool| -> Result<Pass> {
            let mut p = AsyncParams { chaos, combine, ..base.clone() };
            if detect {
                p = p.with_detect(det);
            }
            let mut net = AsyncNetwork::new(graph.clone(), weights.clone(), cfg.dim, None, p)?;
            if trace {
                net.attach_obs(obs.clone());
            }
            net.run(&dict, &task, &x, params)?;
            Ok((
                net.msd_vs(&exact.nu),
                net.sim_time_us(),
                net.chaos_stats(),
                net.stats(),
                net.flagged_suspects(),
                net.excluded_suspects(),
            ))
        };
    let eq = |a: &Pass, b: &Pass| {
        a.0.to_bits() == b.0.to_bits() && a.1 == b.1 && a.2 == b.2 && a.3 == b.3 && a.4 == b.4
            && a.5 == b.5
    };
    let empty = || FaultSchedule::new(cfg.chaos.seed);
    let (msd_clean, ..) = run(CombineMode::Metropolis, empty(), false, false)?;
    let clean_d = run(defense, empty(), false, false)?;
    let msd_clean_defended = clean_d.0;
    let attacked_u = run(CombineMode::Metropolis, schedule.clone(), false, false)?;
    let attacked_d = run(defense, schedule.clone(), false, !det.enabled)?;
    log(&format!(
        "byzantine probe: undefended {:.3e}, defended {:.3e} (clean {:.3e} / {:.3e})",
        attacked_u.0, attacked_d.0, msd_clean, msd_clean_defended,
    ));

    // Detection passes (`--detect`): the clean run with detection armed
    // must be bitwise the clean defended run with zero flags (the
    // zero-false-positive contract), and the attacked detection run —
    // the traced instance — yields the detected MSD and evidence sets.
    let (detect_zero_fp, attacked_det) = if det.enabled {
        let clean_det = run(defense, empty(), true, false)?;
        let zero_fp = eq(&clean_det, &clean_d) && clean_det.4.is_empty() && clean_det.5.is_empty();
        let attacked_det = run(defense, schedule.clone(), true, true)?;
        log(&format!(
            "detection: msd {:.3e}, flagged {:?}, excluded {:?}, zero false positives {}",
            attacked_det.0, attacked_det.4, attacked_det.5, zero_fp,
        ));
        (zero_fp, Some(attacked_det))
    } else {
        (true, None)
    };

    // Replay contract: every attacked run reproduces bit-for-bit —
    // including, for the detection pass, the flagged/excluded sets.
    let replay_u = run(CombineMode::Metropolis, schedule.clone(), false, false)?;
    let replay_d = run(defense, schedule.clone(), false, false)?;
    let replay_bitwise = eq(&attacked_u, &replay_u) && eq(&attacked_d, &replay_d);
    let detect_replay_bitwise = match &attacked_det {
        Some(det_pass) => {
            let replay_det = run(defense, schedule, true, false)?;
            eq(det_pass, &replay_det)
        }
        None => true,
    };

    if let Some(events) = crate::obs::export(&cfg.obs, &obs)? {
        log(&format!(
            "trace: wrote {events} events to {}",
            cfg.obs.trace_path.as_deref().unwrap_or("?")
        ));
    }

    let (msd_detected, detect_gap, flagged, excluded) = match attacked_det {
        Some(p) => (p.0, (p.0 - msd_clean_defended).abs(), p.4, p.5),
        None => (f64::NAN, f64::NAN, Vec::new(), Vec::new()),
    };
    Ok(ByzantineReport {
        attacker: attackers[0],
        attackers,
        policy,
        defense,
        msd_clean,
        msd_clean_defended,
        msd_undefended: attacked_u.0,
        msd_defended: attacked_d.0,
        defense_gap: (attacked_d.0 - msd_clean_defended).abs(),
        replay_bitwise,
        corrupted: attacked_d.2.corrupted,
        detect: det.enabled,
        msd_detected,
        detect_gap,
        flagged,
        excluded,
        detect_zero_fp,
        detect_replay_bitwise,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::experiment::InferenceConfig;

    fn tiny_cfg() -> AsyncConfig {
        let mut cfg = AsyncConfig {
            agents: 12,
            dim: 8,
            ring_k: 1,
            tau: 2,
            compute_us: 50,
            link_us: 10,
            slow_agent: None,
            infer: InferenceConfig { mu: 0.3, iters: 200, gamma: 0.1, delta: 0.5, threads: 1 },
            checkpoints: 5,
            ..AsyncConfig::default()
        };
        cfg.chaos.enabled = true;
        // Heal early (0.2T–0.4T) so well over half the horizon remains
        // for recovery — the acceptance geometry.
        cfg.chaos.partition_frac = 0.25;
        cfg.chaos.partition_start_frac = 0.2;
        cfg.chaos.partition_len_frac = 0.2;
        cfg
    }

    #[test]
    fn chaos_report_is_consistent_and_contracts_hold() {
        let cfg = tiny_cfg();
        let mut lines = Vec::new();
        let r = run_chaos(&cfg, &mut |s| lines.push(s.to_string())).unwrap();
        assert_eq!(r.rows.len(), 5);
        assert!(r.rows.windows(2).all(|w| w[0].t_us <= w[1].t_us));
        assert_eq!(r.rows.last().unwrap().t_us, r.clean_time_us);
        assert!(r.schedule_faults > 0, "schedule must actually contain the partition");
        assert!(r.rows.iter().any(|row| row.partition), "partition window never spanned a row");
        assert!(!r.rows.last().unwrap().partition, "partition must heal before T");
        // The harness contracts.
        assert!(r.replay_bitwise, "chaos run must replay bit-identically");
        assert!(r.empty_parity, "empty schedule must be bitwise fault-free");
        // Degradation machinery actually engaged across the cut...
        let cs = r.chaos_stats;
        assert!(
            cs.forced_combines > 0 || cs.stale_fallbacks > 0,
            "partition never tripped the degradation path: {cs:?}"
        );
        // ...and the run recovered: equal-sim-time MSD within the
        // acceptance band of the unpartitioned trajectory.
        assert!(
            r.recovery_gap < 1e-3,
            "recovery gap {:.3e} after healed partition",
            r.recovery_gap
        );
        assert!(r.chaos_time_us >= r.clean_time_us);
        assert_eq!(r.combine, CombineMode::Metropolis, "undirected faults keep metropolis");
        assert!(!r.auto_pushsum);
        assert!(r.tau_trace.is_none());
        assert!(r.max_staleness <= cfg.tau);
        assert!(!r.summary(cfg.agents).is_empty());
        assert!(!lines.is_empty());
    }

    #[test]
    fn adaptive_tau_rides_along_with_partition_hook() {
        let mut cfg = tiny_cfg();
        cfg.control.adaptive_tau = true;
        cfg.control.tau_min = 0;
        cfg.control.tau_max = 6;
        cfg.checkpoints = 8;
        let r = run_chaos(&cfg, &mut |_| {}).unwrap();
        let trace = r.tau_trace.expect("adaptive run records its trace");
        assert_eq!(trace.len(), 8);
        // The hook marked the cut epochs, matching the rows.
        assert!(trace.iter().any(|d| d.partition));
        for (d, row) in trace.iter().zip(&r.rows) {
            assert_eq!(d.partition, row.partition);
        }
        // Replay covers the adaptive path too.
        assert!(r.replay_bitwise);
        assert!(r.rows.iter().all(|row| row.tau <= cfg.control.tau_max));
    }

    #[test]
    fn crash_agent_out_of_range_rejected() {
        let mut cfg = tiny_cfg();
        cfg.chaos.crash_agent = Some(99);
        assert!(run_chaos(&cfg, &mut |_| {}).is_err());
    }

    #[test]
    fn byzantine_probe_defense_recovers_and_replays() {
        let mut cfg = tiny_cfg();
        cfg.infer.iters = 800;
        cfg.chaos.byzantine_agent = Some(3);
        let mut lines = Vec::new();
        let r = run_byzantine(&cfg, &mut |s| lines.push(s.to_string())).unwrap();
        assert_eq!(r.attacker, 3);
        assert_eq!(r.attackers, vec![3]);
        assert!(!r.detect, "detection defaults off");
        assert!(r.msd_detected.is_nan() && r.detect_gap.is_nan());
        assert!(r.flagged.is_empty() && r.excluded.is_empty());
        assert!(r.detect_zero_fp && r.detect_replay_bitwise, "vacuous when detection is off");
        assert_eq!(r.policy, CorruptPolicy::SignFlip, "default policy is sign-flip");
        assert_eq!(r.defense, CombineMode::TrimmedMean(1), "default defense trims one");
        assert!(r.corrupted > 0, "attack never fired");
        assert!(r.replay_bitwise, "attacked runs must replay bit-identically");
        assert!(
            r.undefended_diverged(),
            "sign-flip should bias metropolis > 10x: undefended {:.3e}, clean {:.3e}",
            r.msd_undefended,
            r.msd_clean
        );
        assert!(
            r.defense_gap < 1e-2,
            "trimmed mean should recover: gap {:.3e}",
            r.defense_gap
        );
        assert!(r.msd_clean_defended.is_finite() && r.msd_defended.is_finite());
        assert!(!r.summary().is_empty());
        assert!(!lines.is_empty());
    }

    #[test]
    fn byzantine_colluders_detection_excludes_and_recovers() {
        // f = 2 adjacent colluders on the k=2 ring: honest judges between
        // them see *both* colluders among their neighbors, so
        // TrimmedMean(1) masking alone trims only the more extreme one
        // per coordinate and the other leaks into the mean — while
        // detection excludes the pair (the leaker cascades once its
        // partner is excluded and it becomes the sole tail extreme) and
        // returns the defended trajectory to its clean fixed point.
        let mut cfg = tiny_cfg();
        cfg.ring_k = 2;
        cfg.infer.iters = 800;
        cfg.chaos.byzantine_agents = "3,4".into();
        cfg.chaos.detect = true;
        let mut lines = Vec::new();
        let r = run_byzantine(&cfg, &mut |s| lines.push(s.to_string())).unwrap();
        assert_eq!(r.attackers, vec![3, 4]);
        assert_eq!(r.attacker, 3);
        assert!(r.detect);
        assert_eq!(r.defense, CombineMode::TrimmedMean(1));
        assert!(r.corrupted > 0, "colluders never fired");
        // Detection flags and excludes the full colluding set...
        assert!(
            r.excluded.contains(&3) && r.excluded.contains(&4),
            "detection must exclude both colluders: excluded {:?}",
            r.excluded
        );
        assert!(r.flagged.contains(&3) && r.flagged.contains(&4));
        // ...with zero false positives on the clean run and a
        // bit-identical replay of the exclusion sequence.
        assert!(r.detect_zero_fp, "clean run with detection armed must stay bitwise clean");
        assert!(r.detect_replay_bitwise, "detection pass must replay bit-identically");
        assert!(r.replay_bitwise);
        // The detection-defended run recovers to its clean fixed point;
        // masking alone stays measurably biased under the collusion.
        assert!(r.msd_detected.is_finite());
        assert!(
            r.detect_gap < 1e-3,
            "detection should recover to the clean defended trajectory: gap {:.3e}",
            r.detect_gap
        );
        assert!(
            r.detect_gap < r.defense_gap,
            "detection ({:.3e}) must beat masking alone ({:.3e}) under collusion",
            r.detect_gap,
            r.defense_gap
        );
        assert!(r.summary().contains("detection"));
        assert!(!lines.is_empty());
    }

    #[test]
    fn byzantine_probe_respects_configured_defense_and_bounds() {
        let mut cfg = tiny_cfg();
        cfg.chaos.byzantine_agent = Some(99);
        assert!(run_byzantine(&cfg, &mut |_| {}).is_err(), "attacker out of range");
        let mut cfg = tiny_cfg();
        cfg.infer.iters = 150;
        cfg.chaos.byzantine_agent = Some(1);
        cfg.chaos.byzantine_policy = "constant".into();
        cfg.chaos.pushsum = "median".into();
        let r = run_byzantine(&cfg, &mut |_| {}).unwrap();
        assert_eq!(r.policy, CorruptPolicy::ConstantPsi { value: 1.0 });
        assert_eq!(r.defense, CombineMode::Median);
        assert!(r.replay_bitwise);
    }

    #[test]
    fn byzantine_schedule_rides_run_chaos_and_bursty_generator_scales() {
        // A Byzantine window in the [chaos] config flows through
        // build_schedule into the main `ddl chaos` loop without breaking
        // the replay contract (empty-parity compares *fault-free* runs,
        // so it holds regardless of the attack).
        let mut cfg = tiny_cfg();
        cfg.chaos.byzantine_agent = Some(2);
        cfg.chaos.pushsum = "trimmed:1".into();
        cfg.chaos.detect = true;
        let r = run_chaos(&cfg, &mut |_| {}).unwrap();
        assert!(r.replay_bitwise, "detection state must replay inside run_chaos too");
        assert!(r.empty_parity);
        assert_eq!(r.combine, CombineMode::TrimmedMean(1));
        assert!(r.chaos_stats.corrupted > 0, "attack never fired inside run_chaos");
        assert!(
            r.chaos_stats.detect_excluded > 0,
            "detection never excluded the attacker inside run_chaos: {:?}",
            r.chaos_stats
        );
        assert!(r.summary(cfg.agents).contains("detection:"));
        // Bursty churn windows come from the Gilbert–Elliott generator.
        let mut cfg = tiny_cfg();
        cfg.chaos.churn_windows = 3;
        let r = run_chaos(&cfg, &mut |_| {}).unwrap();
        assert!(r.replay_bitwise);
        assert!(r.empty_parity);
    }

    #[test]
    fn pushsum_bias_probe_shows_the_correction() {
        let mut cfg = tiny_cfg();
        cfg.infer.iters = 300;
        let mut lines = Vec::new();
        let p = run_pushsum_bias(&cfg, &mut |s| lines.push(s.to_string())).unwrap();
        assert!(p.links_cut > 0);
        assert!(p.msd_metropolis.is_finite() && p.msd_pushsum.is_finite());
        // Push-sum must still converge under the persistent directed
        // outage — that is the claim the combine correction makes.
        assert!(p.msd_pushsum < 5e-2, "push-sum diverged: {:.3e}", p.msd_pushsum);
        assert!(p.bias_ratio().is_finite());
        assert!(!lines.is_empty());
    }
}
