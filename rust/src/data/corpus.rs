//! Synthetic topic-model corpus — the TDT2 substitute (DESIGN.md §4).
//!
//! The NIST TDT2 corpus is LDC-licensed and not redistributable, so the
//! novelty experiments run on a generated corpus that reproduces the two
//! properties the detector exploits: documents have low-rank topical
//! structure (each document's tf-idf vector is approximately a non-negative
//! combination of its dominant topic's word distribution), and novel
//! topics appear at controlled time-steps. Word distributions per topic
//! are Dirichlet draws concentrated on a topic-specific vocabulary band
//! plus a shared background band; documents mix a dominant topic with
//! background noise; features are tf-idf, ℓ2- (or ℓ1-) normalized.

use crate::rng::{Categorical, Dirichlet, Pcg64};
use std::collections::BTreeSet;

/// One document: its feature vector and ground-truth dominant topic.
#[derive(Clone, Debug)]
pub struct Document {
    pub features: Vec<f32>,
    pub topic: usize,
}

/// Corpus generator configuration.
#[derive(Clone, Debug)]
pub struct CorpusConfig {
    /// Vocabulary size (paper TDT2: 19527; scaled default 800).
    pub vocab: usize,
    /// Number of topics (paper: 30).
    pub topics: usize,
    /// Words per document (drawn uniformly in this range).
    pub doc_len: (usize, usize),
    /// Dominant-topic weight (rest is background mixture).
    pub dominance: f64,
    /// ℓ1 instead of ℓ2 feature normalization (the ADMM baseline of [11]
    /// uses ℓ1).
    pub l1_normalize: bool,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            vocab: 800,
            topics: 30,
            doc_len: (60, 140),
            dominance: 0.85,
            l1_normalize: false,
            seed: 0x7D72,
        }
    }
}

/// Streaming corpus with a novel-topic schedule.
///
/// `schedule[s]` lists the topics first introduced at time-step `s`
/// (step 0 is the initialization batch). Documents in batch `s` draw their
/// dominant topic from all topics introduced at steps `≤ s`, with a boost
/// for the newest ones so each step contains a solid block of novel
/// documents (mirroring TDT2's by-topic ordering).
pub struct CorpusStream {
    cfg: CorpusConfig,
    /// Per-topic word samplers.
    word_dist: Vec<Categorical>,
    /// idf weights from a reference collection.
    idf: Vec<f32>,
    /// Topics introduced per step.
    schedule: Vec<Vec<usize>>,
    rng: Pcg64,
}

impl CorpusStream {
    /// Build the generator. `schedule` must cover every topic exactly once.
    pub fn new(cfg: CorpusConfig, schedule: Vec<Vec<usize>>) -> Self {
        let all: Vec<usize> = schedule.iter().flatten().copied().collect();
        let unique: BTreeSet<usize> = all.iter().copied().collect();
        assert_eq!(all.len(), unique.len(), "schedule repeats a topic");
        assert!(
            unique.iter().all(|&t| t < cfg.topics),
            "schedule topic out of range"
        );
        assert_eq!(unique.len(), cfg.topics, "schedule must cover every topic");

        let mut rng = Pcg64::new(cfg.seed);
        // Topic word distributions: band of dedicated words + background.
        let background = cfg.vocab / 5; // first 20% of vocab shared
        let band = (cfg.vocab - background) / cfg.topics;
        let mut word_dist = Vec::with_capacity(cfg.topics);
        for t in 0..cfg.topics {
            let mut weights = vec![0.0f64; cfg.vocab];
            // Background mass.
            let bg = Dirichlet::symmetric(background, 0.5).sample(&mut rng);
            for (i, &w) in bg.iter().enumerate() {
                weights[i] = 0.25 * w;
            }
            // Dedicated band mass.
            let start = background + t * band;
            let len = if t == cfg.topics - 1 { cfg.vocab - start } else { band };
            let dw = Dirichlet::symmetric(len, 0.3).sample(&mut rng);
            for (i, &w) in dw.iter().enumerate() {
                weights[start + i] = 0.75 * w;
            }
            word_dist.push(Categorical::new(&weights));
        }

        // idf from a reference collection spanning all topics.
        let ref_docs = 40 * cfg.topics;
        let mut df = vec![0usize; cfg.vocab];
        for d in 0..ref_docs {
            let t = d % cfg.topics;
            let counts = draw_counts(&cfg, &word_dist, t, &mut rng);
            for (w, &c) in counts.iter().enumerate() {
                if c > 0.0 {
                    df[w] += 1;
                }
            }
        }
        let idf: Vec<f32> = df
            .iter()
            .map(|&d| ((ref_docs as f32 + 1.0) / (d as f32 + 1.0)).ln())
            .collect();

        CorpusStream { cfg, word_dist, idf, schedule, rng }
    }

    /// Default schedule used by the squared-ℓ2 experiment: 6 initial
    /// topics, then 3 new topics at every one of 8 steps (6 + 24 = 30).
    pub fn spread_schedule(topics: usize, steps: usize) -> Vec<Vec<usize>> {
        let init = topics - steps * ((topics.saturating_sub(topics / 5)) / steps.max(1)).min(3);
        let init = init.max(1);
        let mut schedule = vec![(0..init).collect::<Vec<_>>()];
        let mut next = init;
        for s in 0..steps {
            let remaining = topics - next;
            let left_steps = steps - s;
            let take = remaining.div_ceil(left_steps);
            schedule.push((next..next + take).collect());
            next += take;
        }
        schedule
    }

    /// Schedule matching the Huber experiment of §IV-C2: novel topics only
    /// at steps 1, 2, 5, 6, 8 (1-based); other steps introduce nothing.
    pub fn huber_schedule(topics: usize, steps: usize) -> Vec<Vec<usize>> {
        let novel_steps = [1usize, 2, 5, 6, 8];
        let active: Vec<usize> = novel_steps.iter().filter(|&&s| s <= steps).copied().collect();
        let init = topics / 2;
        let mut schedule = vec![(0..init).collect::<Vec<_>>()];
        let mut next = init;
        for s in 1..=steps {
            if active.contains(&s) {
                let pos = active.iter().position(|&a| a == s).unwrap();
                let remaining = topics - next;
                let left = active.len() - pos;
                let take = remaining.div_ceil(left);
                schedule.push((next..next + take).collect());
                next += take;
            } else {
                schedule.push(Vec::new());
            }
        }
        schedule
    }

    /// Topics introduced at step `s` (0 = initialization batch).
    pub fn new_topics_at(&self, s: usize) -> &[usize] {
        &self.schedule[s]
    }

    /// All topics seen in steps `0..=s`.
    pub fn seen_through(&self, s: usize) -> BTreeSet<usize> {
        self.schedule[..=s.min(self.schedule.len() - 1)]
            .iter()
            .flatten()
            .copied()
            .collect()
    }

    /// Number of schedule steps (including step 0).
    pub fn steps(&self) -> usize {
        self.schedule.len()
    }

    /// Generate batch for step `s` with `n` documents. Novel topics (those
    /// introduced at step `s`) receive ≈35% of the batch.
    pub fn batch(&mut self, s: usize, n: usize) -> Vec<Document> {
        let seen_before: Vec<usize> = if s == 0 {
            Vec::new()
        } else {
            self.seen_through(s - 1).into_iter().collect()
        };
        let new: Vec<usize> = self.schedule[s].clone();
        let mut docs = Vec::with_capacity(n);
        for i in 0..n {
            let topic = if s == 0 {
                new[i % new.len()]
            } else if !new.is_empty() && self.rng.next_f64() < 0.35 {
                new[self.rng.next_below(new.len() as u64) as usize]
            } else if !seen_before.is_empty() {
                seen_before[self.rng.next_below(seen_before.len() as u64) as usize]
            } else {
                new[self.rng.next_below(new.len() as u64) as usize]
            };
            docs.push(self.make_doc(topic));
        }
        docs
    }

    /// Fixed test set spanning all topics (the sq-Euclid protocol keeps a
    /// held-out set with every category present).
    pub fn test_set(&mut self, n: usize) -> Vec<Document> {
        (0..n)
            .map(|i| {
                let topic = i % self.cfg.topics;
                self.make_doc(topic)
            })
            .collect()
    }

    fn make_doc(&mut self, topic: usize) -> Document {
        let counts = draw_counts(&self.cfg, &self.word_dist, topic, &mut self.rng);
        let mut feat: Vec<f32> = counts
            .iter()
            .zip(&self.idf)
            .map(|(&c, &w)| c * w)
            .collect();
        if self.cfg.l1_normalize {
            let n = crate::math::vector::norm1(&feat);
            if n > 0.0 {
                crate::math::vector::scale(1.0 / n, &mut feat);
            }
        } else {
            crate::math::vector::normalize(&mut feat);
        }
        Document { features: feat, topic }
    }

    /// Vocabulary size (feature dimension M).
    pub fn dim(&self) -> usize {
        self.cfg.vocab
    }
}

/// Draw raw term counts for a document with the given dominant topic.
fn draw_counts(
    cfg: &CorpusConfig,
    word_dist: &[Categorical],
    topic: usize,
    rng: &mut Pcg64,
) -> Vec<f32> {
    let span = cfg.doc_len.1 - cfg.doc_len.0;
    let len = cfg.doc_len.0 + if span > 0 { rng.next_below(span as u64 + 1) as usize } else { 0 };
    let mut counts = vec![0.0f32; cfg.vocab];
    for _ in 0..len {
        let t = if rng.next_f64() < cfg.dominance {
            topic
        } else {
            rng.next_below(cfg.topics as u64) as usize
        };
        let w = word_dist[t].sample(rng);
        counts[w] += 1.0;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> CorpusConfig {
        CorpusConfig { vocab: 120, topics: 6, ..Default::default() }
    }

    #[test]
    fn spread_schedule_covers_all_topics_once() {
        let s = CorpusStream::spread_schedule(30, 8);
        let all: Vec<usize> = s.iter().flatten().copied().collect();
        let mut sorted = all.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert_eq!(all.len(), 30);
        assert_eq!(s.len(), 9); // init + 8 steps
        assert!(!s[0].is_empty());
    }

    #[test]
    fn huber_schedule_only_at_paper_steps() {
        let s = CorpusStream::huber_schedule(30, 8);
        assert_eq!(s.len(), 9);
        for (step, topics) in s.iter().enumerate().skip(1) {
            let should_have = [1, 2, 5, 6, 8].contains(&step);
            assert_eq!(!topics.is_empty(), should_have, "step {step}");
        }
        let all: Vec<usize> = s.iter().flatten().copied().collect();
        assert_eq!(all.len(), 30);
    }

    #[test]
    fn features_normalized() {
        let cfg = small_cfg();
        let sched = CorpusStream::spread_schedule(6, 3);
        let mut cs = CorpusStream::new(cfg, sched);
        let docs = cs.batch(0, 10);
        for d in &docs {
            let n = crate::math::vector::norm2(&d.features);
            assert!((n - 1.0).abs() < 1e-4, "norm {n}");
            assert!(d.features.iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn l1_normalization_option() {
        let cfg = CorpusConfig { l1_normalize: true, ..small_cfg() };
        let sched = CorpusStream::spread_schedule(6, 3);
        let mut cs = CorpusStream::new(cfg, sched);
        let docs = cs.batch(0, 5);
        for d in &docs {
            let n = crate::math::vector::norm1(&d.features);
            assert!((n - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn same_topic_docs_more_similar_than_cross_topic() {
        let cfg = small_cfg();
        let sched = vec![(0..6).collect::<Vec<_>>()];
        let mut cs = CorpusStream::new(cfg, sched);
        let docs = cs.test_set(60);
        let mut same = Vec::new();
        let mut cross = Vec::new();
        for i in 0..docs.len() {
            for j in i + 1..docs.len() {
                let sim = crate::math::blas::dot(&docs[i].features, &docs[j].features) as f64;
                if docs[i].topic == docs[j].topic {
                    same.push(sim);
                } else {
                    cross.push(sim);
                }
            }
        }
        let ms = crate::math::stats::mean(&same);
        let mc = crate::math::stats::mean(&cross);
        assert!(ms > 2.0 * mc, "same-topic sim {ms} vs cross {mc}");
    }

    #[test]
    fn batch_contains_novel_docs_when_scheduled() {
        let cfg = small_cfg();
        let sched = CorpusStream::spread_schedule(6, 3);
        let mut cs = CorpusStream::new(cfg, sched);
        let _ = cs.batch(0, 20);
        let seen = cs.seen_through(0);
        let b1 = cs.batch(1, 60);
        let novel = b1.iter().filter(|d| !seen.contains(&d.topic)).count();
        assert!(novel > 10, "only {novel} novel docs in step-1 batch");
        assert!(novel < 40, "too many novel docs: {novel}");
    }

    #[test]
    #[should_panic(expected = "schedule must cover every topic")]
    fn incomplete_schedule_rejected() {
        CorpusStream::new(small_cfg(), vec![vec![0, 1]]);
    }

    #[test]
    #[should_panic(expected = "schedule repeats a topic")]
    fn duplicate_schedule_rejected() {
        CorpusStream::new(small_cfg(), vec![vec![0, 1, 2, 3, 4, 5], vec![0]]);
    }
}
