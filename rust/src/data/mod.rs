//! Data substrates.
//!
//! The paper's datasets are not redistributable (van Hateren natural
//! images; the LDC-licensed TDT2 corpus), so this module builds synthetic
//! equivalents that preserve the statistics the algorithms exploit — see
//! DESIGN.md §4 for the substitution arguments.

pub mod corpus;
pub mod field;
pub mod images;
pub mod noise;
pub mod patches;

pub use corpus::{CorpusConfig, CorpusStream, Document};
pub use field::FieldModel;
pub use images::{synth_scene, Image};
pub use noise::add_awgn;
pub use patches::{extract_patch, PatchSampler, Reconstructor};
