//! Synthetic natural-scene generator.
//!
//! Substitutes for the van Hateren natural-image dataset [50] used in the
//! paper's denoising experiment. Natural scenes are characterized by
//! (i) piecewise-smooth regions separated by oriented edges and (ii) a
//! 1/f amplitude spectrum; dictionary learning on such patches produces
//! edge-like atoms (paper Fig. 5c/f/i). The generator composes:
//! smooth illumination gradients + random oriented half-plane edges with
//! soft transitions + elliptical blobs + low-pass textured noise, on the
//! 0–255 intensity scale the paper's PSNR numbers assume.

use crate::rng::Pcg64;

/// Grayscale image, row-major, intensities in `[0, 255]`.
#[derive(Clone, Debug)]
pub struct Image {
    pub width: usize,
    pub height: usize,
    pub pixels: Vec<f32>,
}

impl Image {
    /// Constant image.
    pub fn new(width: usize, height: usize, fill: f32) -> Self {
        Image { width, height, pixels: vec![fill; width * height] }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.pixels[r * self.width + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.pixels[r * self.width + c] = v;
    }

    /// Clamp all intensities into `[0, 255]`.
    pub fn clamp(&mut self) {
        for p in &mut self.pixels {
            *p = p.clamp(0.0, 255.0);
        }
    }

    /// Maximum intensity (the paper's `I_max` for PSNR).
    pub fn max_intensity(&self) -> f32 {
        self.pixels.iter().fold(0.0f32, |m, &v| m.max(v))
    }

    /// Write as ASCII PGM (P2) for eyeballing results.
    pub fn write_pgm(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "P2\n{} {}\n255", self.width, self.height)?;
        for r in 0..self.height {
            let row: Vec<String> = (0..self.width)
                .map(|c| format!("{}", self.get(r, c).clamp(0.0, 255.0) as u32))
                .collect();
            writeln!(f, "{}", row.join(" "))?;
        }
        Ok(())
    }
}

/// Generate a synthetic natural scene of size `side × side`.
pub fn synth_scene(side: usize, rng: &mut Pcg64) -> Image {
    let mut img = Image::new(side, side, 0.0);
    let s = side as f32;

    // 1. Smooth illumination gradient with random direction.
    let ang = rng.next_f32() * std::f32::consts::TAU;
    let (gx, gy) = (ang.cos(), ang.sin());
    let base = 90.0 + 60.0 * rng.next_f32();
    let grad_amp = 30.0 + 30.0 * rng.next_f32();
    for r in 0..side {
        for c in 0..side {
            let t = (gx * c as f32 + gy * r as f32) / s;
            img.set(r, c, base + grad_amp * t);
        }
    }

    // 2. Oriented soft edges: each adds a step across a random line,
    //    smoothed with a logistic profile (edge width 1–3 px). Amplitudes
    //    match natural-scene contrast (van Hateren patches routinely span
    //    >150 intensity levels across an edge).
    let n_edges = 10 + rng.next_below(10) as usize;
    for _ in 0..n_edges {
        let ang = rng.next_f32() * std::f32::consts::TAU;
        let (nx, ny) = (ang.cos(), ang.sin());
        let off = (rng.next_f32() - 0.5) * 1.2 * s;
        let amp = (rng.next_f32() - 0.5) * 220.0;
        let width = 0.8 + 2.2 * rng.next_f32();
        for r in 0..side {
            for c in 0..side {
                let d = nx * (c as f32 - s / 2.0) + ny * (r as f32 - s / 2.0) - off;
                let sgm = 1.0 / (1.0 + (-d / width).exp());
                let v = img.get(r, c) + amp * (sgm - 0.5);
                img.set(r, c, v);
            }
        }
    }

    // 3. Soft elliptical blobs (objects / shading).
    let n_blobs = 3 + rng.next_below(4) as usize;
    for _ in 0..n_blobs {
        let cx = rng.next_f32() * s;
        let cy = rng.next_f32() * s;
        let rx = s * (0.05 + 0.15 * rng.next_f32());
        let ry = s * (0.05 + 0.15 * rng.next_f32());
        let amp = (rng.next_f32() - 0.5) * 140.0;
        for r in 0..side {
            for c in 0..side {
                let dx = (c as f32 - cx) / rx;
                let dy = (r as f32 - cy) / ry;
                let d2 = dx * dx + dy * dy;
                if d2 < 9.0 {
                    let v = img.get(r, c) + amp * (-d2).exp();
                    img.set(r, c, v);
                }
            }
        }
    }

    // 4. Low-pass texture: white noise smoothed by a separable box blur
    //    (approximating the 1/f spectrum's high-frequency rolloff).
    let mut noise: Vec<f32> = (0..side * side).map(|_| rng.next_normal() * 10.0).collect();
    box_blur(&mut noise, side, side, 2);
    for (p, &n) in img.pixels.iter_mut().zip(&noise) {
        *p += n;
    }

    img.clamp();
    img
}

/// Separable box blur with the given radius, in place.
fn box_blur(buf: &mut [f32], w: usize, h: usize, radius: usize) {
    let mut tmp = vec![0.0f32; w * h];
    // Horizontal.
    for r in 0..h {
        for c in 0..w {
            let lo = c.saturating_sub(radius);
            let hi = (c + radius).min(w - 1);
            let mut s = 0.0;
            for cc in lo..=hi {
                s += buf[r * w + cc];
            }
            tmp[r * w + c] = s / (hi - lo + 1) as f32;
        }
    }
    // Vertical.
    for r in 0..h {
        for c in 0..w {
            let lo = r.saturating_sub(radius);
            let hi = (r + radius).min(h - 1);
            let mut s = 0.0;
            for rr in lo..=hi {
                s += tmp[rr * w + c];
            }
            buf[r * w + c] = s / (hi - lo + 1) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scene_in_range() {
        let mut rng = Pcg64::new(1);
        let img = synth_scene(64, &mut rng);
        assert_eq!(img.pixels.len(), 64 * 64);
        assert!(img.pixels.iter().all(|&v| (0.0..=255.0).contains(&v)));
        assert!(img.max_intensity() > 100.0, "scene should use the dynamic range");
    }

    #[test]
    fn scenes_differ_across_seeds() {
        let a = synth_scene(32, &mut Pcg64::new(1));
        let b = synth_scene(32, &mut Pcg64::new(2));
        let diff: f32 = a
            .pixels
            .iter()
            .zip(&b.pixels)
            .map(|(&x, &y)| (x - y).abs())
            .sum();
        assert!(diff > 1000.0);
    }

    #[test]
    fn scene_reproducible_per_seed() {
        let a = synth_scene(32, &mut Pcg64::new(7));
        let b = synth_scene(32, &mut Pcg64::new(7));
        assert_eq!(a.pixels, b.pixels);
    }

    /// Natural-scene proxy property: substantial local gradient structure
    /// (edges) but high neighboring-pixel correlation (smooth regions).
    #[test]
    fn scene_is_piecewise_smooth() {
        let img = synth_scene(64, &mut Pcg64::new(3));
        let mut grads = Vec::new();
        for r in 0..64 {
            for c in 0..63 {
                grads.push((img.get(r, c + 1) - img.get(r, c)).abs() as f64);
            }
        }
        let mean_grad = crate::math::stats::mean(&grads);
        let p95 = crate::math::stats::percentile(&grads, 95.0);
        // Smooth on average (small median step) with heavy tails (edges).
        assert!(mean_grad < 25.0, "mean grad {mean_grad}");
        assert!(p95 > 1.5 * mean_grad, "p95 {p95} vs mean {mean_grad}");
    }

    #[test]
    fn pgm_roundtrip_header() {
        let img = synth_scene(8, &mut Pcg64::new(4));
        let path = std::env::temp_dir().join("ddl_scene_test.pgm");
        img.write_pgm(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("P2\n8 8\n255"));
        std::fs::remove_file(&path).ok();
    }
}
