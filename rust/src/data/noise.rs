//! Noise injection for the denoising experiment.

use crate::data::Image;
use crate::rng::Pcg64;

/// Corrupt an image with additive white Gaussian noise of standard
/// deviation `sigma` (paper: σ = 50 on the 0–255 scale → ≈14.1 dB PSNR).
/// The result is clamped back into `[0, 255]`, matching how the paper's
/// corrupted image is displayed and scored.
pub fn add_awgn(img: &Image, sigma: f32, rng: &mut Pcg64) -> Image {
    let mut out = img.clone();
    for p in &mut out.pixels {
        *p += sigma * rng.next_normal();
    }
    out.clamp();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_scene;
    use crate::metrics::psnr;

    #[test]
    fn noise_has_requested_power() {
        let img = Image::new(64, 64, 128.0); // mid-gray avoids clamping bias
        let noisy = add_awgn(&img, 25.0, &mut Pcg64::new(1));
        let mse = crate::metrics::mse(&img.pixels, &noisy.pixels);
        assert!((mse.sqrt() - 25.0).abs() < 1.5, "std {}", mse.sqrt());
    }

    #[test]
    fn sigma50_gives_about_14db_psnr() {
        // The paper's corrupted image is 14.06 dB; clamping at [0,255]
        // pushes the measured PSNR slightly above the ideal 14.15 dB.
        let mut rng = Pcg64::new(2);
        let img = synth_scene(128, &mut rng);
        let noisy = add_awgn(&img, 50.0, &mut rng);
        let p = psnr(&img.pixels, &noisy.pixels, 255.0);
        assert!((p - 14.1).abs() < 1.5, "psnr {p}");
    }

    #[test]
    fn zero_sigma_identity() {
        let mut rng = Pcg64::new(3);
        let img = synth_scene(16, &mut rng);
        let noisy = add_awgn(&img, 0.0, &mut rng);
        assert_eq!(img.pixels, noisy.pixels);
    }
}
