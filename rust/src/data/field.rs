//! Sensor-network field model (arXiv:1304.3568-style workload).
//!
//! Distributed dictionary learning was originally motivated by sensor
//! networks monitoring a physical field: each of `M` sensors sits at a
//! fixed location and observes a spatially-correlated scalar (temperature,
//! concentration, signal strength). A snapshot of the whole network is one
//! `M`-dimensional sample whose coordinates are correlated through the
//! sensors' spatial proximity — exactly the structure a shared dictionary
//! of smooth spatial modes can compress.
//!
//! The generator here superposes a few Gaussian bumps (point sources with
//! random centers and amplitudes) over a fixed sensor grid on the unit
//! square, plus per-sensor observation noise. Nearby sensors see nearly
//! the same mixture of bumps, so their readings co-vary strongly; distant
//! sensors are nearly independent — spatial correlation without needing a
//! covariance factorization. Sampling is a pure function of the caller's
//! RNG state, so field streams replay bit-identically per seed like every
//! other workload.

use crate::rng::Pcg64;

/// Spatially-correlated field snapshot generator over a fixed sensor grid.
#[derive(Clone, Debug)]
pub struct FieldModel {
    /// Sensor coordinates on the unit square, index-aligned with the
    /// sample dimensions.
    positions: Vec<(f32, f32)>,
    /// Gaussian bumps superposed per snapshot.
    sources: usize,
    /// Bump width (std-dev) in unit-square coordinates.
    width: f32,
    /// Per-sensor observation noise σ.
    noise_sigma: f32,
}

impl FieldModel {
    /// `m` sensors on a near-square grid spanning the unit square.
    pub fn new(m: usize, sources: usize, width: f32, noise_sigma: f32) -> Self {
        let side = (m as f64).sqrt().ceil().max(1.0) as usize;
        let step = 1.0 / side as f32;
        let positions = (0..m)
            .map(|i| {
                let (r, c) = (i / side, i % side);
                // Cell centers so a 1×1 grid sits at (0.5, 0.5).
                ((c as f32 + 0.5) * step, (r as f32 + 0.5) * step)
            })
            .collect();
        FieldModel { positions, sources: sources.max(1), width: width.max(1e-3), noise_sigma }
    }

    /// Sensor count `M` (the sample dimension).
    pub fn dim(&self) -> usize {
        self.positions.len()
    }

    /// Sensor coordinates, index-aligned with sample dimensions.
    pub fn positions(&self) -> &[(f32, f32)] {
        &self.positions
    }

    /// Draw one field snapshot into `out` (length `M`). Consumes exactly
    /// `3 · sources + M` RNG draws regardless of outcome, keeping stream
    /// replay offsets deterministic.
    pub fn sample_into(&self, rng: &mut Pcg64, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.positions.len());
        out.iter_mut().for_each(|v| *v = 0.0);
        let inv_two_w2 = 1.0 / (2.0 * self.width * self.width);
        for _ in 0..self.sources {
            let cx = rng.next_f32();
            let cy = rng.next_f32();
            let amp = 0.5 + rng.next_f32();
            for (v, &(px, py)) in out.iter_mut().zip(self.positions.iter()) {
                let dx = px - cx;
                let dy = py - cy;
                *v += amp * (-(dx * dx + dy * dy) * inv_two_w2).exp();
            }
        }
        if self.noise_sigma > 0.0 {
            for v in out.iter_mut() {
                *v += self.noise_sigma * rng.next_normal();
            }
        } else {
            // Burn the draws anyway so σ = 0 and σ > 0 streams stay
            // offset-aligned.
            for _ in 0..self.positions.len() {
                rng.next_normal();
            }
        }
    }

    /// Allocating convenience wrapper around [`Self::sample_into`].
    pub fn sample(&self, rng: &mut Pcg64) -> Vec<f32> {
        let mut out = vec![0.0; self.dim()];
        self.sample_into(rng, &mut out);
        out
    }
}

/// Mean Pearson correlation of sensor-pair readings over `samples` draws,
/// restricted to pairs whose grid distance is below (`near = true`) or
/// above (`near = false`) the median pair distance. Used by tests and the
/// `ddl field` coordinator to report how spatially structured the stream
/// is.
pub fn spatial_correlation(model: &FieldModel, rng: &mut Pcg64, samples: usize, near: bool) -> f64 {
    let m = model.dim();
    let mut data = vec![0.0f32; samples * m];
    let mut buf = vec![0.0f32; m];
    for s in 0..samples {
        model.sample_into(rng, &mut buf);
        data[s * m..(s + 1) * m].copy_from_slice(&buf);
    }
    // Per-sensor mean/std.
    let mut mean = vec![0.0f64; m];
    for s in 0..samples {
        for i in 0..m {
            mean[i] += f64::from(data[s * m + i]);
        }
    }
    mean.iter_mut().for_each(|v| *v /= samples as f64);
    let mut var = vec![0.0f64; m];
    for s in 0..samples {
        for i in 0..m {
            let d = f64::from(data[s * m + i]) - mean[i];
            var[i] += d * d;
        }
    }
    let sd: Vec<f64> = var.iter().map(|v| (v / samples as f64).sqrt().max(1e-12)).collect();
    // Median pair distance splits "near" from "far".
    let mut dists = Vec::new();
    let pos = model.positions();
    for i in 0..m {
        for j in (i + 1)..m {
            let (dx, dy) = (pos[i].0 - pos[j].0, pos[i].1 - pos[j].1);
            dists.push(((dx * dx + dy * dy) as f64).sqrt());
        }
    }
    dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = dists[dists.len() / 2];
    let mut acc = 0.0;
    let mut count = 0usize;
    for i in 0..m {
        for j in (i + 1)..m {
            let (dx, dy) = (pos[i].0 - pos[j].0, pos[i].1 - pos[j].1);
            let d = ((dx * dx + dy * dy) as f64).sqrt();
            if (d < median) != near {
                continue;
            }
            let mut cov = 0.0;
            for s in 0..samples {
                cov += (f64::from(data[s * m + i]) - mean[i])
                    * (f64::from(data[s * m + j]) - mean[j]);
            }
            acc += cov / (samples as f64 * sd[i] * sd[j]);
            count += 1;
        }
    }
    if count == 0 {
        0.0
    } else {
        acc / count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_samples_replay_per_seed() {
        let model = FieldModel::new(25, 3, 0.15, 0.02);
        let mut a = Pcg64::new(0xF1E1D);
        let mut b = Pcg64::new(0xF1E1D);
        for _ in 0..8 {
            let xa = model.sample(&mut a);
            let xb = model.sample(&mut b);
            assert_eq!(xa, xb, "field stream must replay bit-identically");
        }
        let mut c = Pcg64::new(0xF1E1E);
        assert_ne!(model.sample(&mut c), model.sample(&mut a), "different seeds differ");
    }

    #[test]
    fn neighbors_correlate_more_than_distant_sensors() {
        let model = FieldModel::new(36, 3, 0.15, 0.02);
        let mut rng = Pcg64::new(0xC0441);
        let near = spatial_correlation(&model, &mut rng, 200, true);
        let mut rng = Pcg64::new(0xC0441);
        let far = spatial_correlation(&model, &mut rng, 200, false);
        assert!(
            near > far + 0.1,
            "spatial structure missing: near {near:.3} vs far {far:.3}"
        );
        assert!(near > 0.2, "adjacent sensors should co-vary strongly, got {near:.3}");
    }

    #[test]
    fn noise_free_stream_keeps_rng_offsets_aligned() {
        // σ = 0 burns the same number of draws as σ > 0, so downstream
        // arrival-time draws land identically.
        let noisy = FieldModel::new(16, 2, 0.2, 0.05);
        let clean = FieldModel::new(16, 2, 0.2, 0.0);
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        noisy.sample(&mut a);
        clean.sample(&mut b);
        assert_eq!(a.next_f64().to_bits(), b.next_f64().to_bits());
    }

    #[test]
    fn grid_covers_unit_square() {
        let model = FieldModel::new(10, 1, 0.1, 0.0);
        assert_eq!(model.dim(), 10);
        for &(x, y) in model.positions() {
            assert!((0.0..=1.0).contains(&x) && (0.0..=1.0).contains(&y));
        }
    }
}
