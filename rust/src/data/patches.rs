//! Patch pipeline for the image-denoising experiment (§IV-B).
//!
//! Extraction vectorizes a `p × p` patch by vertically stacking its
//! columns (the paper's convention, M = p²). Training patches have their
//! DC (mean) removed — standard practice in dictionary-learning denoisers
//! [5], [6]; the DC is restored at reconstruction. Denoising slides a
//! window with configurable stride and averages overlapping estimates
//! (overlap-add with per-pixel counts).

use crate::data::Image;
use crate::rng::Pcg64;

/// Extract the `p × p` patch whose top-left corner is `(r, c)`, stacked
/// column-major into `out` (length p²).
pub fn extract_patch(img: &Image, r: usize, c: usize, p: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), p * p);
    debug_assert!(r + p <= img.height && c + p <= img.width);
    for cc in 0..p {
        for rr in 0..p {
            out[cc * p + rr] = img.get(r + rr, c + cc);
        }
    }
}

/// Random patch sampler over a set of images with DC removal and optional
/// low-variance rejection (flat patches carry no gradient signal at the
/// paper's γ = 45 operating point — standard practice in dictionary
/// learning trainers, cf. SPAMS' variance filtering).
pub struct PatchSampler {
    images: Vec<Image>,
    p: usize,
    rng: Pcg64,
    min_std: f32,
}

impl PatchSampler {
    pub fn new(images: Vec<Image>, p: usize, seed: u64) -> Self {
        assert!(!images.is_empty());
        assert!(images.iter().all(|i| i.width >= p && i.height >= p));
        PatchSampler { images, p, rng: Pcg64::new(seed), min_std: 0.0 }
    }

    /// Reject patches whose pixel standard deviation is below `min_std`
    /// (retry-capped; 0 disables rejection).
    pub fn with_min_std(mut self, min_std: f32) -> Self {
        self.min_std = min_std;
        self
    }

    /// Patch dimension M = p².
    pub fn dim(&self) -> usize {
        self.p * self.p
    }

    /// Draw a random patch; returns (patch − mean, mean).
    pub fn sample(&mut self) -> (Vec<f32>, f32) {
        let mut best: Option<(Vec<f32>, f32, f32)> = None;
        for _ in 0..32 {
            let idx = self.rng.next_below(self.images.len() as u64) as usize;
            let img = &self.images[idx];
            let r = self.rng.next_below((img.height - self.p + 1) as u64) as usize;
            let c = self.rng.next_below((img.width - self.p + 1) as u64) as usize;
            let mut patch = vec![0.0f32; self.p * self.p];
            extract_patch(img, r, c, self.p, &mut patch);
            let mean = crate::math::vector::mean(&patch);
            for v in &mut patch {
                *v -= mean;
            }
            let std = (crate::math::vector::norm2_sq(&patch) / patch.len() as f32).sqrt();
            if std >= self.min_std {
                return (patch, mean);
            }
            // Keep the most textured reject as a fallback.
            if best.as_ref().map(|(_, _, s)| std > *s).unwrap_or(true) {
                best = Some((patch, mean, std));
            }
        }
        let (patch, mean, _) = best.unwrap();
        (patch, mean)
    }
}

/// Overlap-add reconstructor for sliding-window denoising.
pub struct Reconstructor {
    acc: Vec<f64>,
    count: Vec<f64>,
    width: usize,
    height: usize,
    p: usize,
}

impl Reconstructor {
    pub fn new(width: usize, height: usize, p: usize) -> Self {
        Reconstructor {
            acc: vec![0.0; width * height],
            count: vec![0.0; width * height],
            width,
            height,
            p,
        }
    }

    /// Deposit a denoised patch (stacked column-major, DC already added
    /// back) at top-left `(r, c)`.
    pub fn add_patch(&mut self, r: usize, c: usize, patch: &[f32]) {
        debug_assert_eq!(patch.len(), self.p * self.p);
        for cc in 0..self.p {
            for rr in 0..self.p {
                let idx = (r + rr) * self.width + (c + cc);
                self.acc[idx] += patch[cc * self.p + rr] as f64;
                self.count[idx] += 1.0;
            }
        }
    }

    /// Finalize into an image; uncovered pixels fall back to `fallback`.
    pub fn finish(self, fallback: &Image) -> Image {
        let mut img = Image::new(self.width, self.height, 0.0);
        for i in 0..self.acc.len() {
            img.pixels[i] = if self.count[i] > 0.0 {
                (self.acc[i] / self.count[i]) as f32
            } else {
                fallback.pixels[i]
            };
        }
        img.clamp();
        img
    }

    /// Iterate the top-left corners of a stride-`s` sliding window that
    /// always includes the last row/column band.
    pub fn corners(width: usize, height: usize, p: usize, stride: usize) -> Vec<(usize, usize)> {
        let stride = stride.max(1);
        let mut rows: Vec<usize> = (0..=height.saturating_sub(p)).step_by(stride).collect();
        let mut cols: Vec<usize> = (0..=width.saturating_sub(p)).step_by(stride).collect();
        if *rows.last().unwrap_or(&0) != height - p {
            rows.push(height - p);
        }
        if *cols.last().unwrap_or(&0) != width - p {
            cols.push(width - p);
        }
        let mut out = Vec::with_capacity(rows.len() * cols.len());
        for &r in &rows {
            for &c in &cols {
                out.push((r, c));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_scene;

    #[test]
    fn extract_column_stacked() {
        let mut img = Image::new(4, 4, 0.0);
        for r in 0..4 {
            for c in 0..4 {
                img.set(r, c, (r * 4 + c) as f32);
            }
        }
        let mut patch = vec![0.0; 4];
        extract_patch(&img, 1, 2, 2, &mut patch);
        // Patch rows 1..3, cols 2..4 → columns stacked: [(1,2),(2,2),(1,3),(2,3)].
        assert_eq!(patch, vec![6.0, 10.0, 7.0, 11.0]);
    }

    #[test]
    fn sampler_removes_dc() {
        let mut rng = Pcg64::new(1);
        let img = synth_scene(32, &mut rng);
        let mut sampler = PatchSampler::new(vec![img], 10, 2);
        for _ in 0..20 {
            let (patch, mean) = sampler.sample();
            assert_eq!(patch.len(), 100);
            assert!(crate::math::vector::mean(&patch).abs() < 1e-3);
            assert!(mean >= 0.0 && mean <= 255.0);
        }
        assert_eq!(sampler.dim(), 100);
    }

    #[test]
    fn reconstruct_identity_when_patches_exact() {
        // Depositing the true patches must reproduce the image exactly.
        let mut rng = Pcg64::new(3);
        let img = synth_scene(24, &mut rng);
        let p = 6;
        let mut rec = Reconstructor::new(24, 24, p);
        for (r, c) in Reconstructor::corners(24, 24, p, 2) {
            let mut patch = vec![0.0; p * p];
            extract_patch(&img, r, c, p, &mut patch);
            rec.add_patch(r, c, &patch);
        }
        let out = rec.finish(&img);
        for (a, b) in out.pixels.iter().zip(&img.pixels) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn corners_cover_borders() {
        let corners = Reconstructor::corners(17, 13, 5, 4);
        assert!(corners.contains(&(8, 12)));
        let max_r = corners.iter().map(|&(r, _)| r).max().unwrap();
        let max_c = corners.iter().map(|&(_, c)| c).max().unwrap();
        assert_eq!(max_r, 13 - 5);
        assert_eq!(max_c, 17 - 5);
    }

    #[test]
    fn uncovered_pixels_use_fallback() {
        let fallback = Image::new(8, 8, 42.0);
        let rec = Reconstructor::new(8, 8, 4);
        let out = rec.finish(&fallback);
        assert!(out.pixels.iter().all(|&v| v == 42.0));
    }
}
