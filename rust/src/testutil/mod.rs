//! Mini property-testing framework (proptest is unavailable offline).
//!
//! Seeded generators + linear shrinking: on failure the runner retries with
//! progressively "smaller" inputs (shrunk toward zero / shorter) and reports
//! the smallest failing case. Deliberately tiny but covers what the
//! invariant tests need: scalars, vectors, matrices, and graphs.

use crate::rng::Pcg64;

/// A generated value together with shrink candidates.
pub trait Gen {
    type Value: std::fmt::Debug + Clone;
    /// Draw a value.
    fn gen(&self, rng: &mut Pcg64) -> Self::Value;
    /// Produce progressively simpler variants of `v` (possibly empty).
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Uniform `f32` in `[lo, hi]`.
pub struct F32Range {
    pub lo: f32,
    pub hi: f32,
}

impl Gen for F32Range {
    type Value = f32;
    fn gen(&self, rng: &mut Pcg64) -> f32 {
        self.lo + (self.hi - self.lo) * rng.next_f32()
    }
    fn shrink(&self, v: &f32) -> Vec<f32> {
        let zero = self.lo.max(0.0f32.min(self.hi));
        if (*v - zero).abs() < 1e-6 {
            Vec::new()
        } else {
            vec![zero, (*v + zero) / 2.0]
        }
    }
}

/// Vector of `f32` with length in `[min_len, max_len]` and entries in
/// `[lo, hi]`.
pub struct VecF32 {
    pub min_len: usize,
    pub max_len: usize,
    pub lo: f32,
    pub hi: f32,
}

impl Gen for VecF32 {
    type Value = Vec<f32>;
    fn gen(&self, rng: &mut Pcg64) -> Vec<f32> {
        let span = (self.max_len - self.min_len) as u64;
        let len = self.min_len + if span > 0 { rng.next_below(span + 1) as usize } else { 0 };
        (0..len)
            .map(|_| self.lo + (self.hi - self.lo) * rng.next_f32())
            .collect()
    }
    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..v.len() / 2].to_vec().into_iter().chain(std::iter::empty()).collect::<Vec<_>>());
            let mut half = v.clone();
            half.truncate((v.len() + self.min_len) / 2);
            out.push(half);
        }
        if v.iter().any(|&x| x != 0.0) {
            out.push(v.iter().map(|&x| x / 2.0).collect());
            out.push(vec![0.0; v.len()]);
        }
        out.retain(|c| c.len() >= self.min_len);
        out
    }
}

/// Pair generator.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);
    fn gen(&self, rng: &mut Pcg64) -> Self::Value {
        (self.0.gen(rng), self.1.gen(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Outcome of a property run.
#[derive(Debug)]
pub struct PropFailure<V: std::fmt::Debug> {
    pub seed: u64,
    pub case: usize,
    pub original: V,
    pub shrunk: V,
    pub message: String,
}

/// Run `prop` over `cases` generated inputs; on failure shrink (up to 200
/// steps) and panic with the minimal counterexample.
pub fn check<G: Gen>(seed: u64, cases: usize, gen: &G, prop: impl Fn(&G::Value) -> Result<(), String>) {
    let mut rng = Pcg64::new(seed);
    for case in 0..cases {
        let v = gen.gen(&mut rng);
        if let Err(msg) = prop(&v) {
            // Shrink loop.
            let mut best = v.clone();
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: while steps < 200 {
                for cand in gen.shrink(&best) {
                    steps += 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if steps >= 200 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed {seed}, case {case}):\n  original: {v:?}\n  shrunk:   {best:?}\n  error: {best_msg}"
            );
        }
    }
}

/// Assert two slices are close within `atol + rtol·|b|`, with a helpful
/// message naming the first offending index.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "index {i}: {x} vs {y} (|diff| {} > tol {tol})",
            (x - y).abs()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, 100, &VecF32 { min_len: 0, max_len: 20, lo: -1.0, hi: 1.0 }, |v| {
            if v.iter().all(|x| x.abs() <= 1.0) {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_shrunk_case() {
        check(2, 100, &VecF32 { min_len: 1, max_len: 30, lo: -10.0, hi: 10.0 }, |v| {
            // False property: all sums are below 5.
            if v.iter().sum::<f32>() < 5.0 {
                Ok(())
            } else {
                Err(format!("sum = {}", v.iter().sum::<f32>()))
            }
        });
    }

    #[test]
    fn shrinking_reduces_magnitude() {
        let g = F32Range { lo: 0.0, hi: 100.0 };
        let cands = g.shrink(&64.0);
        assert!(cands.iter().any(|&c| c < 64.0));
    }

    #[test]
    fn pair_generates_both() {
        let g = Pair(F32Range { lo: 0.0, hi: 1.0 }, F32Range { lo: 5.0, hi: 6.0 });
        let mut rng = Pcg64::new(3);
        let (a, b) = g.gen(&mut rng);
        assert!((0.0..=1.0).contains(&a));
        assert!((5.0..=6.0).contains(&b));
    }

    #[test]
    fn assert_close_accepts_within_tol() {
        assert_close(&[1.0, 2.0], &[1.0005, 2.0], 1e-3, 0.0);
    }

    #[test]
    #[should_panic(expected = "index 1")]
    fn assert_close_names_index() {
        assert_close(&[1.0, 2.0], &[1.0, 3.0], 1e-3, 0.0);
    }
}
