//! The distributed dictionary update (Eq. 51):
//!
//! ```text
//! W_k ← Π_{W_k}[ prox_{μ_w h_{W_k}}( W_k + μ_w · ν° (y_k°)ᵀ ) ]
//! ```
//!
//! Fully local: after inference, agent `k` needs only its own dual
//! estimate `ν°` and its own coefficients `y_k°` — no atom or coefficient
//! exchange (the paper's key property).

use crate::model::{DistributedDictionary, TaskSpec};
use crate::ops::prox::DictProx;

/// Apply the update at every agent using per-agent dual estimates.
///
/// `nu_of_agent(k)` supplies agent `k`'s converged dual iterate (from the
/// diffusion engine); `y` holds the recovered coefficients (agent `k` only
/// reads its own block). `prox` is the dictionary regularizer's proximal
/// operator (identity for `h_W = 0`).
pub fn dictionary_update(
    dict: &mut DistributedDictionary,
    task: &TaskSpec,
    mu_w: f32,
    y: &[f32],
    nu_of_agent: impl Fn(usize) -> Vec<f32>,
    prox: DictProx,
) {
    let constraint = task.atom_constraint();
    for k in 0..dict.agents() {
        let nu = nu_of_agent(k);
        dict.block_gradient_step(k, mu_w, &nu, y);
        if let DictProx::L1(_) = prox {
            // Prox applies to the agent's atom entries only.
            apply_prox_block(dict, k, mu_w, prox);
        }
        dict.project_block(k, constraint);
    }
}

/// Minibatch variant (paper footnote 4): gradients `ν°(y°)ᵀ` are averaged
/// over the batch before the single prox + projection.
///
/// `batch` holds `(nu, y)` pairs from the per-sample inferences (run with
/// the *same* dictionary). The consensus dual estimate is used for every
/// agent, matching the paper's minibatch procedure.
pub fn dictionary_update_minibatch(
    dict: &mut DistributedDictionary,
    task: &TaskSpec,
    mu_w: f32,
    batch: &[(Vec<f32>, Vec<f32>)],
    prox: DictProx,
) {
    if batch.is_empty() {
        return;
    }
    let constraint = task.atom_constraint();
    let scale = mu_w / batch.len() as f32;
    for k in 0..dict.agents() {
        for (nu, y) in batch {
            dict.block_gradient_step(k, scale, nu, y);
        }
        if let DictProx::L1(_) = prox {
            apply_prox_block(dict, k, mu_w, prox);
        }
        dict.project_block(k, constraint);
    }
}

fn apply_prox_block(dict: &mut DistributedDictionary, k: usize, mu_w: f32, prox: DictProx) {
    let (start, len) = dict.block(k);
    let m = dict.m();
    let kk = dict.k();
    let w = dict.mat_mut().as_mut_slice();
    for q in start..start + len {
        for r in 0..m {
            let mut cell = [w[r * kk + q]];
            prox.apply(&mut cell, mu_w);
            w[r * kk + q] = cell[0];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::AtomConstraint;
    use crate::rng::Pcg64;

    fn dict(seed: u64) -> DistributedDictionary {
        let mut rng = Pcg64::new(seed);
        DistributedDictionary::random(6, 4, 4, AtomConstraint::UnitBall, &mut rng).unwrap()
    }

    #[test]
    fn update_moves_toward_gradient() {
        let mut d = dict(1);
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let before = d.atom(0);
        let nu = vec![0.1f32; 6];
        let mut y = vec![0.0f32; 4];
        y[0] = 1.0;
        dictionary_update(&mut d, &task, 0.01, &y, |_| nu.clone(), DictProx::None);
        let after = d.atom(0);
        for i in 0..6 {
            // w + μ_w ν y (unit-norm columns with tiny step stay inside the ball
            // or get rescaled — either way the direction must match).
            assert!(after[i] != before[i] || nu[i] == 0.0);
        }
        // Atoms with y_q = 0 are unchanged.
        crate::testutil::assert_close(&d.atom(1), &dict(1).atom(1), 1e-7, 0.0);
    }

    #[test]
    fn update_respects_constraint() {
        let mut d = dict(2);
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let nu = vec![100.0f32; 6];
        let y = vec![1.0f32; 4];
        dictionary_update(&mut d, &task, 1.0, &y, |_| nu.clone(), DictProx::None);
        for q in 0..4 {
            assert!(crate::math::vector::norm2(&d.atom(q)) <= 1.0 + 1e-5);
        }
    }

    #[test]
    fn nonneg_constraint_enforced() {
        let mut rng = Pcg64::new(3);
        let mut d =
            DistributedDictionary::random(6, 4, 4, AtomConstraint::NonNegUnitBall, &mut rng)
                .unwrap();
        let task = TaskSpec::Nmf { gamma: 0.1, delta: 0.5 };
        let nu = vec![-5.0f32; 6];
        let y = vec![1.0f32; 4];
        dictionary_update(&mut d, &task, 1.0, &y, |_| nu.clone(), DictProx::None);
        assert!(d.mat().as_slice().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn minibatch_equals_averaged_single_updates_before_projection() {
        // With a step small enough that projection never activates, the
        // minibatch update equals the average-gradient update.
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let mut rng = Pcg64::new(4);
        let batch: Vec<(Vec<f32>, Vec<f32>)> = (0..3)
            .map(|_| {
                let nu: Vec<f32> = rng.normal_vec(6).iter().map(|v| v * 0.01).collect();
                let y: Vec<f32> = rng.normal_vec(4).iter().map(|v| v * 0.01).collect();
                (nu, y)
            })
            .collect();
        let mut d1 = dict(5);
        let mut d2 = d1.clone();
        dictionary_update_minibatch(&mut d1, &task, 0.001, &batch, DictProx::None);
        // Manual: accumulate average gradient then project.
        for k in 0..d2.agents() {
            for (nu, y) in &batch {
                d2.block_gradient_step(k, 0.001 / 3.0, nu, y);
            }
            d2.project_block(k, task.atom_constraint());
        }
        crate::testutil::assert_close(d1.mat().as_slice(), d2.mat().as_slice(), 1e-7, 0.0);
    }

    #[test]
    fn l1_prox_sparsifies_atoms() {
        let mut d = dict(6);
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let nu = vec![0.0f32; 6];
        let y = vec![0.0f32; 4];
        // Pure prox shrinkage with huge λ zeroes the dictionary.
        dictionary_update(&mut d, &task, 10.0, &y, |_| nu.clone(), DictProx::L1(1.0));
        assert!(d.mat().as_slice().iter().all(|&v| v == 0.0));
    }
}
