//! Dictionary step-size schedules.

/// Step-size schedule μ_w(·).
#[derive(Clone, Copy, Debug)]
pub enum StepSchedule {
    /// Constant μ_w (image denoising, §IV-B: μ_w = 5e-5).
    Constant(f32),
    /// `μ_w(s) = num / s` over time-steps (novelty, §IV-C: 10/s).
    InverseTime { num: f32 },
    /// `μ_w(t) = num / (offset + t)` over samples.
    InverseSample { num: f32, offset: f32 },
}

impl StepSchedule {
    /// Step size at 1-based step `s`.
    pub fn at(&self, s: usize) -> f32 {
        let s = s.max(1) as f32;
        match *self {
            StepSchedule::Constant(v) => v,
            StepSchedule::InverseTime { num } => num / s,
            StepSchedule::InverseSample { num, offset } => num / (offset + s),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = StepSchedule::Constant(5e-5);
        assert_eq!(s.at(1), 5e-5);
        assert_eq!(s.at(100), 5e-5);
    }

    #[test]
    fn inverse_time_decays() {
        let s = StepSchedule::InverseTime { num: 10.0 };
        assert_eq!(s.at(1), 10.0);
        assert_eq!(s.at(2), 5.0);
        assert_eq!(s.at(5), 2.0);
        // Guard against s = 0.
        assert_eq!(s.at(0), 10.0);
    }

    #[test]
    fn inverse_sample_offset() {
        let s = StepSchedule::InverseSample { num: 1.0, offset: 9.0 };
        assert_eq!(s.at(1), 0.1);
    }
}
