//! Online trainer: Alg. 1 of the paper.
//!
//! For each minibatch, run the distributed dual inference — **batched**:
//! one [`DiffusionEngine::run_batch`] call stacks the minibatch as
//! `V ∈ R^{N×(B·M)}` so a single combine sweep and worker-pool region
//! serve every sample (per-sample trajectories are bit-identical to
//! sequential runs; samples are cold-started together, exactly as the
//! sequential loop cold-started each one). Then recover each agent's
//! coefficients from its **own** dual iterate, and apply the local
//! dictionary update with minibatch-averaged gradients (paper footnote 4).
//! The trainer is generic over the task family.

use crate::error::Result;
use crate::infer::{recover_y_into, DiffusionEngine, DiffusionParams, NuView};
use crate::model::{DistributedDictionary, TaskSpec};
use crate::ops::prox::DictProx;

/// Trainer options.
#[derive(Clone, Copy, Debug)]
pub struct TrainerOptions {
    pub infer: DiffusionParams,
    /// Dictionary regularizer prox (Table I; identity except bi-clustering).
    pub prox: DictProx,
}

/// Rolling statistics from training.
#[derive(Clone, Debug, Default)]
pub struct TrainStats {
    /// Samples consumed.
    pub samples: usize,
    /// Mean (over recent samples) of the residual loss f(x − Wy°).
    pub mean_loss: f64,
    /// Mean fraction of non-zero coefficients.
    pub mean_sparsity: f64,
    /// Mean consensus disagreement at the end of inference.
    pub mean_disagreement: f64,
}

/// Online model-distributed dictionary trainer.
pub struct OnlineTrainer {
    engine: DiffusionEngine,
    /// Recovered coefficients for the current minibatch, flat `B·K` (the
    /// dual iterates stay in the engine's stacked `V` — no per-sample
    /// copies). Reused across steps, so the streaming hot loop performs no
    /// per-sample heap allocation beyond the stats matvec.
    ys: Vec<f32>,
    /// `K`-length correlation scratch for primal recovery.
    corr: Vec<f32>,
    /// `M`-length consensus scratch for the disagreement stat.
    mean: Vec<f32>,
    opts: TrainerOptions,
}

impl OnlineTrainer {
    /// Build a trainer over combination matrix `a` for dimension `m`.
    pub fn new(
        a: &crate::math::Mat,
        m: usize,
        informed: Option<&[usize]>,
        opts: TrainerOptions,
    ) -> Result<Self> {
        Ok(Self::from_engine(DiffusionEngine::new(a, m, informed)?, opts))
    }

    /// Build a trainer around an already-configured engine (e.g. one
    /// constructed from a CSR topology via [`DiffusionEngine::new_csr`]).
    pub fn from_engine(engine: DiffusionEngine, opts: TrainerOptions) -> Self {
        OnlineTrainer { engine, ys: Vec::new(), corr: Vec::new(), mean: Vec::new(), opts }
    }

    /// Access the inference engine (e.g. for evaluation passes).
    pub fn engine_mut(&mut self) -> &mut DiffusionEngine {
        &mut self.engine
    }

    /// Update the inference parameters.
    pub fn set_infer(&mut self, p: DiffusionParams) {
        self.opts.infer = p;
    }

    /// Process one minibatch: one batched inference over all samples, then
    /// the Eq. 51 update with gradients averaged over the batch; returns
    /// statistics. Numerically identical to the historical per-sample loop
    /// (each sample cold-starts and never interacts with its batch mates).
    ///
    /// Implemented as the composition of the two stage functions the
    /// pipelined serving path runs on separate threads —
    /// [`recover_and_stats`] and [`apply_eq51_update`] — so the serial and
    /// pipelined schedules share every arithmetic operation bit-for-bit.
    pub fn step(
        &mut self,
        dict: &mut DistributedDictionary,
        task: &TaskSpec,
        samples: &[&[f32]],
        mu_w: f32,
    ) -> Result<TrainStats> {
        if samples.is_empty() {
            return Ok(TrainStats::default());
        }
        // Shape the engine for this minibatch, then size the scratch so
        // `run_batch` never allocates inside the loop (EXPERIMENTS.md
        // §Perf).
        self.engine.reserve_batch(samples.len());
        self.engine.reserve_atoms(dict.k());
        self.engine.reset();
        self.engine.run_batch(dict, task, samples, self.opts.infer)?;
        let view = self.engine.nu_view();
        let stats = recover_and_stats(
            dict,
            task,
            samples,
            &view,
            &mut self.ys,
            &mut self.corr,
            &mut self.mean,
        )?;
        apply_eq51_update(dict, task, self.opts.prox, mu_w, &self.ys, &view);
        Ok(stats)
    }

    /// Inference-only minibatch step for a **frozen** dictionary
    /// ([`crate::learn::ConvergenceDetector`]): identical to [`Self::step`]
    /// minus [`apply_eq51_update`], so the served coefficients, losses, and
    /// ψ traffic are exactly those of an adapting step at the same
    /// dictionary state — only the Eq. 51 write is skipped. Takes the
    /// dictionary by shared reference: the type system enforces that a
    /// frozen step cannot mutate the model.
    pub fn step_frozen(
        &mut self,
        dict: &DistributedDictionary,
        task: &TaskSpec,
        samples: &[&[f32]],
    ) -> Result<TrainStats> {
        if samples.is_empty() {
            return Ok(TrainStats::default());
        }
        self.engine.reserve_batch(samples.len());
        self.engine.reserve_atoms(dict.k());
        self.engine.reset();
        self.engine.run_batch(dict, task, samples, self.opts.infer)?;
        let view = self.engine.nu_view();
        recover_and_stats(dict, task, samples, &view, &mut self.ys, &mut self.corr, &mut self.mean)
    }
}

/// Stage-3a of a minibatch step: per-sample primal recovery plus the
/// rolling statistics, reading the dual iterates through a [`NuView`] (live
/// engine state or a shipped clone — identical results either way).
///
/// `ys` receives sample `s`'s coefficients at `[s·K..(s+1)·K]`; `corr` and
/// `mean` are `K`- / `M`-length scratch buffers, resized (grow or shrink)
/// as needed. All buffers are caller-owned so streaming loops allocate
/// nothing per batch.
#[allow(clippy::too_many_arguments)]
pub fn recover_and_stats(
    dict: &DistributedDictionary,
    task: &TaskSpec,
    samples: &[&[f32]],
    nu: &NuView<'_>,
    ys: &mut Vec<f32>,
    corr: &mut Vec<f32>,
    mean: &mut Vec<f32>,
) -> Result<TrainStats> {
    let mut stats = TrainStats::default();
    let b = samples.len();
    if b == 0 {
        return Ok(stats);
    }
    debug_assert_eq!(nu.batch(), b);
    let kk = dict.k();
    ys.resize(b * kk, 0.0);
    corr.resize(kk, 0.0);
    mean.resize(dict.m(), 0.0);
    for (s, &x) in samples.iter().enumerate() {
        let y = &mut ys[s * kk..(s + 1) * kk];
        recover_y_into(dict, task, nu, s, y, corr);
        // Stats on the consensus estimate.
        let wy = dict.mat().matvec(y)?;
        let resid = crate::math::vector::sub(x, &wy);
        stats.mean_loss += task.f_loss(&resid) as f64;
        stats.mean_sparsity +=
            y.iter().filter(|v| v.abs() > 1e-12).count() as f64 / y.len() as f64;
        stats.mean_disagreement += nu.disagreement_into(s, mean) as f64;
    }
    stats.samples = b;
    stats.mean_loss /= b as f64;
    stats.mean_sparsity /= b as f64;
    stats.mean_disagreement /= b as f64;
    Ok(stats)
}

/// Stage-3b of a minibatch step: the Eq. 51 dictionary update with
/// per-agent local dual estimates (read through `nu`), gradients averaged
/// over the batch, optional `prox`, and the constraint projection.
///
/// Send-safe by construction — it writes into a **caller-owned** dictionary
/// buffer and borrows nothing from the engine, so the pipelined session
/// runs it on a dedicated updater thread against the write side of a
/// [`crate::model::DictDoubleBuffer`] while the next batch's inference
/// reads the published snapshot.
pub fn apply_eq51_update(
    dict: &mut DistributedDictionary,
    task: &TaskSpec,
    prox: DictProx,
    mu_w: f32,
    ys: &[f32],
    nu: &NuView<'_>,
) {
    let b = nu.batch();
    let kk = dict.k();
    debug_assert_eq!(ys.len(), b * kk);
    let constraint = task.atom_constraint();
    let scale = mu_w / b as f32;
    for k in 0..dict.agents() {
        for s in 0..b {
            let y = &ys[s * kk..(s + 1) * kk];
            dict.block_gradient_step(k, scale, nu.nu(k, s), y);
        }
        if let DictProx::L1(_) = prox {
            let (start, len) = dict.block(k);
            let m = dict.m();
            let kk = dict.k();
            let w = dict.mat_mut().as_mut_slice();
            for q in start..start + len {
                for r in 0..m {
                    let mut cell = [w[r * kk + q]];
                    prox.apply(&mut cell, mu_w);
                    w[r * kk + q] = cell[0];
                }
            }
        }
        dict.project_block(k, constraint);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{metropolis_weights, Graph, Topology};
    use crate::model::AtomConstraint;
    use crate::rng::Pcg64;

    /// Training on samples drawn from a planted dictionary must reduce the
    /// average representation loss.
    #[test]
    fn training_reduces_loss_on_planted_model() {
        let (m, k, n) = (16, 8, 8);
        let mut rng = Pcg64::new(11);
        // Planted generator dictionary.
        let planted =
            DistributedDictionary::random(m, k, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let gen_sample = |rng: &mut Pcg64| -> Vec<f32> {
            // 2-sparse positive combinations.
            let mut x = vec![0.0f32; m];
            for _ in 0..2 {
                let q = rng.next_below(k as u64) as usize;
                let c = 0.5 + rng.next_f32();
                crate::math::vector::axpy(c, &planted.atom(q), &mut x);
            }
            x
        };
        let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
        let a = metropolis_weights(&g);
        let task = TaskSpec::SparseCoding { gamma: 0.05, delta: 0.2 };
        let mut dict =
            DistributedDictionary::random(m, k, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let opts = TrainerOptions {
            infer: DiffusionParams::new(0.3, 400),
            prox: DictProx::None,
        };
        let mut tr = OnlineTrainer::new(&a, m, None, opts).unwrap();

        let mut first_losses = 0.0;
        let mut last_losses = 0.0;
        let rounds = 60;
        for round in 0..rounds {
            let xs: Vec<Vec<f32>> = (0..4).map(|_| gen_sample(&mut rng)).collect();
            let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            let stats = tr.step(&mut dict, &task, &refs, 0.05).unwrap();
            if round < 10 {
                first_losses += stats.mean_loss;
            }
            if round >= rounds - 10 {
                last_losses += stats.mean_loss;
            }
        }
        assert!(
            last_losses < 0.7 * first_losses,
            "loss did not improve: first {first_losses}, last {last_losses}"
        );
    }

    /// The two stage functions applied to a *shipped* `V` clone (the
    /// pipelined updater's input) must reproduce `step` bit-for-bit —
    /// dictionary, stats, and coefficients.
    #[test]
    fn split_stages_on_shipped_v_match_step_bitwise() {
        let (m, n) = (10, 8);
        let mut rng = Pcg64::new(0x5711);
        let dict0 =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::Ring { k: 2 }, &mut rng);
        let a = metropolis_weights(&g);
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.4 };
        let opts = TrainerOptions {
            infer: DiffusionParams::new(0.3, 30),
            prox: DictProx::L1(0.01), // exercise the prox branch too
        };
        let xs: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(m)).collect();
        let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        let mu_w = 0.05f32;

        let mut dict_step = dict0.clone();
        let mut tr = OnlineTrainer::new(&a, m, None, opts).unwrap();
        let stats_step = tr.step(&mut dict_step, &task, &refs, mu_w).unwrap();

        // Pipeline shape: inference-only, ship V, then stage 3 elsewhere.
        let mut dict_pipe = dict0.clone();
        let mut eng = crate::infer::DiffusionEngine::new(&a, m, None).unwrap();
        eng.run_batch(&dict_pipe, &task, &refs, opts.infer).unwrap();
        let shipped = eng.nu_view().to_owned_data();
        drop(eng); // the updater stage has no engine access
        let view = crate::infer::NuView::new(&shipped, n, m, refs.len());
        let (mut ys, mut corr, mut mean) = (Vec::new(), Vec::new(), Vec::new());
        let stats_pipe = recover_and_stats(
            &dict_pipe, &task, &refs, &view, &mut ys, &mut corr, &mut mean,
        )
        .unwrap();
        apply_eq51_update(&mut dict_pipe, &task, opts.prox, mu_w, &ys, &view);

        assert_eq!(dict_step.mat().as_slice(), dict_pipe.mat().as_slice());
        assert_eq!(stats_step.mean_loss.to_bits(), stats_pipe.mean_loss.to_bits());
        assert_eq!(stats_step.mean_sparsity.to_bits(), stats_pipe.mean_sparsity.to_bits());
        assert_eq!(
            stats_step.mean_disagreement.to_bits(),
            stats_pipe.mean_disagreement.to_bits()
        );
        assert_eq!(stats_step.samples, stats_pipe.samples);
    }

    /// A frozen step must be pure inference: repeating it on the same
    /// dictionary and batch reproduces every stat bit-for-bit (an adapting
    /// step would move the dictionary between calls), and it matches the
    /// recover-only half of an adapting step at the same state.
    #[test]
    fn frozen_step_is_pure_inference() {
        let (m, n) = (10, 6);
        let mut rng = Pcg64::new(0xF607E);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let a = crate::graph::uniform_weights(n);
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.4 };
        let opts =
            TrainerOptions { infer: DiffusionParams::new(0.3, 40), prox: DictProx::None };
        let xs: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(m)).collect();
        let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();

        let mut tr = OnlineTrainer::new(&a, m, None, opts).unwrap();
        let s1 = tr.step_frozen(&dict, &task, &refs).unwrap();
        let s2 = tr.step_frozen(&dict, &task, &refs).unwrap();
        assert_eq!(s1.mean_loss.to_bits(), s2.mean_loss.to_bits());
        assert_eq!(s1.mean_sparsity.to_bits(), s2.mean_sparsity.to_bits());
        assert_eq!(s1.mean_disagreement.to_bits(), s2.mean_disagreement.to_bits());
        assert_eq!(s1.samples, refs.len());

        // Same stats as the recover-only half of an adapting step.
        let mut dict_adapt = dict.clone();
        let mut tr2 = OnlineTrainer::new(&a, m, None, opts).unwrap();
        let s3 = tr2.step(&mut dict_adapt, &task, &refs, 0.05).unwrap();
        assert_eq!(s1.mean_loss.to_bits(), s3.mean_loss.to_bits());
        assert_ne!(
            dict.mat().as_slice(),
            dict_adapt.mat().as_slice(),
            "adapting step moves the dictionary; frozen step cannot"
        );
    }

    #[test]
    fn stats_are_populated() {
        let (m, n) = (8, 4);
        let mut rng = Pcg64::new(12);
        let mut dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let a = crate::graph::uniform_weights(n);
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let mut tr = OnlineTrainer::new(
            &a,
            m,
            None,
            TrainerOptions { infer: DiffusionParams::new(0.3, 50), prox: DictProx::None },
        )
        .unwrap();
        let x = rng.normal_vec(m);
        let stats = tr.step(&mut dict, &task, &[&x], 0.01).unwrap();
        assert_eq!(stats.samples, 1);
        assert!(stats.mean_loss > 0.0);
        assert!(stats.mean_sparsity >= 0.0 && stats.mean_sparsity <= 1.0);
    }
}
