//! Online trainer: Alg. 1 of the paper.
//!
//! For each minibatch, run the distributed dual inference per sample,
//! recover each agent's coefficients from its **own** dual iterate, and
//! apply the local dictionary update with minibatch-averaged gradients
//! (paper footnote 4). The trainer is generic over the task family.

use crate::error::Result;
use crate::infer::{DiffusionEngine, DiffusionParams};
use crate::math::Mat;
use crate::model::{DistributedDictionary, TaskSpec};
use crate::ops::prox::DictProx;

/// Trainer options.
#[derive(Clone, Copy, Debug)]
pub struct TrainerOptions {
    pub infer: DiffusionParams,
    /// Dictionary regularizer prox (Table I; identity except bi-clustering).
    pub prox: DictProx,
}

/// Rolling statistics from training.
#[derive(Clone, Debug, Default)]
pub struct TrainStats {
    /// Samples consumed.
    pub samples: usize,
    /// Mean (over recent samples) of the residual loss f(x − Wy°).
    pub mean_loss: f64,
    /// Mean fraction of non-zero coefficients.
    pub mean_sparsity: f64,
    /// Mean consensus disagreement at the end of inference.
    pub mean_disagreement: f64,
}

/// Online model-distributed dictionary trainer.
pub struct OnlineTrainer {
    engine: DiffusionEngine,
    /// Per-sample storage of the stacked dual iterates for the minibatch
    /// (`(V, y)` pairs; agent `k` reads row `k` of `V`).
    batch: Vec<(Mat, Vec<f32>)>,
    opts: TrainerOptions,
}

impl OnlineTrainer {
    /// Build a trainer over combination matrix `a` for dimension `m`.
    pub fn new(
        a: &Mat,
        m: usize,
        informed: Option<&[usize]>,
        opts: TrainerOptions,
    ) -> Result<Self> {
        Ok(OnlineTrainer { engine: DiffusionEngine::new(a, m, informed)?, batch: Vec::new(), opts })
    }

    /// Access the inference engine (e.g. for evaluation passes).
    pub fn engine_mut(&mut self) -> &mut DiffusionEngine {
        &mut self.engine
    }

    /// Update the inference parameters.
    pub fn set_infer(&mut self, p: DiffusionParams) {
        self.opts.infer = p;
    }

    /// Process one minibatch: inference per sample, then the Eq. 51 update
    /// with gradients averaged over the batch; returns statistics.
    pub fn step(
        &mut self,
        dict: &mut DistributedDictionary,
        task: &TaskSpec,
        samples: &[&[f32]],
        mu_w: f32,
    ) -> Result<TrainStats> {
        let mut stats = TrainStats::default();
        self.batch.clear();
        // Size the engine scratch once so the per-sample loop below never
        // allocates inside `run` (EXPERIMENTS.md §Perf).
        self.engine.reserve_atoms(dict.k());
        for &x in samples {
            self.engine.reset();
            self.engine.run(dict, task, x, self.opts.infer)?;
            let y = self.engine.recover_y(dict, task);
            // Stats on the consensus estimate.
            let wy = dict.mat().matvec(&y)?;
            let resid = crate::math::vector::sub(x, &wy);
            stats.mean_loss += task.f_loss(&resid) as f64;
            stats.mean_sparsity +=
                y.iter().filter(|v| v.abs() > 1e-12).count() as f64 / y.len() as f64;
            stats.mean_disagreement += self.engine.disagreement() as f64;
            // Stash per-agent dual iterates + coefficients for the update.
            let mut v = Mat::zeros(self.engine.agents(), self.engine.dim());
            for k in 0..self.engine.agents() {
                v.row_mut(k).copy_from_slice(self.engine.nu(k));
            }
            self.batch.push((v, y));
        }
        let b = samples.len().max(1);
        stats.samples = samples.len();
        stats.mean_loss /= b as f64;
        stats.mean_sparsity /= b as f64;
        stats.mean_disagreement /= b as f64;

        // Eq. 51 with per-agent local dual estimates, averaged over batch.
        let constraint = task.atom_constraint();
        let scale = mu_w / b as f32;
        for k in 0..dict.agents() {
            for (v, y) in &self.batch {
                dict.block_gradient_step(k, scale, v.row(k), y);
            }
            if let DictProx::L1(_) = self.opts.prox {
                let (start, len) = dict.block(k);
                let m = dict.m();
                let kk = dict.k();
                let w = dict.mat_mut().as_mut_slice();
                for q in start..start + len {
                    for r in 0..m {
                        let mut cell = [w[r * kk + q]];
                        self.opts.prox.apply(&mut cell, mu_w);
                        w[r * kk + q] = cell[0];
                    }
                }
            }
            dict.project_block(k, constraint);
        }
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{metropolis_weights, Graph, Topology};
    use crate::model::AtomConstraint;
    use crate::rng::Pcg64;

    /// Training on samples drawn from a planted dictionary must reduce the
    /// average representation loss.
    #[test]
    fn training_reduces_loss_on_planted_model() {
        let (m, k, n) = (16, 8, 8);
        let mut rng = Pcg64::new(11);
        // Planted generator dictionary.
        let planted =
            DistributedDictionary::random(m, k, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let gen_sample = |rng: &mut Pcg64| -> Vec<f32> {
            // 2-sparse positive combinations.
            let mut x = vec![0.0f32; m];
            for _ in 0..2 {
                let q = rng.next_below(k as u64) as usize;
                let c = 0.5 + rng.next_f32();
                crate::math::vector::axpy(c, &planted.atom(q), &mut x);
            }
            x
        };
        let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
        let a = metropolis_weights(&g);
        let task = TaskSpec::SparseCoding { gamma: 0.05, delta: 0.2 };
        let mut dict =
            DistributedDictionary::random(m, k, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let opts = TrainerOptions {
            infer: DiffusionParams::new(0.3, 400),
            prox: DictProx::None,
        };
        let mut tr = OnlineTrainer::new(&a, m, None, opts).unwrap();

        let mut first_losses = 0.0;
        let mut last_losses = 0.0;
        let rounds = 60;
        for round in 0..rounds {
            let xs: Vec<Vec<f32>> = (0..4).map(|_| gen_sample(&mut rng)).collect();
            let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            let stats = tr.step(&mut dict, &task, &refs, 0.05).unwrap();
            if round < 10 {
                first_losses += stats.mean_loss;
            }
            if round >= rounds - 10 {
                last_losses += stats.mean_loss;
            }
        }
        assert!(
            last_losses < 0.7 * first_losses,
            "loss did not improve: first {first_losses}, last {last_losses}"
        );
    }

    #[test]
    fn stats_are_populated() {
        let (m, n) = (8, 4);
        let mut rng = Pcg64::new(12);
        let mut dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let a = crate::graph::uniform_weights(n);
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let mut tr = OnlineTrainer::new(
            &a,
            m,
            None,
            TrainerOptions { infer: DiffusionParams::new(0.3, 50), prox: DictProx::None },
        )
        .unwrap();
        let x = rng.normal_vec(m);
        let stats = tr.step(&mut dict, &task, &[&x], 0.01).unwrap();
        assert_eq!(stats.samples, 1);
        assert!(stats.mean_loss > 0.0);
        assert!(stats.mean_sparsity >= 0.0 && stats.mean_sparsity <= 1.0);
    }
}
