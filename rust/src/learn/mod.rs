//! Dictionary learning: the fully-local update (Eq. 51), minibatch
//! averaging (paper footnote 4), step-size schedules, the online
//! trainer that alternates distributed inference with local updates
//! (Alg. 1), and the convergence detector that freezes/thaws the
//! online update during serving.

pub mod convergence;
pub mod schedule;
pub mod trainer;
pub mod update;

pub use convergence::{ConvEvent, ConvergenceDetector};
pub use schedule::StepSchedule;
pub use trainer::{
    apply_eq51_update, recover_and_stats, OnlineTrainer, TrainerOptions, TrainStats,
};
pub use update::dictionary_update;
