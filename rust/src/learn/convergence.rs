//! Convergence-aware online adaptation: deterministic freeze/thaw detection.
//!
//! The paper's learning strategy is online — each sample is presented once —
//! but a long-running serve session keeps paying for the Eq. 51 dictionary
//! update forever, even after the dictionary has converged. This module adds
//! the production pattern from sklearn's `dict_learning_online` (`tol` /
//! `max_no_improvement` early stopping), adapted to the streaming setting:
//!
//! * **Freeze** — while adapting, every [`ConvergenceConfig::window`] batches
//!   the detector measures the relative dictionary drift
//!   `‖D_j − D_{j−w}‖_F / ‖D_{j−w}‖_F`. After
//!   [`ConvergenceConfig::max_no_improvement`] consecutive windows below
//!   [`ConvergenceConfig::tol`], adaptation freezes: the serve executors skip
//!   the Eq. 51 update and release the update stage's virtual-clock budget to
//!   pure inference (`PipeSim::set_frozen`, the serial loop's update
//!   discount).
//! * **Thaw** — a frozen dictionary has zero drift by construction, so the
//!   detector instead monitors the sliding mean batch loss the frozen
//!   dictionary achieves on the live stream. When that mean exceeds
//!   [`ConvergenceConfig::thaw_ratio`] × the freeze-time reference loss
//!   (e.g. after a distribution shift in the stream), adaptation resumes at
//!   the next batch boundary.
//!
//! **Determinism contract.** Every decision is a pure function of (config,
//! batch index, observed dictionary bytes, observed loss bits): the detector
//! draws no randomness, reads no wall clock, and accumulates drift in a fixed
//! index order — so freeze/thaw points replay bit-identically
//! (`tests/convergence_freeze.rs`), and a disabled detector
//! ([`ConvergenceConfig::tol`]` = 0`, the default) observes nothing and
//! leaves the executors bit-for-bit on their pre-detector paths.

use crate::config::experiment::ConvergenceConfig;
use crate::model::DistributedDictionary;

/// Smallest reference norm / loss the relative measures divide by.
const EPS: f64 = 1e-30;

/// One detector decision or measurement, in batch order. Recorded on
/// [`crate::serve::ServeReport::conv_events`] and mirrored as
/// `drift_norm` / `freeze` / `thaw` obs instants on the serve virtual
/// clocks.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ConvEvent {
    /// Relative dictionary drift measured at an adapting window boundary.
    Drift { batch: usize, norm: f64 },
    /// Adaptation froze after this batch; the next batch runs inference-only.
    Freeze { batch: usize },
    /// Frozen-mode thaw monitor sample: sliding mean loss over the freeze-time
    /// reference loss.
    LossRatio { batch: usize, ratio: f64 },
    /// Adaptation resumed after this batch (the stream drifted away from the
    /// frozen dictionary).
    Thaw { batch: usize },
}

impl ConvEvent {
    /// Batch index the event was observed at.
    pub fn batch(&self) -> usize {
        match *self {
            ConvEvent::Drift { batch, .. }
            | ConvEvent::Freeze { batch }
            | ConvEvent::LossRatio { batch, .. }
            | ConvEvent::Thaw { batch } => batch,
        }
    }
}

/// Deterministic freeze/thaw state machine over the observed dictionary
/// trajectory. One instance per serve session; both the serial loop and the
/// pipelined updater stage feed it the same `(batch index, dictionary after
/// the batch, mean batch loss)` sequence, so a given executor's decisions
/// replay bit-identically.
#[derive(Clone, Debug)]
pub struct ConvergenceDetector {
    cfg: ConvergenceConfig,
    /// Flat snapshot of `D_{j−w}` (the window reference), lazily sized.
    reference: Vec<f32>,
    have_reference: bool,
    batches_since_ref: usize,
    below_tol_windows: usize,
    frozen: bool,
    /// Mean batch loss over `loss_window` at freeze time (thaw baseline).
    freeze_loss: f64,
    batches_since_freeze: usize,
    /// Sliding window of recent batch losses (newest last).
    recent_losses: Vec<f64>,
    frozen_batches: usize,
    events: Vec<ConvEvent>,
    /// Events appended by the most recent [`Self::observe`] call.
    fresh_from: usize,
}

impl ConvergenceDetector {
    pub fn new(cfg: ConvergenceConfig) -> Self {
        ConvergenceDetector {
            cfg,
            reference: Vec::new(),
            have_reference: false,
            batches_since_ref: 0,
            below_tol_windows: 0,
            frozen: false,
            freeze_loss: 0.0,
            batches_since_freeze: 0,
            recent_losses: Vec::new(),
            frozen_batches: 0,
            events: Vec::new(),
            fresh_from: 0,
        }
    }

    /// Whether the detector participates at all (`tol > 0`). When false,
    /// [`Self::observe`] returns immediately without touching any state, so
    /// the executors' behavior is bit-for-bit the always-adapt run.
    pub fn enabled(&self) -> bool {
        self.cfg.enabled()
    }

    /// Whether the *next* batch should skip the Eq. 51 update. Executors
    /// consult this before processing a batch; decisions made by
    /// [`Self::observe`] on batch `j` therefore take effect at the `j + 1`
    /// batch boundary — the "deterministic batch boundary" of the contract.
    pub fn is_frozen(&self) -> bool {
        self.frozen
    }

    /// Batches that ran inference-only under a freeze.
    pub fn frozen_batches(&self) -> usize {
        self.frozen_batches
    }

    /// Full decision/measurement trace, in batch order.
    pub fn events(&self) -> &[ConvEvent] {
        &self.events
    }

    /// Consume the detector, yielding the trace for the session report.
    pub fn into_events(self) -> Vec<ConvEvent> {
        self.events
    }

    /// Feed the detector one completed batch: `j` is the batch index, `dict`
    /// the dictionary *after* the batch (post-update while adapting,
    /// unchanged while frozen), `mean_loss` the batch's mean residual loss.
    /// Returns the events this observation generated (also appended to
    /// [`Self::events`]); the caller mirrors them as obs instants.
    pub fn observe(
        &mut self,
        j: usize,
        dict: &DistributedDictionary,
        mean_loss: f64,
    ) -> &[ConvEvent] {
        self.fresh_from = self.events.len();
        if !self.enabled() {
            return &[];
        }
        self.push_loss(mean_loss);
        if self.frozen {
            self.frozen_batches += 1;
            self.observe_frozen(j, dict);
        } else {
            self.observe_adapting(j, dict);
        }
        &self.events[self.fresh_from..]
    }

    fn observe_adapting(&mut self, j: usize, dict: &DistributedDictionary) {
        if !self.have_reference {
            self.snapshot(dict);
            return;
        }
        self.batches_since_ref += 1;
        if self.batches_since_ref < self.cfg.window {
            return;
        }
        let norm = rel_drift(dict.mat().as_slice(), &self.reference);
        self.events.push(ConvEvent::Drift { batch: j, norm });
        if norm < self.cfg.tol {
            self.below_tol_windows += 1;
        } else {
            self.below_tol_windows = 0;
        }
        self.snapshot(dict);
        if self.below_tol_windows >= self.cfg.max_no_improvement {
            self.frozen = true;
            self.freeze_loss = mean(&self.recent_losses);
            self.batches_since_freeze = 0;
            self.below_tol_windows = 0;
            self.events.push(ConvEvent::Freeze { batch: j });
        }
    }

    fn observe_frozen(&mut self, j: usize, dict: &DistributedDictionary) {
        self.batches_since_freeze += 1;
        if self.batches_since_freeze < self.cfg.loss_window {
            return;
        }
        self.batches_since_freeze = 0;
        let ratio = mean(&self.recent_losses) / self.freeze_loss.max(EPS);
        self.events.push(ConvEvent::LossRatio { batch: j, ratio });
        if ratio > self.cfg.thaw_ratio {
            self.frozen = false;
            self.events.push(ConvEvent::Thaw { batch: j });
            // Re-arm the drift machinery from the frozen dictionary so the
            // next freeze needs fresh evidence of convergence.
            self.snapshot(dict);
        }
    }

    fn snapshot(&mut self, dict: &DistributedDictionary) {
        let flat = dict.mat().as_slice();
        self.reference.clear();
        self.reference.extend_from_slice(flat);
        self.have_reference = true;
        self.batches_since_ref = 0;
    }

    fn push_loss(&mut self, loss: f64) {
        self.recent_losses.push(loss);
        if self.recent_losses.len() > self.cfg.loss_window {
            self.recent_losses.remove(0);
        }
    }
}

/// Relative Frobenius drift `‖cur − ref‖_F / ‖ref‖_F`, accumulated in f64 in
/// a fixed index order so replays are bit-identical on any platform.
fn rel_drift(cur: &[f32], reference: &[f32]) -> f64 {
    debug_assert_eq!(cur.len(), reference.len());
    let mut num = 0f64;
    let mut den = 0f64;
    for (a, b) in cur.iter().zip(reference.iter()) {
        let d = f64::from(*a) - f64::from(*b);
        num += d * d;
        den += f64::from(*b) * f64::from(*b);
    }
    (num / den.max(EPS)).sqrt()
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{AtomConstraint, DistributedDictionary};
    use crate::rng::Pcg64;

    fn dict(seed: u64) -> DistributedDictionary {
        let mut rng = Pcg64::new(seed);
        DistributedDictionary::random(6, 4, 4, AtomConstraint::UnitBall, &mut rng).unwrap()
    }

    fn cfg(tol: f64) -> ConvergenceConfig {
        ConvergenceConfig { tol, window: 2, max_no_improvement: 2, thaw_ratio: 1.5, loss_window: 2 }
    }

    #[test]
    fn disabled_detector_observes_nothing() {
        let mut det = ConvergenceDetector::new(cfg(0.0));
        assert!(!det.enabled());
        let d = dict(1);
        for j in 0..32 {
            assert!(det.observe(j, &d, 1.0).is_empty());
            assert!(!det.is_frozen());
        }
        assert!(det.events().is_empty());
        assert_eq!(det.frozen_batches(), 0);
    }

    /// A stationary (here: literally constant) dictionary freezes after
    /// exactly `window × max_no_improvement` post-reference batches, and a
    /// stationary loss never thaws it.
    #[test]
    fn freezes_after_patience_and_stays_frozen_when_stationary() {
        let mut det = ConvergenceDetector::new(cfg(1e-3));
        let d = dict(2);
        let mut froze_at = None;
        for j in 0..64 {
            det.observe(j, &d, 0.5);
            if froze_at.is_none() && det.is_frozen() {
                froze_at = Some(j);
            }
        }
        // Batch 0 plants the reference; windows complete at batches 2 and 4.
        assert_eq!(froze_at, Some(4));
        assert!(det.is_frozen(), "stationary loss must not thaw");
        assert!(det.events().iter().all(|e| !matches!(e, ConvEvent::Thaw { .. })));
        assert_eq!(det.frozen_batches(), 64 - 5);
        let drifts: Vec<_> = det
            .events()
            .iter()
            .filter(|e| matches!(e, ConvEvent::Drift { .. }))
            .collect();
        assert_eq!(drifts.len(), 2, "no drift measurements once frozen");
    }

    /// A drifting dictionary (norm above tol) never freezes.
    #[test]
    fn drifting_dictionary_never_freezes() {
        let mut det = ConvergenceDetector::new(cfg(1e-6));
        for j in 0..32 {
            // A fresh random dictionary every batch: huge relative drift.
            det.observe(j, &dict(100 + j as u64), 0.5);
        }
        assert!(!det.is_frozen());
        assert!(det.events().iter().all(|e| !matches!(e, ConvEvent::Freeze { .. })));
    }

    /// An elevated loss while frozen (a distribution shift) thaws at a
    /// deterministic loss-window boundary, and drift tracking re-arms.
    #[test]
    fn loss_jump_thaws_then_refreezes() {
        let mut det = ConvergenceDetector::new(cfg(1e-3));
        let d = dict(3);
        for j in 0..8 {
            det.observe(j, &d, 0.5);
        }
        assert!(det.is_frozen());
        // Shift: frozen dictionary now sees 4× the loss.
        let mut thawed_at = None;
        for j in 8..16 {
            det.observe(j, &d, 2.0);
            if thawed_at.is_none() && !det.is_frozen() {
                thawed_at = Some(j);
            }
        }
        let thawed_at = thawed_at.expect("loss jump must thaw");
        assert!(det.events().iter().any(|e| matches!(e, ConvEvent::Thaw { .. })));
        // Still stationary after the thaw → freezes again.
        for j in 16..32 {
            det.observe(j, &d, 2.0);
        }
        assert!(det.is_frozen(), "re-freezes once the drift window clears again");
        let freezes =
            det.events().iter().filter(|e| matches!(e, ConvEvent::Freeze { .. })).count();
        assert_eq!(freezes, 2);
        assert!(thawed_at >= 8);
    }

    /// Bitwise replay: identical observation sequences yield identical event
    /// traces, including the f64 drift/ratio bit patterns.
    #[test]
    fn replay_is_bitwise_identical() {
        let run = |seed: u64| {
            let mut det = ConvergenceDetector::new(cfg(0.05));
            let mut d = dict(seed);
            let mut rng = Pcg64::new(seed ^ 0xD1F7);
            for j in 0..48 {
                // Small random perturbation, then decaying magnitude so the
                // trajectory converges and freezes.
                let scale = 0.1 / (1.0 + j as f32);
                let mat = d.mat_mut();
                let flat = mat.as_mut_slice();
                for v in flat.iter_mut() {
                    *v += scale * rng.next_normal();
                }
                det.observe(j, &d, f64::from(1.0 / (1.0 + j as f32)));
            }
            det.into_events()
        };
        for seed in [7u64, 11, 13] {
            let a = run(seed);
            let b = run(seed);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b.iter()) {
                match (x, y) {
                    (
                        ConvEvent::Drift { batch: b1, norm: n1 },
                        ConvEvent::Drift { batch: b2, norm: n2 },
                    ) => {
                        assert_eq!(b1, b2);
                        assert_eq!(n1.to_bits(), n2.to_bits());
                    }
                    (
                        ConvEvent::LossRatio { batch: b1, ratio: r1 },
                        ConvEvent::LossRatio { batch: b2, ratio: r2 },
                    ) => {
                        assert_eq!(b1, b2);
                        assert_eq!(r1.to_bits(), r2.to_bits());
                    }
                    _ => assert_eq!(x, y),
                }
            }
        }
    }

    #[test]
    fn rel_drift_matches_hand_computation() {
        let a = [1.0f32, 0.0, 0.0];
        let b = [0.0f32, 1.0, 0.0];
        // ‖a − b‖ = √2, ‖b‖ = 1.
        assert!((rel_drift(&a, &b) - 2f64.sqrt()).abs() < 1e-12);
        assert_eq!(rel_drift(&b, &b), 0.0);
        // Zero reference guards the divide.
        let z = [0.0f32; 3];
        assert!(rel_drift(&a, &z).is_finite());
    }
}
