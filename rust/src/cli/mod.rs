//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `ddl <command> [--key value]... [--flag]...`. Typed getters
//! with defaults keep the drivers terse.

use crate::error::{DdlError, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First positional token (subcommand).
    pub command: Option<String>,
    /// Remaining positional tokens.
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(DdlError::Config("empty option name".into()));
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.options.insert(name.to_string(), v);
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// Boolean flag presence.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.options.get(name).map(|v| v == "true").unwrap_or(false)
    }

    /// String option.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// String with default.
    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed getters with defaults; malformed values are errors.
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| DdlError::Config(format!("--{name}: expected integer, got '{v}'"))),
        }
    }

    /// f32 with default.
    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| DdlError::Config(format!("--{name}: expected number, got '{v}'"))),
        }
    }

    /// u64 with default (seeds).
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| DdlError::Config(format!("--{name}: expected integer, got '{v}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["denoise", "--agents", "64", "--gamma=45.0", "--paper-scale"]);
        assert_eq!(a.command.as_deref(), Some("denoise"));
        assert_eq!(a.usize_or("agents", 0).unwrap(), 64);
        assert_eq!(a.f32_or("gamma", 0.0).unwrap(), 45.0);
        assert!(a.flag("paper-scale"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn defaults_when_absent() {
        let a = parse(&["novelty"]);
        assert_eq!(a.usize_or("steps", 8).unwrap(), 8);
        assert_eq!(a.f32_or("mu", 0.5).unwrap(), 0.5);
        assert_eq!(a.str_or("out", "results"), "results");
    }

    #[test]
    fn malformed_value_is_error() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.usize_or("n", 1).is_err());
    }

    #[test]
    fn positional_args() {
        let a = parse(&["run", "one", "two", "--k", "3"]);
        assert_eq!(a.positional, vec!["one", "two"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["run", "--verbose"]);
        assert!(a.flag("verbose"));
    }
}
