//! `ddl` — command-line launcher for the distributed dictionary learning
//! framework.
//!
//! Subcommands:
//! * `info`      — artifact registry + PJRT platform + topology diagnostics
//! * `quickstart`— tiny end-to-end run over the HLO path
//! * `denoise`   — Fig. 5 image-denoising experiment
//! * `novelty`   — Fig. 6/7 novel-document-detection experiment
//! * `tune`      — §IV-A step-size tuning curves (Fig. 4 procedure)
//! * `serve`     — streaming inference service with online adaptation
//! * `field`     — sensor-network field-monitoring serve scenario
//! * `async`     — sync-vs-async diffusion under a straggler delay model
//! * `chaos`     — deterministic fault injection over the async executor
//! * `trace-check`— validate a JSONL trace produced by `--trace`
//! * `bench-gate`— derived-speedup regression gate for BENCH_*.json
//!
//! Options can come from a TOML config (`--config path`) with CLI
//! overrides; see `configs/*.toml`.

use ddl::cli::Args;
use ddl::config::experiment::{AsyncConfig, DenoiseConfig, NoveltyConfig, ServeConfig};
use ddl::config::TomlDoc;
use ddl::coordinator::{run_denoise, run_novelty, NoveltyAlgo};
use std::path::Path;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.command.as_deref() {
        Some("info") => cmd_info(&args),
        Some("quickstart") => cmd_quickstart(&args),
        Some("denoise") => cmd_denoise(&args),
        Some("novelty") => cmd_novelty(&args),
        Some("tune") => cmd_tune(&args),
        Some("serve") => cmd_serve(&args),
        Some("field") => cmd_field(&args),
        Some("async") => cmd_async(&args),
        Some("chaos") => cmd_chaos(&args),
        Some("trace-check") => cmd_trace_check(&args),
        Some("bench-gate") => cmd_bench_gate(&args),
        _ => {
            println!("{HELP}");
            0
        }
    };
    std::process::exit(code);
}

const HELP: &str = "\
ddl — Dictionary Learning over Distributed Models (Chen, Towfic, Sayed; IEEE TSP 2014)

USAGE: ddl <command> [options]

COMMANDS:
  info        show artifacts, PJRT platform, topology diagnostics
  quickstart  tiny end-to-end run over the AOT/PJRT path
  denoise     image-denoising experiment (Fig. 5)     [--config f] [--informed k]
              [--agents n] [--train-samples n] [--baseline] [--per-agent]
  novelty     novel-document detection (Figs. 6-7)    [--config f] [--huber]
              [--algos diffusion,diffusion_fc,mairal,admm] [--steps n]
  tune        step-size tuning SNR curves (Fig. 4)    [--mu x] [--iters n]
  serve       streaming batched inference service     [--config f] [--batch b]
              [--max-wait-us t] [--samples n] [--rate r] [--burst n]
              [--agents n] [--topology ring|grid|er|full] [--mu-w x]
              [--no-adapt] [--pipeline | --no-pipeline] [--pipeline-depth d]
              [--adaptive] [--slo-ms x] [--queue-capacity n]
              [--kill-slot s] [--kill-at-batch j]
              [--stream planted|shift|field] [--shift-count n]
              [--conv-tol x] [--conv-window w] [--conv-patience p]
              [--thaw-ratio x]
              [--poison] [--poison-frac x] [--poison-scale x]
              [--no-poison-screen] [--poison-screen-z x]
              [--trace path] [--trace-format f]
              (three-stage concurrent pipeline: batch formation | diffusion
              inference | Eq. 51 update overlap on separate threads;
              bit-identical schedule; --no-pipeline overrides the TOML;
              --adaptive turns on the control plane: max_batch/max_wait
              re-decided each tick against the p99 SLO, pipeline depth
              re-planned at epoch boundaries, all on a deterministic
              virtual clock so adaptive runs replay bit-identically;
              --queue-capacity bounds admission: overflow is shed with a
              typed QueueFull error and fed back to the controller;
              --kill-slot/--kill-at-batch kill an inference worker
              mid-stream — the dispatcher re-dispatches the lost batch
              deterministically, bit-identical results;
              --stream selects the workload: planted dictionary (default),
              piecewise-stationary distribution shift (--shift-count
              segments beyond the first, boundaries a pure function of
              the seed), or the sensor-network field model;
              --conv-tol > 0 enables convergence-aware freeze/thaw:
              when relative dictionary drift per --conv-window batches
              stays below tol for --conv-patience windows, Eq. 51
              adaptation freezes and the update slot is released to pure
              inference; a sustained mean-loss jump above --thaw-ratio x
              the freeze-time loss thaws it at a deterministic batch
              boundary; TOML [control], [serve], [convergence])
  field       sensor-network field-monitoring scenario: `serve` over the
              spatially-correlated field stream  [same options as serve;
              --field-sources n] [--field-width x] [--field-noise x]
              (reports near/far sensor-pair correlation and adaptation
              gain on top of the serve report; pairs naturally with
              --conv-tol: the field is stationary, so adaptation freezes
              once the dictionary captures the spatial modes;
              --poison corrupts a chaos-seeded fraction of inbound sample
              vectors before admission; the batch former's deterministic
              robust norm-outlier screen (median + z*1.4826*MAD over the
              stream norms) quarantines them before the Eq. 51 update —
              --no-poison-screen measures the undefended run)
  async       sync-vs-async diffusion, straggler modeling [--config f]
              [--tau t] [--agents n] [--dim m] [--topology ring|grid|er|full]
              [--mu x] [--iters n] [--compute-dist zero|const|uniform|exp]
              [--compute-us t] [--link-dist d] [--link-us t]
              [--slow-agent k | --no-straggler] [--slow-factor x]
              [--drift-period-us t] [--checkpoints c] [--ring-k k]
              [--adaptive-tau] [--trace path] [--trace-format f]
              (per-edge psi exchange with bounded staleness tau on a
              deterministic discrete-event clock; tau = 0 reproduces the
              BSP trajectory bit-for-bit and serves as the sync baseline;
              --adaptive-tau runs the tau controller against a tau = 0
              probe, widening on gate-wait, narrowing on MSD drift;
              --drift-period-us rotates the slow agent; TOML [control])
  chaos       deterministic fault injection over the async executor
              [--config f] [--agents n] [--dim m] [--topology ring|grid|er|full]
              [--tau t] [--mu x] [--iters n] [--checkpoints c] [--seed n]
              [--chaos-seed n] [--partition-frac x] [--partition-start-frac x]
              [--partition-len-frac x] [--drop-prob p] [--crash-agent k]
              [--churn-windows w] [--pushsum auto|on|off|median|trimmed:f]
              [--byzantine] [--byzantine-agent k] [--byzantine-agents k1,k2]
              [--byzantine-policy sign-flip|scaled-noise|constant|colluding-offset]
              [--detect] [--detect-flag-after n] [--detect-exclude-after n]
              [--detect-probation-us t] [--detect-warmup n]
              [--adaptive-tau] [--bias-probe] [--trace path] [--trace-format f]
              (FaultSchedule of healing partitions, Gilbert-Elliott bursty
              links, message drops, agent crash/recovery windows, and
              Byzantine corrupted-psi windows — every event a pure
              function of (seed, sim-time), so chaos runs replay
              bit-identically and an empty schedule reproduces the
              fault-free trajectory bit-for-bit; push-sum combine is
              selected automatically when faults make the live topology
              directed; median / trimmed:f select coordinate-wise
              resilient combine; --byzantine runs the attack-vs-defense
              probe: MSD under a corrupted-psi attacker with Metropolis
              vs trimmed-mean combine, plus bitwise replay;
              --byzantine-agents names a *colluding set* (f > 1);
              --detect arms per-neighbor reputation scoring on top of the
              resilient combine: consistent trimmed-tail membership plus
              robust distance outliers accumulate evidence, flag at
              --detect-flag-after, exclude (weights renormalized) at
              --detect-exclude-after, optional probation re-admission
              after --detect-probation-us; every score update is a pure
              function of (config, sim-time, psi bits), so detection
              replays bit-identically and zero-attacker runs stay
              bitwise clean; TOML [chaos])
  trace-check validate a JSONL trace written by --trace: --trace path
              (parses every line, checks the Chrome trace_event fields)
  bench-gate  compare derived speedups in --current json against --baseline
              json; fail below --min-frac (default 0.5) of the baseline

Common: --seed n, --threads t (parallel adapt/combine; results identical),
        --artifacts dir (default: artifacts)
Tracing: --trace path writes a virtual-clock event trace (serve/async/chaos);
        --trace-format auto|jsonl|chrome (auto: .jsonl => JSONL, else a
        Chrome trace_event document loadable at https://ui.perfetto.dev);
        TOML [obs]. Tracing never perturbs a run: traced and untraced
        executions are bit-identical (tests/obs_parity.rs)";

/// Apply the shared `--trace` / `--trace-format` overrides to a config's
/// `[obs]` block (serve, async, and chaos all take them identically).
fn apply_trace_args(obs: &mut ddl::config::experiment::ObsConfig, args: &Args) {
    if let Some(p) = args.get("trace") {
        obs.trace_path = Some(p.to_string());
    }
    obs.format = args.str_or("trace-format", &obs.format).to_string();
}

fn run(code: impl FnOnce() -> ddl::Result<()>) -> i32 {
    match code() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

#[cfg(feature = "xla")]
fn show_runtime(dir: &Path) {
    match ddl::runtime::Runtime::new(dir) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifacts:");
            for name in rt.names() {
                println!("  {name}");
            }
        }
        Err(e) => println!("runtime unavailable: {e}"),
    }
}

#[cfg(not(feature = "xla"))]
fn show_runtime(_dir: &Path) {
    println!("runtime unavailable: built without the `xla` feature (pure-rust build)");
}

fn cmd_info(args: &Args) -> i32 {
    let dir = args.str_or("artifacts", "artifacts").to_string();
    run(move || {
        show_runtime(Path::new(&dir));
        // Topology diagnostics at the denoise default scale.
        let mut rng = ddl::rng::Pcg64::new(1);
        let g = ddl::graph::Graph::generate(
            64,
            &ddl::graph::Topology::ErdosRenyi { p: 0.5 },
            &mut rng,
        );
        let a = ddl::graph::metropolis_weights(&g);
        println!(
            "G(64, 0.5): edges={}, algebraic connectivity={:.3}, spectral gap={:.3}",
            g.edge_count(),
            ddl::graph::laplacian::algebraic_connectivity(&g),
            ddl::graph::laplacian::spectral_gap(&a),
        );
        Ok(())
    })
}

#[cfg(feature = "xla")]
fn cmd_quickstart(args: &Args) -> i32 {
    let dir = args.str_or("artifacts", "artifacts").to_string();
    run(move || {
        ddl::coordinator::quickstart::run_quickstart(Path::new(&dir), &mut |s| println!("{s}"))
    })
}

#[cfg(not(feature = "xla"))]
fn cmd_quickstart(_args: &Args) -> i32 {
    eprintln!("quickstart needs the PJRT bridge: rebuild with `--features xla`");
    2
}

fn cmd_denoise(args: &Args) -> i32 {
    run(|| {
        let doc = match args.get("config") {
            Some(p) => TomlDoc::load(Path::new(p))?,
            None => TomlDoc::default(),
        };
        let mut cfg = DenoiseConfig::from_toml(&doc);
        cfg.seed = args.u64_or("seed", cfg.seed)?;
        cfg.agents = args.usize_or("agents", cfg.agents)?;
        cfg.train_samples = args.usize_or("train-samples", cfg.train_samples)?;
        let threads = args.usize_or("threads", cfg.train_infer.threads)?;
        cfg.train_infer.threads = threads;
        cfg.denoise_infer.threads = threads;
        if let Some(k) = args.get("informed") {
            cfg.informed = Some(
                k.parse()
                    .map_err(|_| ddl::DdlError::Config(format!("--informed: bad value '{k}'")))?,
            );
        }
        let report = run_denoise(&cfg, args.flag("baseline"), args.flag("per-agent"), |s| {
            println!("{s}")
        })?;
        println!("== denoise results ==");
        println!("corrupted:   {:.2} dB", report.psnr_noisy);
        println!("distributed: {:.2} dB", report.psnr_distributed);
        if let Some(p) = report.psnr_centralized {
            println!("centralized: {p:.2} dB");
        }
        if !report.per_agent_psnr.is_empty() {
            let min = report.per_agent_psnr.iter().cloned().fold(f64::MAX, f64::min);
            let max = report.per_agent_psnr.iter().cloned().fold(f64::MIN, f64::max);
            println!("per-agent:   {min:.2}–{max:.2} dB across {} agents", report.per_agent_psnr.len());
        }
        Ok(())
    })
}

fn cmd_novelty(args: &Args) -> i32 {
    run(|| {
        let doc = match args.get("config") {
            Some(p) => TomlDoc::load(Path::new(p))?,
            None => TomlDoc::default(),
        };
        let base = if args.flag("huber") {
            NoveltyConfig::huber()
        } else {
            NoveltyConfig::squared_l2()
        };
        let mut cfg = NoveltyConfig::from_toml(&doc, base);
        cfg.seed = args.u64_or("seed", cfg.seed)?;
        cfg.time_steps = args.usize_or("steps", cfg.time_steps)?;
        cfg.threads = args.usize_or("threads", cfg.threads)?;
        let algos: Vec<NoveltyAlgo> = args
            .str_or("algos", "diffusion,diffusion_fc")
            .split(',')
            .map(|s| match s.trim() {
                "diffusion" => Ok(NoveltyAlgo::Diffusion),
                "diffusion_fc" => Ok(NoveltyAlgo::DiffusionFullyConnected),
                "mairal" => Ok(NoveltyAlgo::CentralizedMairal),
                "admm" => Ok(NoveltyAlgo::CentralizedAdmm),
                other => Err(ddl::DdlError::Config(format!("unknown algo '{other}'"))),
            })
            .collect::<ddl::Result<_>>()?;
        let report = run_novelty(&cfg, &algos, |s| println!("{s}"))?;
        println!("== AUC table ==");
        println!("{:<6} {:<14} {:>6}", "step", "algo", "auc");
        for (step, algo, auc) in report.auc_rows() {
            println!("{step:<6} {algo:<14} {auc:>6.3}");
        }
        Ok(())
    })
}

/// Build a [`ServeConfig`] from `--config` TOML plus CLI overrides; shared
/// by `ddl serve` and `ddl field` (which forces the field stream on top).
fn serve_cfg_from_args(args: &Args) -> ddl::Result<ServeConfig> {
    let doc = match args.get("config") {
        Some(p) => TomlDoc::load(Path::new(p))?,
        None => TomlDoc::default(),
    };
    let mut cfg = ServeConfig::from_toml(&doc);
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.agents = args.usize_or("agents", cfg.agents)?;
    cfg.dim = args.usize_or("dim", cfg.dim)?;
    cfg.topology = args.str_or("topology", &cfg.topology).to_string();
    cfg.ring_k = args.usize_or("ring-k", cfg.ring_k)?;
    cfg.batch = args.usize_or("batch", cfg.batch)?.max(1);
    cfg.max_wait_us = args.u64_or("max-wait-us", cfg.max_wait_us)?;
    cfg.samples = args.usize_or("samples", cfg.samples)?;
    cfg.rate = args.f32_or("rate", cfg.rate as f32)? as f64;
    cfg.burst = args.usize_or("burst", cfg.burst)?.max(1);
    cfg.mu_w = args.f32_or("mu-w", cfg.mu_w)?;
    cfg.pipeline = cfg.pipeline || args.flag("pipeline");
    if args.flag("no-pipeline") {
        // Override a TOML `pipeline = true` for the serial comparison
        // run without editing the config file.
        cfg.pipeline = false;
    }
    cfg.pipeline_depth = args.usize_or("pipeline-depth", cfg.pipeline_depth)?.max(1);
    cfg.queue_capacity = args.usize_or("queue-capacity", cfg.queue_capacity)?;
    if let Some(s) = args.get("kill-slot") {
        cfg.kill_slot = Some(s.parse().map_err(|_| {
            ddl::DdlError::Config(format!("--kill-slot: bad value '{s}'"))
        })?);
    }
    cfg.kill_at_batch = args.usize_or("kill-at-batch", cfg.kill_at_batch)?;
    cfg.infer.mu = args.f32_or("mu", cfg.infer.mu)?;
    cfg.infer.iters = args.usize_or("iters", cfg.infer.iters)?;
    cfg.infer.threads = args.usize_or("threads", cfg.infer.threads)?;
    if args.flag("no-adapt") {
        cfg.mu_w = 0.0;
    }
    cfg.control.enabled = cfg.control.enabled || args.flag("adaptive");
    cfg.control.slo_p99_ms = args.f32_or("slo-ms", cfg.control.slo_p99_ms as f32)? as f64;
    // Data-poisoning injection + the robust norm-outlier screen.
    cfg.poison = cfg.poison || args.flag("poison");
    cfg.poison_frac = (args.f32_or("poison-frac", cfg.poison_frac as f32)? as f64).clamp(0.0, 1.0);
    cfg.poison_scale = args.f32_or("poison-scale", cfg.poison_scale)?;
    if args.flag("no-poison-screen") {
        cfg.poison_screen = false;
    }
    cfg.poison_screen_z = (args.f32_or("poison-screen-z", cfg.poison_screen_z as f32)? as f64).max(0.0);
    // Workload stream + distribution-shift knobs.
    cfg.stream = args.str_or("stream", &cfg.stream).to_string();
    cfg.shift_count = args.usize_or("shift-count", cfg.shift_count)?;
    cfg.field_sources = args.usize_or("field-sources", cfg.field_sources)?.max(1);
    cfg.field_width = args.f32_or("field-width", cfg.field_width)?;
    cfg.field_noise = args.f32_or("field-noise", cfg.field_noise)?;
    // Convergence-aware freeze/thaw (tol = 0 leaves the detector off).
    cfg.convergence.tol = args.f32_or("conv-tol", cfg.convergence.tol as f32)? as f64;
    cfg.convergence.window = args.usize_or("conv-window", cfg.convergence.window)?.max(1);
    cfg.convergence.max_no_improvement =
        args.usize_or("conv-patience", cfg.convergence.max_no_improvement)?.max(1);
    cfg.convergence.thaw_ratio =
        args.f32_or("thaw-ratio", cfg.convergence.thaw_ratio as f32)? as f64;
    apply_trace_args(&mut cfg.obs, args);
    Ok(cfg)
}

fn cmd_serve(args: &Args) -> i32 {
    run(|| {
        let cfg = serve_cfg_from_args(args)?;
        let report = ddl::serve::run_service(&cfg, &mut |s| println!("{s}"))?;
        println!("== serve report ==");
        println!("{}", report.summary(cfg.agents));
        Ok(())
    })
}

fn cmd_field(args: &Args) -> i32 {
    run(|| {
        let cfg = serve_cfg_from_args(args)?;
        let report = ddl::coordinator::run_field(&cfg, &mut |s| println!("{s}"))?;
        println!("== field report (sensor-network monitoring) ==");
        println!("{}", report.summary(cfg.agents));
        Ok(())
    })
}

fn cmd_async(args: &Args) -> i32 {
    run(|| {
        let doc = match args.get("config") {
            Some(p) => TomlDoc::load(Path::new(p))?,
            None => TomlDoc::default(),
        };
        let mut cfg = AsyncConfig::from_toml(&doc);
        cfg.seed = args.u64_or("seed", cfg.seed)?;
        cfg.agents = args.usize_or("agents", cfg.agents)?;
        cfg.dim = args.usize_or("dim", cfg.dim)?;
        cfg.topology = args.str_or("topology", &cfg.topology).to_string();
        cfg.ring_k = args.usize_or("ring-k", cfg.ring_k)?;
        cfg.tau = args.usize_or("tau", cfg.tau)?;
        cfg.compute_dist = args.str_or("compute-dist", &cfg.compute_dist).to_string();
        cfg.compute_us = args.u64_or("compute-us", cfg.compute_us)?;
        cfg.link_dist = args.str_or("link-dist", &cfg.link_dist).to_string();
        cfg.link_us = args.u64_or("link-us", cfg.link_us)?;
        if let Some(k) = args.get("slow-agent") {
            cfg.slow_agent = Some(k.parse().map_err(|_| {
                ddl::DdlError::Config(format!("--slow-agent: bad value '{k}'"))
            })?);
        }
        if args.flag("no-straggler") {
            cfg.slow_agent = None;
        }
        cfg.slow_factor = args.f32_or("slow-factor", cfg.slow_factor as f32)? as f64;
        cfg.drift_period_us = args.u64_or("drift-period-us", cfg.drift_period_us)?;
        cfg.infer.mu = args.f32_or("mu", cfg.infer.mu)?;
        cfg.infer.iters = args.usize_or("iters", cfg.infer.iters)?;
        cfg.checkpoints = args.usize_or("checkpoints", cfg.checkpoints)?.max(1);
        cfg.control.adaptive_tau = cfg.control.adaptive_tau || args.flag("adaptive-tau");
        apply_trace_args(&mut cfg.obs, args);
        if cfg.control.adaptive_tau {
            let report = ddl::coordinator::run_adaptive_tau(&cfg, &mut |s| println!("{s}"))?;
            println!("== adaptive-tau report (per control epoch) ==");
            println!("{}", report.summary(cfg.agents));
        } else {
            let report = ddl::coordinator::run_straggler(&cfg, &mut |s| println!("{s}"))?;
            println!("== async report (MSD vs simulated time) ==");
            println!("{}", report.summary(cfg.agents));
        }
        Ok(())
    })
}

fn cmd_chaos(args: &Args) -> i32 {
    run(|| {
        let doc = match args.get("config") {
            Some(p) => TomlDoc::load(Path::new(p))?,
            None => TomlDoc::default(),
        };
        let mut cfg = AsyncConfig::from_toml(&doc);
        cfg.seed = args.u64_or("seed", cfg.seed)?;
        cfg.agents = args.usize_or("agents", cfg.agents)?;
        cfg.dim = args.usize_or("dim", cfg.dim)?;
        cfg.topology = args.str_or("topology", &cfg.topology).to_string();
        cfg.ring_k = args.usize_or("ring-k", cfg.ring_k)?;
        cfg.tau = args.usize_or("tau", cfg.tau)?;
        cfg.compute_dist = args.str_or("compute-dist", &cfg.compute_dist).to_string();
        cfg.compute_us = args.u64_or("compute-us", cfg.compute_us)?;
        cfg.link_dist = args.str_or("link-dist", &cfg.link_dist).to_string();
        cfg.link_us = args.u64_or("link-us", cfg.link_us)?;
        cfg.infer.mu = args.f32_or("mu", cfg.infer.mu)?;
        cfg.infer.iters = args.usize_or("iters", cfg.infer.iters)?;
        cfg.checkpoints = args.usize_or("checkpoints", cfg.checkpoints)?.max(1);
        cfg.chaos.enabled = true;
        cfg.chaos.seed = args.u64_or("chaos-seed", cfg.chaos.seed)?;
        cfg.chaos.partition_frac =
            args.f32_or("partition-frac", cfg.chaos.partition_frac as f32)? as f64;
        cfg.chaos.partition_start_frac =
            args.f32_or("partition-start-frac", cfg.chaos.partition_start_frac as f32)? as f64;
        cfg.chaos.partition_len_frac =
            args.f32_or("partition-len-frac", cfg.chaos.partition_len_frac as f32)? as f64;
        cfg.chaos.drop_prob = args.f32_or("drop-prob", cfg.chaos.drop_prob as f32)? as f64;
        if let Some(k) = args.get("crash-agent") {
            cfg.chaos.crash_agent = Some(k.parse().map_err(|_| {
                ddl::DdlError::Config(format!("--crash-agent: bad value '{k}'"))
            })?);
        }
        cfg.chaos.churn_windows = args.usize_or("churn-windows", cfg.chaos.churn_windows)?;
        cfg.chaos.pushsum = args.str_or("pushsum", &cfg.chaos.pushsum).to_string();
        if let Some(k) = args.get("byzantine-agent") {
            cfg.chaos.byzantine_agent = Some(k.parse().map_err(|_| {
                ddl::DdlError::Config(format!("--byzantine-agent: bad value '{k}'"))
            })?);
        }
        cfg.chaos.byzantine_policy =
            args.str_or("byzantine-policy", &cfg.chaos.byzantine_policy).to_string();
        cfg.chaos.byzantine_agents =
            args.str_or("byzantine-agents", &cfg.chaos.byzantine_agents).to_string();
        cfg.chaos.detect = cfg.chaos.detect || args.flag("detect");
        cfg.chaos.detect_flag_after =
            args.usize_or("detect-flag-after", cfg.chaos.detect_flag_after)?.max(1);
        cfg.chaos.detect_exclude_after = args
            .usize_or("detect-exclude-after", cfg.chaos.detect_exclude_after)?
            .max(cfg.chaos.detect_flag_after);
        cfg.chaos.detect_probation_us =
            args.u64_or("detect-probation-us", cfg.chaos.detect_probation_us)?;
        cfg.chaos.detect_warmup = args.usize_or("detect-warmup", cfg.chaos.detect_warmup)?;
        cfg.control.adaptive_tau = cfg.control.adaptive_tau || args.flag("adaptive-tau");
        apply_trace_args(&mut cfg.obs, args);
        if args.flag("byzantine") {
            let report = ddl::coordinator::run_byzantine(&cfg, &mut |s| println!("{s}"))?;
            println!("== Byzantine probe (attack vs resilient combine) ==");
            println!("{}", report.summary());
            return Ok(());
        }
        if args.flag("bias-probe") {
            let probe = ddl::coordinator::run_pushsum_bias(&cfg, &mut |s| println!("{s}"))?;
            println!("== push-sum bias probe (persistent directed outage) ==");
            println!(
                "outage from t = {} µs cutting {} directed links\n\
                 metropolis MSD {:.3e} | push-sum MSD {:.3e} (bias ratio {:.2}x)",
                probe.outage_from_us,
                probe.links_cut,
                probe.msd_metropolis,
                probe.msd_pushsum,
                probe.bias_ratio(),
            );
            return Ok(());
        }
        let report = ddl::coordinator::run_chaos(&cfg, &mut |s| println!("{s}"))?;
        println!("== chaos report (MSD vs simulated time) ==");
        println!("{}", report.summary(cfg.agents));
        Ok(())
    })
}

fn cmd_trace_check(args: &Args) -> i32 {
    run(|| {
        let path = args
            .get("trace")
            .ok_or_else(|| ddl::DdlError::Config("trace-check: --trace path required".into()))?;
        let c = ddl::obs::check_jsonl(Path::new(path))?;
        println!(
            "trace-check: {path} ok — {} events ({} span begins, {} span ends, {} instants, \
             {} counters)",
            c.events, c.span_begins, c.span_ends, c.instants, c.counters
        );
        Ok(())
    })
}

fn cmd_bench_gate(args: &Args) -> i32 {
    run(|| {
        let current = args
            .get("current")
            .ok_or_else(|| ddl::DdlError::Config("bench-gate: --current json required".into()))?;
        let baseline = args
            .get("baseline")
            .ok_or_else(|| ddl::DdlError::Config("bench-gate: --baseline json required".into()))?;
        let min_frac = args.f32_or("min-frac", 0.5)? as f64;
        let rows =
            ddl::bench::regression_gate(Path::new(current), Path::new(baseline), min_frac)?;
        println!(
            "{:<52} {:>4} {:>10} {:>10} {:>6}",
            "derived figure", "dir", "baseline", "current", "ok"
        );
        let mut failed = false;
        for r in &rows {
            // Ratio-style figures read as multipliers; latency-style keys
            // are raw values where lower is better.
            let lower = ddl::bench::lower_is_better(&r.key);
            let unit = if lower { " " } else { "x" };
            println!(
                "{:<52} {:>4} {:>9.2}{} {:>9.2}{} {:>6}",
                r.key,
                if lower { "min" } else { "max" },
                r.baseline,
                unit,
                r.current,
                unit,
                if r.ok { "ok" } else { "FAIL" }
            );
            failed |= !r.ok;
        }
        if failed {
            return Err(ddl::DdlError::Runtime(format!(
                "bench-gate: derived speedups regressed below {min_frac} x baseline"
            )));
        }
        println!("bench-gate: {} figures within tolerance", rows.len());
        Ok(())
    })
}

fn cmd_tune(args: &Args) -> i32 {
    run(|| {
        let mu = args.f32_or("mu", 0.5)?;
        let iters = args.usize_or("iters", 1000)?;
        let seed = args.u64_or("seed", 7)?;
        let curves = ddl::coordinator::tuning::tuning_curves(mu, iters, seed)?;
        println!("iter, y_snr_db, nu_snr_db");
        for p in curves.iter().step_by((iters / 25).max(1)) {
            println!("{}, {:.2}, {:.2}", p.iter, p.y_snr_db, p.nu_snr_db);
        }
        Ok(())
    })
}
