//! PJRT execution: compile HLO-text artifacts once, run them repeatedly
//! from the coordinator's request path.

use crate::error::{DdlError, Result};
use crate::math::Mat;
use crate::runtime::artifact::{ArtifactInfo, ArtifactRegistry};
use std::path::Path;

/// Outputs of one inference execution.
#[derive(Clone, Debug)]
pub struct InferOutput {
    /// Stacked dual iterates `V (N, M)`.
    pub v: Mat,
    /// Recovered coefficients `y (N,)` (one atom per agent).
    pub y: Vec<f32>,
    /// Novelty score (artifacts exported `with_cost`).
    pub cost: Option<f32>,
}

/// PJRT runtime: a CPU client plus compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    registry: ArtifactRegistry,
}

/// A compiled inference artifact bound to its metadata.
pub struct LoadedInfer {
    exe: xla::PjRtLoadedExecutable,
    pub info: ArtifactInfo,
}

/// A compiled dictionary-update artifact.
pub struct LoadedUpdate {
    exe: xla::PjRtLoadedExecutable,
    pub info: ArtifactInfo,
}

impl Runtime {
    /// Create a CPU PJRT client over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let registry = ArtifactRegistry::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, registry })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact names available.
    pub fn names(&self) -> Vec<String> {
        self.registry.names().map(String::from).collect()
    }

    fn compile(&self, info: &ArtifactInfo) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(&info.file).map_err(|e| {
            DdlError::Runtime(format!("loading {}: {e}", info.file.display()))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    /// Compile an inference artifact.
    pub fn load_infer(&self, name: &str) -> Result<LoadedInfer> {
        let info = self.registry.get(name)?.clone();
        if info.kind != "infer" {
            return Err(DdlError::Runtime(format!("artifact {name} is not an infer graph")));
        }
        Ok(LoadedInfer { exe: self.compile(&info)?, info })
    }

    /// Compile a dictionary-update artifact.
    pub fn load_update(&self, name: &str) -> Result<LoadedUpdate> {
        let info = self.registry.get(name)?.clone();
        if info.kind != "update" {
            return Err(DdlError::Runtime(format!("artifact {name} is not an update graph")));
        }
        Ok(LoadedUpdate { exe: self.compile(&info)?, info })
    }
}

/// Pack a row-major matrix into an XLA literal of shape `(rows, cols)`.
fn mat_literal(m: &Mat) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(m.as_slice()).reshape(&[m.rows() as i64, m.cols() as i64])?)
}

/// Pack a vector into an XLA literal of shape `(len,)`.
fn vec_literal(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// Unpack a literal into a `Mat` of the expected shape.
fn literal_to_mat(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Mat> {
    let data = lit.to_vec::<f32>()?;
    Mat::from_vec(rows, cols, data)
}

/// The packed scalar parameter block (must match kernels/diffusion.py).
#[derive(Clone, Copy, Debug)]
pub struct ParamPack {
    pub mu: f32,
    pub gamma: f32,
    pub delta: f32,
    /// `c_f / N` with `∇f*(ν) = c_f ν`.
    pub cf_over_n: f32,
    pub clip_bound: f32,
}

impl ParamPack {
    /// Derive from a task spec and network size.
    pub fn from_task(task: &crate::model::TaskSpec, n: usize, mu: f32) -> Self {
        ParamPack {
            mu,
            gamma: task.gamma(),
            delta: task.delta(),
            cf_over_n: task.conj_grad_scale() / n as f32,
            clip_bound: task.dual_clip().unwrap_or(0.0),
        }
    }

    fn to_vec(self) -> Vec<f32> {
        vec![self.mu, self.gamma, self.delta, self.cf_over_n, 0.0, self.clip_bound, 0.0, 0.0]
    }
}

impl LoadedInfer {
    /// Execute: inputs are the transposed dictionary `Wt (N, M)` (row k =
    /// atom of agent k), the sample `x (M,)`, the transposed combination
    /// matrix `At (N, N)`, the informed mask `theta (N,)`, and the scalar
    /// params.
    pub fn run(&self, wt: &Mat, x: &[f32], at: &Mat, theta: &[f32], p: ParamPack) -> Result<InferOutput> {
        let (n, m) = (self.info.n, self.info.m);
        if wt.shape() != (n, m) || at.shape() != (n, n) || x.len() != m || theta.len() != n {
            return Err(DdlError::Shape(format!(
                "artifact {} expects Wt ({n},{m}), x ({m},), At ({n},{n}); got Wt {:?}, x {}, At {:?}",
                self.info.name,
                wt.shape(),
                x.len(),
                at.shape()
            )));
        }
        let inputs = [
            mat_literal(wt)?,
            vec_literal(x),
            mat_literal(at)?,
            vec_literal(theta),
            vec_literal(&p.to_vec()),
        ];
        let result = self.exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        let expected = if self.info.with_cost { 3 } else { 2 };
        if tuple.len() != expected {
            return Err(DdlError::Runtime(format!(
                "artifact {}: expected {expected}-tuple, got {}",
                self.info.name,
                tuple.len()
            )));
        }
        let v = literal_to_mat(&tuple[0], n, m)?;
        let y = tuple[1].to_vec::<f32>()?;
        let cost = if self.info.with_cost {
            Some(tuple[2].to_vec::<f32>()?[0])
        } else {
            None
        };
        Ok(InferOutput { v, y, cost })
    }
}

impl LoadedUpdate {
    /// Execute the Eq. 51 update: `Wt' = Π(Wt + μ_w y νᵀ)`.
    pub fn run(&self, wt: &Mat, nu: &[f32], y: &[f32], mu_w: f32) -> Result<Mat> {
        let (n, m) = (self.info.n, self.info.m);
        if wt.shape() != (n, m) || nu.len() != m || y.len() != n {
            return Err(DdlError::Shape(format!(
                "artifact {}: shape mismatch (Wt {:?}, nu {}, y {})",
                self.info.name,
                wt.shape(),
                nu.len(),
                y.len()
            )));
        }
        let inputs = [
            mat_literal(wt)?,
            vec_literal(nu),
            vec_literal(y),
            xla::Literal::scalar(mu_w),
        ];
        let result = self.exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        literal_to_mat(&out, n, m)
    }
}
