//! Artifact manifest parsing (`artifacts/manifest.json`).

use crate::config::JsonValue;
use crate::error::{DdlError, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Metadata for one AOT artifact, as written by `python/compile/aot.py`.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: PathBuf,
    /// "infer" or "update".
    pub kind: String,
    /// Task variant for infer artifacts ("sq" | "nmf" | "huber").
    pub variant: Option<String>,
    /// Data dimension M.
    pub m: usize,
    /// Agents N (= atoms K on the HLO path).
    pub n: usize,
    /// Baked iteration count (infer artifacts).
    pub iters: Option<usize>,
    /// Whether the infer artifact also emits the novelty cost.
    pub with_cost: bool,
}

/// Registry over an artifacts directory.
#[derive(Clone, Debug)]
pub struct ArtifactRegistry {
    dir: PathBuf,
    artifacts: BTreeMap<String, ArtifactInfo>,
}

impl ArtifactRegistry {
    /// Load `manifest.json` from `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            DdlError::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            ))
        })?;
        let doc = JsonValue::parse(&text)?;
        let version = doc
            .get("version")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| DdlError::Config("manifest missing version".into()))?;
        if version != 1 {
            return Err(DdlError::Config(format!("unsupported manifest version {version}")));
        }
        let arts = doc
            .get("artifacts")
            .and_then(|v| v.as_object())
            .ok_or_else(|| DdlError::Config("manifest missing artifacts".into()))?;
        let mut artifacts = BTreeMap::new();
        for (name, spec) in arts {
            let get_usize = |key: &str| -> Result<usize> {
                spec.get(key)
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| DdlError::Config(format!("artifact {name}: missing {key}")))
            };
            let file = spec
                .get("file")
                .and_then(|v| v.as_str())
                .ok_or_else(|| DdlError::Config(format!("artifact {name}: missing file")))?;
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name: name.clone(),
                    file: dir.join(file),
                    kind: spec
                        .get("kind")
                        .and_then(|v| v.as_str())
                        .unwrap_or("infer")
                        .to_string(),
                    variant: spec.get("variant").and_then(|v| v.as_str()).map(String::from),
                    m: get_usize("m")?,
                    n: get_usize("n")?,
                    iters: spec.get("iters").and_then(|v| v.as_usize()),
                    with_cost: spec
                        .get("with_cost")
                        .and_then(|v| v.as_bool())
                        .unwrap_or(false),
                },
            );
        }
        Ok(ArtifactRegistry { dir: dir.to_path_buf(), artifacts })
    }

    /// Lookup by name.
    pub fn get(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts.get(name).ok_or_else(|| {
            DdlError::Runtime(format!(
                "artifact '{name}' not in manifest ({}); available: {:?}",
                self.dir.display(),
                self.artifacts.keys().collect::<Vec<_>>()
            ))
        })
    }

    /// All artifact names.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.artifacts.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn loads_valid_manifest() {
        let dir = std::env::temp_dir().join("ddl_manifest_ok");
        write_manifest(
            &dir,
            r#"{"version": 1, "scale": "tiny", "artifacts": {
                "quickstart_infer": {"file": "quickstart_infer.hlo.txt", "kind": "infer",
                  "variant": "sq", "m": 16, "n": 8, "iters": 60, "with_cost": false,
                  "inputs": ["wt","x","at","theta","params"], "outputs": ["v","y"]}
            }}"#,
        );
        let reg = ArtifactRegistry::load(&dir).unwrap();
        let a = reg.get("quickstart_infer").unwrap();
        assert_eq!(a.m, 16);
        assert_eq!(a.n, 8);
        assert_eq!(a.iters, Some(60));
        assert_eq!(a.variant.as_deref(), Some("sq"));
        assert!(!a.with_cost);
        assert!(reg.get("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_missing_and_bad_manifests() {
        let dir = std::env::temp_dir().join("ddl_manifest_missing");
        std::fs::remove_dir_all(&dir).ok();
        assert!(ArtifactRegistry::load(&dir).is_err());
        write_manifest(&dir, r#"{"version": 99, "artifacts": {}}"#);
        assert!(ArtifactRegistry::load(&dir).is_err());
        write_manifest(&dir, r#"{"version": 1, "artifacts": {"x": {"kind": "infer"}}}"#);
        assert!(ArtifactRegistry::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
