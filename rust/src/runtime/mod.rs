//! PJRT runtime bridge: load the AOT HLO artifacts and execute them from
//! the rust request path.
//!
//! Python (L1/L2) runs once at `make artifacts`; afterwards this module is
//! the only touchpoint with the compiled computations:
//!
//! ```text
//! PjRtClient::cpu() → HloModuleProto::from_text_file → client.compile
//!                   → executable.execute(literals)
//! ```
//!
//! HLO *text* is the interchange format (xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos — see /opt/xla-example/README.md).

pub mod artifact;
pub mod exec;

pub use artifact::{ArtifactInfo, ArtifactRegistry};
pub use exec::{InferOutput, LoadedInfer, LoadedUpdate, Runtime};
