//! # ddl — Dictionary Learning over Distributed Models
//!
//! A production-quality reproduction of:
//!
//! > J. Chen, Z. J. Towfic, and A. H. Sayed, "Dictionary Learning over
//! > Distributed Models," IEEE Transactions on Signal Processing, 2014.
//! > DOI: 10.1109/TSP.2014.2385045
//!
//! The library implements *model-distributed* dictionary learning: a network
//! of `N` agents, each in charge of a block of dictionary atoms, cooperates
//! to solve the sparse-coding (inference) problem through its **dual**, which
//! decomposes into a sum-of-costs that diffusion strategies minimize with
//! only neighborhood communication of the dual variable `nu`. The optimal
//! dual variable then drives fully local dictionary updates (Eq. 51 in the
//! paper) — no agent ever shares its atoms or coefficients.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordinator: network simulation, diffusion
//!   orchestration, trainers, experiment drivers, metrics, baselines.
//! * **L2 (python/compile/model.py)** — JAX inference/update graphs, AOT
//!   lowered to HLO text, executed from rust through PJRT ([`runtime`]).
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the fused
//!   per-agent diffusion step (adapt + combine), numerically checked
//!   against a pure-jnp oracle.
//!
//! The native rust implementation in [`infer`] mirrors the L1/L2 compute
//! exactly and is cross-validated against the HLO path in integration tests.
//!
//! Start with `README.md` (orientation, quickstart, `ddl` subcommands) and
//! `ARCHITECTURE.md` (executor matrix, ψ-privacy dataflow, determinism
//! contracts) at the repository root; measurement methodology lives in
//! `EXPERIMENTS.md`.

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod graph;
pub mod infer;
pub mod learn;
pub mod math;
pub mod metrics;
pub mod model;
pub mod net;
pub mod obs;
pub mod ops;
pub mod rng;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod serve;
pub mod testutil;

pub use error::{DdlError, Result};
