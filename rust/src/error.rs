//! Crate-wide error type.
use thiserror::Error;

/// Errors surfaced by the ddl library.
#[derive(Error, Debug)]
pub enum DdlError {
    #[error("shape mismatch: {0}")]
    Shape(String),
    #[error("config error: {0}")]
    Config(String),
    #[error("runtime error: {0}")]
    Runtime(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("xla error: {0}")]
    Xla(String),
    #[error("{0}")]
    Other(String),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, DdlError>;

impl From<xla::Error> for DdlError {
    fn from(e: xla::Error) -> Self {
        DdlError::Xla(e.to_string())
    }
}
