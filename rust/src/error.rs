//! Crate-wide error type.
//!
//! Hand-implemented (no `thiserror`): the crate is fully offline and
//! carries zero external dependencies. The `Xla` variant exists even
//! without the optional `xla` feature so error-matching code is
//! feature-independent; the `From<xla::Error>` conversion is only
//! compiled when the PJRT bridge is.

use std::fmt;

/// Errors surfaced by the ddl library.
#[derive(Debug)]
pub enum DdlError {
    /// Dimension / shape mismatch between tensors, graphs, or configs.
    Shape(String),
    /// Invalid or inconsistent configuration.
    Config(String),
    /// Failure while executing (I/O-free) library code: executor stalls,
    /// poisoned channels, violated scheduling invariants.
    Runtime(String),
    /// Bounded admission queue rejected a sample: load must be shed.
    /// Typed (rather than a `Runtime` string) so the serving layer and
    /// the batch controller can match on it and count sheds.
    QueueFull {
        /// Capacity the queue was bounded to when it rejected.
        capacity: usize,
    },
    /// Underlying I/O failure.
    Io(std::io::Error),
    /// Error from the PJRT/XLA bridge (feature `xla`).
    Xla(String),
    /// Anything else.
    Other(String),
}

impl fmt::Display for DdlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DdlError::Shape(s) => write!(f, "shape mismatch: {s}"),
            DdlError::Config(s) => write!(f, "config error: {s}"),
            DdlError::Runtime(s) => write!(f, "runtime error: {s}"),
            DdlError::QueueFull { capacity } => {
                write!(f, "queue full: admission rejected at capacity {capacity}")
            }
            DdlError::Io(e) => write!(f, "io error: {e}"),
            DdlError::Xla(s) => write!(f, "xla error: {s}"),
            DdlError::Other(s) => write!(f, "{s}"),
        }
    }
}

impl std::error::Error for DdlError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DdlError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for DdlError {
    fn from(e: std::io::Error) -> Self {
        DdlError::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for DdlError {
    fn from(e: xla::Error) -> Self {
        DdlError::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, DdlError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_by_variant() {
        assert_eq!(DdlError::Shape("a".into()).to_string(), "shape mismatch: a");
        assert_eq!(DdlError::Config("b".into()).to_string(), "config error: b");
        assert_eq!(DdlError::Runtime("c".into()).to_string(), "runtime error: c");
        assert_eq!(DdlError::Other("d".into()).to_string(), "d");
        assert_eq!(
            DdlError::QueueFull { capacity: 8 }.to_string(),
            "queue full: admission rejected at capacity 8"
        );
        assert!(matches!(DdlError::QueueFull { capacity: 8 }, DdlError::QueueFull { .. }));
    }

    #[test]
    fn io_conversion_and_source() {
        let e: DdlError = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
