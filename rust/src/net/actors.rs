//! Threaded actor executor: agents multiplexed onto worker threads, with
//! `std::sync::mpsc` channels carrying ψ along graph edges.
//!
//! Demonstrates that the diffusion recursion runs unchanged on a genuinely
//! concurrent substrate. `DiffusionParams::threads` caps the number of OS
//! threads: each worker owns a contiguous chunk of agents (their atoms and
//! dual iterates), delivers ψ to same-worker neighbors in memory, and
//! exchanges ψ with other workers through per-worker channels (messages are
//! tagged with the iteration index; BSP semantics are preserved by waiting
//! for exactly the number of cross-worker inbound edges of the current
//! iteration before finishing a combine). With `threads ≥ N` this recovers
//! the classic one-thread-per-agent configuration; with small `threads` it
//! scales to hundreds of agents without hundreds of threads.

use crate::error::{DdlError, Result};
use crate::graph::Graph;
use crate::infer::DiffusionParams;
use crate::math::Mat;
use crate::model::{DistributedDictionary, TaskSpec};
use crate::net::bsp::adapt_step;
use crate::net::message::{MessageStats, PsiMessage};
use crate::net::pool::chunk_range;
use crate::ops::project::clip_linf;
use std::sync::mpsc;

/// One worker's result: its agents' final ν plus the traffic it sent.
type WorkerOut = (Vec<(usize, Vec<f32>)>, MessageStats);

/// Run diffusion on `min(params.threads, N)` worker threads; returns each
/// agent's final ν (indexed by agent) plus traffic statistics.
///
/// Stats follow the convention of [`crate::net::message`]: `rounds` is
/// incremented once per diffusion iteration (one network-wide exchange),
/// exactly as the BSP executor counts it, while `messages`/`bytes` count
/// only the ψ that actually crossed a worker boundary (same-worker
/// neighbors are delivered in memory) — so `messages` shrinks as agents
/// are multiplexed onto fewer workers but `rounds` stays executor-
/// independent.
///
/// `dict` is shared read-only across workers (scoped borrow — the
/// zero-refcount equivalent of an `Arc`): each worker only *reads* its own
/// agents' blocks, so nothing about "agent k stores W_k locally" needs a
/// per-worker deep copy. At hundreds of agents the former per-worker
/// `M×K` clone dominated spawn cost; sharing makes executor startup O(1)
/// in the dictionary size.
pub fn run_threaded(
    graph: &Graph,
    weights: &Mat,
    dict: &DistributedDictionary,
    task: &TaskSpec,
    x: &[f32],
    informed: Option<&[usize]>,
    params: DiffusionParams,
) -> Result<(Vec<Vec<f32>>, MessageStats)> {
    let n = graph.n();
    let m = x.len();
    let workers = params.threads.max(1).min(n);
    let theta = crate::infer::diffusion::build_theta(n, informed)?;

    // Agent → owning worker (contiguous chunks, same partition the engine
    // uses).
    let mut owner = vec![0usize; n];
    for w in 0..workers {
        for k in chunk_range(n, workers, w) {
            owner[k] = w;
        }
    }

    // One channel per worker; messages carry the destination agent.
    let mut senders: Vec<mpsc::Sender<(usize, PsiMessage)>> = Vec::with_capacity(workers);
    let mut receivers: Vec<Option<mpsc::Receiver<(usize, PsiMessage)>>> =
        Vec::with_capacity(workers);
    for _ in 0..workers {
        let (tx, rx) = mpsc::channel();
        senders.push(tx);
        receivers.push(Some(rx));
    }

    let results = std::thread::scope(
        |scope| -> Result<Vec<WorkerOut>> {
            let mut handles = Vec::with_capacity(workers);
            for w in 0..workers {
                let rx = receivers[w].take().ok_or_else(|| {
                    DdlError::Runtime(format!("actor worker {w} receiver already taken"))
                })?;
                let txs = senders.clone();
                let owned = chunk_range(n, workers, w);
                let owner = &owner;
                let theta = &theta;

                handles.push(scope.spawn(move || -> Result<WorkerOut> {
                    let cf_over_n = task.conj_grad_scale() / n as f32;
                    let inv_delta = 1.0 / task.delta();
                    let clip = task.dual_clip();
                    let base = owned.start;
                    let count = owned.len();
                    let mut nu = vec![vec![0.0f32; m]; count];
                    let mut psi = vec![vec![0.0f32; m]; count];
                    let mut thr = vec![0.0f32; dict.k()];
                    // Cross-worker traffic this worker originates.
                    let mut sent = MessageStats::default();
                    // Early-arrival buffer for messages of future iterations.
                    let mut pending: Vec<(usize, PsiMessage)> = Vec::new();
                    // Cross-worker inbound edges this worker must hear from
                    // each iteration.
                    let ext_needed: usize = owned
                        .clone()
                        .map(|k| {
                            graph.neighbors(k).iter().filter(|&&l| owner[l] != w).count()
                        })
                        .sum();

                    for iter in 0..params.iters {
                        // Adapt every owned agent (shared step, see
                        // `bsp::adapt_step`).
                        for (i, k) in owned.clone().enumerate() {
                            adapt_step(
                                dict,
                                task,
                                x,
                                theta[k],
                                k,
                                &nu[i],
                                &mut psi[i],
                                &mut thr,
                                params.mu,
                                cf_over_n,
                                inv_delta,
                            );
                        }
                        // Ship ψ to cross-worker neighbors (one message per
                        // directed edge, as in the per-agent executor).
                        for (i, k) in owned.clone().enumerate() {
                            for &nb in graph.neighbors(k) {
                                if owner[nb] != w {
                                    let msg =
                                        PsiMessage { from: k, iter, psi: psi[i].clone() };
                                    sent.record(&msg);
                                    txs[owner[nb]].send((nb, msg)).map_err(|e| {
                                        DdlError::Runtime(format!("send failed: {e}"))
                                    })?;
                                }
                            }
                        }
                        // Combine: own contribution plus same-worker
                        // neighbors, delivered in memory.
                        for (i, k) in owned.clone().enumerate() {
                            let akk = weights.get(k, k);
                            for j in 0..m {
                                nu[i][j] = akk * psi[i][j];
                            }
                        }
                        for (i, k) in owned.clone().enumerate() {
                            for &nb in graph.neighbors(k) {
                                if owner[nb] == w {
                                    let wgt = weights.get(nb, k);
                                    let src = &psi[nb - base];
                                    let dst = &mut nu[i];
                                    for j in 0..m {
                                        dst[j] += wgt * src[j];
                                    }
                                }
                            }
                        }
                        // Collect the cross-worker messages of this
                        // iteration (later-iteration arrivals are buffered).
                        let apply = |to: usize, msg: &PsiMessage, nu: &mut Vec<Vec<f32>>| {
                            let wgt = weights.get(msg.from, to);
                            let dst = &mut nu[to - base];
                            for j in 0..m {
                                dst[j] += wgt * msg.psi[j];
                            }
                        };
                        let mut got = 0usize;
                        let mut still_pending = Vec::new();
                        for (to, msg) in pending.drain(..) {
                            if msg.iter == iter {
                                apply(to, &msg, &mut nu);
                                got += 1;
                            } else {
                                still_pending.push((to, msg));
                            }
                        }
                        pending = still_pending;
                        while got < ext_needed {
                            let (to, msg) = rx
                                .recv()
                                .map_err(|e| DdlError::Runtime(format!("recv failed: {e}")))?;
                            if msg.iter == iter {
                                apply(to, &msg, &mut nu);
                                got += 1;
                            } else {
                                pending.push((to, msg));
                            }
                        }
                        if let Some(b) = clip {
                            for v in &mut nu {
                                clip_linf(v, b);
                            }
                        }
                    }
                    Ok((owned.zip(nu).collect(), sent))
                }));
            }
            drop(senders);

            let mut out = Vec::with_capacity(workers);
            for h in handles {
                out.push(
                    h.join()
                        .map_err(|_| DdlError::Runtime("agent worker panicked".into()))??,
                );
            }
            Ok(out)
        },
    )?;

    // One exchange round per diffusion iteration, regardless of worker
    // count; per-worker traffic merges additively (net::message convention).
    let mut stats = MessageStats::default();
    stats.add_rounds(params.iters);
    let mut nus: Vec<Vec<f32>> = vec![Vec::new(); n];
    for (chunk, sent) in results {
        stats.merge(&sent);
        for (k, nu) in chunk {
            nus[k] = nu;
        }
    }
    Ok((nus, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{metropolis_weights, Topology};
    use crate::infer::DiffusionEngine;
    use crate::model::AtomConstraint;
    use crate::rng::Pcg64;

    #[test]
    fn threaded_matches_gemm_engine() {
        let (n, m) = (6, 8);
        let mut rng = Pcg64::new(1);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        // One thread per agent — the classic actor configuration.
        let params = DiffusionParams::new(0.3, 40).with_threads(n);

        let mut engine = DiffusionEngine::new(&a, m, None).unwrap();
        engine.run(&dict, &task, &x, DiffusionParams::new(0.3, 40)).unwrap();
        let (nus, stats) = run_threaded(&g, &a, &dict, &task, &x, None, params).unwrap();
        for k in 0..n {
            crate::testutil::assert_close(&nus[k], engine.nu(k), 1e-4, 1e-3);
        }
        // One thread per agent: every directed edge crosses a worker
        // boundary, so traffic matches the BSP executor exactly.
        assert_eq!(stats.rounds, 40);
        assert_eq!(stats.messages, 2 * g.edge_count() * 40);
        assert_eq!(stats.bytes, stats.messages * (16 + m * 4));
        assert!(stats.bytes_per_agent_round(n) > 0.0);
    }

    /// Multiplexed: more agents than worker threads.
    #[test]
    fn multiplexed_workers_match_engine() {
        let (n, m) = (11, 7);
        let mut rng = Pcg64::new(3);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };

        let mut engine = DiffusionEngine::new(&a, m, None).unwrap();
        engine.run(&dict, &task, &x, DiffusionParams::new(0.25, 35)).unwrap();
        for threads in [1, 2, 3] {
            let params = DiffusionParams::new(0.25, 35).with_threads(threads);
            let (nus, stats) = run_threaded(&g, &a, &dict, &task, &x, None, params).unwrap();
            for k in 0..n {
                crate::testutil::assert_close(&nus[k], engine.nu(k), 1e-4, 1e-3);
            }
            // Rounds are executor-independent (one per diffusion
            // iteration); channel traffic counts only cross-worker edges —
            // a single worker delivers everything in memory.
            assert_eq!(stats.rounds, 35, "threads={threads}");
            if threads == 1 {
                assert_eq!(stats.messages, 0);
                assert_eq!(stats.bytes_per_agent_round(n), 0.0);
            } else {
                assert!(stats.messages > 0);
                assert!(stats.messages <= 2 * g.edge_count() * 35);
                assert_eq!(stats.bytes, stats.messages * (16 + m * 4));
            }
        }
    }

    #[test]
    fn threaded_single_informed_agent() {
        let (n, m) = (5, 6);
        let mut rng = Pcg64::new(2);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::Ring { k: 1 }, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let params = DiffusionParams::new(0.2, 30).with_threads(2);
        let mut engine = DiffusionEngine::new(&a, m, Some(&[2])).unwrap();
        engine.run(&dict, &task, &x, DiffusionParams::new(0.2, 30)).unwrap();
        let (nus, _) = run_threaded(&g, &a, &dict, &task, &x, Some(&[2]), params).unwrap();
        for k in 0..n {
            crate::testutil::assert_close(&nus[k], engine.nu(k), 1e-4, 1e-3);
        }
    }
}
