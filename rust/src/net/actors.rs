//! Threaded actor executor: one OS thread per agent, `std::sync::mpsc`
//! channels along graph edges.
//!
//! Demonstrates that the diffusion recursion runs unchanged on a genuinely
//! concurrent substrate — each agent thread owns its atoms and dual
//! iterate, receives neighbor ψ messages, and synchronizes per iteration
//! only through its own channel (messages are tagged with the iteration
//! index; BSP semantics are preserved by waiting for exactly
//! `deg(k)` messages of the current iteration before combining).

use crate::error::{DdlError, Result};
use crate::graph::Graph;
use crate::infer::DiffusionParams;
use crate::math::Mat;
use crate::model::{DistributedDictionary, TaskSpec};
use crate::net::message::PsiMessage;
use crate::ops::project::clip_linf;
use std::sync::mpsc;
use std::thread;

/// Run diffusion with one thread per agent; returns each agent's final ν.
///
/// `dict` is cloned per agent but each thread only reads its own block —
/// the clone stands in for "agent k stores W_k locally".
pub fn run_threaded(
    graph: &Graph,
    weights: &Mat,
    dict: &DistributedDictionary,
    task: &TaskSpec,
    x: &[f32],
    informed: Option<&[usize]>,
    params: DiffusionParams,
) -> Result<Vec<Vec<f32>>> {
    let n = graph.n();
    let m = x.len();
    let mut theta = vec![0.0f32; n];
    match informed {
        None => theta.fill(1.0 / n as f32),
        Some(idx) => {
            if idx.is_empty() {
                return Err(DdlError::Config("need at least one informed agent".into()));
            }
            let w = 1.0 / idx.len() as f32;
            for &k in idx {
                theta[k] = w;
            }
        }
    }

    // Channels: one receiver per agent; senders cloned to its neighbors.
    let mut senders: Vec<mpsc::Sender<PsiMessage>> = Vec::with_capacity(n);
    let mut receivers: Vec<Option<mpsc::Receiver<PsiMessage>>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = mpsc::channel();
        senders.push(tx);
        receivers.push(Some(rx));
    }

    let mut handles = Vec::with_capacity(n);
    for k in 0..n {
        let rx = receivers[k].take().unwrap();
        let neighbor_tx: Vec<(usize, mpsc::Sender<PsiMessage>)> = graph
            .neighbors(k)
            .iter()
            .map(|&nb| (nb, senders[nb].clone()))
            .collect();
        let akk = weights.get(k, k);
        let col_weights: Vec<(usize, f32)> = graph
            .neighbors(k)
            .iter()
            .map(|&l| (l, weights.get(l, k)))
            .collect();
        let dict = dict.clone();
        let task = *task;
        let x = x.to_vec();
        let theta_k = theta[k];
        let deg = graph.degree(k);

        handles.push(thread::spawn(move || -> Result<Vec<f32>> {
            let cf_over_n = task.conj_grad_scale() / n as f32;
            let inv_delta = 1.0 / task.delta();
            let clip = task.dual_clip();
            let mut nu = vec![0.0f32; m];
            let mut psi = vec![0.0f32; m];
            let mut thr = vec![0.0f32; dict.k()];
            // Early-arrival buffer for messages from the next iteration.
            let mut pending: Vec<PsiMessage> = Vec::new();

            for iter in 0..params.iters {
                // Adapt.
                dict.block_correlations(k, &nu, &mut thr);
                let (start, len) = dict.block(k);
                for q in start..start + len {
                    thr[q] = task.threshold(thr[q]) * (-params.mu * inv_delta);
                }
                for i in 0..m {
                    psi[i] = nu[i] - params.mu * (cf_over_n * nu[i] - theta_k * x[i]);
                }
                dict.block_accumulate(k, &thr, &mut psi);
                // Send ψ to neighbors.
                for (_, tx) in &neighbor_tx {
                    tx.send(PsiMessage { from: k, iter, psi: psi.clone() })
                        .map_err(|e| DdlError::Runtime(format!("send failed: {e}")))?;
                }
                // Combine own contribution.
                for i in 0..m {
                    nu[i] = akk * psi[i];
                }
                // Collect exactly deg messages for this iteration (messages
                // from iteration iter+1 may arrive early; buffer them).
                let mut got = 0usize;
                let apply = |msg: &PsiMessage, nu: &mut [f32]| {
                    let w = col_weights
                        .iter()
                        .find(|(l, _)| *l == msg.from)
                        .map(|(_, w)| *w)
                        .unwrap_or(0.0);
                    for i in 0..m {
                        nu[i] += w * msg.psi[i];
                    }
                };
                let mut still_pending = Vec::new();
                for msg in pending.drain(..) {
                    if msg.iter == iter {
                        apply(&msg, &mut nu);
                        got += 1;
                    } else {
                        still_pending.push(msg);
                    }
                }
                pending = still_pending;
                while got < deg {
                    let msg = rx
                        .recv()
                        .map_err(|e| DdlError::Runtime(format!("recv failed: {e}")))?;
                    if msg.iter == iter {
                        apply(&msg, &mut nu);
                        got += 1;
                    } else {
                        pending.push(msg);
                    }
                }
                if let Some(b) = clip {
                    clip_linf(&mut nu, b);
                }
            }
            Ok(nu)
        }));
    }
    drop(senders);

    let mut out = Vec::with_capacity(n);
    for h in handles {
        out.push(h.join().map_err(|_| DdlError::Runtime("agent thread panicked".into()))??);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{metropolis_weights, Topology};
    use crate::infer::DiffusionEngine;
    use crate::model::AtomConstraint;
    use crate::rng::Pcg64;

    #[test]
    fn threaded_matches_gemm_engine() {
        let (n, m) = (6, 8);
        let mut rng = Pcg64::new(1);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let params = DiffusionParams { mu: 0.3, iters: 40 };

        let mut engine = DiffusionEngine::new(&a, m, None).unwrap();
        engine.run(&dict, &task, &x, params).unwrap();
        let nus = run_threaded(&g, &a, &dict, &task, &x, None, params).unwrap();
        for k in 0..n {
            crate::testutil::assert_close(&nus[k], engine.nu(k), 1e-4, 1e-3);
        }
    }

    #[test]
    fn threaded_single_informed_agent() {
        let (n, m) = (5, 6);
        let mut rng = Pcg64::new(2);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::Ring { k: 1 }, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let params = DiffusionParams { mu: 0.2, iters: 30 };
        let mut engine = DiffusionEngine::new(&a, m, Some(&[2])).unwrap();
        engine.run(&dict, &task, &x, params).unwrap();
        let nus = run_threaded(&g, &a, &dict, &task, &x, Some(&[2]), params).unwrap();
        for k in 0..n {
            crate::testutil::assert_close(&nus[k], engine.nu(k), 1e-4, 1e-3);
        }
    }
}
