//! Asynchronous per-edge diffusion executor with straggler modeling.
//!
//! The BSP executor ([`crate::net::BspNetwork`]) and the actor executor
//! ([`crate::net::actors::run_threaded`]) both impose a network-wide
//! barrier: iteration `i`'s combine waits for *every* neighbor's ψ of
//! iteration `i`. The Big-Data deployment the paper targets — hundreds of
//! agents at different spatial locations — is exactly where that barrier
//! hurts: one slow agent (or one slow link) throttles the whole network.
//! The asynchronous dictionary-learning literature (Daneshmand, Scutari,
//! Facchinei, arXiv:1612.07335; time-varying digraphs, arXiv:1808.05933)
//! shows the recursion tolerates relaxed, time-varying connectivity.
//!
//! [`AsyncNetwork`] relaxes the barrier to **per-edge ψ exchange with
//! bounded staleness**: agent `k` at local iteration `i` combines with,
//! from each neighbor, the *freshest received* ψ of iteration `≤ i`,
//! gated only by the staleness bound — that iteration must be
//! `≥ i − τ` ([`AsyncParams::tau`]). Agents otherwise free-run at their
//! own pace.
//!
//! ## Deterministic discrete-event clock
//!
//! Execution is a single-threaded discrete-event simulation on a `u64`
//! microsecond clock (the same virtual-time substrate as
//! [`crate::serve::queue`]). Per-agent compute delays and per-directed-edge
//! link delays are sampled from dedicated [`Pcg64`] streams split off one
//! root seed ([`AsyncParams::seed`]) in a fixed order, so every straggler
//! scenario — slow agent, slow link, heterogeneous compute
//! ([`DelayDist`]) — replays **bit-identically** for a given seed: same ν
//! trajectories, same [`MessageStats`], same simulated completion time.
//! Events at equal timestamps are ordered by a monotone sequence number,
//! so ties (e.g. the all-zero-delay case) are deterministic too.
//!
//! ## Degeneracy to BSP — the correctness anchor
//!
//! With `τ = 0` the staleness gate forces every combine to use exactly
//! iteration-`i` ψ from every neighbor, and the combine accumulates in
//! ascending-neighbor order — the identical floating-point arithmetic of
//! [`crate::net::BspNetwork`]. The ν trajectories are therefore
//! **bit-for-bit equal to BSP for *any* delay configuration** (delays then
//! shift only the clock, not the iterates), and in particular for zero
//! delays (`tests/async_parity.rs`, enforced bitwise). `τ = 0` with
//! nonzero delays *is* the barrier-synchronous baseline with a cost
//! model — which is how the straggler experiments compute the sync
//! comparator's simulated completion time.
//!
//! ## Accounting
//!
//! Traffic uses the same [`MessageStats`] the other executors return: one
//! ψ message of `M` floats per directed edge per adapt, so at equal
//! iteration counts `messages`/`bytes` match BSP exactly. `rounds`
//! follows the network-wide-exchange convention of [`crate::net::message`]
//! generalized to asynchrony: the round counter is the **minimum** number
//! of combines completed by any agent (the number of full exchange waves
//! the network has finished), which coincides with the BSP round count at
//! completion.
//!
//! ## Chaos layer — deterministic fault injection
//!
//! A [`FaultSchedule`] ([`AsyncParams::chaos`]) injects edge churn,
//! healing partitions, directed link outages, message drops, and agent
//! crash/recovery windows, every one a pure function of (schedule,
//! sim-time) — see [`crate::net::chaos`]. Degradation is graceful, never
//! a stall: a send that finds its link down retries with bounded backoff
//! ([`ChaosPolicy`]), a combine gated past the receive timeout proceeds
//! with a stale-ψ fallback (or excludes the never-heard-from neighbor and
//! renormalizes), and a crashed agent's adapt is re-run at recovery and
//! its ψ rebroadcast (the re-join resync). Fallback staleness is
//! accounted in [`ChaosStats`], never in
//! [`Self::max_staleness_observed`][AsyncNetwork::max_staleness_observed],
//! so the τ invariant stays honest. Drop coins come from a dedicated
//! chaos stream: the **empty schedule is bit-for-bit the fault-free
//! executor** — no chaos branches, events, or randomness
//! (`tests/async_parity.rs`, enforced bitwise).
//!
//! When a schedule contains *directed* faults the live topology loses
//! symmetry and the Metropolis combine loses double stochasticity — the
//! executor then auto-selects the push-sum–corrected combine
//! ([`CombineMode`]): mass shares split over the live out-edges, summed
//! on receipt, estimate read as the ratio `s/w` (arXiv:1808.05933).
//!
//! Byzantine windows ([`Fault::Byzantine`]) make an agent *lie*: every ψ
//! it transmits while the window is active is corrupted by its
//! [`CorruptPolicy`] (sign-flip, scaled noise, constant, colluding
//! offset) before it leaves the agent; the attacker's own state stays
//! honest. The receiver-side defense is the opt-in resilient combine
//! ([`CombineMode::Median`] / [`CombineMode::TrimmedMean`]): the
//! coordinate-wise trimmed weighted mean over {self} ∪ neighborhood,
//! which discards the extremes an attacker must occupy to move the
//! aggregate. Corruption noise rides the same dedicated chaos stream as
//! drop coins, so attacked runs replay bit-identically per seed and
//! Byzantine-free schedules consume no extra randomness.
//!
//! Drive it with `ddl async` / `ddl chaos` (TOML `[async]` / `[chaos]`,
//! see [`crate::config::experiment::AsyncConfig`]), benchmark it with
//! `cargo bench --bench bench_async` and `--bench bench_chaos`, and see
//! `ARCHITECTURE.md` (repo root) for where this executor sits in the
//! executor matrix.

use crate::error::{DdlError, Result};
use crate::graph::Graph;
use crate::infer::DiffusionParams;
use crate::math::Mat;
use crate::model::{DistributedDictionary, TaskSpec};
use crate::net::chaos::{
    ChaosPolicy, ChaosStats, CombineMode, CorruptPolicy, DetectionConfig, Fault, FaultSchedule,
};
use crate::net::message::MessageStats;
use crate::obs::{ArgValue, MetricsRegistry, ObsHandle, Track};
use crate::ops::project::clip_linf;
use crate::rng::Pcg64;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Delay distribution for compute steps and link traversals, sampled on a
/// microsecond clock. `Uniform` and `Exp` model heterogeneous compute and
/// bursty links; `Zero`/`Constant` give fully predictable schedules.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DelayDist {
    /// Always 0 µs.
    Zero,
    /// Fixed delay.
    Constant { us: u64 },
    /// Uniform integer delay in `[lo_us, hi_us]` (inclusive).
    Uniform { lo_us: u64, hi_us: u64 },
    /// Exponential delay with the given mean (rounded to whole µs).
    Exp { mean_us: f64 },
}

impl DelayDist {
    /// Draw one delay. `Zero`/`Constant` consume no randomness; the other
    /// variants consume exactly one draw from `rng` — each simulated
    /// component owns a dedicated stream, so draw counts never interleave.
    pub fn sample(&self, rng: &mut Pcg64) -> u64 {
        match *self {
            DelayDist::Zero => 0,
            DelayDist::Constant { us } => us,
            DelayDist::Uniform { lo_us, hi_us } => {
                let (lo, hi) = (lo_us.min(hi_us), lo_us.max(hi_us));
                if hi > lo {
                    lo + rng.next_below(hi - lo + 1)
                } else {
                    lo
                }
            }
            DelayDist::Exp { mean_us } => {
                let u = rng.next_f64().max(1e-12);
                (-u.ln() * mean_us.max(0.0)).round() as u64
            }
        }
    }

    /// Parse a TOML/CLI spec: `zero`, `const`, `uniform` (spread
    /// `[scale/2, 3·scale/2]`), or `exp`, scaled by `scale_us`.
    pub fn parse(kind: &str, scale_us: u64) -> Result<DelayDist> {
        Ok(match kind {
            "zero" => DelayDist::Zero,
            "const" | "constant" => DelayDist::Constant { us: scale_us },
            "uniform" => {
                DelayDist::Uniform { lo_us: scale_us / 2, hi_us: scale_us + scale_us / 2 }
            }
            "exp" | "exponential" => DelayDist::Exp { mean_us: scale_us as f64 },
            other => {
                return Err(DdlError::Config(format!(
                    "unknown delay distribution '{other}' (zero|const|uniform|exp)"
                )))
            }
        })
    }
}

/// Asynchrony and straggler-scenario knobs.
#[derive(Clone, Debug)]
pub struct AsyncParams {
    /// Staleness bound τ: combine at local iteration `i` may use a
    /// neighbor ψ as old as iteration `i − τ`. `0` = barrier-synchronous
    /// (bit-for-bit the BSP trajectory).
    pub tau: usize,
    /// Per-iteration compute delay (adapt + combine, one draw per
    /// iteration per agent).
    pub compute: DelayDist,
    /// Per-message link delay (one draw per directed edge per iteration).
    pub link: DelayDist,
    /// Root seed for all delay streams.
    pub seed: u64,
    /// Agents whose compute delay is multiplied by [`Self::slow_factor`]
    /// (the "slow agent" straggler scenario).
    pub slow_agents: Vec<usize>,
    /// Compute-delay multiplier for [`Self::slow_agents`].
    pub slow_factor: f64,
    /// Undirected edges whose link delay (both directions) is multiplied
    /// by [`Self::slow_link_factor`] (the "slow link" scenario).
    pub slow_links: Vec<(usize, usize)>,
    /// Link-delay multiplier for [`Self::slow_links`].
    pub slow_link_factor: f64,
    /// Drifting-straggler scenario: when > 0, the slow-agent identity
    /// rotates deterministically with simulated time — agent
    /// `⌊t/period⌋ mod N` computes [`Self::slow_factor`]× slower —
    /// overriding the static [`Self::slow_agents`] list. The rotation is
    /// a pure function of the event clock, so replay determinism is
    /// untouched. `0` (default) = static scenario.
    pub drift_period_us: u64,
    /// Fault-injection schedule (chaos layer). The default **empty**
    /// schedule keeps the executor bit-for-bit on the fault-free path:
    /// no chaos branches, no chaos events, no chaos randomness.
    pub chaos: FaultSchedule,
    /// Graceful-degradation knobs (receive timeout, retry/backoff);
    /// consulted only when [`Self::chaos`] is non-empty.
    pub chaos_policy: ChaosPolicy,
    /// Combine rule; `Auto` (default) resolves at construction to
    /// push-sum iff the schedule contains directed faults.
    pub combine: CombineMode,
    /// Byzantine detection-and-exclusion layer over the resilient combine
    /// (see [`DetectionConfig`]). Disabled by default; consulted only by
    /// `Median`/`TrimmedMean` combines, and even when enabled its scoring
    /// pass never touches the aggregate arithmetic or any RNG stream — a
    /// zero-attacker run is bit-for-bit the detection-off run.
    pub detect: DetectionConfig,
}

impl Default for AsyncParams {
    /// Zero delays and `τ = 0`: the executor degenerates to the BSP
    /// trajectory on a zero-cost clock.
    fn default() -> Self {
        AsyncParams {
            tau: 0,
            compute: DelayDist::Zero,
            link: DelayDist::Zero,
            seed: 0xA5_1C,
            slow_agents: Vec::new(),
            slow_factor: 10.0,
            slow_links: Vec::new(),
            slow_link_factor: 10.0,
            drift_period_us: 0,
            chaos: FaultSchedule::default(),
            chaos_policy: ChaosPolicy::default(),
            combine: CombineMode::Auto,
            detect: DetectionConfig::default(),
        }
    }
}

impl AsyncParams {
    /// Builder-style staleness bound.
    pub fn with_tau(mut self, tau: usize) -> Self {
        self.tau = tau;
        self
    }

    /// Builder-style delay distributions.
    pub fn with_delays(mut self, compute: DelayDist, link: DelayDist) -> Self {
        self.compute = compute;
        self.link = link;
        self
    }

    /// Builder-style slow-agent straggler.
    pub fn with_slow_agent(mut self, agent: usize, factor: f64) -> Self {
        self.slow_agents.push(agent);
        self.slow_factor = factor;
        self
    }

    /// Builder-style seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Builder-style drifting straggler: the slow-agent identity rotates
    /// every `period_us` of simulated time, slowed by `factor`.
    pub fn with_drift(mut self, period_us: u64, factor: f64) -> Self {
        self.drift_period_us = period_us;
        self.slow_factor = factor;
        self
    }

    /// Builder-style fault schedule (chaos layer).
    pub fn with_chaos(mut self, schedule: FaultSchedule) -> Self {
        self.chaos = schedule;
        self
    }

    /// Builder-style degradation policy (receive timeout, retry/backoff).
    pub fn with_chaos_policy(mut self, policy: ChaosPolicy) -> Self {
        self.chaos_policy = policy;
        self
    }

    /// Builder-style combine rule.
    pub fn with_combine(mut self, mode: CombineMode) -> Self {
        self.combine = mode;
        self
    }

    /// Builder-style detection layer (see [`DetectionConfig`]).
    pub fn with_detect(mut self, detect: DetectionConfig) -> Self {
        self.detect = detect;
        self
    }
}

/// Per-(judge, neighbor-slot) reputation state of the detection layer.
/// Every transition is a pure function of (config, sim-time, ψ bits) —
/// no randomness, no wall clock — so detection runs replay bit-identically.
#[derive(Clone, Copy, Debug, Default)]
struct NbrScore {
    /// Consecutive combines with full Byzantine evidence (resets to 0 on
    /// the first clean combine).
    score: usize,
    /// Crossed [`DetectionConfig::flag_after`] at least once.
    flagged: bool,
    /// Crossed [`DetectionConfig::exclude_after`]: the suspect's ψ no
    /// longer enters this judge's aggregate.
    excluded: bool,
    /// Sim-time of the exclusion (probation timer origin).
    excluded_at_us: u64,
}

/// Discrete-event kinds. ψ payloads ride inside the event queue — the
/// "network" is the queue itself.
enum EventKind {
    /// Agent finished computing (adapt of its next iteration).
    AdaptDone { agent: usize },
    /// A ψ message reaches `to`; `nb_slot` is the sender's position in
    /// `to`'s sorted neighbor list. `wshare` is the push-sum weight share
    /// riding with the ψ share (0 and never read under Metropolis).
    Deliver { to: usize, nb_slot: usize, iter: usize, psi: Vec<f32>, wshare: f32 },
    /// Chaos: re-attempt a send that found its link down (`edge` indexes
    /// the sender's neighbor list). Never scheduled on a fault-free run.
    Retry { from: usize, edge: usize, iter: usize, psi: Vec<f32>, wshare: f32, attempt: u32 },
    /// Chaos: receive timeout — if the agent is still gated on `iter`,
    /// combine anyway with stale-ψ fallback / neighbor exclusion. Never
    /// scheduled on a fault-free run.
    GateTimeout { agent: usize, iter: usize },
}

struct Event {
    t: u64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.t, self.seq).cmp(&(other.t, other.seq))
    }
}

/// Per-agent simulation state.
struct AgentState {
    nu: Vec<f32>,
    psi: Vec<f32>,
    /// Combines completed; also the iteration index of the next adapt.
    done: usize,
    /// Adapt finished but combine gated on the staleness bound.
    waiting: bool,
    /// Event time at which [`Self::waiting`] was last set (gate-wait
    /// accounting).
    wait_since: u64,
    /// Received ψ per neighbor slot: `(iter, psi, wshare)`, pruned at
    /// combine (Metropolis keeps the freshest; push-sum drains the mass).
    inbox: Vec<Vec<(usize, Vec<f32>, f32)>>,
    /// Freshest iteration ever delivered per neighbor slot (monotone,
    /// survives draining — the push-sum gate reads this, since pending
    /// mass alone cannot express freshness).
    seen: Vec<Option<usize>>,
    /// Push-sum scalar weight (stays 1 under Metropolis).
    w: f32,
    /// Dedicated compute-delay stream.
    rng: Pcg64,
    /// Compute-delay multiplier (static straggler scenarios).
    slow: f64,
}

/// Asynchronous per-edge diffusion executor (see the module docs).
pub struct AsyncNetwork {
    agents: Vec<AgentState>,
    graph: Graph,
    /// Combination weights `a[l][k]` aligned with the graph (column = k).
    weights: Mat,
    theta: Vec<f32>,
    params: AsyncParams,
    /// Dedicated link-delay stream per directed edge `[agent][nb_slot]`.
    link_rngs: Vec<Vec<Pcg64>>,
    /// Link-delay multiplier per directed edge.
    link_slow: Vec<Vec<f64>>,
    /// `rev_slot[k][j]`: position of `k` in the neighbor list of
    /// `graph.neighbors(k)[j]` (the receiver-side inbox slot).
    rev_slot: Vec<Vec<usize>>,
    heap: BinaryHeap<Reverse<Event>>,
    seq: u64,
    now_us: u64,
    stats: MessageStats,
    /// Threshold scratch (K), shared across agents — the simulation is
    /// single-threaded.
    thr: Vec<f32>,
    m: usize,
    started: bool,
    target_iters: usize,
    mu: f32,
    /// Agents that completed `target_iters` combines.
    done_count: usize,
    /// Histogram of agents per completed-combine count (round tracking).
    level_counts: Vec<usize>,
    cur_min: usize,
    max_staleness: usize,
    last_combine_us: u64,
    /// Total simulated time agents spent with an adapt finished but the
    /// combine gated on the staleness bound (summed over agents; the τ
    /// controller's widen signal).
    gate_wait_us: u64,
    /// Dedicated chaos coin stream (message drops) — never interleaves
    /// with the delay streams, so an empty schedule leaves them untouched.
    chaos_rng: Pcg64,
    /// Cached `!params.chaos.is_empty()`: false ⇒ the fault-free fast
    /// path, bit-for-bit the pre-chaos executor.
    chaos_active: bool,
    /// Resolved combine rule (`Auto` collapses at construction; never
    /// `Auto` here). `Median`/`TrimmedMean` share the Metropolis-family
    /// send/gate machinery and swap only the aggregation arithmetic.
    mode: CombineMode,
    /// Cached `mode == PushSum` (hot-path branches).
    pushsum: bool,
    /// True when `Auto` upgraded Metropolis → push-sum (directed faults).
    auto_pushsum: bool,
    chaos_stats: ChaosStats,
    /// Detection-layer reputation state, `det[judge][nb_slot]` aligned
    /// with `graph.neighbors(judge)`. All-default (and never read) when
    /// [`AsyncParams::detect`] is disabled.
    det: Vec<Vec<NbrScore>>,
    /// Trace sink (default: disabled). Emitting never consumes
    /// randomness or advances the clock — traced runs replay untraced
    /// runs bit-for-bit (`tests/obs_parity.rs`).
    obs: ObsHandle,
}

impl AsyncNetwork {
    /// Build over a graph with its (doubly-stochastic) combination matrix;
    /// `informed` as in [`crate::infer::DiffusionEngine::new`].
    pub fn new(
        graph: Graph,
        weights: Mat,
        m: usize,
        informed: Option<&[usize]>,
        params: AsyncParams,
    ) -> Result<Self> {
        let n = graph.n();
        if weights.rows() != n || weights.cols() != n {
            return Err(DdlError::Shape("combination matrix shape mismatch".into()));
        }
        for &k in &params.slow_agents {
            if k >= n {
                return Err(DdlError::Config(format!("slow agent {k} out of range")));
            }
        }
        params.chaos.validate(n)?;
        params.detect.validate()?;
        let (mode, auto_pushsum) = match params.combine {
            CombineMode::Auto => {
                if params.chaos.has_directed_faults() {
                    (CombineMode::PushSum, true)
                } else {
                    (CombineMode::Metropolis, false)
                }
            }
            other => (other, false),
        };
        let pushsum = mode == CombineMode::PushSum;
        let theta = crate::infer::diffusion::build_theta(n, informed)?;
        let mut root = Pcg64::new(params.seed);
        let mut tag = 0u64;
        let mut agents = Vec::with_capacity(n);
        for k in 0..n {
            let slow = if params.slow_agents.contains(&k) { params.slow_factor } else { 1.0 };
            agents.push(AgentState {
                nu: vec![0.0; m],
                psi: vec![0.0; m],
                done: 0,
                waiting: false,
                wait_since: 0,
                inbox: vec![Vec::new(); graph.degree(k)],
                seen: vec![None; graph.degree(k)],
                w: 1.0,
                rng: root.split(tag),
                slow,
            });
            tag += 1;
        }
        let mut link_rngs = Vec::with_capacity(n);
        let mut link_slow = Vec::with_capacity(n);
        let mut rev_slot = Vec::with_capacity(n);
        for k in 0..n {
            let mut rngs = Vec::with_capacity(graph.degree(k));
            let mut slows = Vec::with_capacity(graph.degree(k));
            let mut revs = Vec::with_capacity(graph.degree(k));
            for &nb in graph.neighbors(k) {
                rngs.push(root.split(tag));
                tag += 1;
                let slowed = params
                    .slow_links
                    .iter()
                    .any(|&(a, b)| (a == k && b == nb) || (a == nb && b == k));
                slows.push(if slowed { params.slow_link_factor } else { 1.0 });
                let rev = graph.neighbors(nb).iter().position(|&x| x == k).ok_or_else(|| {
                    DdlError::Shape(format!("graph adjacency must be symmetric ({k} ↔ {nb})"))
                })?;
                revs.push(rev);
            }
            link_rngs.push(rngs);
            link_slow.push(slows);
            rev_slot.push(revs);
        }
        let chaos_rng = Pcg64::new(params.chaos.seed ^ 0xC4A0_55ED);
        let chaos_active = !params.chaos.is_empty();
        let det = (0..n).map(|k| vec![NbrScore::default(); graph.degree(k)]).collect();
        Ok(AsyncNetwork {
            agents,
            graph,
            weights,
            theta,
            params,
            link_rngs,
            link_slow,
            rev_slot,
            heap: BinaryHeap::new(),
            seq: 0,
            now_us: 0,
            stats: MessageStats::default(),
            thr: Vec::new(),
            m,
            started: false,
            target_iters: 0,
            mu: 0.0,
            done_count: 0,
            level_counts: Vec::new(),
            cur_min: 0,
            max_staleness: 0,
            last_combine_us: 0,
            gate_wait_us: 0,
            chaos_rng,
            chaos_active,
            mode,
            pushsum,
            auto_pushsum,
            chaos_stats: ChaosStats::default(),
            det,
            obs: ObsHandle::null(),
        })
    }

    /// Install a trace sink. Call before [`Self::run`] /
    /// [`Self::run_clamped`] so the fault-window spans (emitted once at
    /// start) are captured too.
    pub fn attach_obs(&mut self, obs: ObsHandle) {
        self.obs = obs;
    }

    /// Publish this executor's accounting into the unified
    /// [`MetricsRegistry`] ([`Self::stats`] / [`Self::chaos_stats`] stay
    /// available as typed views; the registry reconstructs them
    /// bit-for-bit).
    pub fn metrics(&self) -> MetricsRegistry {
        let mut r = MetricsRegistry::new();
        r.absorb_message_stats("net", &self.stats);
        r.absorb_chaos_stats(&self.chaos_stats);
        r.set_gauge("async.gate_wait_us", self.gate_wait_us as f64);
        r.set_gauge("async.max_staleness", self.max_staleness as f64);
        r.set_gauge("async.tau", self.params.tau as f64);
        r.set_gauge("async.sim_time_us", self.last_combine_us as f64);
        r
    }

    /// Emit the fault schedule as span pairs on `fault:*` stage lanes —
    /// the windows are pure schedule data, so they are traced up-front
    /// (with future timestamps) rather than re-derived event by event.
    fn trace_fault_windows(&self) {
        if !self.obs.enabled() {
            return;
        }
        for f in self.params.chaos.faults() {
            let (name, a, b, args): (_, u64, u64, Vec<(&'static str, ArgValue)>) = match f {
                Fault::EdgeDown { u, v, from_us, until_us } => (
                    "fault:edge_down",
                    *from_us,
                    *until_us,
                    vec![("u", ArgValue::U(*u as u64)), ("v", ArgValue::U(*v as u64))],
                ),
                Fault::LinkDown { from, to, from_us, until_us } => (
                    "fault:link_down",
                    *from_us,
                    *until_us,
                    vec![("from", ArgValue::U(*from as u64)), ("to", ArgValue::U(*to as u64))],
                ),
                Fault::Partition { side, from_us, until_us } => (
                    "fault:partition",
                    *from_us,
                    *until_us,
                    vec![(
                        "cut_side",
                        ArgValue::U(side.iter().filter(|&&s| s).count() as u64),
                    )],
                ),
                Fault::Crash { agent, from_us, until_us } => (
                    "fault:crash",
                    *from_us,
                    *until_us,
                    vec![("agent", ArgValue::U(*agent as u64))],
                ),
                Fault::Drop { p, from_us, until_us } => {
                    ("fault:drop", *from_us, *until_us, vec![("p", ArgValue::F(*p))])
                }
                Fault::Byzantine { agent, policy, from_us, until_us } => (
                    "fault:byzantine",
                    *from_us,
                    *until_us,
                    vec![
                        ("agent", ArgValue::U(*agent as u64)),
                        ("policy", ArgValue::U(policy.tag())),
                    ],
                ),
            };
            self.obs.emit(crate::obs::TraceEvent {
                t_us: a,
                kind: crate::obs::EventKind::SpanBegin,
                name,
                track: Track::Stage(name),
                args,
            });
            self.obs.span_end(b, name, Track::Stage(name));
        }
    }

    fn push_event(&mut self, t: u64, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Event { t, seq, kind }));
    }

    /// Compute-delay multiplier of agent `k` at simulated time `t`: the
    /// static per-agent factor, or — in the drifting scenario — the
    /// rotating slow-agent schedule (a pure function of `t`, so replays
    /// are untouched).
    fn slow_mult(&self, k: usize, t: u64) -> f64 {
        let period = self.params.drift_period_us;
        if period > 0 {
            if k == ((t / period) as usize) % self.agents.len() {
                self.params.slow_factor
            } else {
                1.0
            }
        } else {
            self.agents[k].slow
        }
    }

    fn sample_compute(&mut self, k: usize, t: u64) -> u64 {
        let mult = self.slow_mult(k, t);
        let ag = &mut self.agents[k];
        let base = self.params.compute.sample(&mut ag.rng);
        (base as f64 * mult).round() as u64
    }

    fn sample_link(&mut self, k: usize, slot: usize) -> u64 {
        let base = self.params.link.sample(&mut self.link_rngs[k][slot]);
        (base as f64 * self.link_slow[k][slot]).round() as u64
    }

    fn ensure_started(&mut self, dict: &DistributedDictionary, params: DiffusionParams) {
        if self.started {
            return;
        }
        self.started = true;
        self.target_iters = params.iters;
        self.mu = params.mu;
        self.thr = vec![0.0; dict.k()];
        self.level_counts = vec![0; params.iters + 1];
        self.level_counts[0] = self.agents.len();
        self.trace_fault_windows();
        if params.iters == 0 {
            self.done_count = self.agents.len();
            return;
        }
        for k in 0..self.agents.len() {
            let d = self.sample_compute(k, 0);
            self.obs.span_begin(0, "adapt", Track::Agent(k));
            self.push_event(d, EventKind::AdaptDone { agent: k });
        }
    }

    /// Run the full diffusion: every agent completes `params.iters`
    /// combines. Problem inputs must not change across calls on one
    /// executor instance (the simulation state persists).
    pub fn run(
        &mut self,
        dict: &DistributedDictionary,
        task: &TaskSpec,
        x: &[f32],
        params: DiffusionParams,
    ) -> Result<()> {
        self.run_clamped(dict, task, x, params, u64::MAX).map(|_| ())
    }

    /// Run until every agent completes `params.iters` combines **or** the
    /// simulated clock would pass `t_stop_us`, whichever comes first.
    /// Returns `true` when the network finished. Calling again with a
    /// later clamp resumes exactly where the simulation paused — the MSD-
    /// vs-simulated-time curves in `bench_async.rs` are produced this way.
    pub fn run_clamped(
        &mut self,
        dict: &DistributedDictionary,
        task: &TaskSpec,
        x: &[f32],
        params: DiffusionParams,
        t_stop_us: u64,
    ) -> Result<bool> {
        let n = self.agents.len();
        if x.len() != self.m {
            return Err(DdlError::Shape(format!(
                "sample length {} != executor dimension {}",
                x.len(),
                self.m
            )));
        }
        if dict.agents() != n {
            return Err(DdlError::Shape(format!(
                "dictionary has {} agents, executor {n}",
                dict.agents()
            )));
        }
        if dict.m() != self.m {
            return Err(DdlError::Shape("dictionary row dimension mismatch".into()));
        }
        self.ensure_started(dict, params);
        if params.iters != self.target_iters || params.mu.to_bits() != self.mu.to_bits() {
            return Err(DdlError::Config(
                "async executor resumed with different DiffusionParams (mu/iters)".into(),
            ));
        }
        while self.done_count < n {
            let next_t = match self.heap.peek() {
                Some(Reverse(ev)) => ev.t,
                None => {
                    return Err(DdlError::Runtime(
                        "async executor stalled: agents pending but no events queued".into(),
                    ))
                }
            };
            if next_t > t_stop_us {
                return Ok(false);
            }
            let Some(Reverse(ev)) = self.heap.pop() else {
                return Err(DdlError::Runtime(
                    "async executor event heap drained between peek and pop".into(),
                ));
            };
            self.now_us = self.now_us.max(ev.t);
            match ev.kind {
                EventKind::AdaptDone { agent } => {
                    self.on_adapt_done(agent, ev.t, dict, task, x)
                }
                EventKind::Deliver { to, nb_slot, iter, psi, wshare } => {
                    if self.obs.enabled() {
                        let from = self.graph.neighbors(to)[nb_slot];
                        self.obs.instant(
                            ev.t,
                            "psi_deliver",
                            Track::Edge { from, to },
                            vec![("iter", ArgValue::U(iter as u64))],
                        );
                    }
                    let ag = &mut self.agents[to];
                    ag.seen[nb_slot] = Some(ag.seen[nb_slot].map_or(iter, |s| s.max(iter)));
                    ag.inbox[nb_slot].push((iter, psi, wshare));
                    if self.agents[to].waiting {
                        self.try_combine(to, ev.t, task, false);
                    }
                }
                EventKind::Retry { from, edge, iter, psi, wshare, attempt } => {
                    self.send_psi(from, edge, iter, psi, wshare, ev.t, attempt);
                }
                EventKind::GateTimeout { agent, iter } => {
                    self.on_gate_timeout(agent, iter, ev.t, task);
                }
            }
        }
        Ok(true)
    }

    /// Adapt (Eq. 31a) for agent `k`'s iteration `done`, then ship ψ to
    /// every neighbor and attempt the gated combine. Under chaos: a
    /// crashed agent defers the whole step to recovery (the lost compute
    /// is re-run and ψ rebroadcast — the re-join resync), and push-sum
    /// splits the re-massed ψ over the *live* out-edges only.
    fn on_adapt_done(
        &mut self,
        k: usize,
        t: u64,
        dict: &DistributedDictionary,
        task: &TaskSpec,
        x: &[f32],
    ) {
        if self.chaos_active && !self.params.chaos.agent_alive(k, t) {
            let rec = self.params.chaos.agent_recover_us(k, t);
            self.chaos_stats.crash_deferrals += 1;
            // The open "adapt" span keeps running across the deferral —
            // that is the per-agent stall the trace makes visible.
            if self.obs.enabled() {
                self.obs.instant(
                    t,
                    "crash_defer",
                    Track::Agent(k),
                    vec![("recover_us", ArgValue::U(rec))],
                );
            }
            self.push_event(rec.max(t.saturating_add(1)), EventKind::AdaptDone { agent: k });
            return;
        }
        self.obs.span_end(t, "adapt", Track::Agent(k));
        let n = self.agents.len();
        let cf_over_n = task.conj_grad_scale() / n as f32;
        let inv_delta = 1.0 / task.delta();
        let mu = self.mu;
        let theta_k = self.theta[k];
        {
            // The arithmetic is literally the BSP executor's adapt step
            // (one shared function, so the copies cannot drift).
            let thr = &mut self.thr;
            let ag = &mut self.agents[k];
            crate::net::bsp::adapt_step(
                dict, task, x, theta_k, k, &ag.nu, &mut ag.psi, thr, mu, cf_over_n, inv_delta,
            );
        }
        // Ship ψ along every outgoing edge (one message per directed edge
        // per iteration — same totals as BSP at equal iteration counts).
        let iter = self.agents[k].done;
        if self.pushsum {
            // Push-sum: re-mass the adapt output (s = value·w), then split
            // s and w uniformly over the live out-edges plus self —
            // column-stochastic over whatever is currently up.
            let w = self.agents[k].w;
            for p in self.agents[k].psi.iter_mut() {
                *p *= w;
            }
            let live: Vec<usize> = (0..self.graph.degree(k))
                .filter(|&j| {
                    !self.chaos_active
                        || self.params.chaos.link_up(k, self.graph.neighbors(k)[j], t)
                })
                .collect();
            let c = 1.0 / (live.len() + 1) as f32;
            for j in live {
                let share: Vec<f32> = self.agents[k].psi.iter().map(|v| c * v).collect();
                self.send_psi(k, j, iter, share, c * w, t, 0);
            }
            let ag = &mut self.agents[k];
            for p in ag.psi.iter_mut() {
                *p *= c;
            }
            ag.w = c * w;
        } else {
            // Byzantine window: corrupt each outgoing ψ copy independently
            // (the retained state stays honest — the attacker deceives its
            // neighbors, not itself). Consulted only under chaos, so the
            // fault-free path takes no extra branch and draws nothing.
            let policy =
                if self.chaos_active { self.params.chaos.byzantine_policy(k, t) } else { None };
            if let Some(p) = policy {
                let fanout = self.graph.degree(k);
                self.chaos_stats.corrupted += fanout;
                if self.obs.enabled() {
                    self.obs.instant(
                        t,
                        "psi_corrupt",
                        Track::Agent(k),
                        vec![
                            ("iter", ArgValue::U(iter as u64)),
                            ("policy", ArgValue::U(p.tag())),
                            ("fanout", ArgValue::U(fanout as u64)),
                        ],
                    );
                }
            }
            for j in 0..self.graph.degree(k) {
                let mut psi = self.agents[k].psi.clone();
                if let Some(p) = policy {
                    corrupt_psi(&mut psi, p, &mut self.chaos_rng);
                }
                self.send_psi(k, j, iter, psi, 0.0, t, 0);
            }
        }
        self.agents[k].waiting = true;
        self.agents[k].wait_since = t;
        self.obs.span_begin(t, "gate_wait", Track::Agent(k));
        if self.chaos_active {
            // Backstop liveness: under faults a gated combine never waits
            // past the receive timeout, so the event loop cannot stall.
            self.push_event(
                t.saturating_add(self.params.chaos_policy.gate_timeout_us.max(1)),
                EventKind::GateTimeout { agent: k, iter },
            );
        }
        self.try_combine(k, t, task, false);
    }

    /// Transmit one ψ (or push-sum share) along edge `edge` of `from`,
    /// honoring the chaos layer: a down link schedules a bounded-backoff
    /// retry (push-sum shares retry indefinitely — abandoning one would
    /// leak mass), an active drop window may lose the transmission (coin
    /// from the dedicated chaos stream). The fault-free path is exactly
    /// the pre-chaos send: one link-delay draw, one stats record, one
    /// `Deliver`.
    fn send_psi(
        &mut self,
        from: usize,
        edge: usize,
        iter: usize,
        psi: Vec<f32>,
        wshare: f32,
        t: u64,
        attempt: u32,
    ) {
        let nb = self.graph.neighbors(from)[edge];
        if self.chaos_active {
            if !self.params.chaos.link_up(from, nb, t) {
                if attempt < self.params.chaos_policy.max_retries || self.pushsum {
                    let backoff = self
                        .params
                        .chaos_policy
                        .retry_backoff_us
                        .max(1)
                        .saturating_mul(1u64 << attempt.min(20));
                    self.chaos_stats.retries += 1;
                    if self.obs.enabled() {
                        self.obs.instant(
                            t,
                            "psi_retry",
                            Track::Edge { from, to: nb },
                            vec![
                                ("iter", ArgValue::U(iter as u64)),
                                ("attempt", ArgValue::U(attempt as u64 + 1)),
                            ],
                        );
                    }
                    self.push_event(
                        t.saturating_add(backoff),
                        EventKind::Retry { from, edge, iter, psi, wshare, attempt: attempt + 1 },
                    );
                } else {
                    self.chaos_stats.abandoned += 1;
                    if self.obs.enabled() {
                        self.obs.instant(
                            t,
                            "psi_abandon",
                            Track::Edge { from, to: nb },
                            vec![("iter", ArgValue::U(iter as u64))],
                        );
                    }
                }
                return;
            }
            let p = self.params.chaos.drop_prob(t);
            if p > 0.0 && self.chaos_rng.next_f64() < p {
                // Transmitted but lost: the wire carried it (accounted),
                // the receiver never sees it, the sender never knows.
                self.stats.record_exchange(1, self.m);
                self.chaos_stats.dropped += 1;
                if self.obs.enabled() {
                    self.obs.instant(
                        t,
                        "psi_drop",
                        Track::Edge { from, to: nb },
                        vec![("iter", ArgValue::U(iter as u64))],
                    );
                }
                return;
            }
        }
        let delay = self.sample_link(from, edge);
        let slot = self.rev_slot[from][edge];
        self.stats.record_exchange(1, self.m);
        if self.obs.enabled() {
            self.obs.instant(
                t,
                "psi_send",
                Track::Edge { from, to: nb },
                vec![("iter", ArgValue::U(iter as u64))],
            );
        }
        self.push_event(
            t.saturating_add(delay),
            EventKind::Deliver { to: nb, nb_slot: slot, iter, psi, wshare },
        );
    }

    /// Chaos receive timeout: an agent still gated on iteration `iter`
    /// stops waiting and combines with whatever it has. Stale timeouts
    /// (the combine already happened) are ignored; a timeout landing in a
    /// crash window re-arms at recovery.
    fn on_gate_timeout(&mut self, k: usize, iter: usize, t: u64, task: &TaskSpec) {
        if !self.agents[k].waiting || self.agents[k].done != iter {
            return;
        }
        if !self.params.chaos.agent_alive(k, t) {
            let rec = self.params.chaos.agent_recover_us(k, t);
            self.push_event(
                rec.max(t.saturating_add(1)),
                EventKind::GateTimeout { agent: k, iter },
            );
            return;
        }
        self.chaos_stats.forced_combines += 1;
        if self.obs.enabled() {
            self.obs.instant(
                t,
                "forced_combine",
                Track::Agent(k),
                vec![("iter", ArgValue::U(iter as u64))],
            );
        }
        self.try_combine(k, t, task, true);
    }

    /// Gated combine: needs, from every *reachable* neighbor, a ψ fresh
    /// under the staleness bound; unreachable neighbors (link down or
    /// crashed, chaos only) are waived up-front — their slots are served
    /// by the stale-ψ fallback or excluded. `force` (the chaos receive
    /// timeout) waives the gate entirely. Fault-free, this is exactly the
    /// pre-chaos gate.
    fn try_combine(&mut self, k: usize, t: u64, task: &TaskSpec, force: bool) {
        let i = self.agents[k].done;
        let tau = self.params.tau;
        if !force {
            // Gate check first (no partial state changes on failure).
            let neighbors = self.graph.neighbors(k);
            for (j, slots) in self.agents[k].inbox.iter().enumerate() {
                if self.chaos_active {
                    let nb = neighbors[j];
                    if !(self.params.chaos.link_up(nb, k, t)
                        && self.params.chaos.agent_alive(nb, t))
                    {
                        continue; // unreachable: waived, degraded below
                    }
                }
                let fresh = if self.pushsum {
                    // Push-sum gates on the freshest iteration ever seen
                    // from this neighbor: shares are drained at combine,
                    // so pending mass alone cannot express freshness.
                    self.agents[k].seen[j].is_some_and(|s| s + tau >= i)
                } else {
                    matches!(
                        slots.iter().filter(|e| e.0 <= i).map(|e| e.0).max(),
                        Some(b) if b + tau >= i
                    )
                };
                if !fresh {
                    return;
                }
            }
        }
        if self.pushsum {
            self.combine_pushsum(k, i, t, task);
        } else {
            match self.mode {
                CombineMode::Median => self.combine_resilient(k, i, t, task, None),
                CombineMode::TrimmedMean(f) => self.combine_resilient(k, i, t, task, Some(f)),
                _ => self.combine_metropolis(k, i, t, task),
            }
        }
        if self.obs.enabled() {
            self.obs.span_end(t, "gate_wait", Track::Agent(k));
            self.obs.instant(
                t,
                "combine",
                Track::Agent(k),
                vec![("iter", ArgValue::U(i as u64)), ("forced", ArgValue::B(force))],
            );
        }
        self.last_combine_us = t;
        // Round tracking: one round per completed network-wide wave.
        self.level_counts[i] -= 1;
        self.level_counts[i + 1] += 1;
        if i == self.cur_min && self.level_counts[i] == 0 {
            self.cur_min += 1;
            self.stats.end_round();
        }
        if self.agents[k].done == self.target_iters {
            self.done_count += 1;
        } else {
            let d = self.sample_compute(k, t);
            self.obs.span_begin(t, "adapt", Track::Agent(k));
            self.push_event(t.saturating_add(d), EventKind::AdaptDone { agent: k });
        }
    }

    /// Metropolis combine for agent `k`'s iteration `i`: freshest ψ per
    /// neighbor. Slots whose freshest ψ is staler than τ fall back to it
    /// anyway (accounted as fallback, not in the τ invariant); slots that
    /// never delivered are excluded and the weights renormalized. On the
    /// fault-free path neither case can occur — the arithmetic is the
    /// pre-chaos combine bit-for-bit.
    fn combine_metropolis(&mut self, k: usize, i: usize, t: u64, task: &TaskSpec) {
        let akk = self.weights.get(k, k);
        let clip = task.dual_clip();
        let m = self.m;
        // Combine: a_{kk}ψ_k first, then neighbors in ascending order —
        // exactly the accumulation order of `BspNetwork::run` (its inbox
        // fills in ascending sender order).
        let neighbors = self.graph.neighbors(k);
        let mut staleness_max = 0usize;
        let mut fallbacks = 0usize;
        let mut fallback_stale = 0usize;
        let mut excluded = 0usize;
        let waited_us;
        {
            let ag = &mut self.agents[k];
            // Gate-wait accounting: time between the adapt finishing and
            // this combine passing the staleness gate (0 when the gate
            // passed immediately).
            waited_us = t.saturating_sub(ag.wait_since);
            let mut wsum = akk;
            for idx in 0..m {
                ag.nu[idx] = akk * ag.psi[idx];
            }
            for (j, &nb) in neighbors.iter().enumerate() {
                let slots = &mut ag.inbox[j];
                let mut best = None;
                for e in slots.iter() {
                    if e.0 <= i && best.map_or(true, |b| e.0 > b) {
                        best = Some(e.0);
                    }
                }
                let used = match best {
                    Some(u) if u + self.params.tau >= i => {
                        staleness_max = staleness_max.max(i - u);
                        u
                    }
                    Some(u) => {
                        // Stale-ψ fallback: reachable data is too old for
                        // the gate, but beats stalling or dropping the
                        // neighbor's contribution.
                        fallbacks += 1;
                        fallback_stale = fallback_stale.max(i - u);
                        u
                    }
                    None => {
                        // Never heard from this neighbor: exclude it and
                        // renormalize the combine below.
                        excluded += 1;
                        continue;
                    }
                };
                let w = self.weights.get(nb, k);
                wsum += w;
                if let Some(e) = slots.iter().find(|e| e.0 == used) {
                    let src = &e.1;
                    for idx in 0..m {
                        ag.nu[idx] += w * src[idx];
                    }
                }
                // Entries older than the one just used can never be
                // selected again (the local iteration only increases).
                slots.retain(|e| e.0 >= used);
            }
            if excluded > 0 && wsum > 0.0 {
                let inv = 1.0 / wsum;
                for idx in 0..m {
                    ag.nu[idx] *= inv;
                }
            }
            if let Some(b) = clip {
                clip_linf(&mut ag.nu, b);
            }
            ag.waiting = false;
            ag.done = i + 1;
        }
        self.max_staleness = self.max_staleness.max(staleness_max);
        self.chaos_stats.stale_fallbacks += fallbacks;
        self.chaos_stats.excluded_neighbors += excluded;
        self.chaos_stats.max_fallback_staleness =
            self.chaos_stats.max_fallback_staleness.max(fallback_stale);
        self.gate_wait_us += waited_us;
    }

    /// Resilient combine (`CombineMode::Median` / `TrimmedMean(f)`) for
    /// agent `k`'s iteration `i`: the neighbor selection, staleness,
    /// fallback, and exclusion bookkeeping of
    /// [`Self::combine_metropolis`], with the weighted sum replaced per
    /// coordinate by the trimmed weighted mean
    /// ([`crate::infer::diffusion::trimmed_weighted_mean`]): participants
    /// {self} ∪ {freshest ψ per delivered neighbor} sorted by value with
    /// deterministic `total_cmp` tie-breaking, the `f` smallest and `f`
    /// largest discarded (`Median`: all but the middle), survivor weights
    /// renormalized to sum to one. Tolerates up to `f` corrupted
    /// neighbors per neighborhood at the cost of a consensus estimate
    /// that is no longer a fixed linear map — so this mode is opt-in,
    /// never `Auto`-selected.
    ///
    /// With [`AsyncParams::detect`] enabled, a scoring pass runs *after*
    /// the aggregate: per delivered neighbor it gathers per-combine
    /// evidence (trimmed-tail membership fraction + L1
    /// distance-to-aggregate against both the median participant distance
    /// and the aggregate's own scale — see [`DetectionConfig`]) on a
    /// **separate** augmented sort, so the aggregate arithmetic and every
    /// RNG stream are untouched and a zero-attacker detection run stays
    /// bit-for-bit the detection-off run. A neighbor past
    /// `exclude_after` consecutive evidence combines is excluded: its ψ
    /// never enters this judge's participant set again (renormalization
    /// is inherent in the trimmed weighted mean — the same never-heard
    /// machinery path), until optional probation re-admits it.
    fn combine_resilient(
        &mut self,
        k: usize,
        i: usize,
        t: u64,
        task: &TaskSpec,
        trim: Option<usize>,
    ) {
        let akk = self.weights.get(k, k);
        let clip = task.dual_clip();
        let m = self.m;
        let neighbors = self.graph.neighbors(k);
        let det = self.params.detect;
        let mut staleness_max = 0usize;
        let mut fallbacks = 0usize;
        let mut fallback_stale = 0usize;
        let mut excluded = 0usize;
        let mut readmitted: Vec<usize> = Vec::new();
        let mut newly_flagged: Vec<usize> = Vec::new();
        let mut newly_excluded: Vec<usize> = Vec::new();
        let waited_us;
        let participants;
        let trimmed_each_side;
        {
            let ag = &mut self.agents[k];
            waited_us = t.saturating_sub(ag.wait_since);
            // Probation sweep: re-admit suspects whose exclusion has aged
            // past the probation window. Scores reset to zero — a
            // re-offender walks the full evidence ladder again.
            if det.enabled && det.probation_us > 0 {
                for (j, s) in self.det[k].iter_mut().enumerate() {
                    if s.excluded && t >= s.excluded_at_us.saturating_add(det.probation_us) {
                        *s = NbrScore::default();
                        readmitted.push(j);
                    }
                }
            }
            // Participants: (weight, ψ) — self first, then neighbors in
            // ascending order (the Metropolis accumulation order; the sort
            // inside the aggregate makes the order immaterial, but keeping
            // it fixed keeps the trace readable). `src[p]` remembers which
            // neighbor slot produced `parts[p]` (`usize::MAX` = self) for
            // the detection pass.
            let mut parts: Vec<(f32, Vec<f32>)> = Vec::with_capacity(neighbors.len() + 1);
            let mut src: Vec<usize> = Vec::with_capacity(neighbors.len() + 1);
            parts.push((akk, ag.psi.clone()));
            src.push(usize::MAX);
            for (j, &nb) in neighbors.iter().enumerate() {
                let slots = &mut ag.inbox[j];
                if det.enabled && self.det[k][j].excluded {
                    // Detection exclusion: the suspect's ψ never enters the
                    // aggregate; its inbox is drained so state stays
                    // bounded while it keeps transmitting.
                    slots.clear();
                    continue;
                }
                let mut best = None;
                for e in slots.iter() {
                    if e.0 <= i && best.map_or(true, |b| e.0 > b) {
                        best = Some(e.0);
                    }
                }
                let used = match best {
                    Some(u) if u + self.params.tau >= i => {
                        staleness_max = staleness_max.max(i - u);
                        u
                    }
                    Some(u) => {
                        fallbacks += 1;
                        fallback_stale = fallback_stale.max(i - u);
                        u
                    }
                    None => {
                        excluded += 1;
                        continue;
                    }
                };
                let w = self.weights.get(nb, k);
                if let Some(e) = slots.iter().find(|e| e.0 == used) {
                    parts.push((w, e.1.clone()));
                    src.push(j);
                }
                slots.retain(|e| e.0 >= used);
            }
            participants = parts.len();
            let g = match trim {
                None => participants.saturating_sub(1) / 2,
                Some(f) => f.min(participants.saturating_sub(1) / 2),
            };
            trimmed_each_side = g;
            // Coordinate-wise trimmed weighted mean (renormalization is
            // inside the aggregate, so exclusions need no extra pass).
            let mut tail_hits = vec![0usize; participants];
            let mut scratch: Vec<(f32, f32)> = Vec::with_capacity(participants);
            let mut order: Vec<(f32, usize)> = Vec::with_capacity(participants);
            for idx in 0..m {
                scratch.clear();
                scratch.extend(parts.iter().map(|(w, v)| (v[idx], *w)));
                if det.enabled && g > 0 && i >= det.warmup_iters {
                    // Augmented (value, participant) sort for tail
                    // attribution — separate from the aggregate's own
                    // sort, so detection cannot perturb the trajectory.
                    order.clear();
                    order.extend(parts.iter().enumerate().map(|(p, (_, v))| (v[idx], p)));
                    order.sort_by(|a, b| a.0.total_cmp(&b.0));
                    for &(_, p) in order[..g].iter().chain(order[participants - g..].iter()) {
                        tail_hits[p] += 1;
                    }
                }
                ag.nu[idx] =
                    crate::infer::diffusion::trimmed_weighted_mean(&mut scratch, trim);
            }
            // Evidence pass — a pure function of (config, ψ bits, the
            // pre-clip aggregate just computed). Evidence requires ALL
            // THREE: tail-membership frequency, distance dominance over
            // the median participant, and distance significance against
            // the aggregate's own L1 scale (suppresses transient-phase
            // false positives, when everything is still near zero).
            if det.enabled && participants > 1 && i >= det.warmup_iters {
                let mut dist = vec![0f64; participants];
                for (p, (_, v)) in parts.iter().enumerate() {
                    let mut d = 0f64;
                    for idx in 0..m {
                        d += (v[idx] - ag.nu[idx]).abs() as f64;
                    }
                    dist[p] = d;
                }
                let mut sorted = dist.clone();
                sorted.sort_by(f64::total_cmp);
                let med = sorted[(participants - 1) / 2].max(1e-12);
                let nu_l1: f64 = ag.nu.iter().map(|v| v.abs() as f64).sum();
                for p in 1..participants {
                    let j = src[p];
                    let tail_frac = tail_hits[p] as f64 / m.max(1) as f64;
                    let evidence = tail_frac >= det.tail_frac_min
                        && dist[p] >= det.dist_ratio * med
                        && dist[p] >= det.rel_dist_min * (nu_l1 + 1e-6);
                    let s = &mut self.det[k][j];
                    if evidence {
                        s.score += 1;
                        if !s.flagged && s.score >= det.flag_after {
                            s.flagged = true;
                            newly_flagged.push(j);
                        }
                        if !s.excluded && s.score >= det.exclude_after {
                            s.excluded = true;
                            s.excluded_at_us = t;
                            newly_excluded.push(j);
                        }
                    } else {
                        s.score = 0;
                    }
                }
            }
            if let Some(b) = clip {
                clip_linf(&mut ag.nu, b);
            }
            ag.waiting = false;
            ag.done = i + 1;
        }
        self.chaos_stats.readmitted += readmitted.len();
        self.chaos_stats.flagged += newly_flagged.len();
        self.chaos_stats.detect_excluded += newly_excluded.len();
        if self.obs.enabled() {
            for &j in &readmitted {
                self.obs.instant(
                    t,
                    "agent_readmitted",
                    Track::Agent(neighbors[j]),
                    vec![("judge", ArgValue::U(k as u64)), ("iter", ArgValue::U(i as u64))],
                );
            }
            for &j in &newly_flagged {
                self.obs.instant(
                    t,
                    "agent_flagged",
                    Track::Agent(neighbors[j]),
                    vec![
                        ("judge", ArgValue::U(k as u64)),
                        ("iter", ArgValue::U(i as u64)),
                        ("score", ArgValue::U(det.flag_after as u64)),
                    ],
                );
            }
            for &j in &newly_excluded {
                self.obs.instant(
                    t,
                    "agent_excluded",
                    Track::Agent(neighbors[j]),
                    vec![
                        ("judge", ArgValue::U(k as u64)),
                        ("iter", ArgValue::U(i as u64)),
                        ("score", ArgValue::U(det.exclude_after as u64)),
                    ],
                );
            }
            self.obs.instant(
                t,
                "combine_trimmed",
                Track::Agent(k),
                vec![
                    ("iter", ArgValue::U(i as u64)),
                    ("participants", ArgValue::U(participants as u64)),
                    ("trimmed_each_side", ArgValue::U(trimmed_each_side as u64)),
                ],
            );
        }
        self.max_staleness = self.max_staleness.max(staleness_max);
        self.chaos_stats.stale_fallbacks += fallbacks;
        self.chaos_stats.excluded_neighbors += excluded;
        self.chaos_stats.max_fallback_staleness =
            self.chaos_stats.max_fallback_staleness.max(fallback_stale);
        self.gate_wait_us += waited_us;
    }

    /// Push-sum combine for agent `k`'s iteration `i`: sum the retained
    /// self-share with **every** pending share (mass conservation — shares
    /// are drained, not sampled), then read the estimate as the ratio
    /// `s / w`. Freshness bookkeeping runs off the `seen` watermarks.
    fn combine_pushsum(&mut self, k: usize, i: usize, t: u64, task: &TaskSpec) {
        let clip = task.dual_clip();
        let m = self.m;
        let mut staleness_max = 0usize;
        let mut fallbacks = 0usize;
        let mut fallback_stale = 0usize;
        let waited_us;
        {
            let ag = &mut self.agents[k];
            waited_us = t.saturating_sub(ag.wait_since);
            let mut w_acc = ag.w;
            for idx in 0..m {
                ag.nu[idx] = ag.psi[idx];
            }
            for (j, slots) in ag.inbox.iter_mut().enumerate() {
                match ag.seen[j] {
                    Some(s) if s + self.params.tau >= i => {
                        staleness_max = staleness_max.max(i.saturating_sub(s));
                    }
                    Some(s) => {
                        fallbacks += 1;
                        fallback_stale = fallback_stale.max(i - s);
                    }
                    None => {}
                }
                for e in slots.iter() {
                    for idx in 0..m {
                        ag.nu[idx] += e.1[idx];
                    }
                    w_acc += e.2;
                }
                slots.clear();
            }
            // The estimate is the ratio; the mass scalar carries over to
            // the next adapt's re-massing.
            let inv = 1.0 / w_acc.max(1e-12);
            for idx in 0..m {
                ag.nu[idx] *= inv;
            }
            ag.w = w_acc;
            if let Some(b) = clip {
                clip_linf(&mut ag.nu, b);
            }
            ag.waiting = false;
            ag.done = i + 1;
        }
        self.max_staleness = self.max_staleness.max(staleness_max);
        self.chaos_stats.stale_fallbacks += fallbacks;
        self.chaos_stats.max_fallback_staleness =
            self.chaos_stats.max_fallback_staleness.max(fallback_stale);
        self.gate_wait_us += waited_us;
    }

    /// Swap the staleness bound mid-run (the τ controller's actuator,
    /// `ddl async --adaptive-tau`). Call between [`Self::run_clamped`]
    /// segments at a simulated time `t_us` at or past the last processed
    /// event. Widening re-attempts the gated combine of every waiting
    /// agent (in ascending agent order — deterministic); narrowing simply
    /// tightens the gate for future combines. Waiting agents' staleness
    /// never exceeds the widest bound in effect while they waited.
    pub fn set_tau(&mut self, tau: usize, task: &TaskSpec, t_us: u64) {
        let widened = tau > self.params.tau;
        if self.obs.enabled() && tau != self.params.tau {
            self.obs.instant(
                t_us,
                "tau_set",
                Track::Controller("tau"),
                vec![
                    ("tau", ArgValue::U(tau as u64)),
                    ("prev", ArgValue::U(self.params.tau as u64)),
                ],
            );
        }
        self.params.tau = tau;
        if widened {
            for k in 0..self.agents.len() {
                if self.agents[k].waiting {
                    self.try_combine(k, t_us, task, false);
                }
            }
        }
    }

    /// Agent `k`'s dual estimate.
    pub fn nu(&self, k: usize) -> &[f32] {
        &self.agents[k].nu
    }

    /// Combines completed by agent `k`.
    pub fn iters_done(&self, k: usize) -> usize {
        self.agents[k].done
    }

    /// Minimum combines completed across all agents (= completed
    /// network-wide waves = the `rounds` counter).
    pub fn min_iters_done(&self) -> usize {
        self.cur_min
    }

    /// Mean combines completed across all agents.
    pub fn mean_iters_done(&self) -> f64 {
        let total: usize = self.agents.iter().map(|a| a.done).sum();
        total as f64 / self.agents.len().max(1) as f64
    }

    /// Simulated time of the most recent combine (µs); the completion time
    /// of the network once [`Self::run`] returns.
    pub fn sim_time_us(&self) -> u64 {
        self.last_combine_us
    }

    /// Current simulated clock (µs) — the time of the last processed event.
    pub fn clock_us(&self) -> u64 {
        self.now_us
    }

    /// Largest per-neighbor staleness `i − iter(ψ used)` observed by any
    /// combine; never exceeds [`AsyncParams::tau`] (the widest bound in
    /// effect, under [`Self::set_tau`]).
    pub fn max_staleness_observed(&self) -> usize {
        self.max_staleness
    }

    /// Staleness bound currently in effect.
    pub fn tau(&self) -> usize {
        self.params.tau
    }

    /// Total simulated µs agents spent with an adapt finished but the
    /// combine gated on the staleness bound, summed over agents and
    /// accounted at each *completed* combine. Dominating the simulated
    /// time budget (`gate_wait_us / (N · elapsed)` large) is the τ
    /// controller's signal to widen the bound.
    pub fn gate_wait_us(&self) -> u64 {
        self.gate_wait_us
    }

    /// [`Self::gate_wait_us`] plus the in-progress waits of agents still
    /// gated at simulated time `t_us` (which must be at or past the last
    /// processed event). Controllers difference *this* per epoch: an
    /// epoch in which agents sat blocked the whole time — no combine
    /// landed to charge [`Self::gate_wait_us`] — still registers its full
    /// wait immediately, and because a wait's in-progress prefix is
    /// exactly what the completed charge later includes, per-epoch
    /// differences telescope with no double counting.
    pub fn gate_wait_us_at(&self, t_us: u64) -> u64 {
        let in_progress: u64 = self
            .agents
            .iter()
            .filter(|a| a.waiting)
            .map(|a| t_us.saturating_sub(a.wait_since))
            .sum();
        self.gate_wait_us.saturating_add(in_progress)
    }

    /// Traffic statistics (see the accounting note in the module docs).
    pub fn stats(&self) -> MessageStats {
        self.stats
    }

    /// Chaos-layer counters (all zero on a fault-free run).
    pub fn chaos_stats(&self) -> ChaosStats {
        self.chaos_stats
    }

    /// Resolved combine rule (`Auto` collapses at construction; never
    /// returns `Auto`).
    pub fn combine_mode(&self) -> CombineMode {
        self.mode
    }

    /// True when `Auto` upgraded the combine to push-sum because the
    /// schedule contains directed faults.
    pub fn auto_pushsum(&self) -> bool {
        self.auto_pushsum
    }

    /// The installed fault schedule.
    pub fn fault_schedule(&self) -> &FaultSchedule {
        &self.params.chaos
    }

    /// The installed detection configuration.
    pub fn detection(&self) -> DetectionConfig {
        self.params.detect
    }

    /// Agents currently excluded by at least one judge's detection state
    /// (ascending, deduplicated). Always empty when detection is off.
    pub fn excluded_suspects(&self) -> Vec<usize> {
        self.collect_suspects(|s| s.excluded)
    }

    /// Agents flagged (suspicion threshold crossed) by at least one judge
    /// (ascending, deduplicated). A superset of
    /// [`Self::excluded_suspects`] while the flag bit persists.
    pub fn flagged_suspects(&self) -> Vec<usize> {
        self.collect_suspects(|s| s.flagged)
    }

    fn collect_suspects(&self, pred: impl Fn(&NbrScore) -> bool) -> Vec<usize> {
        let mut out = Vec::new();
        for (k, scores) in self.det.iter().enumerate() {
            for (j, s) in scores.iter().enumerate() {
                if pred(s) {
                    out.push(self.graph.neighbors(k)[j]);
                }
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Normalized mean-square deviation of the agents' duals from a
    /// reference `ν` (typically [`crate::infer::exact_dual`]'s ν°):
    /// `mean_k ‖ν_k − ν_ref‖² / ‖ν_ref‖²`.
    pub fn msd_vs(&self, nu_ref: &[f32]) -> f64 {
        let denom = crate::math::vector::norm2_sq(nu_ref).max(1e-30) as f64;
        let sum: f64 = self
            .agents
            .iter()
            .map(|a| crate::math::vector::dist_sq(&a.nu, nu_ref) as f64)
            .sum();
        sum / (self.agents.len().max(1) as f64 * denom)
    }
}

/// Apply a [`CorruptPolicy`] to one outgoing ψ copy. Scaled-noise draws
/// come from the dedicated chaos stream (passed in) — exactly `m` draws
/// per corrupted message, zero otherwise — so attacks replay
/// bit-identically and honest windows consume no randomness.
fn corrupt_psi(psi: &mut [f32], policy: CorruptPolicy, chaos_rng: &mut Pcg64) {
    match policy {
        CorruptPolicy::SignFlip => {
            for v in psi.iter_mut() {
                *v = -*v;
            }
        }
        CorruptPolicy::ScaledNoise { sigma } => {
            for v in psi.iter_mut() {
                *v += sigma * chaos_rng.next_normal();
            }
        }
        CorruptPolicy::ConstantPsi { value } => {
            for v in psi.iter_mut() {
                *v = value;
            }
        }
        CorruptPolicy::ColludingOffset { magnitude } => {
            for v in psi.iter_mut() {
                *v += magnitude;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{metropolis_weights, Topology};
    use crate::model::AtomConstraint;
    use crate::net::BspNetwork;

    fn problem(
        n: usize,
        m: usize,
        seed: u64,
        topo: &Topology,
    ) -> (DistributedDictionary, Graph, Mat, Vec<f32>) {
        let mut rng = Pcg64::new(seed);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, topo, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        (dict, g, a, x)
    }

    /// τ = 0 with zero delays is bit-for-bit the BSP executor, including
    /// traffic accounting.
    #[test]
    fn zero_delay_tau0_is_bitwise_bsp() {
        let (n, m, iters) = (9, 7, 37);
        let (dict, g, a, x) = problem(n, m, 0xA5_01, &Topology::ErdosRenyi { p: 0.5 });
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let params = DiffusionParams::new(0.3, iters);

        let mut bsp = BspNetwork::new(g.clone(), a.clone(), m, None);
        bsp.run(&dict, &task, &x, params).unwrap();

        let mut anet = AsyncNetwork::new(g, a, m, None, AsyncParams::default()).unwrap();
        anet.run(&dict, &task, &x, params).unwrap();

        for k in 0..n {
            assert_eq!(anet.nu(k), bsp.nu(k), "agent {k}");
        }
        assert_eq!(anet.stats(), bsp.stats());
        assert_eq!(anet.sim_time_us(), 0);
        assert_eq!(anet.max_staleness_observed(), 0);
        assert_eq!(anet.min_iters_done(), iters);
    }

    /// τ = 0 with *random* delays still reproduces the BSP trajectory
    /// bit-for-bit — delays move the clock, never the arithmetic.
    #[test]
    fn random_delay_tau0_trajectory_unchanged() {
        let (n, m, iters) = (8, 6, 25);
        let (dict, g, a, x) = problem(n, m, 0xA5_02, &Topology::Ring { k: 2 });
        let task = TaskSpec::SparseCoding { gamma: 0.15, delta: 0.4 };
        let params = DiffusionParams::new(0.25, iters);

        let mut bsp = BspNetwork::new(g.clone(), a.clone(), m, None);
        bsp.run(&dict, &task, &x, params).unwrap();

        let ap = AsyncParams::default()
            .with_delays(DelayDist::Exp { mean_us: 120.0 }, DelayDist::Uniform {
                lo_us: 5,
                hi_us: 60,
            })
            .with_seed(77);
        let mut anet = AsyncNetwork::new(g, a, m, None, ap).unwrap();
        anet.run(&dict, &task, &x, params).unwrap();
        for k in 0..n {
            assert_eq!(anet.nu(k), bsp.nu(k), "agent {k}");
        }
        assert!(anet.sim_time_us() > 0);
        assert_eq!(anet.stats().messages, bsp.stats().messages);
        assert_eq!(anet.stats().bytes, bsp.stats().bytes);
        assert_eq!(anet.stats().rounds, iters);
    }

    /// Same seed ⇒ identical replay (trajectories, stats, clock); the
    /// whole straggler scenario is reproducible.
    #[test]
    fn deterministic_replay_per_seed() {
        let (n, m, iters) = (10, 5, 30);
        let (dict, g, a, x) = problem(n, m, 0xA5_03, &Topology::Ring { k: 1 });
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let params = DiffusionParams::new(0.3, iters);
        let ap = AsyncParams::default()
            .with_tau(3)
            .with_delays(DelayDist::Exp { mean_us: 100.0 }, DelayDist::Exp { mean_us: 20.0 })
            .with_slow_agent(4, 10.0)
            .with_seed(123);

        let mut a1 = AsyncNetwork::new(g.clone(), a.clone(), m, None, ap.clone()).unwrap();
        a1.run(&dict, &task, &x, params).unwrap();
        let mut a2 = AsyncNetwork::new(g, a, m, None, ap).unwrap();
        a2.run(&dict, &task, &x, params).unwrap();

        for k in 0..n {
            assert_eq!(a1.nu(k), a2.nu(k), "agent {k}");
        }
        assert_eq!(a1.stats(), a2.stats());
        assert_eq!(a1.sim_time_us(), a2.sim_time_us());
        assert_eq!(a1.max_staleness_observed(), a2.max_staleness_observed());
    }

    /// Incremental `run_clamped` stepping resumes exactly: stepping the
    /// clock in chunks lands bit-identical to one uninterrupted run.
    #[test]
    fn clamped_stepping_matches_one_shot() {
        let (n, m, iters) = (8, 6, 24);
        let (dict, g, a, x) = problem(n, m, 0xA5_04, &Topology::Ring { k: 2 });
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let params = DiffusionParams::new(0.3, iters);
        let ap = AsyncParams::default()
            .with_tau(2)
            .with_delays(DelayDist::Uniform { lo_us: 10, hi_us: 200 }, DelayDist::Constant {
                us: 15,
            })
            .with_seed(9);

        let mut oneshot = AsyncNetwork::new(g.clone(), a.clone(), m, None, ap.clone()).unwrap();
        oneshot.run(&dict, &task, &x, params).unwrap();

        let mut stepped = AsyncNetwork::new(g, a, m, None, ap).unwrap();
        let mut t = 100u64;
        while !stepped.run_clamped(&dict, &task, &x, params, t).unwrap() {
            t += 100;
        }
        for k in 0..n {
            assert_eq!(oneshot.nu(k), stepped.nu(k), "agent {k}");
        }
        assert_eq!(oneshot.stats(), stepped.stats());
        assert_eq!(oneshot.sim_time_us(), stepped.sim_time_us());
    }

    /// The staleness bound is a hard invariant for every τ.
    #[test]
    fn staleness_never_exceeds_tau() {
        let (n, m, iters) = (12, 4, 40);
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let params = DiffusionParams::new(0.2, iters);
        for tau in [0usize, 1, 2, 5] {
            let (dict, g, a, x) = problem(n, m, 0xA5_05 + tau as u64, &Topology::Ring { k: 2 });
            let ap = AsyncParams::default()
                .with_tau(tau)
                .with_delays(DelayDist::Exp { mean_us: 80.0 }, DelayDist::Exp { mean_us: 40.0 })
                .with_slow_agent(0, 6.0)
                .with_seed(31 + tau as u64);
            let mut anet = AsyncNetwork::new(g, a, m, None, ap).unwrap();
            anet.run(&dict, &task, &x, params).unwrap();
            assert!(
                anet.max_staleness_observed() <= tau,
                "tau={tau}: observed {}",
                anet.max_staleness_observed()
            );
            for k in 0..n {
                assert_eq!(anet.iters_done(k), iters);
            }
        }
    }

    /// With τ > 0 and a clamped clock, non-straggler agents run ahead of
    /// the slow agent — the whole point of relaxing the barrier.
    #[test]
    fn straggler_does_not_gate_neighbors_under_tau() {
        let (n, m) = (10, 4);
        let (dict, g, a, x) = problem(n, m, 0xA5_06, &Topology::Ring { k: 1 });
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let params = DiffusionParams::new(0.2, 400);
        let ap = AsyncParams::default()
            .with_tau(4)
            .with_delays(DelayDist::Constant { us: 100 }, DelayDist::Zero)
            .with_slow_agent(0, 10.0)
            .with_seed(5);
        let mut anet = AsyncNetwork::new(g, a, m, None, ap).unwrap();
        // Budget of ~60 slow-agent iterations.
        let done = anet.run_clamped(&dict, &task, &x, params, 60_000).unwrap();
        assert!(!done, "400 iterations cannot finish in this budget");
        let slow_done = anet.iters_done(0);
        let max_done = (0..n).map(|k| anet.iters_done(k)).max().unwrap();
        assert!(slow_done < max_done, "straggler {slow_done} vs fastest {max_done}");
        assert!(anet.mean_iters_done() > slow_done as f64);
        // ...but bounded staleness chains the network to the straggler:
        // an agent at graph distance d can lead by at most d·(τ+1)
        // (each hop adds one staleness window plus the in-flight adapt).
        for k in 0..n {
            let d = k.min(n - k); // ring distance to agent 0
            assert!(
                anet.iters_done(k) <= slow_done + d * 5,
                "agent {k} too far ahead: {} vs straggler {slow_done}",
                anet.iters_done(k)
            );
        }
    }

    /// Huber's dual-box projection applies in the async executor too.
    #[test]
    fn huber_clipped_async() {
        let (n, m) = (6, 5);
        let mut rng = Pcg64::new(0xA5_07);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::NonNegUnitBall, &mut rng)
                .unwrap();
        let g = Graph::generate(n, &Topology::Ring { k: 1 }, &mut rng);
        let a = metropolis_weights(&g);
        let mut x = rng.normal_vec(m);
        crate::math::vector::scale(8.0, &mut x);
        let task = TaskSpec::HuberNmf { gamma: 0.1, delta: 0.5, eta: 0.2 };
        let ap = AsyncParams::default()
            .with_tau(2)
            .with_delays(DelayDist::Exp { mean_us: 50.0 }, DelayDist::Exp { mean_us: 10.0 });
        let mut anet = AsyncNetwork::new(g, a, m, None, ap).unwrap();
        anet.run(&dict, &task, &x, DiffusionParams::new(0.4, 120)).unwrap();
        for k in 0..n {
            assert!(crate::math::vector::norm_inf(anet.nu(k)) <= 1.0 + 1e-6);
        }
    }

    /// Gate-wait accounting: the barrier (τ = 0) under iid compute jitter
    /// charges every agent the neighborhood max each iteration, while a
    /// wide τ absorbs the jitter — the wait *fraction* of simulated time
    /// collapses. (A permanent straggler is deliberately not used here:
    /// with one, both executors rate-match to the slow agent in steady
    /// state and the fractions converge.)
    #[test]
    fn gate_wait_fraction_collapses_with_wide_tau() {
        let (n, m, iters) = (10, 4, 80);
        let (dict, g, a, x) = problem(n, m, 0xA5_10, &Topology::Ring { k: 1 });
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let params = DiffusionParams::new(0.2, iters);
        let mk = |tau| {
            AsyncParams::default()
                .with_tau(tau)
                .with_delays(DelayDist::Exp { mean_us: 100.0 }, DelayDist::Exp { mean_us: 10.0 })
                .with_seed(44)
        };
        let mut sync = AsyncNetwork::new(g.clone(), a.clone(), m, None, mk(0)).unwrap();
        sync.run(&dict, &task, &x, params).unwrap();
        let mut wide = AsyncNetwork::new(g, a, m, None, mk(8)).unwrap();
        wide.run(&dict, &task, &x, params).unwrap();
        assert!(sync.gate_wait_us() > 0, "the barrier must charge gate-wait time");
        let frac = |net: &AsyncNetwork| {
            net.gate_wait_us() as f64 / (net.sim_time_us().max(1) as f64 * n as f64)
        };
        assert!(
            frac(&wide) < frac(&sync),
            "τ=8 wait fraction {} should undercut τ=0 fraction {}",
            frac(&wide),
            frac(&sync)
        );
    }

    /// `gate_wait_us_at` surfaces in-progress waits mid-run (agents
    /// blocked on a straggler that has not yet produced its ψ), and
    /// collapses back to the completed-combine total once the run ends.
    #[test]
    fn gate_wait_at_includes_in_progress_waits() {
        let (n, m, iters) = (8, 4, 12);
        let (dict, g, a, x) = problem(n, m, 0xA5_13, &Topology::Ring { k: 1 });
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let params = DiffusionParams::new(0.2, iters);
        let ap = AsyncParams::default()
            .with_delays(DelayDist::Constant { us: 100 }, DelayDist::Zero)
            .with_slow_agent(0, 100.0) // 10 ms per straggler iteration
            .with_seed(3);
        let mut net = AsyncNetwork::new(g, a, m, None, ap).unwrap();
        // Clamp mid-way through the straggler's first iteration: its
        // neighbors sit gated with no combine landed to charge the
        // completed counter.
        let done = net.run_clamped(&dict, &task, &x, params, 5_000).unwrap();
        assert!(!done);
        assert!(
            net.gate_wait_us_at(5_000) > net.gate_wait_us(),
            "in-progress waits must be visible mid-run"
        );
        net.run(&dict, &task, &x, params).unwrap();
        // Everyone finished: nobody is waiting, the two views agree.
        assert_eq!(net.gate_wait_us_at(net.sim_time_us()), net.gate_wait_us());
    }

    /// Widening τ mid-run releases gated agents deterministically and the
    /// staleness invariant holds against the widest bound used; two
    /// identically-scheduled runs replay bit-identically.
    #[test]
    fn set_tau_mid_run_is_deterministic() {
        let (n, m, iters) = (8, 5, 80);
        let (dict, g, a, x) = problem(n, m, 0xA5_11, &Topology::Ring { k: 1 });
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let params = DiffusionParams::new(0.25, iters);
        let ap = AsyncParams::default()
            .with_tau(0)
            .with_delays(DelayDist::Exp { mean_us: 80.0 }, DelayDist::Exp { mean_us: 15.0 })
            .with_slow_agent(2, 8.0)
            .with_seed(91);
        let run_schedule = |taus: &[usize]| {
            let mut net = AsyncNetwork::new(g.clone(), a.clone(), m, None, ap.clone()).unwrap();
            let mut t = 0u64;
            for &tau in taus {
                t += 3_000;
                if net.run_clamped(&dict, &task, &x, params, t).unwrap() {
                    break;
                }
                net.set_tau(tau, &task, t);
            }
            net.run(&dict, &task, &x, params).unwrap();
            net
        };
        let n1 = run_schedule(&[1, 2, 3, 2, 4]);
        let n2 = run_schedule(&[1, 2, 3, 2, 4]);
        for k in 0..n {
            assert_eq!(n1.nu(k), n2.nu(k), "agent {k}");
        }
        assert_eq!(n1.stats(), n2.stats());
        assert_eq!(n1.sim_time_us(), n2.sim_time_us());
        assert_eq!(n1.gate_wait_us(), n2.gate_wait_us());
        assert_eq!(n1.tau(), 4);
        assert!(n1.max_staleness_observed() <= 4, "staleness bounded by the widest τ");
        for k in 0..n {
            assert_eq!(n1.iters_done(k), iters);
        }
    }

    /// The drifting straggler rotates the slow identity on schedule and
    /// stays seed-reproducible.
    #[test]
    fn drifting_straggler_rotates_and_replays() {
        let (n, m, iters) = (6, 4, 120);
        let (dict, g, a, x) = problem(n, m, 0xA5_12, &Topology::Ring { k: 1 });
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let params = DiffusionParams::new(0.2, iters);
        let ap = AsyncParams::default()
            .with_tau(3)
            .with_delays(DelayDist::Constant { us: 100 }, DelayDist::Zero)
            .with_drift(5_000, 10.0)
            .with_seed(7);
        let mut n1 = AsyncNetwork::new(g.clone(), a.clone(), m, None, ap.clone()).unwrap();
        n1.run(&dict, &task, &x, params).unwrap();
        let mut n2 = AsyncNetwork::new(g, a, m, None, ap).unwrap();
        n2.run(&dict, &task, &x, params).unwrap();
        for k in 0..n {
            assert_eq!(n1.nu(k), n2.nu(k), "agent {k}");
        }
        assert_eq!(n1.sim_time_us(), n2.sim_time_us());
        // With constant 100 µs compute and a 10x drifting slowdown the
        // run must outlast the all-fast schedule (the rotating straggler
        // really slows someone) but stay well under the everyone-
        // always-slow bound plus chaining transients (rotation lets the
        // network burn the new straggler's accumulated lead each window).
        assert!(n1.sim_time_us() > iters as u64 * 100);
        assert!(n1.sim_time_us() < iters as u64 * 1_500);
    }

    #[test]
    fn delay_dist_parse_and_bounds() {
        assert_eq!(DelayDist::parse("zero", 10).unwrap(), DelayDist::Zero);
        assert_eq!(DelayDist::parse("const", 10).unwrap(), DelayDist::Constant { us: 10 });
        assert_eq!(
            DelayDist::parse("uniform", 100).unwrap(),
            DelayDist::Uniform { lo_us: 50, hi_us: 150 }
        );
        assert!(matches!(DelayDist::parse("exp", 20).unwrap(), DelayDist::Exp { .. }));
        assert!(DelayDist::parse("gauss", 1).is_err());
        let mut rng = Pcg64::new(1);
        for _ in 0..200 {
            let v = DelayDist::Uniform { lo_us: 5, hi_us: 9 }.sample(&mut rng);
            assert!((5..=9).contains(&v));
        }
        assert_eq!(DelayDist::Zero.sample(&mut rng), 0);
        assert_eq!(DelayDist::Constant { us: 7 }.sample(&mut rng), 7);
    }

    /// An **empty** fault schedule (even with a nonzero chaos seed) is
    /// bit-for-bit the fault-free executor: trajectories, stats, clock,
    /// and zero chaos counters.
    #[test]
    fn empty_fault_schedule_is_bitwise_fault_free() {
        let (n, m, iters) = (9, 6, 35);
        let (dict, g, a, x) = problem(n, m, 0xC4_01, &Topology::ErdosRenyi { p: 0.5 });
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let params = DiffusionParams::new(0.3, iters);
        let ap = AsyncParams::default()
            .with_tau(2)
            .with_delays(DelayDist::Exp { mean_us: 90.0 }, DelayDist::Exp { mean_us: 25.0 })
            .with_seed(55);
        let mut plain = AsyncNetwork::new(g.clone(), a.clone(), m, None, ap.clone()).unwrap();
        plain.run(&dict, &task, &x, params).unwrap();
        let mut chaos = AsyncNetwork::new(
            g,
            a,
            m,
            None,
            ap.with_chaos(FaultSchedule::new(0xDEAD_BEEF)),
        )
        .unwrap();
        chaos.run(&dict, &task, &x, params).unwrap();
        for k in 0..n {
            assert_eq!(plain.nu(k), chaos.nu(k), "agent {k}");
        }
        assert_eq!(plain.stats(), chaos.stats());
        assert_eq!(plain.sim_time_us(), chaos.sim_time_us());
        assert_eq!(chaos.chaos_stats(), ChaosStats::default());
        assert_eq!(chaos.combine_mode(), CombineMode::Metropolis);
    }

    /// A healing partition: the run completes (no stall), replays
    /// bit-identically, and the degradation counters light up.
    #[test]
    fn healing_partition_completes_and_replays() {
        let (n, m, iters) = (10, 5, 60);
        let (dict, g, a, x) = problem(n, m, 0xC4_02, &Topology::Ring { k: 1 });
        let task = TaskSpec::SparseCoding { gamma: 0.15, delta: 0.5 };
        let params = DiffusionParams::new(0.25, iters);
        let side = FaultSchedule::split_side(n, 0.4);
        let schedule = FaultSchedule::new(3).with_partition(side, 2_000, 12_000);
        let ap = AsyncParams::default()
            .with_tau(2)
            .with_delays(DelayDist::Constant { us: 100 }, DelayDist::Constant { us: 20 })
            .with_seed(8)
            .with_chaos(schedule);
        let run = || {
            let mut net =
                AsyncNetwork::new(g.clone(), a.clone(), m, None, ap.clone()).unwrap();
            net.run(&dict, &task, &x, params).unwrap();
            net
        };
        let n1 = run();
        let n2 = run();
        for k in 0..n {
            assert_eq!(n1.nu(k), n2.nu(k), "agent {k}");
            assert_eq!(n1.iters_done(k), iters);
        }
        assert_eq!(n1.stats(), n2.stats());
        assert_eq!(n1.sim_time_us(), n2.sim_time_us());
        assert_eq!(n1.chaos_stats(), n2.chaos_stats());
        let cs = n1.chaos_stats();
        assert!(
            cs.forced_combines > 0 || cs.stale_fallbacks > 0,
            "a 10 ms partition at 100 µs compute must trip the degradation path: {cs:?}"
        );
        assert!(n1.max_staleness_observed() <= 2, "τ invariant must survive chaos");
    }

    /// Crash/recovery: the agent re-joins, everyone finishes, and the
    /// crash deferral is visible.
    #[test]
    fn crash_recovery_rejoins() {
        let (n, m, iters) = (8, 4, 50);
        let (dict, g, a, x) = problem(n, m, 0xC4_03, &Topology::Ring { k: 1 });
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let params = DiffusionParams::new(0.2, iters);
        let schedule = FaultSchedule::new(1).with_crash(3, 500, 6_000);
        let ap = AsyncParams::default()
            .with_tau(3)
            .with_delays(DelayDist::Constant { us: 100 }, DelayDist::Constant { us: 10 })
            .with_chaos(schedule);
        let mut net = AsyncNetwork::new(g, a, m, None, ap).unwrap();
        net.run(&dict, &task, &x, params).unwrap();
        for k in 0..n {
            assert_eq!(net.iters_done(k), iters, "agent {k} must finish despite the crash");
        }
        assert!(net.chaos_stats().crash_deferrals > 0);
        assert!(net.sim_time_us() >= 6_000, "the crashed agent's re-join gates completion");
    }

    /// Message drops degrade but never wedge the run, and the drop coins
    /// come from a dedicated stream (replays stay bit-identical).
    #[test]
    fn drop_window_degrades_gracefully() {
        let (n, m, iters) = (8, 4, 40);
        let (dict, g, a, x) = problem(n, m, 0xC4_04, &Topology::Ring { k: 1 });
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let params = DiffusionParams::new(0.2, iters);
        let schedule = FaultSchedule::new(77).with_drops(0.4, 0, u64::MAX);
        let ap = AsyncParams::default()
            .with_tau(2)
            .with_delays(DelayDist::Constant { us: 100 }, DelayDist::Constant { us: 10 })
            .with_chaos(schedule);
        let run = || {
            let mut net =
                AsyncNetwork::new(g.clone(), a.clone(), m, None, ap.clone()).unwrap();
            net.run(&dict, &task, &x, params).unwrap();
            net
        };
        let n1 = run();
        let n2 = run();
        assert!(n1.chaos_stats().dropped > 0, "40% drops must lose messages");
        for k in 0..n {
            assert_eq!(n1.nu(k), n2.nu(k), "agent {k}");
            assert_eq!(n1.iters_done(k), iters);
        }
        assert_eq!(n1.chaos_stats(), n2.chaos_stats());
    }

    /// Directed outage auto-upgrades `Auto` → push-sum; a forced
    /// Metropolis run under the same schedule stays Metropolis. On a
    /// *symmetric* fault-free problem, forced push-sum still converges to
    /// the same dual (sanity for the corrected combine).
    #[test]
    fn pushsum_auto_select_and_fault_free_convergence() {
        let (n, m, iters) = (10, 5, 400);
        let (dict, g, a, x) = problem(n, m, 0xC4_05, &Topology::Ring { k: 2 });
        let task = TaskSpec::SparseCoding { gamma: 0.15, delta: 0.5 };
        let params = DiffusionParams::new(0.3, iters);

        // Auto + directed fault → push-sum; forced Metropolis respected.
        let directed = FaultSchedule::new(0).with_link_down(0, 1, 0, 1_000);
        let ap_auto = AsyncParams::default().with_chaos(directed.clone()).with_tau(2);
        let net = AsyncNetwork::new(g.clone(), a.clone(), m, None, ap_auto).unwrap();
        assert_eq!(net.combine_mode(), CombineMode::PushSum);
        assert!(net.auto_pushsum());
        let ap_forced = AsyncParams::default()
            .with_chaos(directed)
            .with_combine(CombineMode::Metropolis);
        let net = AsyncNetwork::new(g.clone(), a.clone(), m, None, ap_forced).unwrap();
        assert_eq!(net.combine_mode(), CombineMode::Metropolis);
        assert!(!net.auto_pushsum());

        // Fault-free forced push-sum reaches the same fixed point the
        // Metropolis combine does (not bitwise — different weights — but
        // the same dual optimum).
        let exact = crate::infer::exact_dual(&dict, &task, &x, 1e-6, 20_000).unwrap();
        let mut ps = AsyncNetwork::new(
            g,
            a,
            m,
            None,
            AsyncParams::default().with_tau(1).with_combine(CombineMode::PushSum),
        )
        .unwrap();
        ps.run(&dict, &task, &x, params).unwrap();
        let msd = ps.msd_vs(&exact.nu);
        assert!(msd < 1e-3, "fault-free push-sum should converge: msd {msd}");
    }

    /// Under edge churn the τ invariant holds for gated combines —
    /// fallback staleness is accounted separately.
    #[test]
    fn churn_respects_tau_invariant() {
        let (n, m, iters) = (12, 4, 80);
        let (dict, g, a, x) = problem(n, m, 0xC4_06, &Topology::Ring { k: 2 });
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let params = DiffusionParams::new(0.2, iters);
        let schedule =
            FaultSchedule::new(5).with_edge_churn(&g, 12, 3_000, 30_000, 0xC4_06);
        let ap = AsyncParams::default()
            .with_tau(3)
            .with_delays(DelayDist::Exp { mean_us: 80.0 }, DelayDist::Exp { mean_us: 15.0 })
            .with_seed(21)
            .with_chaos(schedule);
        let mut net = AsyncNetwork::new(g, a, m, None, ap).unwrap();
        net.run(&dict, &task, &x, params).unwrap();
        assert!(
            net.max_staleness_observed() <= 3,
            "gated staleness {} exceeded τ",
            net.max_staleness_observed()
        );
        for k in 0..n {
            assert_eq!(net.iters_done(k), iters);
        }
    }

    #[test]
    fn shape_and_config_errors() {
        let (dict, g, a, x) = problem(5, 4, 0xA5_08, &Topology::Ring { k: 1 });
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        // Out-of-range straggler.
        assert!(AsyncNetwork::new(
            g.clone(),
            a.clone(),
            4,
            None,
            AsyncParams::default().with_slow_agent(9, 2.0)
        )
        .is_err());
        // Wrong sample length.
        let mut anet = AsyncNetwork::new(g.clone(), a.clone(), 4, None, AsyncParams::default())
            .unwrap();
        assert!(anet.run(&dict, &task, &x[..3], DiffusionParams::new(0.1, 2)).is_err());
        // Resuming with a different iteration target is rejected.
        let mut anet = AsyncNetwork::new(g, a, 4, None, AsyncParams::default()).unwrap();
        anet.run(&dict, &task, &x, DiffusionParams::new(0.1, 3)).unwrap();
        assert!(anet
            .run_clamped(&dict, &task, &x, DiffusionParams::new(0.1, 4), u64::MAX)
            .is_err());
    }

    /// With zero Byzantine agents the resilient modes are deterministic:
    /// same seed ⇒ bitwise replay (trajectories, stats, clock), and the
    /// resolved mode is reported as requested.
    #[test]
    fn resilient_modes_fault_free_replay_bitwise() {
        let (n, m, iters) = (10, 5, 40);
        let task = TaskSpec::SparseCoding { gamma: 0.15, delta: 0.5 };
        let params = DiffusionParams::new(0.25, iters);
        for mode in [CombineMode::Median, CombineMode::TrimmedMean(1)] {
            let (dict, g, a, x) = problem(n, m, 0xB1_2A, &Topology::Ring { k: 2 });
            let ap = AsyncParams::default()
                .with_tau(2)
                .with_delays(DelayDist::Exp { mean_us: 70.0 }, DelayDist::Exp { mean_us: 20.0 })
                .with_seed(77)
                .with_combine(mode);
            let mut a1 = AsyncNetwork::new(g.clone(), a.clone(), m, None, ap.clone()).unwrap();
            a1.run(&dict, &task, &x, params).unwrap();
            assert_eq!(a1.combine_mode(), mode);
            let mut a2 = AsyncNetwork::new(g, a, m, None, ap).unwrap();
            a2.run(&dict, &task, &x, params).unwrap();
            for k in 0..n {
                assert_eq!(a1.nu(k), a2.nu(k), "{mode:?}: agent {k}");
            }
            assert_eq!(a1.stats(), a2.stats(), "{mode:?}");
            assert_eq!(a1.sim_time_us(), a2.sim_time_us(), "{mode:?}");
            assert_eq!(a1.chaos_stats(), ChaosStats::default(), "{mode:?}: no chaos");
        }
    }

    /// Fault-free, the trimmed mean still reaches the dual optimum: the
    /// aggregate stays a convex combination summing to one, so the
    /// consensus fixed point is unchanged (not bitwise vs Metropolis —
    /// different arithmetic — but the same ν°).
    #[test]
    fn trimmed_mean_fault_free_converges() {
        let (n, m, iters) = (12, 5, 1500);
        let (dict, g, a, x) = problem(n, m, 0xB1_2B, &Topology::Ring { k: 2 });
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let params = DiffusionParams::new(0.4, iters);
        let exact = crate::infer::exact_dual(&dict, &task, &x, 1e-6, 20_000).unwrap();
        let mut net = AsyncNetwork::new(
            g,
            a,
            m,
            None,
            AsyncParams::default().with_combine(CombineMode::TrimmedMean(1)),
        )
        .unwrap();
        net.run(&dict, &task, &x, params).unwrap();
        let msd = net.msd_vs(&exact.nu);
        assert!(msd < 1e-3, "fault-free trimmed mean should converge: msd {msd}");
    }

    /// The acceptance scenario in miniature: a sign-flip attacker biases
    /// the undefended Metropolis combine by orders of magnitude, while
    /// `TrimmedMean(1)` recovers to the clean fixed point; the attacked
    /// runs replay bitwise and the corruption counter lights up.
    #[test]
    fn sign_flip_attacker_defended_by_trimmed_mean() {
        let (n, m, iters) = (12, 5, 1500);
        let (dict, g, a, x) = problem(n, m, 0xB1_2C, &Topology::Ring { k: 2 });
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let params = DiffusionParams::new(0.4, iters);
        let exact = crate::infer::exact_dual(&dict, &task, &x, 1e-6, 20_000).unwrap();
        let schedule = FaultSchedule::new(0xB1_2C)
            .with_byzantine(3, CorruptPolicy::SignFlip, 0, u64::MAX);
        let mk = |mode: CombineMode| {
            AsyncParams::default()
                .with_tau(1)
                .with_delays(DelayDist::Constant { us: 40 }, DelayDist::Constant { us: 10 })
                .with_seed(11)
                .with_chaos(schedule.clone())
                .with_combine(mode)
        };

        let mut undefended =
            AsyncNetwork::new(g.clone(), a.clone(), m, None, mk(CombineMode::Metropolis))
                .unwrap();
        undefended.run(&dict, &task, &x, params).unwrap();
        let msd_undefended = undefended.msd_vs(&exact.nu);
        assert!(undefended.chaos_stats().corrupted > 0, "attacker transmitted lies");

        let mut defended =
            AsyncNetwork::new(g.clone(), a.clone(), m, None, mk(CombineMode::TrimmedMean(1)))
                .unwrap();
        defended.run(&dict, &task, &x, params).unwrap();
        let msd_defended = defended.msd_vs(&exact.nu);

        assert!(
            !msd_undefended.is_finite() || msd_undefended > 10.0 * msd_defended.max(1e-12),
            "attack must bias the undefended run: undefended {msd_undefended:.3e} vs \
             defended {msd_defended:.3e}"
        );
        assert!(
            msd_defended < 1e-2,
            "trimmed mean must hold near the clean optimum: {msd_defended:.3e}"
        );

        // Replay: the attacked run is a pure function of its seed.
        let mut replay =
            AsyncNetwork::new(g, a, m, None, mk(CombineMode::TrimmedMean(1))).unwrap();
        replay.run(&dict, &task, &x, params).unwrap();
        for k in 0..n {
            assert_eq!(defended.nu(k), replay.nu(k), "agent {k}");
        }
        assert_eq!(defended.chaos_stats(), replay.chaos_stats());
        assert_eq!(defended.sim_time_us(), replay.sim_time_us());
    }

    /// Scaled-noise corruption draws from the dedicated chaos stream
    /// only: a schedule whose Byzantine window has expired leaves the
    /// trajectory identical to a schedule with no Byzantine fault at all
    /// past the window (same delay-stream consumption).
    #[test]
    fn expired_byzantine_window_consumes_no_randomness() {
        let (n, m, iters) = (8, 4, 40);
        let (dict, g, a, x) = problem(n, m, 0xB1_2D, &Topology::Ring { k: 1 });
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let params = DiffusionParams::new(0.3, iters);
        // Window [0, 1): closed before the first adapt completes under
        // nonzero compute delays — no message is ever corrupted.
        let sched_expired = FaultSchedule::new(7).with_byzantine(
            2,
            CorruptPolicy::ScaledNoise { sigma: 2.0 },
            0,
            1,
        );
        // A crash window far past completion: same chaos_active=true
        // footprint (gate timeouts scheduled), different fault list.
        let sched_inert = FaultSchedule::new(7).with_crash(2, u64::MAX - 2, u64::MAX - 1);
        let mk = |s: FaultSchedule| {
            AsyncParams::default()
                .with_tau(1)
                .with_delays(DelayDist::Constant { us: 50 }, DelayDist::Constant { us: 10 })
                .with_seed(13)
                .with_chaos(s)
        };
        let mut a1 = AsyncNetwork::new(g.clone(), a.clone(), m, None, mk(sched_expired)).unwrap();
        a1.run(&dict, &task, &x, params).unwrap();
        assert_eq!(a1.chaos_stats().corrupted, 0, "window closed before any send");
        let mut a2 = AsyncNetwork::new(g, a, m, None, mk(sched_inert)).unwrap();
        a2.run(&dict, &task, &x, params).unwrap();
        for k in 0..n {
            assert_eq!(a1.nu(k), a2.nu(k), "agent {k}");
        }
        assert_eq!(a1.stats(), a2.stats());
        assert_eq!(a1.sim_time_us(), a2.sim_time_us());
    }

    /// Detection contract, zero-attacker side: arming the detector on a
    /// run with no Byzantine fault is bitwise inert — same trajectories,
    /// same stats, same clock as detection-off — and no honest agent is
    /// ever flagged or excluded (zero false positives).
    #[test]
    fn detection_zero_attacker_is_bitwise_inert() {
        let (n, m, iters) = (12, 5, 600);
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let params = DiffusionParams::new(0.3, iters);
        let mk = |detect: DetectionConfig| {
            AsyncParams::default()
                .with_tau(2)
                .with_delays(DelayDist::Exp { mean_us: 60.0 }, DelayDist::Exp { mean_us: 15.0 })
                .with_seed(31)
                .with_combine(CombineMode::TrimmedMean(1))
                .with_detect(detect)
        };
        let (dict, g, a, x) = problem(n, m, 0xDE_7E, &Topology::Ring { k: 2 });
        let mut off = AsyncNetwork::new(g.clone(), a.clone(), m, None, mk(DetectionConfig::default()))
            .unwrap();
        off.run(&dict, &task, &x, params).unwrap();
        let mut on = AsyncNetwork::new(g, a, m, None, mk(DetectionConfig::armed())).unwrap();
        on.run(&dict, &task, &x, params).unwrap();
        for k in 0..n {
            assert_eq!(off.nu(k), on.nu(k), "agent {k}: detection must not perturb the run");
        }
        assert_eq!(off.stats(), on.stats());
        assert_eq!(off.sim_time_us(), on.sim_time_us());
        assert_eq!(on.chaos_stats().flagged, 0, "no honest agent may be flagged");
        assert_eq!(on.chaos_stats().detect_excluded, 0, "no honest agent may be excluded");
        assert!(on.flagged_suspects().is_empty());
        assert!(on.excluded_suspects().is_empty());
    }

    /// Detection contract, attacker side: a persistent sign-flip attacker
    /// is flagged and excluded by its neighbors, only the attacker is
    /// suspected, the post-exclusion MSD approaches the clean defended
    /// fixed point, and the detection run replays bit-identically.
    #[test]
    fn detection_excludes_sign_flip_attacker_and_replays() {
        let (n, m, iters) = (12, 5, 1500);
        let (dict, g, a, x) = problem(n, m, 0xDE_7F, &Topology::Ring { k: 2 });
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let params = DiffusionParams::new(0.4, iters);
        let exact = crate::infer::exact_dual(&dict, &task, &x, 1e-6, 20_000).unwrap();
        let schedule = FaultSchedule::new(0xDE_7F)
            .with_byzantine(3, CorruptPolicy::SignFlip, 0, u64::MAX);
        let mk = || {
            AsyncParams::default()
                .with_tau(1)
                .with_delays(DelayDist::Constant { us: 40 }, DelayDist::Constant { us: 10 })
                .with_seed(17)
                .with_chaos(schedule.clone())
                .with_combine(CombineMode::TrimmedMean(1))
                .with_detect(DetectionConfig::armed())
        };
        let mut net = AsyncNetwork::new(g.clone(), a.clone(), m, None, mk()).unwrap();
        net.run(&dict, &task, &x, params).unwrap();
        assert_eq!(net.excluded_suspects(), vec![3], "exactly the attacker is excluded");
        assert!(net.flagged_suspects().contains(&3), "the attacker is flagged");
        assert!(net.chaos_stats().flagged > 0);
        assert!(net.chaos_stats().detect_excluded > 0);
        let msd = net.msd_vs(&exact.nu);
        assert!(msd < 1e-2, "post-exclusion MSD should be near the clean optimum: {msd:.3e}");

        let mut replay = AsyncNetwork::new(g, a, m, None, mk()).unwrap();
        replay.run(&dict, &task, &x, params).unwrap();
        for k in 0..n {
            assert_eq!(net.nu(k), replay.nu(k), "agent {k}");
        }
        assert_eq!(net.chaos_stats(), replay.chaos_stats());
        assert_eq!(net.sim_time_us(), replay.sim_time_us());
    }

    /// Probation: when the Byzantine window closes before the run ends and
    /// probation is armed, the excluded (now honest) agent is re-admitted
    /// and participates again — the readmission counter lights up and no
    /// exclusion is left standing at the end.
    #[test]
    fn detection_probation_readmits_reformed_agent() {
        let (n, m, iters) = (12, 5, 1200);
        let (dict, g, a, x) = problem(n, m, 0xDE_80, &Topology::Ring { k: 2 });
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let params = DiffusionParams::new(0.4, iters);
        // Constant 40+10 µs steps ⇒ one iteration ≈ 50 µs; attack for the
        // first ~300 iterations, probation 5 000 µs ≈ 100 iterations.
        let schedule = FaultSchedule::new(0xDE_80)
            .with_byzantine(5, CorruptPolicy::SignFlip, 0, 15_000);
        let detect = DetectionConfig { probation_us: 5_000, ..DetectionConfig::armed() };
        let ap = AsyncParams::default()
            .with_tau(1)
            .with_delays(DelayDist::Constant { us: 40 }, DelayDist::Constant { us: 10 })
            .with_seed(19)
            .with_chaos(schedule)
            .with_combine(CombineMode::TrimmedMean(1))
            .with_detect(detect);
        let mut net = AsyncNetwork::new(g, a, m, None, ap).unwrap();
        net.run(&dict, &task, &x, params).unwrap();
        assert!(net.chaos_stats().detect_excluded > 0, "attacker was excluded");
        assert!(net.chaos_stats().readmitted > 0, "probation re-admitted it");
        assert!(
            net.excluded_suspects().is_empty(),
            "no exclusion left standing once the agent reforms: {:?}",
            net.excluded_suspects()
        );
    }
}
