//! Message types and traffic accounting for the simulated network.
//!
//! ## Round-accounting convention
//!
//! One **round** is one network-wide ψ exchange — i.e. one combine step of
//! the diffusion recursion. Every executor that moves ψ between agents
//! must bump `rounds` exactly once per diffusion iteration, regardless of
//! how agents are multiplexed onto threads: the BSP executor
//! ([`crate::net::BspNetwork`]) after each exchange/combine, the actor
//! executor ([`crate::net::actors::run_threaded`]) once per iteration even
//! though only *cross-worker* edges travel over channels, the async
//! executor ([`crate::net::AsyncNetwork`]) once per completed
//! network-wide wave (minimum per-agent combine count), and the serving
//! session ([`crate::serve::run_service`]) once per iteration per drained
//! batch. This keeps [`MessageStats::bytes_per_agent_round`] comparable
//! across executors.
//!
//! The convention is runnable: on a tiny ring where every edge crosses a
//! worker boundary, the BSP and actor executors must agree on `rounds`
//! and on bytes per agent per round exactly.
//!
//! ```
//! use ddl::graph::{metropolis_weights, Graph, Topology};
//! use ddl::infer::DiffusionParams;
//! use ddl::model::{AtomConstraint, DistributedDictionary, TaskSpec};
//! use ddl::net::{actors, BspNetwork};
//! use ddl::rng::Pcg64;
//!
//! let (n, m, iters) = (6, 5, 4);
//! let mut rng = Pcg64::new(7);
//! let dict = DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng)?;
//! let g = Graph::generate(n, &Topology::Ring { k: 1 }, &mut rng);
//! let a = metropolis_weights(&g);
//! let x = rng.normal_vec(m);
//! let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
//!
//! // BSP: one ψ per directed edge per round.
//! let mut bsp = BspNetwork::new(g.clone(), a.clone(), m, None);
//! bsp.run(&dict, &task, &x, DiffusionParams::new(0.2, iters))?;
//!
//! // Actors, one thread per agent: every edge crosses a worker boundary,
//! // so channel traffic equals the BSP wire traffic.
//! let (_, actor_stats) = actors::run_threaded(
//!     &g, &a, &dict, &task, &x, None,
//!     DiffusionParams::new(0.2, iters).with_threads(n),
//! )?;
//!
//! assert_eq!(bsp.stats().rounds, iters);
//! assert_eq!(actor_stats.rounds, iters);
//! assert_eq!(bsp.stats().messages, actor_stats.messages);
//! assert_eq!(
//!     bsp.stats().bytes_per_agent_round(n),
//!     actor_stats.bytes_per_agent_round(n),
//! );
//! # Ok::<(), ddl::DdlError>(())
//! ```

/// One diffusion message: agent `from`'s intermediate estimate ψ for
/// iteration `iter`. This is the *only* payload agents ever exchange —
/// `M` floats per neighbor per iteration (`B·M` when a minibatch diffuses
/// in one sweep); atoms `W_k` and coefficients `y_k` never leave their
/// agent (the paper's privacy property).
#[derive(Clone, Debug)]
pub struct PsiMessage {
    pub from: usize,
    pub iter: usize,
    pub psi: Vec<f32>,
}

/// Wire-size of a ψ message header (`from` + `iter` as u64).
pub const WIRE_HEADER_BYTES: usize = 2 * std::mem::size_of::<u64>();

/// Wire size of a ψ payload of `floats` f32 entries, including the header.
pub fn wire_bytes_for(floats: usize) -> usize {
    WIRE_HEADER_BYTES + floats * std::mem::size_of::<f32>()
}

impl PsiMessage {
    /// Wire size in bytes (header + payload), for traffic accounting.
    pub fn wire_bytes(&self) -> usize {
        wire_bytes_for(self.psi.len())
    }
}

/// Cumulative traffic statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MessageStats {
    pub messages: usize,
    pub bytes: usize,
    pub rounds: usize,
}

impl MessageStats {
    pub fn record(&mut self, msg: &PsiMessage) {
        self.messages += 1;
        self.bytes += msg.wire_bytes();
    }

    /// Record `count` messages of `floats` f32 payload each without
    /// materializing them (bulk accounting for the batched serving path).
    pub fn record_exchange(&mut self, count: usize, floats: usize) {
        self.messages += count;
        self.bytes += count * wire_bytes_for(floats);
    }

    /// Mark one completed exchange round (see the module convention).
    pub fn end_round(&mut self) {
        self.rounds += 1;
    }

    /// Bulk variant of [`Self::end_round`].
    pub fn add_rounds(&mut self, rounds: usize) {
        self.rounds += rounds;
    }

    /// Merge another executor's counters: traffic adds up, rounds take the
    /// maximum (workers of one executor share the same exchange rounds —
    /// summing would double-count them).
    pub fn merge(&mut self, other: &MessageStats) {
        self.messages += other.messages;
        self.bytes += other.bytes;
        self.rounds = self.rounds.max(other.rounds);
    }

    /// Average bytes per agent per round.
    pub fn bytes_per_agent_round(&self, agents: usize) -> f64 {
        if self.rounds == 0 || agents == 0 {
            return 0.0;
        }
        self.bytes as f64 / (self.rounds as f64 * agents as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_bytes_counts_payload() {
        let m = PsiMessage { from: 0, iter: 3, psi: vec![0.0; 10] };
        assert_eq!(m.wire_bytes(), 16 + 40);
        assert_eq!(wire_bytes_for(10), m.wire_bytes());
    }

    #[test]
    fn stats_accumulate() {
        let mut s = MessageStats::default();
        let m = PsiMessage { from: 1, iter: 0, psi: vec![0.0; 4] };
        s.record(&m);
        s.record(&m);
        s.add_rounds(2);
        assert_eq!(s.messages, 2);
        assert_eq!(s.bytes, 2 * (16 + 16));
        assert!((s.bytes_per_agent_round(1) - 32.0).abs() < 1e-12);
    }

    /// `bytes_per_agent_round` on a degree-`d` exchange must equal
    /// `d · wire_bytes(M)` independent of how many rounds ran: every agent
    /// receives `d` neighbor messages per round.
    #[test]
    fn bytes_per_agent_round_matches_degree() {
        let (n, deg, m_dim) = (10usize, 2usize, 7usize);
        let mut s = MessageStats::default();
        for _ in 0..13 {
            // One round: every agent sends ψ to each of its `deg` neighbors.
            s.record_exchange(n * deg, m_dim);
            s.end_round();
        }
        assert_eq!(s.rounds, 13);
        assert_eq!(s.messages, 13 * n * deg);
        let expect = (deg * wire_bytes_for(m_dim)) as f64;
        assert!((s.bytes_per_agent_round(n) - expect).abs() < 1e-9);
        // Zero denominators are safe.
        assert_eq!(MessageStats::default().bytes_per_agent_round(n), 0.0);
        assert_eq!(s.bytes_per_agent_round(0), 0.0);
    }

    #[test]
    fn merge_sums_traffic_but_not_rounds() {
        let mut a = MessageStats { messages: 3, bytes: 300, rounds: 5 };
        let b = MessageStats { messages: 2, bytes: 200, rounds: 5 };
        a.merge(&b);
        assert_eq!(a.messages, 5);
        assert_eq!(a.bytes, 500);
        assert_eq!(a.rounds, 5, "workers share rounds; merge must not double-count");
    }
}
