//! Message types and traffic accounting for the simulated network.

/// One diffusion message: agent `from`'s intermediate estimate ψ for
/// iteration `iter`. This is the *only* payload agents ever exchange —
/// `M` floats per neighbor per iteration; atoms `W_k` and coefficients
/// `y_k` never leave their agent (the paper's privacy property).
#[derive(Clone, Debug)]
pub struct PsiMessage {
    pub from: usize,
    pub iter: usize,
    pub psi: Vec<f32>,
}

impl PsiMessage {
    /// Wire size in bytes (header + payload), for traffic accounting.
    pub fn wire_bytes(&self) -> usize {
        2 * std::mem::size_of::<u64>() + self.psi.len() * std::mem::size_of::<f32>()
    }
}

/// Cumulative traffic statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MessageStats {
    pub messages: usize,
    pub bytes: usize,
    pub rounds: usize,
}

impl MessageStats {
    pub fn record(&mut self, msg: &PsiMessage) {
        self.messages += 1;
        self.bytes += msg.wire_bytes();
    }

    /// Average bytes per agent per round.
    pub fn bytes_per_agent_round(&self, agents: usize) -> f64 {
        if self.rounds == 0 || agents == 0 {
            return 0.0;
        }
        self.bytes as f64 / (self.rounds as f64 * agents as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_bytes_counts_payload() {
        let m = PsiMessage { from: 0, iter: 3, psi: vec![0.0; 10] };
        assert_eq!(m.wire_bytes(), 16 + 40);
    }

    #[test]
    fn stats_accumulate() {
        let mut s = MessageStats::default();
        let m = PsiMessage { from: 1, iter: 0, psi: vec![0.0; 4] };
        s.record(&m);
        s.record(&m);
        s.rounds = 2;
        assert_eq!(s.messages, 2);
        assert_eq!(s.bytes, 2 * (16 + 16));
        assert!((s.bytes_per_agent_round(1) - 32.0).abs() < 1e-12);
    }
}
