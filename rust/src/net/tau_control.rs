//! Staleness-adaptive τ: the feedback controller that closes the loop the
//! async executor already measures.
//!
//! [`crate::net::AsyncNetwork`] exposes two opposing signals:
//!
//! * **gate-wait time** ([`crate::net::AsyncNetwork::gate_wait_us`]) —
//!   when it dominates simulated time, agents sit at the staleness gate
//!   and a wider τ would convert waiting into progress;
//! * **MSD drift versus a τ = 0 probe** — a second executor instance run
//!   at τ = 0 under the identical delay model (free to build: the sync
//!   comparator of every straggler experiment). When the adaptive run's
//!   MSD at equal simulated time falls *behind* the probe's by more than
//!   a bound, staleness is hurting accuracy faster than asynchrony is
//!   buying time, and τ must narrow.
//!
//! [`TauController::decide`] turns those two signals into a ±1 move per
//! control epoch, clamped to `[tau_min, tau_max]` — narrow wins over
//! widen when both fire (accuracy first). Every decision is a pure
//! function of (config, the executor's deterministic measurements), so an
//! adaptive run replays bit-identically for a given seed; the decision
//! trace is recorded for the replay test
//! (`tests/control_adaptive.rs`). The driver loop that steps the
//! adaptive and probe executors through shared sim-time epochs lives in
//! [`crate::coordinator::run_adaptive_tau`] (`ddl async --adaptive-tau`);
//! the serve-side controllers it mirrors live in
//! [`crate::serve::control`].

use crate::config::experiment::ControlConfig;

/// One τ-controller decision, recorded per control epoch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TauDecision {
    /// Simulated time of the decision (µs, the epoch boundary).
    pub t_us: u64,
    /// τ in effect after the decision.
    pub tau: usize,
    /// Gate-wait fraction of the epoch's simulated time (per agent).
    pub gate_wait_frac: f64,
    /// Relative MSD excess of the adaptive run over the τ = 0 probe
    /// (0 when the adaptive run is at least as converged).
    pub msd_drift: f64,
    /// Whether a network partition was reported active for this epoch
    /// (see [`TauController::observe_partition`]).
    pub partition: bool,
}

/// The ±1-per-epoch staleness controller (see the module docs).
pub struct TauController {
    tau_min: usize,
    tau_max: usize,
    gate_wait_hi: f64,
    msd_drift_bound: f64,
    last_t_us: u64,
    last_gate_wait_us: u64,
    partition_active: bool,
    trace: Vec<TauDecision>,
}

impl TauController {
    /// Controller from the `[control]` block.
    pub fn new(cfg: &ControlConfig) -> Self {
        TauController {
            tau_min: cfg.tau_min,
            tau_max: cfg.tau_max.max(cfg.tau_min),
            gate_wait_hi: cfg.gate_wait_hi,
            msd_drift_bound: cfg.msd_drift_bound,
            last_t_us: 0,
            last_gate_wait_us: 0,
            partition_active: false,
            trace: Vec::new(),
        }
    }

    /// A starting τ clamped into the controller's bounds.
    pub fn initial_tau(&self, tau: usize) -> usize {
        tau.clamp(self.tau_min, self.tau_max)
    }

    /// Partition-event hook from the chaos layer
    /// ([`crate::net::chaos::FaultSchedule::partition_active`]): while a
    /// partition is reported active, MSD drift against the fault-free
    /// probe measures the *fault*, not staleness, so the narrow branch of
    /// [`TauController::decide`] is suppressed — narrowing τ cannot
    /// reconnect a cut graph, it only stalls the survivors harder. The
    /// flag is sticky until the next call reports the heal. Calling it is
    /// optional; drivers without a chaos layer never do and the
    /// controller behaves exactly as before.
    pub fn observe_partition(&mut self, active: bool) {
        self.partition_active = active;
    }

    /// One control-epoch decision at simulated time `t_us`:
    /// `gate_wait_total_us` is the executor's cumulative
    /// [`crate::net::AsyncNetwork::gate_wait_us_at`] snapshot at `t_us`
    /// (in-progress waits included, so fully-starved epochs register
    /// immediately; the controller differences it against the previous
    /// epoch itself), `msd_adaptive` / `msd_probe` the two executors'
    /// MSD at this epoch boundary. Returns the τ to run the next epoch
    /// at (possibly unchanged) and records the decision.
    pub fn decide(
        &mut self,
        t_us: u64,
        agents: usize,
        gate_wait_total_us: u64,
        msd_adaptive: f64,
        msd_probe: f64,
        cur_tau: usize,
    ) -> usize {
        let span_us = t_us.saturating_sub(self.last_t_us).max(1) * agents.max(1) as u64;
        let waited = gate_wait_total_us.saturating_sub(self.last_gate_wait_us);
        let gate_wait_frac = waited as f64 / span_us as f64;
        self.last_t_us = t_us;
        self.last_gate_wait_us = gate_wait_total_us;
        let msd_drift = if msd_probe > 0.0 {
            ((msd_adaptive - msd_probe) / msd_probe).max(0.0)
        } else {
            0.0
        };
        let tau = if msd_drift > self.msd_drift_bound && !self.partition_active {
            // Accuracy first: staleness is visibly hurting convergence.
            cur_tau.saturating_sub(1).max(self.tau_min)
        } else if gate_wait_frac > self.gate_wait_hi {
            (cur_tau + 1).min(self.tau_max)
        } else {
            cur_tau
        };
        self.trace.push(TauDecision {
            t_us,
            tau,
            gate_wait_frac,
            msd_drift,
            partition: self.partition_active,
        });
        tau
    }

    /// The decision trace so far.
    pub fn trace(&self) -> &[TauDecision] {
        &self.trace
    }

    /// Tear down, keeping the decision trace.
    pub fn into_trace(self) -> Vec<TauDecision> {
        self.trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ControlConfig {
        ControlConfig {
            tau_min: 0,
            tau_max: 8,
            gate_wait_hi: 0.25,
            msd_drift_bound: 0.5,
            ..ControlConfig::default()
        }
    }

    #[test]
    fn widens_on_gate_wait_and_narrows_on_drift() {
        let mut ctl = TauController::new(&cfg());
        // Epoch 1: heavy gate wait (50% of 10 agents x 1000 µs), no drift.
        let tau = ctl.decide(1_000, 10, 5_000, 1e-3, 1e-3, 2);
        assert_eq!(tau, 3);
        // Epoch 2: light wait -> hold.
        let tau = ctl.decide(2_000, 10, 5_500, 1e-3, 1e-3, tau);
        assert_eq!(tau, 3);
        // Epoch 3: adaptive MSD 2x the probe -> narrow, even though the
        // wait signal also fires (accuracy first).
        let tau = ctl.decide(3_000, 10, 15_000, 2e-3, 1e-3, tau);
        assert_eq!(tau, 2);
        let tr = ctl.trace();
        assert_eq!(tr.len(), 3);
        assert!((tr[0].gate_wait_frac - 0.5).abs() < 1e-12);
        assert!((tr[2].msd_drift - 1.0).abs() < 1e-12);
        assert_eq!(tr[2].tau, 2);
    }

    #[test]
    fn clamps_to_bounds_and_clamps_initial() {
        let c = ControlConfig { tau_min: 1, tau_max: 3, ..cfg() };
        let mut ctl = TauController::new(&c);
        assert_eq!(ctl.initial_tau(9), 3);
        assert_eq!(ctl.initial_tau(0), 1);
        // Widen at the ceiling holds.
        assert_eq!(ctl.decide(1_000, 4, 4_000, 1e-3, 1e-3, 3), 3);
        // Narrow at the floor holds.
        assert_eq!(ctl.decide(2_000, 4, 4_000, 9.0, 1e-3, 1), 1);
    }

    #[test]
    fn drift_is_one_sided_and_probe_zero_safe() {
        let mut ctl = TauController::new(&cfg());
        // Adaptive ahead of the probe: drift clamps to 0, no narrow.
        let tau = ctl.decide(1_000, 10, 0, 1e-4, 1e-3, 4);
        assert_eq!(tau, 4);
        assert_eq!(ctl.trace()[0].msd_drift, 0.0);
        // Zero-probe MSD (degenerate) never divides by zero.
        let tau = ctl.decide(2_000, 10, 0, 1.0, 0.0, tau);
        assert_eq!(tau, 4);
    }

    #[test]
    fn partition_hook_suppresses_narrow_until_heal() {
        let mut ctl = TauController::new(&cfg());
        // Partition reported: heavy drift would normally narrow, but the
        // drift is the fault's doing — hold (and still widen on wait).
        ctl.observe_partition(true);
        let tau = ctl.decide(1_000, 10, 0, 9.0, 1e-3, 4);
        assert_eq!(tau, 4, "narrow suppressed during partition");
        assert!(ctl.trace()[0].partition);
        let tau = ctl.decide(2_000, 10, 5_000, 9.0, 1e-3, tau);
        assert_eq!(tau, 5, "gate-wait widening still active during partition");
        // Healed: the same drift now narrows again.
        ctl.observe_partition(false);
        let tau = ctl.decide(3_000, 10, 5_000, 9.0, 1e-3, tau);
        assert_eq!(tau, 4, "narrow resumes after heal");
        assert!(!ctl.trace()[2].partition);
    }

    #[test]
    fn gate_wait_is_differenced_per_epoch() {
        let mut ctl = TauController::new(&cfg());
        // Cumulative 3000 over epoch of 10 x 1000 -> 0.3 > 0.25: widen.
        assert_eq!(ctl.decide(1_000, 10, 3_000, 1e-3, 1e-3, 0), 1);
        // No *new* wait in epoch 2: fraction 0, hold (not re-counted).
        assert_eq!(ctl.decide(2_000, 10, 3_000, 1e-3, 1e-3, 1), 1);
        assert_eq!(ctl.trace()[1].gate_wait_frac, 0.0);
    }
}
