//! Simulated distributed runtime.
//!
//! The matrix-form engine in [`crate::infer::diffusion`] computes the
//! combine step as one gemm — fast, but it hides the message-passing
//! structure. This module makes the distribution *real*: agents with
//! mailboxes exchange `ψ` vectors along graph edges only, with message
//! and byte accounting, in three executors:
//!
//! * [`bsp`] — deterministic bulk-synchronous rounds (used by tests to
//!   prove equivalence with the gemm engine, and by the drivers when
//!   accounting is wanted);
//! * [`actors`] — worker threads with channels (one or more agents per
//!   thread, capped by `DiffusionParams::threads`), demonstrating that the
//!   algorithm runs on a genuinely concurrent substrate;
//! * [`async_exec`] — asynchronous per-edge exchange with bounded
//!   staleness `τ` on a deterministic discrete-event clock, modeling
//!   stragglers (slow agents, slow links, heterogeneous compute); at
//!   `τ = 0` it degenerates bit-for-bit to the BSP trajectory.
//!
//! All three bump the same [`MessageStats`] under the round-accounting
//! convention documented (and doc-tested) in [`message`], so sync-vs-async
//! traffic and convergence are directly comparable.
//!
//! The [`chaos`] module is the deterministic fault-injection layer over
//! [`async_exec`]: edge churn (independent or Gilbert–Elliott bursty),
//! healing partitions, directed outages, message drops, agent
//! crash/recovery, and Byzantine corruption windows, every event a pure
//! function of (seed, sim-time) — an empty schedule degenerates
//! bit-for-bit to the fault-free trajectory, directed faults auto-select
//! the push-sum–corrected combine, and corrupted-ψ attacks are defended
//! by the opt-in resilient combine (`CombineMode::Median` /
//! `TrimmedMean`, `ddl chaos --byzantine`).
//!
//! The [`pool`] module provides the shared scoped-thread worker pool that
//! both the matrix-form engine and the scalar cost-consensus use for
//! row-partitioned parallelism, and [`tau_control`] the staleness-τ
//! feedback controller (`ddl async --adaptive-tau`) that widens τ when
//! gate-wait time dominates and narrows it when MSD drifts from a τ = 0
//! probe.
//!
//! The full executor matrix — which executor to reach for, what each one
//! proves, and the ψ-privacy dataflow they all share — is laid out in
//! `ARCHITECTURE.md` at the repository root.

pub mod actors;
pub mod async_exec;
pub mod bsp;
pub mod chaos;
pub mod message;
pub mod pool;
pub mod tau_control;

pub use async_exec::{AsyncNetwork, AsyncParams, DelayDist};
pub use bsp::BspNetwork;
pub use chaos::{
    ChaosPolicy, ChaosStats, CombineMode, CorruptPolicy, DetectionConfig, Fault, FaultSchedule,
};
pub use message::{MessageStats, PsiMessage};
pub use pool::{chunk_range, PersistentPool, SharedRows, WorkerPool};
pub use tau_control::{TauController, TauDecision};
