//! Simulated distributed runtime.
//!
//! The matrix-form engine in [`crate::infer::diffusion`] computes the
//! combine step as one gemm — fast, but it hides the message-passing
//! structure. This module makes the distribution *real*: agents with
//! mailboxes exchange `ψ` vectors along graph edges only, with message
//! and byte accounting, in two executors:
//!
//! * [`bsp`] — deterministic bulk-synchronous rounds (used by tests to
//!   prove equivalence with the gemm engine, and by the drivers when
//!   accounting is wanted);
//! * [`actors`] — worker threads with channels (one or more agents per
//!   thread, capped by `DiffusionParams::threads`), demonstrating that the
//!   algorithm runs on a genuinely concurrent substrate.
//!
//! The [`pool`] module provides the shared scoped-thread worker pool that
//! both the matrix-form engine and the scalar cost-consensus use for
//! row-partitioned parallelism.

pub mod actors;
pub mod bsp;
pub mod message;
pub mod pool;

pub use bsp::BspNetwork;
pub use message::{MessageStats, PsiMessage};
pub use pool::{chunk_range, PersistentPool, SharedRows, WorkerPool};
