//! Simulated distributed runtime.
//!
//! The matrix-form engine in [`crate::infer::diffusion`] computes the
//! combine step as one gemm — fast, but it hides the message-passing
//! structure. This module makes the distribution *real*: agents with
//! mailboxes exchange `ψ` vectors along graph edges only, with message
//! and byte accounting, in two executors:
//!
//! * [`bsp`] — deterministic bulk-synchronous rounds (used by tests to
//!   prove equivalence with the gemm engine, and by the drivers when
//!   accounting is wanted);
//! * [`actors`] — one OS thread per agent with channels, demonstrating
//!   that the algorithm runs on a genuinely concurrent substrate.

pub mod actors;
pub mod bsp;
pub mod message;

pub use bsp::BspNetwork;
pub use message::{MessageStats, PsiMessage};
