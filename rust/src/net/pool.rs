//! Scoped-thread worker pool for row-partitioned SPMD loops.
//!
//! The diffusion hot loops are embarrassingly parallel over agents: adapt
//! writes row `k` of `Ψ` reading only row `k` of `V`, and combine writes
//! row `k` of `V` reading all of `Ψ`. This module provides the three
//! pieces the engine (and `scalar_consensus`) need to exploit that without
//! external dependencies:
//!
//! * [`WorkerPool`] — spawns `threads − 1` scoped workers plus the calling
//!   thread and runs one closure per worker. Iteration loops live *inside*
//!   the closure with a [`std::sync::Barrier`] per phase, so threads are
//!   spawned once per `run()`, not once per iteration.
//! * [`chunk_range`] — the deterministic row partition. Work is split by
//!   static ranges (never work-stealing) so each row is computed by exactly
//!   one worker with the same per-row arithmetic as the serial path —
//!   results are bit-identical for every thread count.
//! * [`SharedRows`] — an unsafe-but-small escape hatch that lets workers
//!   hold disjoint mutable row windows of one buffer across barrier phases,
//!   which safe borrows cannot express.

use std::marker::PhantomData;
use std::ops::Range;

/// Deterministic contiguous partition: range of `idx` (0-based) among
/// `parts` near-equal chunks of `total` items. Leading chunks take the
/// remainder, so sizes differ by at most one.
pub fn chunk_range(total: usize, parts: usize, idx: usize) -> Range<usize> {
    debug_assert!(parts > 0 && idx < parts);
    let base = total / parts;
    let rem = total % parts;
    let start = idx * base + idx.min(rem);
    let len = base + usize::from(idx < rem);
    start..start + len
}

/// A reusable handle describing how many workers an SPMD region runs on.
///
/// Workers are scoped threads: they borrow the caller's data and are joined
/// before the method returns, so no `'static` bounds or `Arc` plumbing leak
/// into call sites.
#[derive(Clone, Copy, Debug)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// A pool of `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> Self {
        WorkerPool { threads: threads.max(1) }
    }

    /// Worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(worker_id)` on every worker; `worker_id` ∈ `0..threads`.
    /// Worker 0 executes on the calling thread. Returns after all workers
    /// finish.
    pub fn spmd<F>(&self, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if self.threads == 1 {
            f(0);
            return;
        }
        std::thread::scope(|scope| {
            for w in 1..self.threads {
                let fr = &f;
                scope.spawn(move || fr(w));
            }
            f(0);
        });
    }

    /// Like [`Self::spmd`], but hands each worker exclusive `&mut` access
    /// to one element of `states` (per-worker scratch that outlives the
    /// call — the engine reuses these buffers across `run()` invocations to
    /// stay allocation-free). `states` must hold at least `threads`
    /// elements; extras are untouched.
    pub fn spmd_with<S, F>(&self, states: &mut [S], f: F)
    where
        S: Send,
        F: Fn(usize, &mut S) + Sync,
    {
        assert!(
            states.len() >= self.threads,
            "spmd_with: {} states for {} workers",
            states.len(),
            self.threads
        );
        if self.threads == 1 {
            f(0, &mut states[0]);
            return;
        }
        let (first, rest) = states.split_at_mut(1);
        std::thread::scope(|scope| {
            for (i, st) in rest.iter_mut().take(self.threads - 1).enumerate() {
                let fr = &f;
                scope.spawn(move || fr(i + 1, st));
            }
            f(0, &mut first[0]);
        });
    }

}

/// Shared mutable view of a row-major buffer for barrier-phased SPMD.
///
/// Safe Rust cannot express "worker `w` mutably owns rows `r_w..r_{w+1}`
/// during phase A, then everyone reads the whole buffer during phase B"
/// across scoped threads; this wrapper carries the raw pointer and pushes
/// the aliasing discipline to the (two) call sites.
///
/// # Safety contract
/// * [`Self::rows_mut`] windows handed to concurrent workers must be
///   disjoint;
/// * a phase that reads overlapping data written by another worker must be
///   separated from the writes by a barrier (or scope join);
/// * the view must not outlive the borrow it was created from (enforced by
///   the lifetime parameter).
pub struct SharedRows<'a> {
    ptr: *mut f32,
    len: usize,
    _marker: PhantomData<&'a mut [f32]>,
}

unsafe impl Send for SharedRows<'_> {}
unsafe impl Sync for SharedRows<'_> {}

impl<'a> SharedRows<'a> {
    /// Wrap a mutable buffer.
    pub fn new(data: &'a mut [f32]) -> Self {
        SharedRows { ptr: data.as_mut_ptr(), len: data.len(), _marker: PhantomData }
    }

    /// Immutable view of rows `start..start + nrows` (row length `cols`).
    ///
    /// # Safety
    /// No worker may concurrently write any element of the window (see the
    /// type-level contract).
    #[inline]
    pub unsafe fn rows(&self, start: usize, nrows: usize, cols: usize) -> &[f32] {
        let off = start * cols;
        let len = nrows * cols;
        debug_assert!(off + len <= self.len);
        std::slice::from_raw_parts(self.ptr.add(off), len)
    }

    /// Mutable view of rows `start..start + nrows` (row length `cols`).
    ///
    /// # Safety
    /// Windows handed to concurrent workers must be disjoint and unread by
    /// others until the next barrier (see the type-level contract).
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn rows_mut(&self, start: usize, nrows: usize, cols: usize) -> &mut [f32] {
        let off = start * cols;
        let len = nrows * cols;
        debug_assert!(off + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(off), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Barrier;

    #[test]
    fn chunk_ranges_cover_and_partition() {
        for &(total, parts) in &[(10usize, 3usize), (6, 4), (4, 7), (0, 2), (100, 1)] {
            let mut covered = vec![false; total];
            let mut prev_end = 0;
            for w in 0..parts {
                let r = chunk_range(total, parts, w);
                assert_eq!(r.start, prev_end, "chunks must be contiguous");
                prev_end = r.end;
                for i in r {
                    assert!(!covered[i]);
                    covered[i] = true;
                }
            }
            assert_eq!(prev_end, total);
            assert!(covered.into_iter().all(|c| c));
        }
    }

    #[test]
    fn chunk_sizes_near_equal() {
        for w in 0..5 {
            let len = chunk_range(23, 5, w).len();
            assert!((4..=5).contains(&len));
        }
    }

    #[test]
    fn spmd_runs_every_worker() {
        let count = AtomicUsize::new(0);
        let seen: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        WorkerPool::new(4).spmd(|w| {
            count.fetch_add(1, Ordering::SeqCst);
            seen[w].fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 4);
        for s in &seen {
            assert_eq!(s.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    fn spmd_with_gives_exclusive_state() {
        let mut states = vec![0usize; 3];
        WorkerPool::new(3).spmd_with(&mut states, |w, st| {
            *st = w + 10;
        });
        assert_eq!(states, vec![10, 11, 12]);
    }

    #[test]
    fn shared_rows_barrier_phases() {
        // Phase 1: workers write disjoint rows; phase 2: everyone reads
        // the full buffer and checks the other workers' writes landed.
        let threads = 3;
        let (rows, cols) = (7usize, 4usize);
        let mut buf = vec![0.0f32; rows * cols];
        let shared = SharedRows::new(&mut buf);
        let barrier = Barrier::new(threads);
        WorkerPool::new(threads).spmd(|w| {
            let mine = chunk_range(rows, threads, w);
            let window = unsafe { shared.rows_mut(mine.start, mine.len(), cols) };
            for (i, v) in window.iter_mut().enumerate() {
                *v = (mine.start * cols + i) as f32;
            }
            barrier.wait();
            let all = unsafe { shared.rows(0, rows, cols) };
            for (i, &v) in all.iter().enumerate() {
                assert_eq!(v, i as f32);
            }
        });
        assert_eq!(buf[rows * cols - 1], (rows * cols - 1) as f32);
    }
}
