//! Worker pools for row-partitioned SPMD loops.
//!
//! The diffusion hot loops are embarrassingly parallel over agents: adapt
//! writes row `k` of `Ψ` reading only row `k` of `V`, and combine writes
//! row `k` of `V` reading all of `Ψ`. This module provides the pieces the
//! engine (and `scalar_consensus`) need to exploit that without external
//! dependencies:
//!
//! * [`WorkerPool`] — spawns `threads − 1` scoped workers plus the calling
//!   thread and runs one closure per worker. Iteration loops live *inside*
//!   the closure with a [`std::sync::Barrier`] per phase, so threads are
//!   spawned once per `run()`, not once per iteration.
//! * [`PersistentPool`] — the long-lived variant for streaming callers: OS
//!   threads are spawned once at construction and dispatched borrowed SPMD
//!   closures through channels, so a serving loop pays a channel round-trip
//!   per minibatch instead of a thread spawn. The handle is `Send + Sync`
//!   and is shared across pipeline stages behind an `Arc`
//!   ([`crate::infer::DiffusionEngine::set_pool`]).
//! * [`chunk_range`] — the deterministic row partition. Work is split by
//!   static ranges (never work-stealing) so each row is computed by exactly
//!   one worker with the same per-row arithmetic as the serial path —
//!   results are bit-identical for every thread count *and* for either pool
//!   flavor.
//! * [`SharedRows`] — an unsafe-but-small escape hatch that lets workers
//!   hold disjoint mutable row windows of one buffer across barrier phases,
//!   which safe borrows cannot express.

use std::marker::PhantomData;
use std::ops::Range;
use std::sync::{mpsc, Mutex};
use std::thread::JoinHandle;

/// Deterministic contiguous partition: range of `idx` (0-based) among
/// `parts` near-equal chunks of `total` items. Leading chunks take the
/// remainder, so sizes differ by at most one.
pub fn chunk_range(total: usize, parts: usize, idx: usize) -> Range<usize> {
    debug_assert!(parts > 0 && idx < parts);
    let base = total / parts;
    let rem = total % parts;
    let start = idx * base + idx.min(rem);
    let len = base + usize::from(idx < rem);
    start..start + len
}

/// A reusable handle describing how many workers an SPMD region runs on.
///
/// Workers are scoped threads: they borrow the caller's data and are joined
/// before the method returns, so no `'static` bounds or `Arc` plumbing leak
/// into call sites.
#[derive(Clone, Copy, Debug)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// A pool of `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> Self {
        WorkerPool { threads: threads.max(1) }
    }

    /// Worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(worker_id)` on every worker; `worker_id` ∈ `0..threads`.
    /// Worker 0 executes on the calling thread. Returns after all workers
    /// finish.
    pub fn spmd<F>(&self, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if self.threads == 1 {
            f(0);
            return;
        }
        std::thread::scope(|scope| {
            for w in 1..self.threads {
                let fr = &f;
                scope.spawn(move || fr(w));
            }
            f(0);
        });
    }

    /// Like [`Self::spmd`], but hands each worker exclusive `&mut` access
    /// to one element of `states` (per-worker scratch that outlives the
    /// call — the engine reuses these buffers across `run()` invocations to
    /// stay allocation-free). `states` must hold at least `threads`
    /// elements; extras are untouched.
    pub fn spmd_with<S, F>(&self, states: &mut [S], f: F)
    where
        S: Send,
        F: Fn(usize, &mut S) + Sync,
    {
        assert!(
            states.len() >= self.threads,
            "spmd_with: {} states for {} workers",
            states.len(),
            self.threads
        );
        if self.threads == 1 {
            f(0, &mut states[0]);
            return;
        }
        let (first, rest) = states.split_at_mut(1);
        std::thread::scope(|scope| {
            for (i, st) in rest.iter_mut().take(self.threads - 1).enumerate() {
                let fr = &f;
                scope.spawn(move || fr(i + 1, st));
            }
            f(0, &mut first[0]);
        });
    }

}

/// Shared mutable view of a row-major buffer for barrier-phased SPMD.
///
/// Safe Rust cannot express "worker `w` mutably owns rows `r_w..r_{w+1}`
/// during phase A, then everyone reads the whole buffer during phase B"
/// across scoped threads; this wrapper carries the raw pointer and pushes
/// the aliasing discipline to the (two) call sites.
///
/// # Safety contract
/// * [`Self::rows_mut`] windows handed to concurrent workers must be
///   disjoint;
/// * a phase that reads overlapping data written by another worker must be
///   separated from the writes by a barrier (or scope join);
/// * the view must not outlive the borrow it was created from (enforced by
///   the lifetime parameter).
pub struct SharedRows<'a> {
    ptr: *mut f32,
    len: usize,
    _marker: PhantomData<&'a mut [f32]>,
}

unsafe impl Send for SharedRows<'_> {}
unsafe impl Sync for SharedRows<'_> {}

impl<'a> SharedRows<'a> {
    /// Wrap a mutable buffer.
    pub fn new(data: &'a mut [f32]) -> Self {
        SharedRows { ptr: data.as_mut_ptr(), len: data.len(), _marker: PhantomData }
    }

    /// Immutable view of rows `start..start + nrows` (row length `cols`).
    ///
    /// # Safety
    /// No worker may concurrently write any element of the window (see the
    /// type-level contract).
    #[inline]
    pub unsafe fn rows(&self, start: usize, nrows: usize, cols: usize) -> &[f32] {
        let off = start * cols;
        let len = nrows * cols;
        debug_assert!(off + len <= self.len);
        std::slice::from_raw_parts(self.ptr.add(off), len)
    }

    /// Mutable view of rows `start..start + nrows` (row length `cols`).
    ///
    /// # Safety
    /// Windows handed to concurrent workers must be disjoint and unread by
    /// others until the next barrier (see the type-level contract).
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn rows_mut(&self, start: usize, nrows: usize, cols: usize) -> &mut [f32] {
        let off = start * cols;
        let len = nrows * cols;
        debug_assert!(off + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(off), len)
    }
}

/// One dispatched SPMD region for one worker: a lifetime-erased pointer to
/// the caller's closure plus the completion channel. The pointer is only
/// dereferenced between dispatch and the `done` signal, and the dispatching
/// call blocks on every signal before returning — so the borrow it was
/// erased from is still alive whenever a worker touches it.
struct Job {
    f: *const (dyn Fn(usize) + Sync),
    done: mpsc::Sender<()>,
}

// SAFETY: the raw closure pointer is dereferenced only while the submitting
// `spmd_active` call is blocked waiting for `done` (see `Job`); the Sender
// is Send on its own.
unsafe impl Send for Job {}

/// Long-lived worker pool: `threads − 1` OS threads parked on job channels
/// plus the calling thread as worker 0.
///
/// Semantics are identical to [`WorkerPool`] (same worker ids, same
/// [`chunk_range`] partitions, closures may contain [`std::sync::Barrier`]
/// phases — every active worker runs on its own thread, never queued behind
/// another worker's job). The difference is purely dispatch cost: a channel
/// send/recv pair per worker per region instead of a thread spawn/join,
/// which matters for streaming loops that enter an SPMD region per
/// minibatch.
///
/// One SPMD region at a time: dispatch is serialized internally, but
/// closures that synchronize workers (barriers) assume all active workers
/// belong to the *same* region — do not call `spmd_active` concurrently
/// from two threads with such closures.
pub struct PersistentPool {
    /// `txs[i]` feeds the thread running worker id `i + 1`.
    txs: Mutex<Vec<mpsc::Sender<Job>>>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
}

impl PersistentPool {
    /// Spawn a pool of `threads` workers (clamped to at least 1; `1` means
    /// no background threads — everything runs on the caller).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let mut txs = Vec::with_capacity(threads.saturating_sub(1));
        let mut handles = Vec::with_capacity(threads.saturating_sub(1));
        for id in 1..threads {
            let (tx, rx) = mpsc::channel::<Job>();
            let handle = std::thread::Builder::new()
                .name(format!("ddl-pool-{id}"))
                .spawn(move || {
                    for job in rx.iter() {
                        // SAFETY: the submitter keeps the closure alive
                        // until it has received our `done` signal.
                        let f = unsafe { &*job.f };
                        f(id);
                        let _ = job.done.send(());
                    }
                })
                .expect("PersistentPool: failed to spawn worker thread");
            txs.push(tx);
            handles.push(handle);
        }
        PersistentPool { txs: Mutex::new(txs), handles, threads }
    }

    /// Worker count the pool was built with.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(worker_id)` on workers `0..active` (clamped to the pool
    /// size); worker 0 executes on the calling thread. Returns after every
    /// active worker has finished — exactly the join semantics of
    /// [`WorkerPool::spmd`].
    pub fn spmd_active<F>(&self, active: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let active = active.clamp(1, self.threads);
        if active == 1 {
            f(0);
            return;
        }
        let (done_tx, done_rx) = mpsc::channel();
        let mut sent = 0usize;
        let mut dead_worker = false;
        {
            let f_obj: &(dyn Fn(usize) + Sync) = &f;
            // SAFETY: lifetime erasure only — every worker drops its use of
            // the closure before signalling `done`, and we block on every
            // *dispatched* job (even on unwind, via `DrainOnDrop` below)
            // before `f` can go out of scope. No code path panics between a
            // successful send and the guard's installation.
            let f_ptr: *const (dyn Fn(usize) + Sync) = unsafe {
                std::mem::transmute::<
                    &(dyn Fn(usize) + Sync),
                    &'static (dyn Fn(usize) + Sync),
                >(f_obj)
            };
            let txs = self.txs.lock().expect("PersistentPool: poisoned dispatch lock");
            for tx in txs.iter().take(active - 1) {
                // A failed send means that worker's thread died; defer the
                // panic until after the join guard is armed so already-
                // dispatched workers are waited for first.
                if tx.send(Job { f: f_ptr, done: done_tx.clone() }).is_ok() {
                    sent += 1;
                } else {
                    dead_worker = true;
                    break;
                }
            }
        }
        // The guard's drain terminates in every case because the original
        // sender is dropped here: each dispatched worker either sends `()`
        // or (on panic) drops its clone, closing the channel.
        drop(done_tx);
        // Unwind guard: if anything below panics on the calling thread, we
        // still wait for every dispatched worker before this frame (and the
        // erased closure plus whatever it borrows) is torn down — matching
        // the join-on-unwind semantics of the scoped WorkerPool.
        let mut guard = DrainOnDrop { rx: &done_rx, left: sent };
        assert!(!dead_worker, "PersistentPool: worker thread exited");
        f(0);
        while guard.left > 0 {
            done_rx.recv().expect("PersistentPool: worker thread panicked");
            guard.left -= 1;
        }
    }

    /// Like [`Self::spmd_active`], but hands worker `w` exclusive `&mut`
    /// access to `states[w]` — the persistent counterpart of
    /// [`WorkerPool::spmd_with`]. `states` must hold at least `active`
    /// elements (after clamping to the pool size); extras are untouched.
    pub fn spmd_with_active<S, F>(&self, active: usize, states: &mut [S], f: F)
    where
        S: Send,
        F: Fn(usize, &mut S) + Sync,
    {
        let active = active.clamp(1, self.threads);
        assert!(
            states.len() >= active,
            "spmd_with_active: {} states for {} workers",
            states.len(),
            active
        );
        let base = states.as_mut_ptr() as usize;
        self.spmd_active(active, move |w| {
            // SAFETY: worker ids are distinct, so each worker touches a
            // distinct element; `states` outlives the (joining) dispatch.
            let st = unsafe { &mut *(base as *mut S).add(w) };
            f(w, st);
        });
    }
}

/// Blocks until every outstanding worker of one SPMD region has finished,
/// even when the submitting closure unwinds: a worker that completes sends
/// `()`, a worker that panics drops its `done` sender — either way `recv`
/// returns and the drain terminates.
struct DrainOnDrop<'a> {
    rx: &'a mpsc::Receiver<()>,
    left: usize,
}

impl Drop for DrainOnDrop<'_> {
    fn drop(&mut self) {
        while self.left > 0 {
            if self.rx.recv().is_err() {
                break;
            }
            self.left -= 1;
        }
    }
}

impl Drop for PersistentPool {
    fn drop(&mut self) {
        // Closing the channels makes every worker's `rx.iter()` finish —
        // including when the dispatch lock was poisoned by a failed send
        // (clearing anyway is what unblocks the surviving workers, so the
        // subsequent joins terminate).
        match self.txs.lock() {
            Ok(mut txs) => txs.clear(),
            Err(poisoned) => poisoned.into_inner().clear(),
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Barrier;

    #[test]
    fn chunk_ranges_cover_and_partition() {
        for &(total, parts) in &[(10usize, 3usize), (6, 4), (4, 7), (0, 2), (100, 1)] {
            let mut covered = vec![false; total];
            let mut prev_end = 0;
            for w in 0..parts {
                let r = chunk_range(total, parts, w);
                assert_eq!(r.start, prev_end, "chunks must be contiguous");
                prev_end = r.end;
                for i in r {
                    assert!(!covered[i]);
                    covered[i] = true;
                }
            }
            assert_eq!(prev_end, total);
            assert!(covered.into_iter().all(|c| c));
        }
    }

    #[test]
    fn chunk_sizes_near_equal() {
        for w in 0..5 {
            let len = chunk_range(23, 5, w).len();
            assert!((4..=5).contains(&len));
        }
    }

    #[test]
    fn spmd_runs_every_worker() {
        let count = AtomicUsize::new(0);
        let seen: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        WorkerPool::new(4).spmd(|w| {
            count.fetch_add(1, Ordering::SeqCst);
            seen[w].fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 4);
        for s in &seen {
            assert_eq!(s.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    fn spmd_with_gives_exclusive_state() {
        let mut states = vec![0usize; 3];
        WorkerPool::new(3).spmd_with(&mut states, |w, st| {
            *st = w + 10;
        });
        assert_eq!(states, vec![10, 11, 12]);
    }

    #[test]
    fn persistent_pool_runs_every_worker() {
        let pool = PersistentPool::new(4);
        assert_eq!(pool.threads(), 4);
        let count = AtomicUsize::new(0);
        let seen: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        // Reuse across many regions — the whole point of persistence.
        for _ in 0..10 {
            pool.spmd_active(4, |w| {
                count.fetch_add(1, Ordering::SeqCst);
                seen[w].fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(count.load(Ordering::SeqCst), 40);
        for s in &seen {
            assert_eq!(s.load(Ordering::SeqCst), 10);
        }
    }

    #[test]
    fn persistent_pool_active_subset_and_clamp() {
        let pool = PersistentPool::new(3);
        let seen: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
        pool.spmd_active(2, |w| {
            seen[w].fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(seen[0].load(Ordering::SeqCst), 1);
        assert_eq!(seen[1].load(Ordering::SeqCst), 1);
        assert_eq!(seen[2].load(Ordering::SeqCst), 0, "inactive worker untouched");
        // Requesting more workers than the pool has clamps to the pool size.
        pool.spmd_active(9, |w| {
            seen[w].fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(seen[2].load(Ordering::SeqCst), 1);
        // active = 1 runs inline on the caller.
        pool.spmd_active(1, |w| assert_eq!(w, 0));
    }

    #[test]
    fn persistent_pool_spmd_with_gives_exclusive_state() {
        let pool = PersistentPool::new(3);
        let mut states = vec![0usize; 3];
        pool.spmd_with_active(3, &mut states, |w, st| {
            *st = w + 10;
        });
        assert_eq!(states, vec![10, 11, 12]);
    }

    /// Active workers run concurrently on distinct threads, so barrier-
    /// phased closures (the engine's iteration loop shape) must not
    /// deadlock and must see each other's pre-barrier writes.
    #[test]
    fn persistent_pool_supports_barrier_phases() {
        let threads = 3;
        let pool = PersistentPool::new(threads);
        let (rows, cols) = (7usize, 4usize);
        let mut buf = vec![0.0f32; rows * cols];
        let shared = SharedRows::new(&mut buf);
        let barrier = Barrier::new(threads);
        pool.spmd_active(threads, |w| {
            let mine = chunk_range(rows, threads, w);
            let window = unsafe { shared.rows_mut(mine.start, mine.len(), cols) };
            for (i, v) in window.iter_mut().enumerate() {
                *v = (mine.start * cols + i) as f32;
            }
            barrier.wait();
            let all = unsafe { shared.rows(0, rows, cols) };
            for (i, &v) in all.iter().enumerate() {
                assert_eq!(v, i as f32);
            }
        });
        assert_eq!(buf[rows * cols - 1], (rows * cols - 1) as f32);
    }

    #[test]
    fn shared_rows_barrier_phases() {
        // Phase 1: workers write disjoint rows; phase 2: everyone reads
        // the full buffer and checks the other workers' writes landed.
        let threads = 3;
        let (rows, cols) = (7usize, 4usize);
        let mut buf = vec![0.0f32; rows * cols];
        let shared = SharedRows::new(&mut buf);
        let barrier = Barrier::new(threads);
        WorkerPool::new(threads).spmd(|w| {
            let mine = chunk_range(rows, threads, w);
            let window = unsafe { shared.rows_mut(mine.start, mine.len(), cols) };
            for (i, v) in window.iter_mut().enumerate() {
                *v = (mine.start * cols + i) as f32;
            }
            barrier.wait();
            let all = unsafe { shared.rows(0, rows, cols) };
            for (i, &v) in all.iter().enumerate() {
                assert_eq!(v, i as f32);
            }
        });
        assert_eq!(buf[rows * cols - 1], (rows * cols - 1) as f32);
    }
}
