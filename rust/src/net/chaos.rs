//! Deterministic fault injection for the async executor: the chaos layer.
//!
//! A [`FaultSchedule`] is a declarative list of fault windows — edge
//! up/down churn, network partitions that heal, directed link outages,
//! message-drop windows, and agent crash/recovery windows. Every query is
//! a **pure function of (schedule, sim-time)**: the schedule is built
//! up-front (optionally from a seeded generator, itself a pure function of
//! its arguments), so a chaos run replays bit-identically for a given
//! (seed, schedule) and an **empty schedule degenerates bit-for-bit to the
//! fault-free trajectory** — the executor takes no chaos branch, draws no
//! chaos randomness, and schedules no chaos events
//! (`tests/async_parity.rs`).
//!
//! ## Fault model
//!
//! * [`Fault::EdgeDown`] — an undirected edge is down for a window; both
//!   directions fail. Models flaky links (churn).
//! * [`Fault::LinkDown`] — **one direction** of an edge is down. This is
//!   the time-varying *digraph* setting of arXiv:1808.05933 /
//!   arXiv:1612.07335: effective connectivity loses symmetry, Metropolis
//!   weights are no longer doubly stochastic over the live topology, and
//!   plain diffusion acquires a consensus bias. The executor auto-selects
//!   the push-sum–corrected combine ([`CombineMode::PushSum`]) when a
//!   schedule contains directed faults.
//! * [`Fault::Partition`] — a bipartition of the agents; every edge
//!   crossing the cut is down for the window, then **heals**.
//! * [`Fault::Crash`] — an agent stops computing for a window, then
//!   recovers and **re-joins**: its interrupted adapt is re-run from its
//!   retained state and its ψ re-broadcast (the resync). Its mailbox
//!   keeps accepting ψ while it is down (state survives the crash; this
//!   models a process stall/restart, not disk loss).
//! * [`Fault::Drop`] — each physically transmitted message in the window
//!   is lost i.i.d. with probability `p` (coins come from the schedule's
//!   dedicated chaos stream, never from the executor's delay streams).
//! * [`Fault::Byzantine`] — an agent **lies**: every ψ it transmits while
//!   the window is active is corrupted by a [`CorruptPolicy`] before it
//!   leaves the agent (the agent's own state stays honest — it deceives
//!   its neighbors, not itself). Scaled-noise draws come from the same
//!   dedicated chaos stream as drop coins, so attacks replay
//!   bit-identically and a schedule without Byzantine windows consumes no
//!   extra randomness. The receiver-side defense is the resilient
//!   combine ([`CombineMode::Median`] / [`CombineMode::TrimmedMean`]).
//!
//! ## Correlated failures (Gilbert–Elliott)
//!
//! [`FaultSchedule::with_bursty_links`] generates *correlated* link
//! failures: each affected edge runs a two-state Gilbert–Elliott Markov
//! process (good/bad with exponential holding times), so down-windows
//! arrive in bursts instead of the independent up/down windows of
//! [`FaultSchedule::with_edge_churn`]. Like every generator here it is a
//! pure function of its arguments.
//!
//! ## Degradation policy
//!
//! [`ChaosPolicy`] holds the executor's graceful-degradation knobs: a
//! per-receive gate timeout (after which a gated combine proceeds with a
//! stale-ψ fallback or excludes the unreachable neighbor), and bounded
//! retry/backoff for sends that hit a down link.

use crate::error::{DdlError, Result};
use crate::graph::Graph;
use crate::rng::Pcg64;

/// How a Byzantine agent corrupts the ψ copies it transmits. Applied to
/// each outgoing message independently, after the honest adapt — the
/// attacker's own retained state is never touched.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CorruptPolicy {
    /// Transmit `−ψ` (the classic direction-reversing attacker).
    SignFlip,
    /// Transmit `ψ + σ·g`, `g` i.i.d. standard normal per coordinate.
    /// Draws come from the executor's dedicated chaos stream, so the
    /// attack replays bit-identically per seed.
    ScaledNoise { sigma: f32 },
    /// Transmit a constant vector (every coordinate = `value`),
    /// regardless of the honest iterate.
    ConstantPsi { value: f32 },
    /// Transmit `ψ + magnitude·1`. Colluding attackers sharing one
    /// `magnitude` push every neighborhood toward the same offset — the
    /// coordinated-bias attack trimmed aggregation is sized against.
    ColludingOffset { magnitude: f32 },
}

impl CorruptPolicy {
    /// Stable numeric tag for trace events (`fault:byzantine` spans).
    pub fn tag(&self) -> u64 {
        match self {
            CorruptPolicy::SignFlip => 0,
            CorruptPolicy::ScaledNoise { .. } => 1,
            CorruptPolicy::ConstantPsi { .. } => 2,
            CorruptPolicy::ColludingOffset { .. } => 3,
        }
    }

    /// Short human-readable name (report summaries).
    pub fn name(&self) -> &'static str {
        match self {
            CorruptPolicy::SignFlip => "sign-flip",
            CorruptPolicy::ScaledNoise { .. } => "scaled-noise",
            CorruptPolicy::ConstantPsi { .. } => "constant",
            CorruptPolicy::ColludingOffset { .. } => "colluding-offset",
        }
    }
}

/// One fault window. All windows are half-open `[from_us, until_us)` on
/// the simulated microsecond clock.
#[derive(Clone, Debug, PartialEq)]
pub enum Fault {
    /// Undirected edge `{u, v}` is down (both directions).
    EdgeDown { u: usize, v: usize, from_us: u64, until_us: u64 },
    /// Directed link `from → to` is down (the reverse stays up) — the
    /// asymmetric outage that motivates push-sum.
    LinkDown { from: usize, to: usize, from_us: u64, until_us: u64 },
    /// Every edge crossing the bipartition given by `side` is down;
    /// heals at `until_us`. `side.len()` must equal the agent count.
    Partition { side: Vec<bool>, from_us: u64, until_us: u64 },
    /// Agent stops computing; recovers (re-joins) at `until_us`.
    Crash { agent: usize, from_us: u64, until_us: u64 },
    /// Transmitted messages are dropped i.i.d. with probability `p`.
    Drop { p: f64, from_us: u64, until_us: u64 },
    /// Agent transmits corrupted ψ for the window (its own state stays
    /// honest; see [`CorruptPolicy`]).
    Byzantine { agent: usize, policy: CorruptPolicy, from_us: u64, until_us: u64 },
}

#[inline]
fn covers(from_us: u64, until_us: u64, t: u64) -> bool {
    from_us <= t && t < until_us
}

/// Deterministic fault schedule (see the module docs). The default value
/// is the **empty** schedule: no faults, no chaos branches, bit-for-bit
/// the fault-free executor.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSchedule {
    /// Seed of the chaos coin stream (message-drop decisions). Dedicated:
    /// the executor's delay streams are never touched by fault handling.
    pub seed: u64,
    faults: Vec<Fault>,
}

impl FaultSchedule {
    /// Empty schedule with a chaos-stream seed.
    pub fn new(seed: u64) -> Self {
        FaultSchedule { seed, faults: Vec::new() }
    }

    /// True when no fault window exists — the executor takes the
    /// fault-free path bit-for-bit.
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// The fault windows, in insertion order.
    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }

    /// Add an undirected edge-down window.
    pub fn with_edge_down(mut self, u: usize, v: usize, from_us: u64, until_us: u64) -> Self {
        self.faults.push(Fault::EdgeDown { u, v, from_us, until_us });
        self
    }

    /// Add a directed link-down window (`from → to` only).
    pub fn with_link_down(mut self, from: usize, to: usize, from_us: u64, until_us: u64) -> Self {
        self.faults.push(Fault::LinkDown { from, to, from_us, until_us });
        self
    }

    /// Add a healing partition given the cut side.
    pub fn with_partition(mut self, side: Vec<bool>, from_us: u64, until_us: u64) -> Self {
        self.faults.push(Fault::Partition { side, from_us, until_us });
        self
    }

    /// Add an agent crash/recovery window.
    pub fn with_crash(mut self, agent: usize, from_us: u64, until_us: u64) -> Self {
        self.faults.push(Fault::Crash { agent, from_us, until_us });
        self
    }

    /// Add a message-drop window.
    pub fn with_drops(mut self, p: f64, from_us: u64, until_us: u64) -> Self {
        self.faults.push(Fault::Drop { p: p.clamp(0.0, 1.0), from_us, until_us });
        self
    }

    /// Add a Byzantine window: `agent` transmits ψ corrupted by `policy`
    /// for the window's duration.
    pub fn with_byzantine(
        mut self,
        agent: usize,
        policy: CorruptPolicy,
        from_us: u64,
        until_us: u64,
    ) -> Self {
        self.faults.push(Fault::Byzantine { agent, policy, from_us, until_us });
        self
    }

    /// Add a **colluding set**: every agent in `agents` transmits ψ
    /// corrupted by the *same* `policy` over the same window. Sharing one
    /// policy is what makes the set coordinated — e.g. a common
    /// [`CorruptPolicy::ColludingOffset`] pushes every neighborhood in
    /// the same direction, and `f` colluders defeat a `trimmed:f−1`
    /// combine (one corrupted value survives each coordinate's trim).
    pub fn with_colluders(
        mut self,
        agents: &[usize],
        policy: CorruptPolicy,
        from_us: u64,
        until_us: u64,
    ) -> Self {
        for &agent in agents {
            self.faults.push(Fault::Byzantine { agent, policy, from_us, until_us });
        }
        self
    }

    /// Convenience: a bipartition putting the first `⌈frac·n⌉` agents
    /// (clamped to `[1, n−1]` so both sides are non-empty) on one side.
    pub fn split_side(n: usize, frac: f64) -> Vec<bool> {
        let cut = ((n as f64 * frac).ceil() as usize).clamp(1, n.saturating_sub(1).max(1));
        (0..n).map(|k| k < cut).collect()
    }

    /// Seeded edge-churn generator: `windows` down-windows on random
    /// edges of `graph`, start uniform in `[0, horizon_us)`, length
    /// exponential with mean `mean_down_us`. A pure function of its
    /// arguments — the same call always yields the same schedule.
    pub fn with_edge_churn(
        mut self,
        graph: &Graph,
        windows: usize,
        mean_down_us: u64,
        horizon_us: u64,
        seed: u64,
    ) -> Self {
        let edges: Vec<(usize, usize)> = (0..graph.n())
            .flat_map(|u| {
                graph.neighbors(u).iter().filter(move |&&v| v > u).map(move |&v| (u, v))
            })
            .collect();
        if edges.is_empty() || horizon_us == 0 {
            return self;
        }
        let mut rng = Pcg64::new(seed);
        for _ in 0..windows {
            let (u, v) = edges[rng.next_below(edges.len() as u64) as usize];
            let from = rng.next_below(horizon_us);
            let len =
                (-rng.next_f64().max(1e-12).ln() * mean_down_us.max(1) as f64).round() as u64;
            self.faults.push(Fault::EdgeDown { u, v, from_us: from, until_us: from + len.max(1) });
        }
        self
    }

    /// Seeded Gilbert–Elliott bursty-link generator: `links` randomly
    /// chosen edges of `graph` each run an independent two-state Markov
    /// process over `[0, horizon_us)` — *good* (up) with exponential
    /// holding time of mean `mean_up_us`, then *bad* (down, one
    /// [`Fault::EdgeDown`] window) with exponential holding time of mean
    /// `mean_down_us`, and so on until the horizon. Down-windows on one
    /// edge therefore arrive in temporally correlated bursts, unlike the
    /// independent windows of [`Self::with_edge_churn`]. A pure function
    /// of its arguments — the same call always yields the same schedule.
    pub fn with_bursty_links(
        mut self,
        graph: &Graph,
        links: usize,
        mean_up_us: u64,
        mean_down_us: u64,
        horizon_us: u64,
        seed: u64,
    ) -> Self {
        let edges: Vec<(usize, usize)> = (0..graph.n())
            .flat_map(|u| {
                graph.neighbors(u).iter().filter(move |&&v| v > u).map(move |&v| (u, v))
            })
            .collect();
        if edges.is_empty() || horizon_us == 0 {
            return self;
        }
        let mut rng = Pcg64::new(seed);
        let exp = |rng: &mut Pcg64, mean: u64| -> u64 {
            (-rng.next_f64().max(1e-12).ln() * mean.max(1) as f64).round().max(1.0) as u64
        };
        for _ in 0..links {
            let (u, v) = edges[rng.next_below(edges.len() as u64) as usize];
            let mut t = 0u64;
            loop {
                t = t.saturating_add(exp(&mut rng, mean_up_us));
                if t >= horizon_us {
                    break;
                }
                let down = exp(&mut rng, mean_down_us);
                self.faults.push(Fault::EdgeDown {
                    u,
                    v,
                    from_us: t,
                    until_us: t.saturating_add(down),
                });
                t = t.saturating_add(down);
                if t >= horizon_us {
                    break;
                }
            }
        }
        self
    }

    /// Validate agent indices and window shapes against a network size.
    pub fn validate(&self, n: usize) -> Result<()> {
        for f in &self.faults {
            let ok = match f {
                Fault::EdgeDown { u, v, from_us, until_us } => {
                    *u < n && *v < n && u != v && from_us < until_us
                }
                Fault::LinkDown { from, to, from_us, until_us } => {
                    *from < n && *to < n && from != to && from_us < until_us
                }
                Fault::Partition { side, from_us, until_us } => {
                    side.len() == n
                        && side.iter().any(|&s| s)
                        && side.iter().any(|&s| !s)
                        && from_us < until_us
                }
                Fault::Crash { agent, from_us, until_us } => *agent < n && from_us < until_us,
                Fault::Drop { p, from_us, until_us } => {
                    (0.0..=1.0).contains(p) && from_us < until_us
                }
                Fault::Byzantine { agent, policy, from_us, until_us } => {
                    let sane = match policy {
                        CorruptPolicy::ScaledNoise { sigma } => {
                            sigma.is_finite() && *sigma >= 0.0
                        }
                        CorruptPolicy::ConstantPsi { value } => value.is_finite(),
                        CorruptPolicy::ColludingOffset { magnitude } => magnitude.is_finite(),
                        CorruptPolicy::SignFlip => true,
                    };
                    *agent < n && from_us < until_us && sane
                }
            };
            if !ok {
                return Err(DdlError::Config(format!("invalid fault window: {f:?}")));
            }
        }
        Ok(())
    }

    /// Is agent `k` computing at time `t` (not inside a crash window)?
    pub fn agent_alive(&self, k: usize, t: u64) -> bool {
        !self.faults.iter().any(|f| {
            matches!(f, Fault::Crash { agent, from_us, until_us }
                if *agent == k && covers(*from_us, *until_us, t))
        })
    }

    /// Earliest time `≥ t` at which agent `k` is out of every crash
    /// window covering `t` (recovery may chain across overlapping
    /// windows; one extra pass per overlap resolves the chain).
    pub fn agent_recover_us(&self, k: usize, t: u64) -> u64 {
        let mut rec = t;
        loop {
            let mut advanced = false;
            for f in &self.faults {
                if let Fault::Crash { agent, from_us, until_us } = f {
                    if *agent == k && covers(*from_us, *until_us, rec) && *until_us > rec {
                        rec = *until_us;
                        advanced = true;
                    }
                }
            }
            if !advanced {
                return rec;
            }
        }
    }

    /// Is the directed link `from → to` transmitting at time `t`?
    /// (Crash windows do not close links: a crashed agent's mailbox
    /// still accepts ψ — see the module docs.)
    pub fn link_up(&self, from: usize, to: usize, t: u64) -> bool {
        !self.faults.iter().any(|f| match f {
            Fault::EdgeDown { u, v, from_us, until_us } => {
                covers(*from_us, *until_us, t)
                    && ((*u == from && *v == to) || (*u == to && *v == from))
            }
            Fault::LinkDown { from: a, to: b, from_us, until_us } => {
                covers(*from_us, *until_us, t) && *a == from && *b == to
            }
            Fault::Partition { side, from_us, until_us } => {
                covers(*from_us, *until_us, t) && side[from] != side[to]
            }
            _ => false,
        })
    }

    /// Message-drop probability in effect at time `t` (max over active
    /// drop windows).
    pub fn drop_prob(&self, t: u64) -> f64 {
        self.faults
            .iter()
            .filter_map(|f| match f {
                Fault::Drop { p, from_us, until_us } if covers(*from_us, *until_us, t) => Some(*p),
                _ => None,
            })
            .fold(0.0, f64::max)
    }

    /// Does the schedule contain *directed* faults (the live topology can
    /// lose symmetry)? When true, Metropolis weights are no longer doubly
    /// stochastic over the live graph and the executor auto-selects the
    /// push-sum combine.
    pub fn has_directed_faults(&self) -> bool {
        self.faults.iter().any(|f| matches!(f, Fault::LinkDown { .. }))
    }

    /// Is any partition window active at time `t`? (The τ controller's
    /// partition hook observes this.)
    pub fn partition_active(&self, t: u64) -> bool {
        self.faults.iter().any(|f| {
            matches!(f, Fault::Partition { from_us, until_us, .. }
                if covers(*from_us, *until_us, t))
        })
    }

    /// Number of live outgoing links of agent `k` at time `t`.
    pub fn live_out_degree(&self, graph: &Graph, k: usize, t: u64) -> usize {
        graph.neighbors(k).iter().filter(|&&nb| self.link_up(k, nb, t)).count()
    }

    /// Corruption policy in effect for agent `k` at time `t` (`None` when
    /// the agent transmits honestly). First matching window wins, in
    /// insertion order.
    pub fn byzantine_policy(&self, k: usize, t: u64) -> Option<CorruptPolicy> {
        self.faults.iter().find_map(|f| match f {
            Fault::Byzantine { agent, policy, from_us, until_us }
                if *agent == k && covers(*from_us, *until_us, t) =>
            {
                Some(*policy)
            }
            _ => None,
        })
    }

    /// Does the schedule contain any Byzantine window? (Report summaries
    /// and the `--byzantine` probe key off this.)
    pub fn has_byzantine(&self) -> bool {
        self.faults.iter().any(|f| matches!(f, Fault::Byzantine { .. }))
    }

    /// Sorted, deduplicated agents with at least one Byzantine window —
    /// the attacker set the detection probe checks exclusions against.
    pub fn byzantine_agents(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .faults
            .iter()
            .filter_map(|f| match f {
                Fault::Byzantine { agent, .. } => Some(*agent),
                _ => None,
            })
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }
}

/// Deterministic detection-and-exclusion knobs for the resilient combine
/// (the layer above masking: instead of paying the trimming tax forever,
/// persistently suspicious neighbors are *excluded* and the surviving
/// weights renormalize through the existing never-heard machinery).
///
/// Every judgement is a pure function of (this config, sim-time, ψ bits):
/// per combine, a receiving agent accumulates **evidence** against each
/// participating neighbor, where evidence requires all three of
///
/// 1. the neighbor's value landed in the trimmed tail in at least
///    `tail_frac_min` of the coordinates,
/// 2. its L1 distance to the aggregate is at least `dist_ratio` × the
///    median participant distance (scale-free outlier test), and
/// 3. that distance is at least `rel_dist_min` × the aggregate's own L1
///    norm (suppresses the transient, where everything is far from
///    everything).
///
/// Evidence increments a per-neighbor score; any combine without evidence
/// resets it (honest neighbors cannot drift into exclusion). At
/// `flag_after` consecutive evidence combines the neighbor is *flagged*
/// (`agent_flagged` instant), at `exclude_after` it is *excluded* from
/// this agent's future combines (`agent_excluded`). With
/// `probation_us > 0` an excluded neighbor is re-admitted with a clean
/// score after that long (`agent_readmitted`) — re-offending re-excludes
/// it. No RNG is drawn and no clock is moved, so detection runs replay
/// bit-identically and (since the aggregate arithmetic is untouched) a
/// zero-attacker run is bitwise identical to a detection-off run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DetectionConfig {
    /// Master switch; `false` (default) is bitwise-inert.
    pub enabled: bool,
    /// Minimum fraction of coordinates in the trimmed tail (condition 1).
    pub tail_frac_min: f64,
    /// Multiple of the median participant distance (condition 2).
    pub dist_ratio: f64,
    /// Multiple of the aggregate's L1 norm (condition 3).
    pub rel_dist_min: f64,
    /// Consecutive evidence combines before flagging.
    pub flag_after: usize,
    /// Consecutive evidence combines before exclusion (≥ `flag_after`).
    pub exclude_after: usize,
    /// Probation: µs after exclusion at which the neighbor is re-admitted
    /// (0 = exclusion is permanent for the run).
    pub probation_us: u64,
    /// Local iterations before the evidence pass arms. During the early
    /// transient every agent is far from the (near-zero) aggregate, so
    /// scoring there would be pure false-positive risk; a persistent
    /// attacker loses nothing to a short warmup.
    pub warmup_iters: usize,
}

impl Default for DetectionConfig {
    fn default() -> Self {
        DetectionConfig {
            enabled: false,
            tail_frac_min: 0.40,
            dist_ratio: 1.4,
            rel_dist_min: 0.5,
            flag_after: 6,
            exclude_after: 12,
            probation_us: 0,
            warmup_iters: 8,
        }
    }
}

impl DetectionConfig {
    /// An enabled config with the default thresholds.
    pub fn armed() -> Self {
        DetectionConfig { enabled: true, ..Self::default() }
    }

    /// Sanity-check the thresholds.
    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        let ok = (0.0..=1.0).contains(&self.tail_frac_min)
            && self.dist_ratio.is_finite()
            && self.dist_ratio >= 1.0
            && self.rel_dist_min.is_finite()
            && self.rel_dist_min >= 0.0
            && self.flag_after >= 1
            && self.exclude_after >= self.flag_after;
        if !ok {
            return Err(DdlError::Config(format!("invalid detection config: {self:?}")));
        }
        Ok(())
    }
}

/// Combine rule of the async executor.
///
/// `Metropolis` is the paper's symmetric doubly-stochastic combine.
/// `PushSum` is the ratio-of-sums correction for directed / time-varying
/// live topologies (Nedić–Olshevsky subgradient-push; arXiv:1808.05933):
/// each agent carries a mass vector `s` and a scalar weight `w`, splits
/// both uniformly over its **live** out-edges plus itself
/// (column-stochastic by construction, whatever is currently up), sums
/// every share that arrives, and reads its estimate as `s / w` — mass
/// conservation keeps the consensus unbiased when connectivity loses
/// symmetry, where Metropolis acquires a bias.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CombineMode {
    /// Resolve at construction: push-sum when the schedule contains
    /// directed faults, Metropolis otherwise (the default).
    #[default]
    Auto,
    /// Force the symmetric Metropolis combine (even under directed
    /// faults — the biased baseline the chaos report compares against).
    Metropolis,
    /// Force the push-sum–corrected combine.
    PushSum,
    /// Resilient combine: coordinate-wise weighted **median** over
    /// {self} ∪ in-neighborhood. The maximally robust member of the
    /// trimmed family — tolerates up to ⌊(d−1)/2⌋ corrupted neighbors at
    /// the cost of discarding the most information per combine.
    Median,
    /// Resilient combine: coordinate-wise **trimmed weighted mean** —
    /// sort participant values per coordinate (deterministic
    /// `total_cmp` tie-breaking), discard the `f` smallest and `f`
    /// largest, and take the Metropolis-weighted mean of the survivors
    /// with weights renormalized to sum to one. Tolerates up to `f`
    /// corrupted neighbors per neighborhood.
    TrimmedMean(usize),
}

/// Graceful-degradation knobs (all only consulted when a non-empty
/// [`FaultSchedule`] is installed).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChaosPolicy {
    /// Receive timeout: a combine gated longer than this proceeds with
    /// stale-ψ fallback / neighbor exclusion instead of waiting forever.
    pub gate_timeout_us: u64,
    /// Base backoff before re-attempting a send that hit a down link
    /// (doubles per attempt).
    pub retry_backoff_us: u64,
    /// Send attempts beyond the first before the message is abandoned.
    pub max_retries: u32,
}

impl Default for ChaosPolicy {
    fn default() -> Self {
        ChaosPolicy { gate_timeout_us: 50_000, retry_backoff_us: 500, max_retries: 3 }
    }
}

/// Fault-handling counters (all zero on a fault-free run).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChaosStats {
    /// Messages transmitted but lost in a drop window.
    pub dropped: usize,
    /// Send retries scheduled after hitting a down link.
    pub retries: usize,
    /// Messages abandoned after exhausting retries.
    pub abandoned: usize,
    /// Adapt steps deferred because the agent was crashed.
    pub crash_deferrals: usize,
    /// Combines forced by the gate timeout.
    pub forced_combines: usize,
    /// Neighbor slots served by the stale-ψ fallback in forced combines.
    pub stale_fallbacks: usize,
    /// Neighbor slots excluded entirely (no ψ ever received) in forced
    /// combines.
    pub excluded_neighbors: usize,
    /// Largest staleness used by a fallback (the τ invariant tracks
    /// gated combines only; fallbacks are accounted here).
    pub max_fallback_staleness: usize,
    /// ψ copies corrupted before transmission by a Byzantine window
    /// (one per outgoing message of a corrupted adapt).
    pub corrupted: usize,
    /// (judge, suspect) pairs flagged by the detection layer (a suspect
    /// is counted once per flagging judge).
    pub flagged: usize,
    /// (judge, suspect) pairs excluded by the detection layer — distinct
    /// from `excluded_neighbors`, which counts never-heard exclusions in
    /// forced combines.
    pub detect_excluded: usize,
    /// (judge, suspect) pairs re-admitted after probation.
    pub readmitted: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Topology;

    #[test]
    fn empty_schedule_is_empty_and_valid() {
        let s = FaultSchedule::default();
        assert!(s.is_empty());
        assert!(s.validate(10).is_ok());
        assert!(s.agent_alive(3, 500));
        assert!(s.link_up(0, 1, 500));
        assert_eq!(s.drop_prob(500), 0.0);
        assert!(!s.has_directed_faults());
        assert!(!s.partition_active(0));
    }

    #[test]
    fn windows_are_half_open_and_pure() {
        let s = FaultSchedule::new(1)
            .with_edge_down(0, 1, 100, 200)
            .with_crash(2, 50, 150)
            .with_drops(0.5, 10, 20);
        assert!(s.link_up(0, 1, 99));
        assert!(!s.link_up(0, 1, 100));
        assert!(!s.link_up(1, 0, 199), "EdgeDown cuts both directions");
        assert!(s.link_up(0, 1, 200), "half-open: healed at until");
        assert!(!s.agent_alive(2, 149));
        assert!(s.agent_alive(2, 150));
        assert_eq!(s.agent_recover_us(2, 60), 150);
        assert_eq!(s.agent_recover_us(2, 150), 150);
        assert_eq!(s.drop_prob(15), 0.5);
        assert_eq!(s.drop_prob(25), 0.0);
    }

    #[test]
    fn link_down_is_directed() {
        let s = FaultSchedule::new(0).with_link_down(3, 4, 0, 1000);
        assert!(!s.link_up(3, 4, 10));
        assert!(s.link_up(4, 3, 10), "reverse direction stays up");
        assert!(s.has_directed_faults());
    }

    #[test]
    fn partition_cuts_cross_edges_only_and_heals() {
        let side = FaultSchedule::split_side(6, 0.5);
        assert_eq!(side, vec![true, true, true, false, false, false]);
        let s = FaultSchedule::new(0).with_partition(side, 100, 300);
        assert!(!s.link_up(0, 4, 150));
        assert!(!s.link_up(4, 0, 150));
        assert!(s.link_up(0, 1, 150), "within-side edges stay up");
        assert!(s.link_up(0, 4, 300), "healed");
        assert!(s.partition_active(150));
        assert!(!s.partition_active(300));
    }

    #[test]
    fn overlapping_crashes_chain_recovery() {
        let s = FaultSchedule::new(0).with_crash(0, 100, 200).with_crash(0, 150, 400);
        assert_eq!(s.agent_recover_us(0, 120), 400);
    }

    #[test]
    fn churn_generator_is_deterministic() {
        let mut rng = Pcg64::new(9);
        let g = Graph::generate(12, &Topology::Ring { k: 2 }, &mut rng);
        let a = FaultSchedule::new(0).with_edge_churn(&g, 5, 1_000, 50_000, 7);
        let b = FaultSchedule::new(0).with_edge_churn(&g, 5, 1_000, 50_000, 7);
        let c = FaultSchedule::new(0).with_edge_churn(&g, 5, 1_000, 50_000, 8);
        assert_eq!(a, b, "same seed, same schedule");
        assert_ne!(a, c, "seed moves the schedule");
        assert_eq!(a.faults().len(), 5);
        assert!(a.validate(12).is_ok());
    }

    #[test]
    fn live_out_degree_counts_up_links() {
        let mut rng = Pcg64::new(2);
        let g = Graph::generate(6, &Topology::Ring { k: 1 }, &mut rng);
        let s = FaultSchedule::new(0).with_link_down(0, 1, 0, 100);
        assert_eq!(s.live_out_degree(&g, 0, 50), 1, "one of two ring links is down");
        assert_eq!(s.live_out_degree(&g, 0, 100), 2);
        assert_eq!(s.live_out_degree(&g, 1, 50), 2, "reverse direction unaffected");
    }

    #[test]
    fn validation_rejects_bad_windows() {
        assert!(FaultSchedule::new(0).with_crash(9, 0, 10).validate(5).is_err());
        assert!(FaultSchedule::new(0).with_edge_down(0, 0, 0, 10).validate(5).is_err());
        assert!(FaultSchedule::new(0).with_edge_down(0, 1, 10, 10).validate(5).is_err());
        assert!(FaultSchedule::new(0)
            .with_partition(vec![true; 5], 0, 10)
            .validate(5)
            .is_err());
        assert!(FaultSchedule::new(0).with_partition(vec![true, false], 0, 10).validate(5).is_err());
        assert!(FaultSchedule::new(0)
            .with_byzantine(7, CorruptPolicy::SignFlip, 0, 10)
            .validate(5)
            .is_err());
        assert!(FaultSchedule::new(0)
            .with_byzantine(1, CorruptPolicy::SignFlip, 10, 10)
            .validate(5)
            .is_err());
        assert!(FaultSchedule::new(0)
            .with_byzantine(1, CorruptPolicy::ScaledNoise { sigma: -1.0 }, 0, 10)
            .validate(5)
            .is_err());
        assert!(FaultSchedule::new(0)
            .with_byzantine(1, CorruptPolicy::ConstantPsi { value: f32::NAN }, 0, 10)
            .validate(5)
            .is_err());
    }

    #[test]
    fn byzantine_windows_query_and_validate() {
        let s = FaultSchedule::new(0)
            .with_byzantine(2, CorruptPolicy::SignFlip, 100, 200)
            .with_byzantine(4, CorruptPolicy::ScaledNoise { sigma: 0.5 }, 0, 50);
        assert!(s.validate(6).is_ok());
        assert!(s.has_byzantine());
        assert_eq!(s.byzantine_policy(2, 150), Some(CorruptPolicy::SignFlip));
        assert_eq!(s.byzantine_policy(2, 99), None, "before the window");
        assert_eq!(s.byzantine_policy(2, 200), None, "half-open: honest at until");
        assert_eq!(s.byzantine_policy(3, 150), None, "other agents honest");
        assert_eq!(
            s.byzantine_policy(4, 10),
            Some(CorruptPolicy::ScaledNoise { sigma: 0.5 })
        );
        assert!(!FaultSchedule::new(0).with_drops(0.1, 0, 10).has_byzantine());
    }

    #[test]
    fn colluder_builder_and_query_agree() {
        let s = FaultSchedule::new(0).with_colluders(
            &[4, 1, 4],
            CorruptPolicy::SignFlip,
            100,
            200,
        );
        assert!(s.validate(6).is_ok());
        assert_eq!(s.faults().len(), 3, "one window per listed agent");
        assert_eq!(s.byzantine_agents(), vec![1, 4], "sorted + deduped");
        assert_eq!(s.byzantine_policy(1, 150), Some(CorruptPolicy::SignFlip));
        assert_eq!(s.byzantine_policy(4, 150), Some(CorruptPolicy::SignFlip));
        assert_eq!(s.byzantine_policy(2, 150), None);
        assert!(FaultSchedule::new(0).byzantine_agents().is_empty());
    }

    #[test]
    fn detection_config_defaults_and_validation() {
        let d = DetectionConfig::default();
        assert!(!d.enabled, "detection is off by default (bitwise-inert)");
        assert!(d.validate().is_ok());
        let armed = DetectionConfig::armed();
        assert!(armed.enabled);
        assert!(armed.validate().is_ok());
        assert!(armed.exclude_after >= armed.flag_after);
        let bad = DetectionConfig { flag_after: 0, ..DetectionConfig::armed() };
        assert!(bad.validate().is_err());
        let bad = DetectionConfig { exclude_after: 1, flag_after: 4, ..DetectionConfig::armed() };
        assert!(bad.validate().is_err());
        let bad = DetectionConfig { tail_frac_min: 1.5, ..DetectionConfig::armed() };
        assert!(bad.validate().is_err());
        // A disabled config never fails validation, whatever the knobs.
        let off = DetectionConfig { enabled: false, flag_after: 0, ..DetectionConfig::default() };
        assert!(off.validate().is_ok());
    }

    #[test]
    fn corrupt_policy_tags_and_names_are_stable() {
        let all = [
            CorruptPolicy::SignFlip,
            CorruptPolicy::ScaledNoise { sigma: 1.0 },
            CorruptPolicy::ConstantPsi { value: 1.0 },
            CorruptPolicy::ColludingOffset { magnitude: 1.0 },
        ];
        assert_eq!(all.map(|p| p.tag()), [0, 1, 2, 3]);
        assert_eq!(
            all.map(|p| p.name()),
            ["sign-flip", "scaled-noise", "constant", "colluding-offset"]
        );
    }

    #[test]
    fn bursty_generator_is_deterministic_and_bursty() {
        let mut rng = Pcg64::new(9);
        let g = Graph::generate(12, &Topology::Ring { k: 2 }, &mut rng);
        let a = FaultSchedule::new(0).with_bursty_links(&g, 3, 5_000, 1_000, 200_000, 7);
        let b = FaultSchedule::new(0).with_bursty_links(&g, 3, 5_000, 1_000, 200_000, 7);
        let c = FaultSchedule::new(0).with_bursty_links(&g, 3, 5_000, 1_000, 200_000, 8);
        assert_eq!(a, b, "same seed, same schedule");
        assert_ne!(a, c, "seed moves the schedule");
        assert!(a.validate(12).is_ok());
        // A single link alternates good/bad to the horizon, so its
        // windows form a *burst*: consecutive, ordered, non-overlapping
        // down-windows on one edge — unlike independent churn.
        let single = FaultSchedule::new(0).with_bursty_links(&g, 1, 5_000, 1_000, 200_000, 7);
        let windows: Vec<(usize, usize, u64, u64)> = single
            .faults()
            .iter()
            .map(|f| match f {
                Fault::EdgeDown { u, v, from_us, until_us } => (*u, *v, *from_us, *until_us),
                other => panic!("bursty generator only emits EdgeDown, got {other:?}"),
            })
            .collect();
        assert!(windows.len() >= 2, "200ms horizon / 5ms mean up-time yields a burst");
        let (u0, v0) = (windows[0].0, windows[0].1);
        for w in windows.windows(2) {
            assert_eq!((w[1].0, w[1].1), (u0, v0), "one link, one burst");
            assert!(w[0].3 <= w[1].2, "windows ordered, non-overlapping");
        }
    }
}
