//! Bulk-synchronous message-passing executor.
//!
//! Runs the identical diffusion recursion as
//! [`crate::infer::DiffusionEngine`], but each agent only ever touches its
//! own state plus explicit [`PsiMessage`]s received from graph neighbors —
//! no global matrices. Used to validate that the gemm engine is a faithful
//! simulation and to account communication (paper's efficiency claim:
//! `M` floats per edge per iteration, nothing else).

use crate::error::Result;
use crate::graph::Graph;
use crate::infer::DiffusionParams;
use crate::math::Mat;
use crate::model::{DistributedDictionary, TaskSpec};
use crate::net::message::{MessageStats, PsiMessage};
use crate::obs::{ArgValue, MetricsRegistry, ObsHandle, Track};
use crate::ops::project::clip_linf;

/// Per-agent state in the message-passing simulation.
struct AgentState {
    nu: Vec<f32>,
    psi: Vec<f32>,
    inbox: Vec<PsiMessage>,
}

/// One agent's adapt step (Eq. 31a) in the message-passing executors:
/// `ψ_k = ν_k − μ(c_f/N·ν_k − θ_k x) − (μ/δ)·W_k thr_γ(W_kᵀν_k)`.
///
/// Shared **verbatim** by [`BspNetwork`], the actor executor, and the
/// async executor so their per-agent arithmetic (and floating-point
/// operation order) cannot drift apart — the τ=0 bitwise-BSP parity of
/// [`crate::net::AsyncNetwork`] and the actor-vs-engine equivalence both
/// rest on this. `thr` is a `K`-length scratch buffer.
#[allow(clippy::too_many_arguments)]
#[inline]
pub(crate) fn adapt_step(
    dict: &DistributedDictionary,
    task: &TaskSpec,
    x: &[f32],
    theta_k: f32,
    k: usize,
    nu: &[f32],
    psi: &mut [f32],
    thr: &mut [f32],
    mu: f32,
    cf_over_n: f32,
    inv_delta: f32,
) {
    dict.block_correlations(k, nu, thr);
    let (start, len) = dict.block(k);
    for q in start..start + len {
        thr[q] = task.threshold(thr[q]) * (-mu * inv_delta);
    }
    for (i, p) in psi.iter_mut().enumerate() {
        *p = nu[i] - mu * (cf_over_n * nu[i] - theta_k * x[i]);
    }
    dict.block_accumulate(k, thr, psi);
}

/// Bulk-synchronous network executor.
pub struct BspNetwork {
    agents: Vec<AgentState>,
    /// Combination weights `a[l][k]` aligned with the graph (column = k).
    weights: Mat,
    graph: Graph,
    theta: Vec<f32>,
    stats: MessageStats,
    /// Trace sink (default: disabled). BSP has no time axis, so events
    /// are stamped with the **iteration index** (`tests/obs_parity.rs`
    /// holds the traced ≡ untraced contract here too).
    obs: ObsHandle,
}

impl BspNetwork {
    /// Build over a graph with its (doubly-stochastic) combination matrix.
    ///
    /// Panics on an invalid `informed` set (empty, or an index ≥ `N`) —
    /// the shared θ builder ([`crate::infer::diffusion`]'s, also used by
    /// the engine and the async executor) validates it.
    pub fn new(graph: Graph, weights: Mat, m: usize, informed: Option<&[usize]>) -> Self {
        let n = graph.n();
        assert_eq!(weights.rows(), n);
        let theta = crate::infer::diffusion::build_theta(n, informed)
            .expect("invalid informed-agent set");
        let agents = (0..n)
            .map(|_| AgentState { nu: vec![0.0; m], psi: vec![0.0; m], inbox: Vec::new() })
            .collect();
        BspNetwork {
            agents,
            weights,
            graph,
            theta,
            stats: MessageStats::default(),
            obs: ObsHandle::null(),
        }
    }

    /// Install a trace sink (events are stamped with the iteration index).
    pub fn attach_obs(&mut self, obs: ObsHandle) {
        self.obs = obs;
    }

    /// Publish this executor's accounting into the unified
    /// [`MetricsRegistry`] ([`Self::stats`] stays the typed view).
    pub fn metrics(&self) -> MetricsRegistry {
        let mut r = MetricsRegistry::new();
        r.absorb_message_stats("net", &self.stats);
        r
    }

    /// Run diffusion; agents communicate only along graph edges.
    pub fn run(
        &mut self,
        dict: &DistributedDictionary,
        task: &TaskSpec,
        x: &[f32],
        params: DiffusionParams,
    ) -> Result<()> {
        let n = self.agents.len();
        let m = x.len();
        let cf_over_n = task.conj_grad_scale() / n as f32;
        let inv_delta = 1.0 / task.delta();
        let clip = task.dual_clip();
        let mut thr = vec![0.0f32; dict.k()];

        for iter in 0..params.iters {
            // Adapt: local-only computation (shared step, see `adapt_step`).
            for k in 0..n {
                let ag = &mut self.agents[k];
                adapt_step(
                    dict,
                    task,
                    x,
                    self.theta[k],
                    k,
                    &ag.nu,
                    &mut ag.psi,
                    &mut thr,
                    params.mu,
                    cf_over_n,
                    inv_delta,
                );
            }
            // Exchange: ψ flows along edges only.
            for k in 0..n {
                let psi = self.agents[k].psi.clone();
                for &nb in self.graph.neighbors(k) {
                    let msg = PsiMessage { from: k, iter, psi: psi.clone() };
                    self.stats.record(&msg);
                    self.agents[nb].inbox.push(msg);
                }
            }
            // Combine: a_{kk} ψ_k + Σ incoming a_{ℓk} ψ_ℓ.
            for k in 0..n {
                let akk = self.weights.get(k, k);
                let ag = &mut self.agents[k];
                for i in 0..m {
                    ag.nu[i] = akk * ag.psi[i];
                }
                let inbox = std::mem::take(&mut ag.inbox);
                for msg in &inbox {
                    let w = self.weights.get(msg.from, k);
                    for i in 0..m {
                        self.agents[k].nu[i] += w * msg.psi[i];
                    }
                }
                if let Some(b) = clip {
                    clip_linf(&mut self.agents[k].nu, b);
                }
            }
            // One network-wide ψ exchange completed (see the round
            // convention in `net::message`).
            self.stats.end_round();
            if self.obs.enabled() {
                self.obs.instant(
                    iter as u64,
                    "bsp_round",
                    Track::Run,
                    vec![("messages", ArgValue::U(self.stats.messages as u64))],
                );
            }
        }
        Ok(())
    }

    /// Agent `k`'s dual estimate.
    pub fn nu(&self, k: usize) -> &[f32] {
        &self.agents[k].nu
    }

    /// Traffic statistics.
    pub fn stats(&self) -> MessageStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{metropolis_weights, Topology};
    use crate::infer::DiffusionEngine;
    use crate::model::AtomConstraint;
    use crate::rng::Pcg64;

    /// The message-passing executor and the gemm engine must produce
    /// bit-comparable iterates (same arithmetic, different organization).
    #[test]
    fn bsp_matches_gemm_engine() {
        let (n, m) = (7, 9);
        let mut rng = Pcg64::new(1);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let params = DiffusionParams::new(0.3, 57);

        let mut engine = DiffusionEngine::new(&a, m, None).unwrap();
        engine.run(&dict, &task, &x, params).unwrap();

        let mut bsp = BspNetwork::new(g, a, m, None);
        bsp.run(&dict, &task, &x, params).unwrap();

        for k in 0..n {
            crate::testutil::assert_close(bsp.nu(k), engine.nu(k), 1e-4, 1e-3);
        }
    }

    #[test]
    fn traffic_matches_edge_count() {
        let (n, m) = (6, 5);
        let mut rng = Pcg64::new(2);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::Ring { k: 1 }, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let iters = 10;
        let edges = g.edge_count();
        let mut bsp = BspNetwork::new(g, a, m, None);
        bsp.run(&dict, &task, &x, DiffusionParams::new(0.2, iters)).unwrap();
        let st = bsp.stats();
        // Each undirected edge carries 2 messages per round.
        assert_eq!(st.messages, 2 * edges * iters);
        assert_eq!(st.rounds, iters);
        assert_eq!(st.bytes, st.messages * (16 + m * 4));
    }

    #[test]
    fn huber_clipped_in_bsp_too() {
        let (n, m) = (5, 6);
        let mut rng = Pcg64::new(3);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::NonNegUnitBall, &mut rng)
                .unwrap();
        let g = Graph::generate(n, &Topology::FullyConnected, &mut rng);
        let a = metropolis_weights(&g);
        let mut x = rng.normal_vec(m);
        crate::math::vector::scale(8.0, &mut x);
        let task = TaskSpec::HuberNmf { gamma: 0.1, delta: 0.5, eta: 0.2 };
        let mut bsp = BspNetwork::new(g, a, m, None);
        bsp.run(&dict, &task, &x, DiffusionParams::new(0.4, 100)).unwrap();
        for k in 0..n {
            assert!(crate::math::vector::norm_inf(bsp.nu(k)) <= 1.0 + 1e-6);
        }
    }
}
