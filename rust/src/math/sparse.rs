//! Compressed-sparse-row matrices for neighborhood-sparse combine.
//!
//! A Metropolis combination matrix over a degree-`d` topology has only
//! `N·(d+1)` structural non-zeros, yet the dense combine `V ← AᵀΨ` pays the
//! full `O(N²·M)` gemm. Storing `Aᵀ` in CSR turns combine into the spmm
//! `O(nnz·M) = O(|E|·M)` — the asymptotic win that makes hundreds of agents
//! tractable (see EXPERIMENTS.md §Perf for measured speedups).
//!
//! Row ranges of [`CsrMat::spmm_rows`] are independent, which is what the
//! multi-threaded combine in [`crate::infer::DiffusionEngine`] partitions
//! across workers: each output row is accumulated in CSR index order
//! regardless of the partition, so threaded and serial results are
//! bit-identical.

use crate::error::{DdlError, Result};
use crate::math::Mat;
use std::ops::Range;

/// Immutable CSR matrix of `f32` with sorted column indices per row.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMat {
    rows: usize,
    cols: usize,
    /// Row pointers, length `rows + 1`.
    indptr: Vec<usize>,
    /// Column index of each stored entry, ascending within a row.
    indices: Vec<usize>,
    /// Stored entry values, aligned with `indices`.
    values: Vec<f32>,
}

impl CsrMat {
    /// Build from raw CSR arrays, validating the invariants.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<usize>,
        values: Vec<f32>,
    ) -> Result<Self> {
        if indptr.len() != rows + 1 {
            return Err(DdlError::Shape(format!(
                "csr: indptr length {} != rows + 1 = {}",
                indptr.len(),
                rows + 1
            )));
        }
        if indices.len() != values.len() {
            return Err(DdlError::Shape("csr: indices/values length mismatch".into()));
        }
        if indptr[0] != 0 || indptr[rows] != indices.len() {
            return Err(DdlError::Shape("csr: indptr endpoints inconsistent".into()));
        }
        for r in 0..rows {
            if indptr[r] > indptr[r + 1] {
                return Err(DdlError::Shape(format!("csr: indptr not monotone at row {r}")));
            }
            let row = &indices[indptr[r]..indptr[r + 1]];
            for w in row.windows(2) {
                if w[0] >= w[1] {
                    return Err(DdlError::Shape(format!(
                        "csr: column indices not strictly ascending in row {r}"
                    )));
                }
            }
            if let Some(&last) = row.last() {
                if last >= cols {
                    return Err(DdlError::Shape(format!(
                        "csr: column index {last} out of range in row {r}"
                    )));
                }
            }
        }
        Ok(CsrMat { rows, cols, indptr, indices, values })
    }

    /// Compress a dense matrix, keeping entries with `|v| > tol`.
    pub fn from_dense(a: &Mat, tol: f32) -> Self {
        let (rows, cols) = a.shape();
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for r in 0..rows {
            for (c, &v) in a.row(r).iter().enumerate() {
                if v.abs() > tol {
                    indices.push(c);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        CsrMat { rows, cols, indptr, indices, values }
    }

    /// Compress the *transpose* of a dense matrix without materializing it:
    /// row `r` of the result holds `{a[i][r] : |a[i][r]| > tol}`. This is
    /// how combine matrices enter the engine — `V ← AᵀΨ` wants `Aᵀ` rows.
    pub fn from_dense_transposed(a: &Mat, tol: f32) -> Self {
        let (arows, acols) = a.shape();
        let mut indptr = Vec::with_capacity(acols + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for r in 0..acols {
            for i in 0..arows {
                let v = a.get(i, r);
                if v.abs() > tol {
                    indices.push(i);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        CsrMat { rows: acols, cols: arows, indptr, indices, values }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Stored entry count.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fill fraction `nnz / (rows·cols)`.
    pub fn density(&self) -> f32 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f32 / (self.rows * self.cols) as f32
    }

    /// Column indices and values of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> (&[usize], &[f32]) {
        let span = self.indptr[r]..self.indptr[r + 1];
        (&self.indices[span.clone()], &self.values[span])
    }

    /// Densify (diagnostics and tests).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let (idx, val) = self.row(r);
            for (&c, &v) in idx.iter().zip(val) {
                m.set(r, c, v);
            }
        }
        m
    }

    /// Sparse × dense: `out = self · B` where `B` is row-major
    /// `cols × b_cols` and `out` is row-major `rows × b_cols`.
    pub fn spmm(&self, b: &[f32], b_cols: usize, out: &mut [f32]) {
        debug_assert_eq!(b.len(), self.cols * b_cols);
        debug_assert_eq!(out.len(), self.rows * b_cols);
        self.spmm_rows(0..self.rows, b, b_cols, out);
    }

    /// Row-range spmm: computes output rows `rows` into `out`, which covers
    /// **only** that range (`out.len() == rows.len() * b_cols`). Each output
    /// row accumulates its non-zeros in CSR index order, so any partition
    /// of the row space produces bit-identical results.
    pub fn spmm_rows(&self, rows: Range<usize>, b: &[f32], b_cols: usize, out: &mut [f32]) {
        debug_assert!(rows.end <= self.rows);
        debug_assert_eq!(b.len(), self.cols * b_cols);
        debug_assert_eq!(out.len(), rows.len() * b_cols);
        let base = rows.start;
        for r in rows {
            let out_row = &mut out[(r - base) * b_cols..(r - base + 1) * b_cols];
            out_row.fill(0.0);
            for p in self.indptr[r]..self.indptr[r + 1] {
                let a = self.values[p];
                let b_row = &b[self.indices[p] * b_cols..self.indices[p] * b_cols + b_cols];
                for (o, &bv) in out_row.iter_mut().zip(b_row) {
                    *o += a * bv;
                }
            }
        }
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::blas;
    use crate::rng::Pcg64;

    fn random_sparse_dense(n: usize, m: usize, p: f64, rng: &mut Pcg64) -> Mat {
        Mat::from_fn(n, m, |_, _| if rng.next_f64() < p { rng.next_normal() } else { 0.0 })
    }

    #[test]
    fn from_dense_round_trips() {
        let mut rng = Pcg64::new(1);
        let a = random_sparse_dense(13, 9, 0.3, &mut rng);
        let csr = CsrMat::from_dense(&a, 0.0);
        assert_eq!(csr.to_dense(), a);
        assert!(csr.density() < 0.6);
    }

    #[test]
    fn from_dense_transposed_matches_transpose() {
        let mut rng = Pcg64::new(2);
        let a = random_sparse_dense(11, 7, 0.4, &mut rng);
        let csr = CsrMat::from_dense_transposed(&a, 0.0);
        assert_eq!(csr.rows(), 7);
        assert_eq!(csr.cols(), 11);
        assert_eq!(csr.to_dense(), a.transpose());
    }

    #[test]
    fn spmm_matches_gemm() {
        let mut rng = Pcg64::new(3);
        for &(n, k, m, p) in &[(5usize, 5usize, 8usize, 0.5), (17, 13, 6, 0.2), (1, 9, 4, 0.9)] {
            let a = random_sparse_dense(n, k, p, &mut rng);
            let b = Mat::from_fn(k, m, |_, _| rng.next_normal());
            let csr = CsrMat::from_dense(&a, 0.0);
            let mut out = vec![0.0f32; n * m];
            csr.spmm(b.as_slice(), m, &mut out);
            let mut dense = vec![0.0f32; n * m];
            blas::gemm(n, m, k, 1.0, a.as_slice(), b.as_slice(), 0.0, &mut dense);
            crate::testutil::assert_close(&out, &dense, 1e-5, 1e-5);
        }
    }

    #[test]
    fn spmm_rows_partition_is_bit_identical() {
        let mut rng = Pcg64::new(4);
        let a = random_sparse_dense(12, 12, 0.3, &mut rng);
        let b = Mat::from_fn(12, 5, |_, _| rng.next_normal());
        let csr = CsrMat::from_dense(&a, 0.0);
        let mut full = vec![0.0f32; 12 * 5];
        csr.spmm(b.as_slice(), 5, &mut full);
        let mut parts = vec![0.0f32; 12 * 5];
        for rows in [0..5, 5..9, 9..12] {
            let span = rows.start * 5..rows.end * 5;
            csr.spmm_rows(rows, b.as_slice(), 5, &mut parts[span]);
        }
        assert_eq!(full, parts);
    }

    #[test]
    fn spmm_single_column() {
        let a = Mat::from_vec(2, 3, vec![1.0, 0.0, 2.0, 0.0, 3.0, 0.0]).unwrap();
        let csr = CsrMat::from_dense(&a, 0.0);
        assert_eq!(csr.nnz(), 3);
        let mut y = vec![0.0f32; 2];
        csr.spmm(&[1.0, 1.0, 1.0], 1, &mut y);
        assert_eq!(y, vec![3.0, 3.0]);
    }

    #[test]
    fn from_parts_validates() {
        assert!(CsrMat::from_parts(2, 2, vec![0, 1, 1], vec![0], vec![1.0]).is_ok());
        // Wrong indptr length.
        assert!(CsrMat::from_parts(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err());
        // Column out of range.
        assert!(CsrMat::from_parts(1, 2, vec![0, 1], vec![5], vec![1.0]).is_err());
        // Unsorted columns.
        assert!(CsrMat::from_parts(1, 3, vec![0, 2], vec![2, 0], vec![1.0, 1.0]).is_err());
        // indices/values mismatch.
        assert!(CsrMat::from_parts(1, 2, vec![0, 1], vec![0], vec![1.0, 2.0]).is_err());
    }

    #[test]
    fn empty_rows_are_fine() {
        let a = Mat::zeros(4, 4);
        let csr = CsrMat::from_dense(&a, 0.0);
        assert_eq!(csr.nnz(), 0);
        let mut out = vec![1.0f32; 8];
        csr.spmm(&[1.0; 8], 2, &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }
}
