//! Small statistics helpers used by metrics and the bench harness.

/// Sample mean.
pub fn mean(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().sum::<f64>() / x.len() as f64
}

/// Sample variance (population, divides by n).
pub fn variance(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    let m = mean(x);
    x.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / x.len() as f64
}

/// Standard deviation.
pub fn std_dev(x: &[f64]) -> f64 {
    variance(x).sqrt()
}

/// Median (copies and sorts).
pub fn median(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    let mut v = x.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Median absolute deviation (robust spread measure for bench timings).
pub fn mad(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    let m = median(x);
    let dev: Vec<f64> = x.iter().map(|v| (v - m).abs()).collect();
    median(&dev)
}

/// Percentile in [0, 100] with linear interpolation over an
/// already-sorted slice. The slice must be ascending (as produced by
/// [`Percentiles`]); an empty slice reads 0.0, and `p` outside [0, 100]
/// clamps to the extremes instead of indexing out of bounds.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let max_rank = (sorted.len() - 1) as f64;
    let rank = ((p / 100.0) * max_rank).clamp(0.0, max_rank);
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (rank - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Sort-once percentile reader: pay the `O(n log n)` sort a single time
/// and answer any number of percentile queries against it. The serving
/// reports (p50/p95/p99/max over one latency vector) and the batch
/// controller's latency window both use this instead of re-sorting per
/// call via [`percentile`].
#[derive(Clone, Debug)]
pub struct Percentiles {
    sorted: Vec<f64>,
}

impl Percentiles {
    /// Copy and sort `x` (NaNs are not supported, as in [`median`]).
    pub fn new(x: &[f64]) -> Self {
        let mut sorted = x.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Percentiles { sorted }
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    /// Whether the sample set is empty.
    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// Percentile `p` in [0, 100] with linear interpolation (0.0 when
    /// empty, matching [`percentile`]).
    pub fn get(&self, p: f64) -> f64 {
        percentile_sorted(&self.sorted, p)
    }

    /// Largest sample (0.0 when empty).
    pub fn max(&self) -> f64 {
        self.sorted.last().copied().unwrap_or(0.0)
    }
}

/// Percentile in [0, 100] with linear interpolation. Thin wrapper over
/// [`Percentiles`]; when querying several percentiles of one vector,
/// build the `Percentiles` once instead.
pub fn percentile(x: &[f64], p: f64) -> f64 {
    Percentiles::new(x).get(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        let x = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&x), 2.5);
        assert!((variance(&x) - 1.25).abs() < 1e-12);
        assert!((std_dev(&x) - 1.25f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn mad_robust() {
        let x = [1.0, 1.0, 1.0, 1.0, 100.0];
        assert_eq!(mad(&x), 0.0); // median is 1, most deviations 0
    }

    #[test]
    fn percentile_interp() {
        let x = [0.0, 10.0];
        assert_eq!(percentile(&x, 0.0), 0.0);
        assert_eq!(percentile(&x, 100.0), 10.0);
        assert_eq!(percentile(&x, 50.0), 5.0);
    }

    /// The sort-once reader agrees bitwise with the per-call wrapper at
    /// every queried percentile, including the empty-input convention.
    #[test]
    fn percentiles_match_percentile() {
        let x = [3.0, 1.0, 4.0, 1.5, 9.0, 2.6, 5.0];
        let pct = Percentiles::new(&x);
        assert_eq!(pct.len(), 7);
        assert!(!pct.is_empty());
        for p in [0.0, 12.5, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(pct.get(p).to_bits(), percentile(&x, p).to_bits(), "p = {p}");
        }
        assert_eq!(pct.max(), 9.0);
        let empty = Percentiles::new(&[]);
        assert!(empty.is_empty());
        assert_eq!(empty.get(50.0), 0.0);
        assert_eq!(empty.max(), 0.0);
    }

    #[test]
    fn percentile_sorted_requires_no_resort() {
        let sorted = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&sorted, 50.0), 2.5);
        assert_eq!(percentile_sorted(&[], 50.0), 0.0);
    }

    /// Exact-rank contract at the boundaries: a 1-element vector answers
    /// every percentile with that element, a 2-element vector hits its
    /// endpoints exactly at p = 0/100, and out-of-range p clamps instead
    /// of indexing past the end (the off-by-one this test pinned down:
    /// `rank.ceil()` used to exceed `len − 1` for p > 100 and panic).
    #[test]
    fn percentile_boundaries_exact_rank() {
        let one = Percentiles::new(&[7.5]);
        for p in [-10.0, 0.0, 37.0, 50.0, 100.0, 150.0] {
            assert_eq!(one.get(p), 7.5, "1-element, p = {p}");
        }
        assert_eq!(one.max(), 7.5);

        let two = Percentiles::new(&[10.0, 2.0]);
        assert_eq!(two.get(0.0), 2.0, "p = 0 is the minimum, exactly");
        assert_eq!(two.get(100.0), 10.0, "p = 100 is the maximum, exactly");
        assert_eq!(two.get(50.0), 6.0);
        assert_eq!(two.get(-5.0), 2.0, "below-range clamps to min");
        assert_eq!(two.get(120.0), 10.0, "above-range clamps to max");

        let flat = Percentiles::new(&[4.0; 5]);
        for p in [0.0, 25.0, 50.0, 99.0, 100.0] {
            assert_eq!(flat.get(p), 4.0, "all-equal, p = {p}");
        }

        // Exact ranks land on samples, no interpolation residue: for
        // n = 5, p = 25 is rank 1 exactly.
        let five = Percentiles::new(&[50.0, 10.0, 20.0, 30.0, 40.0]);
        assert_eq!(five.get(25.0), 20.0);
        assert_eq!(five.get(75.0), 40.0);
    }
}
