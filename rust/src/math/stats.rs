//! Small statistics helpers used by metrics and the bench harness.

/// Sample mean.
pub fn mean(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().sum::<f64>() / x.len() as f64
}

/// Sample variance (population, divides by n).
pub fn variance(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    let m = mean(x);
    x.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / x.len() as f64
}

/// Standard deviation.
pub fn std_dev(x: &[f64]) -> f64 {
    variance(x).sqrt()
}

/// Median (copies and sorts).
pub fn median(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    let mut v = x.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Median absolute deviation (robust spread measure for bench timings).
pub fn mad(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    let m = median(x);
    let dev: Vec<f64> = x.iter().map(|v| (v - m).abs()).collect();
    median(&dev)
}

/// Percentile in [0, 100] with linear interpolation.
pub fn percentile(x: &[f64], p: f64) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    let mut v = x.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        let x = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&x), 2.5);
        assert!((variance(&x) - 1.25).abs() < 1e-12);
        assert!((std_dev(&x) - 1.25f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn mad_robust() {
        let x = [1.0, 1.0, 1.0, 1.0, 100.0];
        assert_eq!(mad(&x), 0.0); // median is 1, most deviations 0
    }

    #[test]
    fn percentile_interp() {
        let x = [0.0, 10.0];
        assert_eq!(percentile(&x, 0.0), 0.0);
        assert_eq!(percentile(&x, 100.0), 10.0);
        assert_eq!(percentile(&x, 50.0), 5.0);
    }
}
