//! Dense linear algebra substrate.
//!
//! The build environment is fully offline (no `ndarray`/`nalgebra`), so the
//! library ships its own small, fast, row-major `f32` matrix type plus the
//! kernels the learning stack needs: a blocked gemm microkernel, gemv,
//! vector ops, and a Cholesky solver (used by the Mairal baseline).

pub mod blas;
pub mod matrix;
pub mod solve;
pub mod sparse;
pub mod stats;
pub mod vector;

pub use matrix::Mat;
pub use sparse::CsrMat;
