//! Allocation-free BLAS-like kernels on raw slices.
//!
//! `gemm` is the perf-critical kernel: the diffusion *combine* step
//! `V ← AᵀΨ` dominates the inference flop count (`2·N²·M` per iteration).
//! The implementation is a cache-blocked, register-tiled microkernel
//! (4x8 accumulator tile, unrolled k-loop) that the compiler
//! auto-vectorizes well at `opt-level=3`. See EXPERIMENTS.md §Perf for the
//! measured roofline.

/// `C = alpha * A*B + beta * C` where `A` is `m x k`, `B` is `k x n`,
/// `C` is `m x n`, all row-major.
pub fn gemm(m: usize, n: usize, k: usize, alpha: f32, a: &[f32], b: &[f32], beta: f32, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);

    if beta == 0.0 {
        c.fill(0.0);
    } else if beta != 1.0 {
        for v in c.iter_mut() {
            *v *= beta;
        }
    }
    if alpha == 0.0 || m == 0 || n == 0 || k == 0 {
        return;
    }

    // Cache blocking parameters (L1-friendly for f32 on a typical x86 core).
    const MC: usize = 64;
    const KC: usize = 256;
    const NC: usize = 512;

    for jc in (0..n).step_by(NC) {
        let nb = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kb = KC.min(k - pc);
            for ic in (0..m).step_by(MC) {
                let mb = MC.min(m - ic);
                gemm_block(ic, jc, pc, mb, nb, kb, n, k, alpha, a, b, c);
            }
        }
    }
}

/// Inner blocked panel: C[ic..ic+mb, jc..jc+nb] += alpha * A_panel * B_panel.
#[allow(clippy::too_many_arguments)]
#[inline]
fn gemm_block(
    ic: usize,
    jc: usize,
    pc: usize,
    mb: usize,
    nb: usize,
    kb: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    const MR: usize = 4; // rows per register tile
    const NR: usize = 8; // cols per register tile

    let mut i = 0;
    while i < mb {
        let mr = MR.min(mb - i);
        let mut j = 0;
        while j < nb {
            let nr = NR.min(nb - j);
            if mr == MR && nr == NR {
                micro_4x8(ic + i, jc + j, pc, kb, n, k, alpha, a, b, c);
            } else if nr == NR {
                // Row remainder with a full 8-column tile: the 1x8
                // microkernel walks B row-contiguously (one load of 8
                // B values per k step shared across the 8 accumulators)
                // instead of the strided per-output B walk below. Each
                // output keeps its own accumulator summed over p in
                // ascending order, so results are bit-identical to the
                // scalar edge loop.
                for ii in 0..mr {
                    micro_1x8(ic + i + ii, jc + j, pc, kb, n, k, alpha, a, b, c);
                }
            } else {
                // Edge tile (column remainder): simple loop.
                for ii in 0..mr {
                    let arow = (ic + i + ii) * k + pc;
                    let crow = (ic + i + ii) * n + jc + j;
                    for jj in 0..nr {
                        let mut acc = 0.0f32;
                        for p in 0..kb {
                            acc += a[arow + p] * b[(pc + p) * n + jc + j + jj];
                        }
                        c[crow + jj] += alpha * acc;
                    }
                }
            }
            j += nr;
        }
        i += mr;
    }
}

/// 4x8 register-tiled microkernel.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_4x8(
    row: usize,
    col: usize,
    pc: usize,
    kb: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    let mut acc = [[0.0f32; 8]; 4];
    let a0 = row * k + pc;
    let a1 = (row + 1) * k + pc;
    let a2 = (row + 2) * k + pc;
    let a3 = (row + 3) * k + pc;
    for p in 0..kb {
        let brow = (pc + p) * n + col;
        let bvals = &b[brow..brow + 8];
        let av = [a[a0 + p], a[a1 + p], a[a2 + p], a[a3 + p]];
        for (ai, accrow) in av.iter().zip(acc.iter_mut()) {
            for (jj, accv) in accrow.iter_mut().enumerate() {
                *accv += ai * bvals[jj];
            }
        }
    }
    for (ii, accrow) in acc.iter().enumerate() {
        let crow = (row + ii) * n + col;
        let cv = &mut c[crow..crow + 8];
        for (jj, &v) in accrow.iter().enumerate() {
            cv[jj] += alpha * v;
        }
    }
}

/// 1x8 register-tiled microkernel for the row-remainder edge (m % 4 rows
/// against a full 8-column tile).
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_1x8(
    row: usize,
    col: usize,
    pc: usize,
    kb: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    let mut acc = [0.0f32; 8];
    let a0 = row * k + pc;
    for p in 0..kb {
        let brow = (pc + p) * n + col;
        let bvals = &b[brow..brow + 8];
        let av = a[a0 + p];
        for (accv, &bv) in acc.iter_mut().zip(bvals) {
            *accv += av * bv;
        }
    }
    let crow = row * n + col;
    let cv = &mut c[crow..crow + 8];
    for (cvv, &v) in cv.iter_mut().zip(acc.iter()) {
        *cvv += alpha * v;
    }
}

/// `y = A*x` for row-major `A (m x n)`; `y` is overwritten.
pub fn gemv(m: usize, n: usize, a: &[f32], x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(x.len(), n);
    debug_assert_eq!(y.len(), m);
    for (r, yv) in y.iter_mut().enumerate() {
        *yv = dot(&a[r * n..(r + 1) * n], x);
    }
}

/// `y = Aᵀ*x` for row-major `A (m x n)`; `y` (len n) is overwritten.
pub fn gemv_t(m: usize, n: usize, a: &[f32], x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(x.len(), m);
    debug_assert_eq!(y.len(), n);
    y.fill(0.0);
    for r in 0..m {
        let xr = x[r];
        if xr == 0.0 {
            continue;
        }
        let row = &a[r * n..(r + 1) * n];
        for (yv, &av) in y.iter_mut().zip(row) {
            *yv += xr * av;
        }
    }
}

/// Dot product with 4-way unrolled accumulation (helps the vectorizer and
/// improves numerical behaviour vs a single serial accumulator).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// Rank-1 update `A += alpha * x yᵀ` for row-major `A (m x n)`.
pub fn ger(m: usize, n: usize, alpha: f32, x: &[f32], y: &[f32], a: &mut [f32]) {
    debug_assert_eq!(x.len(), m);
    debug_assert_eq!(y.len(), n);
    debug_assert_eq!(a.len(), m * n);
    for r in 0..m {
        let ax = alpha * x[r];
        if ax == 0.0 {
            continue;
        }
        let row = &mut a[r * n..(r + 1) * n];
        for (av, &yv) in row.iter_mut().zip(y) {
            *av += ax * yv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference triple-loop gemm for validation.
    fn gemm_ref(m: usize, n: usize, k: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    fn pseudo(seed: u64, n: usize) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s >> 11) as f32 / (1u64 << 53) as f32) * 2.0 - 0.5
            })
            .collect()
    }

    #[test]
    fn gemm_matches_reference_various_shapes() {
        // Shapes chosen to exercise every remainder combination of the
        // 4x8 tile: full tiles only, row remainders against full 8-col
        // tiles (the 1x8 microkernel: 5x9x13 hits mr in {1}, nr in
        // {8, 1}; 4x7x8 is column-remainder only; 7x8x5 is row-remainder
        // only; 3x16x4 is all-rows-remainder with two full column
        // tiles; 2x9x3 hits both remainders in one block).
        for &(m, n, k) in &[
            (1, 1, 1),
            (3, 5, 7),
            (4, 8, 16),
            (17, 23, 9),
            (64, 64, 64),
            (65, 70, 33),
            (5, 9, 13),
            (4, 7, 8),
            (7, 8, 5),
            (3, 16, 4),
            (2, 9, 3),
            (6, 24, 11),
        ] {
            let a = pseudo(m as u64, m * k);
            let b = pseudo(n as u64 + 100, k * n);
            let mut c = vec![0.0; m * n];
            gemm(m, n, k, 1.0, &a, &b, 0.0, &mut c);
            let cref = gemm_ref(m, n, k, &a, &b);
            for (x, y) in c.iter().zip(&cref) {
                assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "{m}x{n}x{k}: {x} vs {y}");
            }
        }
    }

    /// The 1x8 remainder microkernel preserves the scalar edge loop's
    /// accumulation order (per-output accumulator, k ascending), so
    /// remainder rows are bit-identical to the naive per-element sum.
    #[test]
    fn gemm_row_remainder_bit_identical_to_scalar_order() {
        let (m, n, k) = (5, 8, 20); // row 4 takes the 1x8 path, one k-block
        let a = pseudo(31, m * k);
        let b = pseudo(32, k * n);
        let mut c = vec![0.0; m * n];
        gemm(m, n, k, 1.0, &a, &b, 0.0, &mut c);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for p in 0..k {
                    acc += a[i * k + p] * b[p * n + j];
                }
                assert_eq!(
                    c[i * n + j].to_bits(),
                    acc.to_bits(),
                    "({i},{j}) drifted from the scalar accumulation order"
                );
            }
        }
    }

    #[test]
    fn gemm_alpha_beta() {
        let (m, n, k) = (5, 6, 4);
        let a = pseudo(1, m * k);
        let b = pseudo(2, k * n);
        let c0 = pseudo(3, m * n);
        let mut c = c0.clone();
        gemm(m, n, k, 2.0, &a, &b, 0.5, &mut c);
        let cref = gemm_ref(m, n, k, &a, &b);
        for i in 0..m * n {
            let expect = 2.0 * cref[i] + 0.5 * c0[i];
            assert!((c[i] - expect).abs() < 1e-4);
        }
    }

    #[test]
    fn gemv_matches_gemm() {
        let (m, n) = (13, 29);
        let a = pseudo(7, m * n);
        let x = pseudo(8, n);
        let mut y = vec![0.0; m];
        gemv(m, n, &a, &x, &mut y);
        let yref = gemm_ref(m, 1, n, &a, &x);
        for i in 0..m {
            assert!((y[i] - yref[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn gemv_t_matches_transpose() {
        let (m, n) = (11, 17);
        let a = pseudo(9, m * n);
        let x = pseudo(10, m);
        let mut y = vec![0.0; n];
        gemv_t(m, n, &a, &x, &mut y);
        // transpose A and gemv
        let mut at = vec![0.0; m * n];
        for r in 0..m {
            for c in 0..n {
                at[c * m + r] = a[r * n + c];
            }
        }
        let mut yref = vec![0.0; n];
        gemv(n, m, &at, &x, &mut yref);
        for i in 0..n {
            assert!((y[i] - yref[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1., 2., 3.], &[4., 5., 6.]), 32.0);
        let a = pseudo(11, 103);
        assert!((dot(&a, &a) - a.iter().map(|v| v * v).sum::<f32>()).abs() < 1e-3);
    }

    #[test]
    fn ger_rank1() {
        let (m, n) = (3, 4);
        let mut a = vec![0.0; m * n];
        ger(m, n, 2.0, &[1., 2., 3.], &[1., 0., 1., 0.], &mut a);
        assert_eq!(a[0], 2.0); // 2*1*1
        assert_eq!(a[2], 2.0);
        assert_eq!(a[1 * n + 0], 4.0);
        assert_eq!(a[2 * n + 2], 6.0);
        assert_eq!(a[1], 0.0);
    }
}
