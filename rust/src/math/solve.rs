//! Direct solvers: Cholesky factorization and triangular solves.
//!
//! Used by the Mairal-2010 centralized baseline (normal-equation lasso
//! warm starts) and by tests that need exact small-system solutions.

use crate::error::{DdlError, Result};
use crate::math::Mat;

/// Cholesky factorization `A = L Lᵀ` of a symmetric positive-definite
/// matrix. Returns the lower-triangular factor `L` (full storage, upper
/// half zeroed).
pub fn cholesky(a: &Mat) -> Result<Mat> {
    let n = a.rows();
    if a.cols() != n {
        return Err(DdlError::Shape("cholesky: matrix not square".into()));
    }
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.get(i, j);
            for k in 0..j {
                s -= l.get(i, k) * l.get(j, k);
            }
            if i == j {
                if s <= 0.0 {
                    return Err(DdlError::Other(format!(
                        "cholesky: not positive definite at pivot {i} (s = {s})"
                    )));
                }
                l.set(i, j, s.sqrt());
            } else {
                l.set(i, j, s / l.get(j, j));
            }
        }
    }
    Ok(l)
}

/// Solve `L x = b` for lower-triangular `L` (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f32]) -> Vec<f32> {
    let n = l.rows();
    debug_assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l.get(i, k) * x[k];
        }
        x[i] = s / l.get(i, i);
    }
    x
}

/// Solve `Lᵀ x = b` for lower-triangular `L` (back substitution).
pub fn solve_lower_t(l: &Mat, b: &[f32]) -> Vec<f32> {
    let n = l.rows();
    debug_assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in i + 1..n {
            s -= l.get(k, i) * x[k];
        }
        x[i] = s / l.get(i, i);
    }
    x
}

/// Solve the SPD system `A x = b` via Cholesky.
pub fn solve_spd(a: &Mat, b: &[f32]) -> Result<Vec<f32>> {
    let l = cholesky(a)?;
    let y = solve_lower(&l, b);
    Ok(solve_lower_t(&l, &y))
}

/// Largest eigenvalue (and eigenvector) of a symmetric matrix via power
/// iteration. Used for Lipschitz-constant estimation in FISTA and for the
/// Laplacian spectral analysis in [`crate::graph`].
pub fn power_iteration(a: &Mat, iters: usize, seed: u64) -> (f32, Vec<f32>) {
    let n = a.rows();
    assert_eq!(a.cols(), n, "power_iteration: square matrix required");
    let mut rng = crate::rng::Pcg64::new(seed);
    let mut v: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
    crate::math::vector::normalize(&mut v);
    let mut lambda = 0.0;
    let mut av = vec![0.0; n];
    for _ in 0..iters {
        crate::math::blas::gemv(n, n, a.as_slice(), &v, &mut av);
        lambda = crate::math::blas::dot(&v, &av);
        let nn = crate::math::vector::norm2(&av);
        if nn == 0.0 {
            return (0.0, v);
        }
        for (vi, &ai) in v.iter_mut().zip(&av) {
            *vi = ai / nn;
        }
    }
    (lambda, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd_matrix(n: usize, seed: u64) -> Mat {
        // A = B Bᵀ + n I is SPD.
        let mut rng = crate::rng::Pcg64::new(seed);
        let b = Mat::from_fn(n, n, |_, _| rng.next_f32() - 0.5);
        let mut a = b.matmul(&b.transpose()).unwrap();
        for i in 0..n {
            a.set(i, i, a.get(i, i) + n as f32);
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = spd_matrix(8, 42);
        let l = cholesky(&a).unwrap();
        let rec = l.matmul(&l.transpose()).unwrap();
        assert!(a.rel_diff(&rec, 1e-3) < 1e-3);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]).unwrap(); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn solve_spd_accurate() {
        let a = spd_matrix(10, 7);
        let x_true: Vec<f32> = (0..10).map(|i| (i as f32) * 0.3 - 1.0).collect();
        let b = a.matvec(&x_true).unwrap();
        let x = solve_spd(&a, &b).unwrap();
        for (xs, xt) in x.iter().zip(&x_true) {
            assert!((xs - xt).abs() < 1e-3, "{xs} vs {xt}");
        }
    }

    #[test]
    fn power_iteration_finds_dominant() {
        // diag(5, 2, 1) has top eigenvalue 5 with e1.
        let a = Mat::from_vec(3, 3, vec![5., 0., 0., 0., 2., 0., 0., 0., 1.]).unwrap();
        let (lambda, v) = power_iteration(&a, 200, 3);
        assert!((lambda - 5.0).abs() < 1e-3);
        assert!(v[0].abs() > 0.99);
    }
}
