//! Row-major dense `f32` matrix.

use crate::error::{DdlError, Result};
use std::fmt;

/// Row-major dense matrix of `f32`.
///
/// The type is deliberately small: it owns a `Vec<f32>` and exposes
/// shape-checked views. Hot-path kernels live in [`crate::math::blas`]
/// and operate on raw slices to keep them allocation-free.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    /// Zero matrix of shape `rows x cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with `v`.
    pub fn full(rows: usize, cols: usize, v: f32) -> Self {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    /// Identity matrix of size `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(DdlError::Shape(format!(
                "from_vec: {}x{} needs {} elements, got {}",
                rows,
                cols,
                rows * cols,
                data.len()
            )));
        }
        Ok(Mat { rows, cols, data })
    }

    /// Build from a function of `(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)`.
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Raw row-major data.
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Mutable raw row-major data.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the backing vector.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Element accessor.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    /// Element setter.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Borrow row `r` mutably.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert!(r < self.rows);
        let c = self.cols;
        &mut self.data[r * c..(r + 1) * c]
    }

    /// Copy column `c` into a fresh vector.
    pub fn col(&self, c: usize) -> Vec<f32> {
        let mut out = vec![0.0; self.rows];
        self.col_into(c, &mut out);
        out
    }

    /// Allocation-free variant of [`Self::col`]: write column `c` into a
    /// caller-provided buffer of length `rows`.
    pub fn col_into(&self, c: usize, out: &mut [f32]) {
        debug_assert!(c < self.cols);
        debug_assert_eq!(out.len(), self.rows);
        for (r, o) in out.iter_mut().enumerate() {
            *o = self.data[r * self.cols + c];
        }
    }

    /// Write `v` into column `c`.
    pub fn set_col(&mut self, c: usize, v: &[f32]) {
        debug_assert_eq!(v.len(), self.rows);
        for (r, &x) in v.iter().enumerate() {
            self.data[r * self.cols + c] = x;
        }
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on larger matrices.
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        t.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        t
    }

    /// Matrix product `self * rhs` (allocates; see `blas::gemm` for the
    /// in-place kernel).
    pub fn matmul(&self, rhs: &Mat) -> Result<Mat> {
        if self.cols != rhs.rows {
            return Err(DdlError::Shape(format!(
                "matmul: {}x{} * {}x{}",
                self.rows, self.cols, rhs.rows, rhs.cols
            )));
        }
        let mut out = Mat::zeros(self.rows, rhs.cols);
        crate::math::blas::gemm(
            self.rows,
            rhs.cols,
            self.cols,
            1.0,
            &self.data,
            &rhs.data,
            0.0,
            &mut out.data,
        );
        Ok(out)
    }

    /// Matrix-vector product `self * x`.
    pub fn matvec(&self, x: &[f32]) -> Result<Vec<f32>> {
        if self.cols != x.len() {
            return Err(DdlError::Shape(format!(
                "matvec: {}x{} * len {}",
                self.rows,
                self.cols,
                x.len()
            )));
        }
        let mut y = vec![0.0; self.rows];
        crate::math::blas::gemv(self.rows, self.cols, &self.data, x, &mut y);
        Ok(y)
    }

    /// `selfᵀ * x` without materializing the transpose.
    pub fn matvec_t(&self, x: &[f32]) -> Result<Vec<f32>> {
        if self.rows != x.len() {
            return Err(DdlError::Shape(format!(
                "matvec_t: ({}x{})ᵀ * len {}",
                self.rows,
                self.cols,
                x.len()
            )));
        }
        let mut y = vec![0.0; self.cols];
        crate::math::blas::gemv_t(self.rows, self.cols, &self.data, x, &mut y);
        Ok(y)
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// In-place `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Mat) -> Result<()> {
        if self.shape() != other.shape() {
            return Err(DdlError::Shape("axpy: shape mismatch".into()));
        }
        crate::math::vector::axpy(alpha, &other.data, &mut self.data);
        Ok(())
    }

    /// In-place scale.
    pub fn scale(&mut self, alpha: f32) {
        for v in &mut self.data {
            *v *= alpha;
        }
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f32 {
        crate::math::vector::norm2(&self.data)
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Sum of all entries.
    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    /// Check shapes and subtract: `self - other`.
    pub fn sub(&self, other: &Mat) -> Result<Mat> {
        if self.shape() != other.shape() {
            return Err(DdlError::Shape("sub: shape mismatch".into()));
        }
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| a - b)
            .collect();
        Ok(Mat { rows: self.rows, cols: self.cols, data })
    }

    /// Max relative elementwise difference against `other`, with absolute
    /// floor `eps` in the denominator (used by cross-validation tests).
    pub fn rel_diff(&self, other: &Mat, eps: f32) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs() / (a.abs().max(b.abs()).max(eps)))
            .fold(0.0f32, f32::max)
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(8);
        for r in 0..show_r {
            write!(f, "  ")?;
            for c in 0..show_c {
                write!(f, "{:10.4} ", self.get(r, c))?;
            }
            writeln!(f, "{}", if self.cols > show_c { "…" } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_eye() {
        let z = Mat::zeros(3, 4);
        assert_eq!(z.shape(), (3, 4));
        assert!(z.as_slice().iter().all(|&v| v == 0.0));
        let i = Mat::eye(3);
        assert_eq!(i.get(0, 0), 1.0);
        assert_eq!(i.get(0, 1), 0.0);
        assert_eq!(i.sum(), 3.0);
    }

    #[test]
    fn from_vec_shape_check() {
        assert!(Mat::from_vec(2, 2, vec![1.0; 3]).is_err());
        let m = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(m.get(1, 0), 3.0);
    }

    #[test]
    fn transpose_round_trip() {
        let m = Mat::from_fn(7, 13, |r, c| (r * 13 + c) as f32);
        let t = m.transpose();
        assert_eq!(t.shape(), (13, 7));
        assert_eq!(t.get(3, 5), m.get(5, 3));
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn matmul_identity() {
        let m = Mat::from_fn(4, 4, |r, c| (r + 2 * c) as f32);
        let i = Mat::eye(4);
        assert_eq!(m.matmul(&i).unwrap(), m);
        assert_eq!(i.matmul(&m).unwrap(), m);
    }

    #[test]
    fn matmul_known_values() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.as_slice(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_shape_mismatch() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn matvec_and_transposed() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(a.matvec(&[1., 1., 1.]).unwrap(), vec![6., 15.]);
        assert_eq!(a.matvec_t(&[1., 1.]).unwrap(), vec![5., 7., 9.]);
    }

    #[test]
    fn col_ops() {
        let mut m = Mat::from_fn(3, 2, |r, c| (r * 2 + c) as f32);
        assert_eq!(m.col(1), vec![1., 3., 5.]);
        let mut buf = [0.0f32; 3];
        m.col_into(1, &mut buf);
        assert_eq!(buf, [1., 3., 5.]);
        m.set_col(0, &[9., 9., 9.]);
        assert_eq!(m.col(0), vec![9., 9., 9.]);
    }

    #[test]
    fn axpy_and_norms() {
        let mut a = Mat::full(2, 2, 1.0);
        let b = Mat::full(2, 2, 2.0);
        a.axpy(0.5, &b).unwrap();
        assert_eq!(a.as_slice(), &[2.0, 2.0, 2.0, 2.0]);
        assert!((a.frob_norm() - 4.0).abs() < 1e-6);
        assert_eq!(a.max_abs(), 2.0);
    }

    #[test]
    fn rel_diff_detects_mismatch() {
        let a = Mat::full(2, 2, 1.0);
        let mut b = a.clone();
        assert_eq!(a.rel_diff(&b, 1e-6), 0.0);
        b.set(0, 0, 1.1);
        assert!(a.rel_diff(&b, 1e-6) > 0.05);
    }
}
