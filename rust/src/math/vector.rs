//! Vector helpers on `&[f32]`.

/// Euclidean norm.
pub fn norm2(x: &[f32]) -> f32 {
    crate::math::blas::dot(x, x).sqrt()
}

/// Squared Euclidean norm.
pub fn norm2_sq(x: &[f32]) -> f32 {
    crate::math::blas::dot(x, x)
}

/// ℓ1 norm.
pub fn norm1(x: &[f32]) -> f32 {
    x.iter().map(|v| v.abs()).sum()
}

/// ℓ∞ norm.
pub fn norm_inf(x: &[f32]) -> f32 {
    x.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

/// `y += alpha * x`.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += alpha * xv;
    }
}

/// `y = x` (copy).
pub fn copy(x: &[f32], y: &mut [f32]) {
    y.copy_from_slice(x);
}

/// In-place scale.
pub fn scale(alpha: f32, x: &mut [f32]) {
    for v in x {
        *v *= alpha;
    }
}

/// Elementwise subtraction into a fresh vector.
pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x - y).collect()
}

/// Elementwise addition into a fresh vector.
pub fn add(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x + y).collect()
}

/// Squared distance `‖a − b‖²`.
pub fn dist_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| (x - y) * (x - y)).sum()
}

/// Normalize to unit ℓ2 norm (no-op on the zero vector). Returns the
/// original norm.
pub fn normalize(x: &mut [f32]) -> f32 {
    let n = norm2(x);
    if n > 0.0 {
        scale(1.0 / n, x);
    }
    n
}

/// Mean of the entries.
pub fn mean(x: &[f32]) -> f32 {
    if x.is_empty() {
        0.0
    } else {
        x.iter().sum::<f32>() / x.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms() {
        let x = [3.0, -4.0];
        assert!((norm2(&x) - 5.0).abs() < 1e-6);
        assert_eq!(norm2_sq(&x), 25.0);
        assert_eq!(norm1(&x), 7.0);
        assert_eq!(norm_inf(&x), 4.0);
    }

    #[test]
    fn axpy_works() {
        let mut y = vec![1.0, 2.0];
        axpy(2.0, &[10.0, 20.0], &mut y);
        assert_eq!(y, vec![21.0, 42.0]);
    }

    #[test]
    fn normalize_unit() {
        let mut x = vec![3.0, 4.0];
        let n = normalize(&mut x);
        assert!((n - 5.0).abs() < 1e-6);
        assert!((norm2(&x) - 1.0).abs() < 1e-6);
        let mut z = vec![0.0, 0.0];
        assert_eq!(normalize(&mut z), 0.0);
        assert_eq!(z, vec![0.0, 0.0]);
    }

    #[test]
    fn arith_helpers() {
        assert_eq!(sub(&[3.0, 1.0], &[1.0, 1.0]), vec![2.0, 0.0]);
        assert_eq!(add(&[3.0, 1.0], &[1.0, 1.0]), vec![4.0, 2.0]);
        assert_eq!(dist_sq(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }
}
