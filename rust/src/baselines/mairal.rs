//! Online dictionary learning (Mairal et al., JMLR 2010) — reference [6].
//!
//! The centralized comparator for Figs. 5–6 / Table III. Alternates:
//!
//! 1. **Sparse coding** of each sample by coordinate descent on the
//!    elastic net `min_y ½‖x − Wy‖² + γ‖y‖₁ + (δ/2)‖y‖²` (with a
//!    non-negative variant for NMF/topic tasks);
//! 2. **Dictionary update** by block-coordinate descent on the surrogate
//!    `½ tr(WᵀWA) − tr(WᵀB)` with the accumulators `A ← A + yyᵀ`,
//!    `B ← B + xyᵀ`, projecting atoms onto the constraint set.

use crate::error::Result;
use crate::math::{blas, Mat};
use crate::model::{AtomConstraint, TaskSpec};
use crate::ops::{soft_threshold, soft_threshold_plus};

/// Coordinate-descent elastic net:
/// `min_y ½‖x − Wy‖² + γ‖y‖₁ + (δ/2)‖y‖²` (two-sided), or the
/// non-negative variant when `nonneg` is set.
///
/// `gram = WᵀW` and `corr = Wᵀx` must be precomputed; `y` is updated in
/// place (warm starts welcome). Returns the number of sweeps used.
pub fn elastic_net_cd(
    gram: &Mat,
    corr: &[f32],
    gamma: f32,
    delta: f32,
    nonneg: bool,
    y: &mut [f32],
    max_sweeps: usize,
    tol: f32,
) -> usize {
    let k = corr.len();
    debug_assert_eq!(gram.rows(), k);
    debug_assert_eq!(y.len(), k);
    // Residual correlation r = corr − Gram·y maintained incrementally.
    let mut r = corr.to_vec();
    for j in 0..k {
        if y[j] != 0.0 {
            let gj = gram.row(j);
            let yj = y[j];
            for i in 0..k {
                r[i] -= gj[i] * yj;
            }
        }
    }
    for sweep in 0..max_sweeps {
        let mut max_delta = 0.0f32;
        for j in 0..k {
            let gjj = gram.get(j, j).max(1e-12);
            // Partial residual excludes y_j's own contribution.
            let rho = r[j] + gjj * y[j];
            let new = if nonneg {
                soft_threshold_plus(rho, gamma) / (gjj + delta)
            } else {
                soft_threshold(rho, gamma) / (gjj + delta)
            };
            let diff = new - y[j];
            if diff != 0.0 {
                let gj = gram.row(j);
                for i in 0..k {
                    r[i] -= gj[i] * diff;
                }
                y[j] = new;
                max_delta = max_delta.max(diff.abs());
            }
        }
        if max_delta < tol {
            return sweep + 1;
        }
    }
    max_sweeps
}

/// Options for the online learner.
#[derive(Clone, Copy, Debug)]
pub struct MairalOptions {
    pub gamma: f32,
    pub delta: f32,
    /// Non-negative coding + atoms (NMF / topic modeling).
    pub nonneg: bool,
    /// Coordinate-descent sweeps per sample.
    pub cd_sweeps: usize,
    /// Dictionary block-coordinate passes per sample.
    pub dict_passes: usize,
}

impl MairalOptions {
    /// Paper §IV-B settings for the denoising comparison.
    pub fn denoising() -> Self {
        MairalOptions { gamma: 45.0, delta: 0.1, nonneg: false, cd_sweeps: 60, dict_passes: 1 }
    }
    /// Paper §IV-C1 settings for the novelty comparison.
    pub fn novelty() -> Self {
        MairalOptions { gamma: 0.05, delta: 0.1, nonneg: true, cd_sweeps: 60, dict_passes: 1 }
    }
}

/// Online dictionary learner with A/B accumulators.
pub struct MairalLearner {
    pub w: Mat,
    a: Mat,
    b: Mat,
    opts: MairalOptions,
    samples_seen: usize,
}

impl MairalLearner {
    pub fn new(w0: Mat, opts: MairalOptions) -> Self {
        let k = w0.cols();
        let m = w0.rows();
        MairalLearner { w: w0, a: Mat::zeros(k, k), b: Mat::zeros(m, k), opts, samples_seen: 0 }
    }

    /// Sparse-code `x` against the current dictionary.
    pub fn code(&self, x: &[f32]) -> Vec<f32> {
        let gram = self.w.transpose().matmul(&self.w).unwrap();
        let corr = self.w.matvec_t(x).unwrap();
        let mut y = vec![0.0f32; self.w.cols()];
        elastic_net_cd(
            &gram,
            &corr,
            self.opts.gamma,
            self.opts.delta,
            self.opts.nonneg,
            &mut y,
            self.opts.cd_sweeps,
            1e-6,
        );
        y
    }

    /// Representation loss `½‖x − Wy‖² + γ‖y‖₁ + (δ/2)‖y‖²` at the coded
    /// solution (the novelty score of the centralized comparator).
    pub fn objective(&self, x: &[f32]) -> f32 {
        let y = self.code(x);
        let wy = self.w.matvec(&y).unwrap();
        let r = crate::math::vector::sub(x, &wy);
        0.5 * crate::math::vector::norm2_sq(&r)
            + self.opts.gamma * crate::math::vector::norm1(&y)
            + 0.5 * self.opts.delta * crate::math::vector::norm2_sq(&y)
    }

    /// Process one sample: code, accumulate, update the dictionary.
    pub fn step(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        let y = self.code(x);
        let k = self.w.cols();
        let m = self.w.rows();
        // A += y yᵀ (+ δI contribution keeps diagonals positive);
        // B += x yᵀ.
        blas::ger(k, k, 1.0, &y, &y, self.a.as_mut_slice());
        blas::ger(m, k, 1.0, x, &y, self.b.as_mut_slice());
        self.samples_seen += 1;
        self.update_dictionary();
        Ok(y)
    }

    /// Block-coordinate dictionary update (Mairal Alg. 2):
    /// `u_j = (b_j − W a_j)/A_jj + w_j`, then project onto the constraint.
    fn update_dictionary(&mut self) {
        let k = self.w.cols();
        let m = self.w.rows();
        for _ in 0..self.opts.dict_passes {
            for j in 0..k {
                let ajj = self.a.get(j, j);
                if ajj < 1e-10 {
                    continue; // atom never used yet
                }
                // w_j ← w_j + (b_j − W a_j)/A_jj, column ops on row-major W.
                let aj = self.a.col(j);
                let waj = self.w.matvec(&aj).unwrap();
                for r in 0..m {
                    let bval = self.b.get(r, j);
                    let cur = self.w.get(r, j);
                    let mut v = cur + (bval - waj[r]) / ajj;
                    if self.opts.nonneg {
                        v = v.max(0.0);
                    }
                    self.w.set(r, j, v);
                }
                // Project onto the unit ball.
                let mut col = self.w.col(j);
                crate::ops::project_unit_ball(&mut col);
                self.w.set_col(j, &col);
            }
        }
    }

    /// Grow the dictionary by `extra` random atoms (novelty time-steps).
    pub fn expand(&mut self, extra: usize, rng: &mut crate::rng::Pcg64) {
        let m = self.w.rows();
        let old_k = self.w.cols();
        let new_k = old_k + extra;
        let mut w = Mat::zeros(m, new_k);
        for r in 0..m {
            w.row_mut(r)[..old_k].copy_from_slice(self.w.row(r));
        }
        for q in old_k..new_k {
            let mut col: Vec<f32> = (0..m)
                .map(|_| {
                    let v = rng.next_normal();
                    if self.opts.nonneg {
                        v.abs()
                    } else {
                        v
                    }
                })
                .collect();
            crate::math::vector::normalize(&mut col);
            w.set_col(q, &col);
        }
        // Preserve accumulator history for old atoms; zero for new.
        let mut a = Mat::zeros(new_k, new_k);
        for r in 0..old_k {
            a.row_mut(r)[..old_k].copy_from_slice(self.a.row(r));
        }
        let mut b = Mat::zeros(m, new_k);
        for r in 0..m {
            b.row_mut(r)[..old_k].copy_from_slice(self.b.row(r));
        }
        self.w = w;
        self.a = a;
        self.b = b;
    }

    /// Constraint-consistent task spec (used by cross-comparison tests).
    pub fn task(&self) -> TaskSpec {
        if self.opts.nonneg {
            TaskSpec::Nmf { gamma: self.opts.gamma, delta: self.opts.delta }
        } else {
            TaskSpec::SparseCoding { gamma: self.opts.gamma, delta: self.opts.delta }
        }
    }

    /// Atom constraint for this learner.
    pub fn constraint(&self) -> AtomConstraint {
        if self.opts.nonneg {
            AtomConstraint::NonNegUnitBall
        } else {
            AtomConstraint::UnitBall
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_dict(m: usize, k: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut w = Mat::from_fn(m, k, |_, _| rng.next_normal());
        crate::model::dictionary::normalize_columns(&mut w);
        w
    }

    /// Coordinate descent must solve the elastic net: validate against the
    /// FISTA dual solver through the primal-dual relationship.
    #[test]
    fn cd_matches_exact_dual_solution() {
        let (m, k) = (12, 6);
        let mut rng = Pcg64::new(1);
        let w = random_dict(m, k, 2);
        let x = rng.normal_vec(m);
        let (gamma, delta) = (0.2f32, 0.5f32);
        let gram = w.transpose().matmul(&w).unwrap();
        let corr = w.matvec_t(&x).unwrap();
        let mut y = vec![0.0f32; k];
        elastic_net_cd(&gram, &corr, gamma, delta, false, &mut y, 500, 1e-9);

        let dict = crate::model::DistributedDictionary::from_mat(w, k).unwrap();
        let task = TaskSpec::SparseCoding { gamma, delta };
        let exact = crate::infer::exact_dual(&dict, &task, &x, 1e-8, 20000).unwrap();
        crate::testutil::assert_close(&y, &exact.y, 1e-3, 1e-2);
    }

    #[test]
    fn cd_nonneg_variant_nonnegative() {
        let (m, k) = (10, 5);
        let mut rng = Pcg64::new(3);
        let w = random_dict(m, k, 4);
        let x = rng.normal_vec(m);
        let gram = w.transpose().matmul(&w).unwrap();
        let corr = w.matvec_t(&x).unwrap();
        let mut y = vec![0.0f32; k];
        elastic_net_cd(&gram, &corr, 0.05, 0.1, true, &mut y, 200, 1e-8);
        assert!(y.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn online_learning_reduces_objective() {
        let (m, k) = (16, 8);
        let mut rng = Pcg64::new(5);
        let planted = random_dict(m, k, 6);
        let sample = |rng: &mut Pcg64| {
            let mut x = vec![0.0f32; m];
            for _ in 0..2 {
                let q = rng.next_below(k as u64) as usize;
                crate::math::vector::axpy(0.5 + rng.next_f32(), &planted.col(q), &mut x);
            }
            x
        };
        let mut learner = MairalLearner::new(
            random_dict(m, k, 7),
            MairalOptions { gamma: 0.05, delta: 0.1, nonneg: false, cd_sweeps: 50, dict_passes: 1 },
        );
        let probe: Vec<Vec<f32>> = (0..20).map(|_| sample(&mut rng)).collect();
        let before: f32 = probe.iter().map(|x| learner.objective(x)).sum();
        for _ in 0..300 {
            let x = sample(&mut rng);
            learner.step(&x).unwrap();
        }
        let after: f32 = probe.iter().map(|x| learner.objective(x)).sum();
        assert!(after < 0.6 * before, "objective did not improve: {before} → {after}");
        // Atoms remain feasible.
        for q in 0..k {
            assert!(crate::math::vector::norm2(&learner.w.col(q)) <= 1.0 + 1e-4);
        }
    }

    #[test]
    fn expand_preserves_atoms_and_accumulators() {
        let mut rng = Pcg64::new(8);
        let mut learner = MairalLearner::new(random_dict(6, 3, 9), MairalOptions::novelty());
        let x: Vec<f32> = rng.normal_vec(6).iter().map(|v| v.abs()).collect();
        learner.step(&x).unwrap();
        let w0 = learner.w.col(0);
        learner.expand(2, &mut rng);
        assert_eq!(learner.w.cols(), 5);
        crate::testutil::assert_close(&learner.w.col(0), &w0, 1e-7, 0.0);
        assert_eq!(learner.a.rows(), 5);
        assert_eq!(learner.b.cols(), 5);
    }
}
