//! Online ℓ1-dictionary learning via ADMM (Kasiviswanathan et al., NIPS
//! 2012) — reference [11], the comparator in Fig. 7 / Table IV.
//!
//! Model: `min_{W,Y} ‖X − WY‖₁ + γ‖Y‖₁` with non-negative atoms in the
//! ℓ1 ball (`‖w‖₁ ≤ 1, w ⪰ 0`) and ℓ1-normalized data.
//!
//! Sparse coding splits `r = x − Wy` and alternates:
//! `y ← argmin γ‖y‖₁ + (ρ/2)‖x − Wy − r + u‖²` (ISTA inner loop),
//! `r ← prox_{‖·‖₁/ρ}(x − Wy + u)` (soft threshold),
//! `u ← u + x − Wy − r` (dual ascent).
//! The dictionary update is projected subgradient descent on
//! `‖x − Wy‖₁` with ℓ1-ball + non-negativity projection.

use crate::math::Mat;
use crate::ops::{project_l1_ball, soft_threshold, soft_threshold_plus};

/// ADMM options (defaults follow the protocol in §IV-C2).
#[derive(Clone, Copy, Debug)]
pub struct AdmmOptions {
    /// ℓ1 weight on the coefficients.
    pub gamma: f32,
    /// Augmented-Lagrangian parameter ρ.
    pub rho: f32,
    /// ADMM iterations per sample (paper caps sparse coding at 35).
    pub admm_iters: usize,
    /// ISTA iterations inside the y-update.
    pub ista_iters: usize,
    /// Dictionary subgradient steps per batch (paper caps at 10).
    pub dict_iters: usize,
    /// Dictionary step size.
    pub dict_step: f32,
    /// Non-negative coefficients.
    pub nonneg: bool,
}

impl Default for AdmmOptions {
    fn default() -> Self {
        AdmmOptions {
            gamma: 1.0,
            rho: 1.0,
            admm_iters: 35,
            ista_iters: 12,
            dict_iters: 10,
            dict_step: 0.05,
            nonneg: true,
        }
    }
}

/// Online ℓ1 dictionary learner.
pub struct AdmmDictLearner {
    pub w: Mat,
    opts: AdmmOptions,
    /// Lipschitz estimate for the ISTA inner step (‖W‖² · ρ).
    lip: f32,
}

impl AdmmDictLearner {
    pub fn new(w0: Mat, opts: AdmmOptions) -> Self {
        let mut s = AdmmDictLearner { w: w0, opts, lip: 1.0 };
        s.refresh_lipschitz();
        s
    }

    /// Recompute the ISTA Lipschitz estimate after external edits to `w`.
    pub fn refresh_lipschitz_pub(&mut self) {
        self.refresh_lipschitz();
    }

    fn refresh_lipschitz(&mut self) {
        let gram = self.w.transpose().matmul(&self.w).unwrap();
        let (sig, _) = crate::math::solve::power_iteration(&gram, 60, 0xADA);
        self.lip = (self.opts.rho * sig.max(1e-6)).max(1e-6);
    }

    /// ADMM sparse coding; returns `(y, r)` with residual split `r`.
    pub fn code(&self, x: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let k = self.w.cols();
        let m = self.w.rows();
        let mut y = vec![0.0f32; k];
        let mut r = vec![0.0f32; m];
        let mut u = vec![0.0f32; m];
        let rho = self.opts.rho;
        let step = 1.0 / self.lip;
        for _ in 0..self.opts.admm_iters {
            // y-step: ISTA on γ‖y‖₁ + (ρ/2)‖x − Wy − r + u‖².
            for _ in 0..self.opts.ista_iters {
                let wy = self.w.matvec(&y).unwrap();
                // grad = −ρ Wᵀ(x − Wy − r + u)
                let mut resid = vec![0.0f32; m];
                for i in 0..m {
                    resid[i] = x[i] - wy[i] - r[i] + u[i];
                }
                let grad = self.w.matvec_t(&resid).unwrap();
                for j in 0..k {
                    let cand = y[j] + step * rho * grad[j];
                    y[j] = if self.opts.nonneg {
                        soft_threshold_plus(cand, step * self.opts.gamma)
                    } else {
                        soft_threshold(cand, step * self.opts.gamma)
                    };
                }
            }
            // r-step: prox of ‖·‖₁/ρ at (x − Wy + u).
            let wy = self.w.matvec(&y).unwrap();
            for i in 0..m {
                r[i] = soft_threshold(x[i] - wy[i] + u[i], 1.0 / rho);
            }
            // u-step.
            for i in 0..m {
                u[i] += x[i] - wy[i] - r[i];
            }
        }
        (y, r)
    }

    /// Representation objective `‖x − Wy‖₁ + γ‖y‖₁` at the coded solution
    /// (the ADMM comparator's novelty score).
    pub fn objective(&self, x: &[f32]) -> f32 {
        let (y, _) = self.code(x);
        let wy = self.w.matvec(&y).unwrap();
        let resid = crate::math::vector::sub(x, &wy);
        crate::math::vector::norm1(&resid) + self.opts.gamma * crate::math::vector::norm1(&y)
    }

    /// Batch dictionary update: projected subgradient on Σ‖x − Wy‖₁.
    pub fn update_dictionary(&mut self, batch: &[(&[f32], Vec<f32>)]) {
        if batch.is_empty() {
            return;
        }
        let m = self.w.rows();
        let k = self.w.cols();
        for _ in 0..self.opts.dict_iters {
            let mut grad = Mat::zeros(m, k);
            for (x, y) in batch {
                let wy = self.w.matvec(y).unwrap();
                // subgrad of ‖x − Wy‖₁ wrt W = −sign(x − Wy) yᵀ
                let sign: Vec<f32> = x
                    .iter()
                    .zip(&wy)
                    .map(|(&xv, &wv)| (xv - wv).signum())
                    .collect();
                crate::math::blas::ger(m, k, -1.0, &sign, y, grad.as_mut_slice());
            }
            let step = self.opts.dict_step / batch.len() as f32;
            for (wv, &gv) in self.w.as_mut_slice().iter_mut().zip(grad.as_slice()) {
                *wv -= step * gv;
            }
            // Project columns onto {‖w‖₁ ≤ 1, w ⪰ 0}.
            for q in 0..k {
                let mut col = self.w.col(q);
                for v in &mut col {
                    *v = v.max(0.0);
                }
                project_l1_ball(&mut col, 1.0);
                self.w.set_col(q, &col);
            }
        }
        self.refresh_lipschitz();
    }

    /// Alternate coding and dictionary updates over a batch (the paper
    /// initializes with 35 alternations).
    pub fn fit_batch(&mut self, xs: &[&[f32]], alternations: usize) {
        for _ in 0..alternations {
            let coded: Vec<(&[f32], Vec<f32>)> =
                xs.iter().map(|&x| (x, self.code(x).0)).collect();
            self.update_dictionary(&coded);
        }
    }

    /// Grow the dictionary with `extra` random non-negative ℓ1-ball atoms.
    pub fn expand(&mut self, extra: usize, rng: &mut crate::rng::Pcg64) {
        let m = self.w.rows();
        let old_k = self.w.cols();
        let new_k = old_k + extra;
        let mut w = Mat::zeros(m, new_k);
        for r in 0..m {
            w.row_mut(r)[..old_k].copy_from_slice(self.w.row(r));
        }
        for q in old_k..new_k {
            let mut col: Vec<f32> = (0..m).map(|_| rng.next_normal().abs()).collect();
            let n1 = crate::math::vector::norm1(&col);
            if n1 > 0.0 {
                crate::math::vector::scale(1.0 / n1, &mut col);
            }
            w.set_col(q, &col);
        }
        self.w = w;
        self.refresh_lipschitz();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn l1_dict(m: usize, k: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut w = Mat::from_fn(m, k, |_, _| rng.next_normal().abs());
        for q in 0..k {
            let mut col = w.col(q);
            let n = crate::math::vector::norm1(&col);
            crate::math::vector::scale(1.0 / n, &mut col);
            w.set_col(q, &col);
        }
        w
    }

    #[test]
    fn coding_reduces_l1_objective_vs_zero() {
        let (m, k) = (20, 6);
        let mut rng = Pcg64::new(1);
        let w = l1_dict(m, k, 2);
        // x built from the dictionary so a good code exists.
        let coeff: Vec<f32> = (0..k).map(|_| rng.next_f32()).collect();
        let x = w.matvec(&coeff).unwrap();
        let learner = AdmmDictLearner::new(w, AdmmOptions { gamma: 0.01, ..Default::default() });
        let (y, _) = learner.code(&x);
        let wy = learner.w.matvec(&y).unwrap();
        let fit = crate::math::vector::norm1(&crate::math::vector::sub(&x, &wy));
        let zero_fit = crate::math::vector::norm1(&x);
        assert!(fit < 0.3 * zero_fit, "fit {fit} vs zero {zero_fit}");
        assert!(y.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn dictionary_stays_feasible_after_update() {
        let (m, k) = (15, 4);
        let mut rng = Pcg64::new(3);
        let mut learner = AdmmDictLearner::new(l1_dict(m, k, 4), AdmmOptions::default());
        let xs: Vec<Vec<f32>> = (0..6)
            .map(|_| {
                let mut x: Vec<f32> = rng.normal_vec(m).iter().map(|v| v.abs()).collect();
                let n = crate::math::vector::norm1(&x);
                crate::math::vector::scale(1.0 / n, &mut x);
                x
            })
            .collect();
        let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        learner.fit_batch(&refs, 3);
        for q in 0..k {
            let col = learner.w.col(q);
            assert!(crate::math::vector::norm1(&col) <= 1.0 + 1e-4);
            assert!(col.iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn training_improves_fit_on_planted_data() {
        let (m, k) = (18, 5);
        let mut rng = Pcg64::new(5);
        let planted = l1_dict(m, k, 6);
        let sample = |rng: &mut Pcg64| {
            let q = rng.next_below(k as u64) as usize;
            let mut x = planted.col(q);
            for v in &mut x {
                *v *= 0.9 + 0.2 * rng.next_f32();
            }
            x
        };
        let mut learner = AdmmDictLearner::new(
            l1_dict(m, k, 7),
            AdmmOptions { gamma: 0.05, dict_step: 0.1, ..Default::default() },
        );
        let probe: Vec<Vec<f32>> = (0..10).map(|_| sample(&mut rng)).collect();
        let before: f32 = probe.iter().map(|x| learner.objective(x)).sum();
        let xs: Vec<Vec<f32>> = (0..40).map(|_| sample(&mut rng)).collect();
        let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        learner.fit_batch(&refs, 8);
        let after: f32 = probe.iter().map(|x| learner.objective(x)).sum();
        assert!(after < before, "objective did not improve: {before} → {after}");
    }

    #[test]
    fn expand_adds_feasible_atoms() {
        let mut rng = Pcg64::new(8);
        let mut learner = AdmmDictLearner::new(l1_dict(10, 3, 9), AdmmOptions::default());
        learner.expand(2, &mut rng);
        assert_eq!(learner.w.cols(), 5);
        for q in 3..5 {
            let col = learner.w.col(q);
            assert!((crate::math::vector::norm1(&col) - 1.0).abs() < 1e-4);
        }
    }
}
