//! Centralized baselines the paper compares against.
//!
//! * [`mairal`] — online dictionary learning of Mairal, Bach, Ponce &
//!   Sapiro (JMLR 2010) [6]: the comparator in Fig. 5 (denoising) and
//!   Fig. 6 / Table III (novelty). Re-implemented from the paper since the
//!   SPAMS toolbox is MATLAB/C++.
//! * [`admm`] — the online ℓ1-dictionary learning of Kasiviswanathan,
//!   Wang, Banerjee & Melville (NIPS 2012) [11]: the comparator in
//!   Fig. 7 / Table IV.

pub mod admm;
pub mod mairal;

pub use admm::{AdmmDictLearner, AdmmOptions};
pub use mairal::{elastic_net_cd, MairalLearner, MairalOptions};
