//! Typed experiment configurations, loadable from TOML with paper-faithful
//! defaults (scaled for the single-core CPU testbed; set
//! `paper_scale = true` to restore the exact paper parameters).

use super::TomlDoc;

/// Diffusion inference hyperparameters (§III-B, §IV-A).
#[derive(Clone, Debug)]
pub struct InferenceConfig {
    /// Diffusion step size μ.
    pub mu: f32,
    /// Number of diffusion iterations per sample.
    pub iters: usize,
    /// ℓ1 weight γ of the elastic net.
    pub gamma: f32,
    /// ℓ2 weight δ of the elastic net.
    pub delta: f32,
    /// Worker threads for the adapt/combine loops (results are identical
    /// for every value; 1 = serial).
    pub threads: usize,
}

/// Image denoising experiment (Fig. 5).
#[derive(Clone, Debug)]
pub struct DenoiseConfig {
    pub seed: u64,
    /// Number of agents = number of atoms (one atom per agent, §IV-B).
    pub agents: usize,
    /// Patch side length (paper: 10 → M = 100).
    pub patch: usize,
    /// Edge probability of the random topology (paper: 0.5).
    pub edge_prob: f64,
    /// Training patch presentations (paper: 1e6; scaled default 12k).
    pub train_samples: usize,
    /// Minibatch size (paper: 4).
    pub minibatch: usize,
    /// Dictionary step size μ_w (paper: 5e-5).
    pub mu_w: f32,
    /// Inference settings for training (paper: μ=0.7, 300 iters).
    pub train_infer: InferenceConfig,
    /// Inference settings for denoising (paper: μ=1.0, 500 iters).
    pub denoise_infer: InferenceConfig,
    /// Synthetic image side (paper image: 1019; scaled default 192).
    pub image_side: usize,
    /// AWGN standard deviation (paper: σ = 50 on 0–255 scale → 14.06 dB).
    pub noise_sigma: f32,
    /// Denoising patch stride (1 = every patch; larger = faster).
    pub denoise_stride: usize,
    /// Informed agents: `None` = all informed, `Some(k)` = only first k.
    pub informed: Option<usize>,
}

impl Default for DenoiseConfig {
    fn default() -> Self {
        DenoiseConfig {
            seed: 0xD1C7,
            agents: 64,
            patch: 10,
            edge_prob: 0.5,
            train_samples: 12_000,
            minibatch: 4,
            mu_w: 5e-5,
            train_infer: InferenceConfig { mu: 0.7, iters: 200, gamma: 45.0, delta: 0.1, threads: 1 },
            denoise_infer: InferenceConfig { mu: 1.0, iters: 300, gamma: 45.0, delta: 0.1, threads: 1 },
            image_side: 192,
            noise_sigma: 50.0,
            denoise_stride: 2,
            informed: None,
        }
    }
}

impl DenoiseConfig {
    /// The paper's exact parameters (§IV-B): N = 196 agents, 1M patches,
    /// 300/500 inference iterations. Expensive on a laptop-class core.
    pub fn paper_scale() -> Self {
        DenoiseConfig {
            agents: 196,
            train_samples: 1_000_000,
            train_infer: InferenceConfig { mu: 0.7, iters: 300, gamma: 45.0, delta: 0.1, threads: 1 },
            denoise_infer: InferenceConfig { mu: 1.0, iters: 500, gamma: 45.0, delta: 0.1, threads: 1 },
            image_side: 1019,
            denoise_stride: 1,
            ..Default::default()
        }
    }

    /// Load from TOML (section `[denoise]`), falling back to defaults.
    pub fn from_toml(doc: &TomlDoc) -> Self {
        let mut c = if doc.bool_or("denoise", "paper_scale", false) {
            Self::paper_scale()
        } else {
            Self::default()
        };
        c.seed = doc.usize_or("denoise", "seed", c.seed as usize) as u64;
        c.agents = doc.usize_or("denoise", "agents", c.agents);
        c.patch = doc.usize_or("denoise", "patch", c.patch);
        c.edge_prob = doc.f32_or("denoise", "edge_prob", c.edge_prob as f32) as f64;
        c.train_samples = doc.usize_or("denoise", "train_samples", c.train_samples);
        c.minibatch = doc.usize_or("denoise", "minibatch", c.minibatch);
        c.mu_w = doc.f32_or("denoise", "mu_w", c.mu_w);
        c.train_infer.mu = doc.f32_or("denoise", "train_mu", c.train_infer.mu);
        c.train_infer.iters = doc.usize_or("denoise", "train_iters", c.train_infer.iters);
        c.train_infer.gamma = doc.f32_or("denoise", "gamma", c.train_infer.gamma);
        c.train_infer.delta = doc.f32_or("denoise", "delta", c.train_infer.delta);
        c.denoise_infer.gamma = c.train_infer.gamma;
        c.denoise_infer.delta = c.train_infer.delta;
        c.denoise_infer.mu = doc.f32_or("denoise", "denoise_mu", c.denoise_infer.mu);
        c.denoise_infer.iters = doc.usize_or("denoise", "denoise_iters", c.denoise_infer.iters);
        c.image_side = doc.usize_or("denoise", "image_side", c.image_side);
        c.noise_sigma = doc.f32_or("denoise", "noise_sigma", c.noise_sigma);
        c.denoise_stride = doc.usize_or("denoise", "denoise_stride", c.denoise_stride);
        let threads = doc.usize_or("denoise", "threads", c.train_infer.threads);
        c.train_infer.threads = threads;
        c.denoise_infer.threads = threads;
        c
    }
}

/// Feedback control plane (`serve/control.rs`, `net/tau_control.rs`):
/// measurement-driven batch size, pipeline depth, and staleness τ. Loaded
/// from the TOML section `[control]`; enabled per loop via
/// `ddl serve --adaptive` / `ddl async --adaptive-tau` or the TOML keys.
///
/// Every controller decision is a pure function of (this config, seed,
/// measured history on the virtual µs clocks), so adaptive runs replay
/// bit-identically; with [`Self::enabled`] false (the default) the serve
/// executors take exactly their static PR 3 code paths, and with
/// [`Self::adaptive_tau`] false `ddl async` is untouched.
#[derive(Clone, Debug)]
pub struct ControlConfig {
    /// Master switch for the serve-side controllers (batch + depth).
    pub enabled: bool,
    /// p99 request-latency SLO (ms) the batch controller steers to.
    pub slo_p99_ms: f64,
    /// Batch-controller decision cadence on the virtual clock (µs).
    pub tick_us: u64,
    /// Bounds for the adaptive `max_batch` knob.
    pub batch_min: usize,
    pub batch_max: usize,
    /// Bounds for the adaptive `max_wait_us` knob.
    pub wait_min_us: u64,
    pub wait_max_us: u64,
    /// Sliding window of completed-request latencies feeding the p99
    /// estimate (and of recent batch sizes feeding the fill estimate).
    /// The batch controller clamps this up to its actionable-p99 floor
    /// (16 samples) so a tiny window cannot silently disable SLO
    /// steering.
    pub window: usize,
    /// Virtual service-time model used by adaptive sessions in place of
    /// measured wall time (the determinism anchor): one batch of `B`
    /// samples costs `svc_base_us + svc_per_sample_us · B` µs in the
    /// serial loop / inference stage.
    pub svc_base_us: u64,
    pub svc_per_sample_us: u64,
    /// Virtual Eq. 51 update-stage cost per sample (µs), pipeline mode.
    pub upd_per_sample_us: u64,
    /// Calibrate the service model from the first [`Self::calib_batches`]
    /// measured (batch size, wall service µs) pairs of the session, then
    /// freeze the fitted model for the rest of the run. The fit itself is a
    /// pure function of the observed samples (`serve/control.rs`,
    /// `ServiceCalibrator`), but the samples are wall-clock measurements —
    /// so a calibrated session tracks this machine's real service law at
    /// the price of cross-machine bit-replay. Default false: adaptive
    /// sessions stay on the configured model and replay bit-identically.
    pub calibrate: bool,
    /// Leading batches fed to the calibrator before it freezes.
    pub calib_batches: usize,
    /// Depth-controller bounds (pipeline mode) and the re-plan epoch in
    /// batches; depth moves by at most ±1 per epoch boundary so the swap
    /// schedule stays well-defined.
    pub depth_min: usize,
    pub depth_max: usize,
    pub epoch_batches: usize,
    /// Master switch for the τ controller (`ddl async --adaptive-tau`).
    pub adaptive_tau: bool,
    /// Bounds for the adaptive staleness τ.
    pub tau_min: usize,
    pub tau_max: usize,
    /// τ-controller decision epoch on the simulated clock (µs).
    pub tau_epoch_us: u64,
    /// Widen τ (+1) when the per-epoch gate-wait fraction of simulated
    /// time exceeds this.
    pub gate_wait_hi: f64,
    /// Narrow τ (−1) when the relative MSD excess versus the τ = 0 probe
    /// exceeds this bound.
    pub msd_drift_bound: f64,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig {
            enabled: false,
            slo_p99_ms: 20.0,
            tick_us: 2_000,
            batch_min: 1,
            batch_max: 64,
            wait_min_us: 0,
            wait_max_us: 50_000,
            window: 512,
            svc_base_us: 800,
            svc_per_sample_us: 150,
            upd_per_sample_us: 60,
            calibrate: false,
            calib_batches: 12,
            depth_min: 1,
            depth_max: 4,
            epoch_batches: 16,
            adaptive_tau: false,
            tau_min: 0,
            tau_max: 16,
            tau_epoch_us: 20_000,
            gate_wait_hi: 0.25,
            msd_drift_bound: 0.5,
        }
    }
}

impl ControlConfig {
    /// Load from TOML (section `[control]`), falling back to defaults.
    /// Bounds are sanitized so `min ≤ max` always holds.
    pub fn from_toml(doc: &TomlDoc) -> Self {
        let defaults = Self::default();
        let mut c = defaults;
        c.enabled = doc.bool_or("control", "enabled", c.enabled);
        c.slo_p99_ms = doc.f32_or("control", "slo_p99_ms", c.slo_p99_ms as f32) as f64;
        c.tick_us = doc.usize_or("control", "tick_us", c.tick_us as usize) as u64;
        c.batch_min = doc.usize_or("control", "batch_min", c.batch_min).max(1);
        c.batch_max = doc.usize_or("control", "batch_max", c.batch_max).max(c.batch_min);
        c.wait_min_us = doc.usize_or("control", "wait_min_us", c.wait_min_us as usize) as u64;
        c.wait_max_us = (doc.usize_or("control", "wait_max_us", c.wait_max_us as usize) as u64)
            .max(c.wait_min_us);
        c.window = doc.usize_or("control", "window", c.window).max(1);
        c.svc_base_us = doc.usize_or("control", "svc_base_us", c.svc_base_us as usize) as u64;
        c.svc_per_sample_us =
            doc.usize_or("control", "svc_per_sample_us", c.svc_per_sample_us as usize) as u64;
        c.upd_per_sample_us =
            doc.usize_or("control", "upd_per_sample_us", c.upd_per_sample_us as usize) as u64;
        c.calibrate = doc.bool_or("control", "calibrate", c.calibrate);
        c.calib_batches = doc.usize_or("control", "calib_batches", c.calib_batches).max(2);
        c.depth_min = doc.usize_or("control", "depth_min", c.depth_min).max(1);
        c.depth_max = doc.usize_or("control", "depth_max", c.depth_max).max(c.depth_min);
        c.epoch_batches = doc.usize_or("control", "epoch_batches", c.epoch_batches).max(1);
        c.adaptive_tau = doc.bool_or("control", "adaptive_tau", c.adaptive_tau);
        c.tau_min = doc.usize_or("control", "tau_min", c.tau_min);
        c.tau_max = doc.usize_or("control", "tau_max", c.tau_max).max(c.tau_min);
        c.tau_epoch_us =
            (doc.usize_or("control", "tau_epoch_us", c.tau_epoch_us as usize) as u64).max(1);
        c.gate_wait_hi = doc.f32_or("control", "gate_wait_hi", c.gate_wait_hi as f32) as f64;
        c.msd_drift_bound =
            doc.f32_or("control", "msd_drift_bound", c.msd_drift_bound as f32) as f64;
        c
    }
}

/// Convergence-aware online adaptation (`learn/convergence.rs`): freeze
/// the Eq. 51 update when the dictionary stops drifting, thaw it when the
/// stream shifts. Loaded from the TOML section `[convergence]`.
///
/// Disabled by default (`tol = 0`): the serve executors then take exactly
/// their pre-detector code paths, bit-for-bit. When enabled, every
/// freeze/thaw decision is a pure function of (this config, the observed
/// dictionary bytes, the observed batch losses) — no RNG draws, no clock
/// reads — so freeze/thaw points replay bit-identically
/// (`tests/convergence_freeze.rs`).
#[derive(Clone, Debug)]
pub struct ConvergenceConfig {
    /// Relative dictionary-drift tolerance: adaptation freezes once
    /// `‖D_j − D_{j−w}‖_F / ‖D_{j−w}‖_F` has stayed below this for
    /// [`Self::max_no_improvement`] consecutive windows. `0` (default)
    /// disables the detector entirely.
    pub tol: f64,
    /// Window length `w` in batches between drift measurements.
    pub window: usize,
    /// Consecutive below-`tol` windows before the freeze fires
    /// (sklearn's `max_no_improvement` semantics).
    pub max_no_improvement: usize,
    /// Thaw when the sliding mean batch loss while frozen exceeds this
    /// multiple of the freeze-time mean loss (the drift norm is zero by
    /// construction while the dictionary is frozen, so thaw monitors the
    /// loss the frozen dictionary achieves on the live stream — a
    /// distribution shift elevates it).
    pub thaw_ratio: f64,
    /// Sliding window of batch losses feeding both the freeze-time
    /// reference loss and the frozen-mode thaw monitor.
    pub loss_window: usize,
}

impl Default for ConvergenceConfig {
    fn default() -> Self {
        ConvergenceConfig {
            tol: 0.0,
            window: 8,
            max_no_improvement: 2,
            thaw_ratio: 1.5,
            loss_window: 8,
        }
    }
}

impl ConvergenceConfig {
    /// Whether the detector is active at all.
    pub fn enabled(&self) -> bool {
        self.tol > 0.0
    }

    /// Load from TOML (section `[convergence]`), falling back to defaults.
    pub fn from_toml(doc: &TomlDoc) -> Self {
        let mut c = Self::default();
        c.tol = doc.f32_or("convergence", "tol", c.tol as f32) as f64;
        c.window = doc.usize_or("convergence", "window", c.window).max(1);
        c.max_no_improvement =
            doc.usize_or("convergence", "max_no_improvement", c.max_no_improvement).max(1);
        c.thaw_ratio = doc.f32_or("convergence", "thaw_ratio", c.thaw_ratio as f32) as f64;
        c.loss_window = doc.usize_or("convergence", "loss_window", c.loss_window).max(1);
        c
    }
}

/// Streaming inference service (`ddl serve`, `serve/` subsystem).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub seed: u64,
    /// Number of agents `N` (= atoms; one atom per agent, §IV-B).
    pub agents: usize,
    /// Data dimension `M` (e.g. 100 for 10×10 patches).
    pub dim: usize,
    /// Topology: `ring` | `grid` | `er` | `full`.
    pub topology: String,
    /// Neighbors per side for the ring topology.
    pub ring_k: usize,
    /// Edge probability for the `er` topology.
    pub edge_prob: f64,
    /// Micro-batch size cap `B` handed to the batched engine.
    pub batch: usize,
    /// Longest a queued request may wait (µs) before a partial batch is
    /// released.
    pub max_wait_us: u64,
    /// Stream length (requests served per session).
    pub samples: usize,
    /// Arrival rate in requests/second; `0` = saturated (peak-throughput
    /// mode: every request is available at t = 0).
    pub rate: f64,
    /// Arrival burstiness: requests arrive in clumps of this size (one
    /// shared timestamp per clump, exponential gaps between clumps scaled
    /// so the mean rate is preserved). `1` (default) is the plain Poisson
    /// stream; only meaningful when `rate > 0`.
    pub burst: usize,
    /// Dictionary step size μ_w for the online update; `0` freezes the
    /// dictionary (inference-only serving).
    pub mu_w: f32,
    /// Run the three-stage concurrent pipeline (`serve/pipeline.rs`):
    /// batch formation, diffusion inference, and the Eq. 51 update overlap
    /// on separate threads with a double-buffered dictionary.
    pub pipeline: bool,
    /// Batches in flight in the inference stage (pipeline mode only;
    /// clamped to ≥ 1). Updates lag inference by exactly this depth —
    /// the fixed swap schedule that keeps the pipeline bit-reproducible.
    pub pipeline_depth: usize,
    /// Admission-queue capacity bound: arrivals beyond this many pending
    /// requests are load-shed with a typed
    /// [`crate::DdlError::QueueFull`] and counted. `0` (default) =
    /// unbounded (the pre-capacity behavior).
    pub queue_capacity: usize,
    /// Fault injection: the inference worker owning this pipeline slot
    /// dies when it receives batch [`Self::kill_at_batch`] (`None` =
    /// nobody dies; spell it `kill_slot = -1` in TOML). The victim's
    /// batch and all later work re-dispatch deterministically to the
    /// surviving slots. Pipeline mode only.
    pub kill_slot: Option<usize>,
    /// Global batch index at which [`Self::kill_slot`] dies.
    pub kill_at_batch: usize,
    /// Diffusion inference settings for each served batch.
    pub infer: InferenceConfig,
    /// Informed agents: `None` = all informed, `Some(k)` = only first k.
    pub informed: Option<usize>,
    /// Workload generator for the request stream:
    /// `planted` (default; 2-sparse codes over a planted dictionary) |
    /// `shift` (piecewise-stationary: the planted dictionary is redrawn at
    /// seed-derived boundaries — the thaw/controller test bed) |
    /// `field` (spatially-correlated sensor-network field snapshots,
    /// `data/field.rs`).
    pub stream: String,
    /// Number of distribution shifts for the `shift` stream (the stream
    /// has `shift_count + 1` stationary segments).
    pub shift_count: usize,
    /// Gaussian bumps per field snapshot (`field` stream).
    pub field_sources: usize,
    /// Bump width (std-dev) in unit-square coordinates (`field` stream).
    pub field_width: f32,
    /// Per-sensor observation noise σ (`field` stream).
    pub field_noise: f32,
    /// Data poisoning (`ddl serve --poison`): corrupt a seed-derived
    /// fraction of inbound sample vectors with large additive noise
    /// *after* stream generation, from a dedicated RNG stream — the
    /// arrival process and honest sample bits are untouched, so a
    /// `poison_frac = 0` run is bit-identical to an unpoisoned one.
    pub poison: bool,
    /// Fraction of stream samples the poisoner corrupts.
    pub poison_frac: f64,
    /// Scale of the additive Gaussian corruption per coordinate.
    pub poison_scale: f32,
    /// Robust norm-outlier screen in the batch former: quarantine
    /// poisoned samples before they reach the Eq. 51 update
    /// (`serve/queue.rs::screen_batch`). Only meaningful with
    /// [`Self::poison`]; on by default so `--poison` is defended unless
    /// the screen is explicitly disabled (the undefended comparison run).
    pub poison_screen: bool,
    /// Screen aggressiveness `z`: threshold = median + max(z·1.4826·MAD,
    /// 0.5·median) over the post-poison stream norms.
    pub poison_screen_z: f64,
    /// Convergence detector (`[convergence]` TOML block, `--conv-tol`).
    pub convergence: ConvergenceConfig,
    /// Feedback control plane (`[control]` TOML block, `--adaptive`).
    pub control: ControlConfig,
    /// Observability layer (`[obs]` TOML block, `--trace`).
    pub obs: ObsConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            seed: 0x5E12_4E,
            agents: 100,
            dim: 100,
            topology: "grid".into(),
            ring_k: 2,
            edge_prob: 0.1,
            batch: 8,
            max_wait_us: 2_000,
            samples: 512,
            rate: 0.0,
            burst: 1,
            mu_w: 0.05,
            pipeline: false,
            pipeline_depth: 2,
            queue_capacity: 0,
            kill_slot: None,
            kill_at_batch: 0,
            infer: InferenceConfig { mu: 0.4, iters: 120, gamma: 0.08, delta: 0.2, threads: 1 },
            informed: None,
            stream: "planted".into(),
            shift_count: 2,
            field_sources: 3,
            field_width: 0.15,
            field_noise: 0.02,
            poison: false,
            poison_frac: 0.08,
            poison_scale: 25.0,
            poison_screen: true,
            poison_screen_z: 6.0,
            convergence: ConvergenceConfig::default(),
            control: ControlConfig::default(),
            obs: ObsConfig::default(),
        }
    }
}

impl ServeConfig {
    /// Load from TOML (section `[serve]`), falling back to defaults.
    pub fn from_toml(doc: &TomlDoc) -> Self {
        let defaults = Self::default();
        let mut c = defaults;
        c.seed = doc.usize_or("serve", "seed", c.seed as usize) as u64;
        c.agents = doc.usize_or("serve", "agents", c.agents);
        c.dim = doc.usize_or("serve", "dim", c.dim);
        c.topology = doc.str_or("serve", "topology", &c.topology).to_string();
        c.ring_k = doc.usize_or("serve", "ring_k", c.ring_k);
        c.edge_prob = doc.f32_or("serve", "edge_prob", c.edge_prob as f32) as f64;
        c.batch = doc.usize_or("serve", "batch", c.batch).max(1);
        c.max_wait_us = doc.usize_or("serve", "max_wait_us", c.max_wait_us as usize) as u64;
        c.samples = doc.usize_or("serve", "samples", c.samples);
        c.rate = doc.f32_or("serve", "rate", c.rate as f32) as f64;
        c.burst = doc.usize_or("serve", "burst", c.burst).max(1);
        c.mu_w = doc.f32_or("serve", "mu_w", c.mu_w);
        c.pipeline = doc.bool_or("serve", "pipeline", c.pipeline);
        c.pipeline_depth = doc.usize_or("serve", "pipeline_depth", c.pipeline_depth).max(1);
        c.queue_capacity = doc.usize_or("serve", "queue_capacity", c.queue_capacity);
        if let Some(v) = doc.get("serve", "kill_slot") {
            if let Some(i) = v.as_i64() {
                c.kill_slot = if i < 0 { None } else { Some(i as usize) };
            }
        }
        c.kill_at_batch = doc.usize_or("serve", "kill_at_batch", c.kill_at_batch);
        c.infer.mu = doc.f32_or("serve", "mu", c.infer.mu);
        c.infer.iters = doc.usize_or("serve", "iters", c.infer.iters);
        c.infer.gamma = doc.f32_or("serve", "gamma", c.infer.gamma);
        c.infer.delta = doc.f32_or("serve", "delta", c.infer.delta);
        c.infer.threads = doc.usize_or("serve", "threads", c.infer.threads);
        if let Some(v) = doc.get("serve", "informed") {
            c.informed = v.as_usize();
        }
        c.stream = doc.str_or("serve", "stream", &c.stream).to_string();
        c.shift_count = doc.usize_or("serve", "shift_count", c.shift_count);
        c.field_sources = doc.usize_or("serve", "field_sources", c.field_sources).max(1);
        c.field_width = doc.f32_or("serve", "field_width", c.field_width);
        c.field_noise = doc.f32_or("serve", "field_noise", c.field_noise);
        c.poison = doc.bool_or("serve", "poison", c.poison);
        c.poison_frac =
            (doc.f32_or("serve", "poison_frac", c.poison_frac as f32) as f64).clamp(0.0, 1.0);
        c.poison_scale = doc.f32_or("serve", "poison_scale", c.poison_scale);
        c.poison_screen = doc.bool_or("serve", "poison_screen", c.poison_screen);
        c.poison_screen_z =
            (doc.f32_or("serve", "poison_screen_z", c.poison_screen_z as f32) as f64).max(0.0);
        c.convergence = ConvergenceConfig::from_toml(doc);
        c.control = ControlConfig::from_toml(doc);
        c.obs = ObsConfig::from_toml(doc);
        c
    }
}

/// Deterministic fault-injection layer over the async executor
/// (`ddl chaos`, `net/chaos.rs`). Loaded from the TOML section `[chaos]`.
///
/// The window knobs are *fractions of the fault-free baseline horizon* T
/// (the simulated time the unfaulted run needs for its full iteration
/// budget): the chaos driver first runs the clean baseline to pin T, then
/// scales the schedule to it, so one config stresses any network size.
/// Every fault event is a pure function of ([`Self::seed`], sim-time) —
/// chaos runs replay bit-identically, and with [`Self::enabled`] false
/// (the default) the schedule is empty and `ddl async` is bit-for-bit
/// untouched.
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    /// Master switch; the `ddl chaos` subcommand forces it on.
    pub enabled: bool,
    /// Chaos seed: drop coins and churn windows derive from it,
    /// independently of the executor's delay/ordering streams.
    pub seed: u64,
    /// Fraction of agents on the cut side of the healing partition
    /// (`0` disables the partition; clamped so both sides are non-empty).
    pub partition_frac: f64,
    /// Partition onset as a fraction of the baseline horizon T.
    pub partition_start_frac: f64,
    /// Partition duration as a fraction of T (the reference experiment
    /// heals after 20% of the horizon).
    pub partition_len_frac: f64,
    /// Message-drop probability over the whole run (`0` disables).
    pub drop_prob: f64,
    /// Crash/recover this agent across the partition window
    /// (`None` = nobody crashes; spell it `crash_agent = -1` in TOML).
    pub crash_agent: Option<usize>,
    /// Number of links running the Gilbert–Elliott bursty up/down process
    /// generated from the seed (`0` disables link churn).
    pub churn_windows: usize,
    /// Combine selection: `auto` (push-sum iff the live topology loses
    /// symmetry) | `on` (force push-sum) | `off` (force Metropolis) |
    /// `median` | `trimmed:<f>` (Byzantine-resilient aggregation).
    pub pushsum: String,
    /// Byzantine attacker: this agent transmits corrupted ψ for the whole
    /// run (`None` = everyone honest; spell it `byzantine_agent = -1` in
    /// TOML).
    pub byzantine_agent: Option<usize>,
    /// Corruption policy of the attacker: `sign-flip` | `scaled-noise` |
    /// `constant` | `colluding-offset` (unit parameters; see
    /// [`crate::net::CorruptPolicy`]).
    pub byzantine_policy: String,
    /// Colluding attacker set (f > 1): comma-separated agent indices, e.g.
    /// `byzantine_agents = "3,7"`. Every listed agent transmits under the
    /// same [`Self::byzantine_policy`] for the whole run. Merged with
    /// [`Self::byzantine_agent`] (either spelling works; both together
    /// dedup). Empty (default) = use `byzantine_agent` alone.
    pub byzantine_agents: String,
    /// Detection-and-exclusion layer over the resilient combine
    /// (`--detect`): per-neighbor reputation scores accumulate
    /// trimmed-tail + distance evidence each combine; past
    /// [`Self::detect_exclude_after`] consecutive strikes the neighbor is
    /// excluded and its weight renormalized away. Pure function of
    /// (config, sim-time, ψ bits) — zero RNG draws — so detection runs
    /// replay bit-identically and a zero-attacker detection run is
    /// bitwise the detection-off run.
    pub detect: bool,
    /// Consecutive evidence strikes before a neighbor is flagged
    /// (observability only; exclusion is the enforcement step).
    pub detect_flag_after: usize,
    /// Consecutive evidence strikes before a neighbor is excluded.
    pub detect_exclude_after: usize,
    /// Probation: re-admit an excluded neighbor after this much sim-time
    /// (µs) with a clean slate; `0` (default) = exclusion is permanent.
    pub detect_probation_us: u64,
    /// Local iterations before the evidence pass arms (the transient
    /// phase looks anomalous to any distance statistic).
    pub detect_warmup: usize,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            enabled: false,
            seed: 0xC4A05,
            partition_frac: 0.2,
            partition_start_frac: 0.4,
            partition_len_frac: 0.2,
            drop_prob: 0.0,
            crash_agent: None,
            churn_windows: 0,
            pushsum: "auto".into(),
            byzantine_agent: None,
            byzantine_policy: "sign-flip".into(),
            byzantine_agents: String::new(),
            detect: false,
            detect_flag_after: 6,
            detect_exclude_after: 12,
            detect_probation_us: 0,
            detect_warmup: 8,
        }
    }
}

impl ChaosConfig {
    /// Load from TOML (section `[chaos]`), falling back to defaults.
    pub fn from_toml(doc: &TomlDoc) -> Self {
        let mut c = Self::default();
        c.enabled = doc.bool_or("chaos", "enabled", c.enabled);
        c.seed = doc.usize_or("chaos", "seed", c.seed as usize) as u64;
        c.partition_frac =
            doc.f32_or("chaos", "partition_frac", c.partition_frac as f32) as f64;
        c.partition_start_frac =
            doc.f32_or("chaos", "partition_start_frac", c.partition_start_frac as f32) as f64;
        c.partition_len_frac =
            doc.f32_or("chaos", "partition_len_frac", c.partition_len_frac as f32) as f64;
        c.drop_prob = doc.f32_or("chaos", "drop_prob", c.drop_prob as f32) as f64;
        if let Some(v) = doc.get("chaos", "crash_agent") {
            if let Some(i) = v.as_i64() {
                c.crash_agent = if i < 0 { None } else { Some(i as usize) };
            }
        }
        c.churn_windows = doc.usize_or("chaos", "churn_windows", c.churn_windows);
        c.pushsum = doc.str_or("chaos", "pushsum", &c.pushsum).to_string();
        if let Some(v) = doc.get("chaos", "byzantine_agent") {
            if let Some(i) = v.as_i64() {
                c.byzantine_agent = if i < 0 { None } else { Some(i as usize) };
            }
        }
        c.byzantine_policy =
            doc.str_or("chaos", "byzantine_policy", &c.byzantine_policy).to_string();
        c.byzantine_agents =
            doc.str_or("chaos", "byzantine_agents", &c.byzantine_agents).to_string();
        c.detect = doc.bool_or("chaos", "detect", c.detect);
        c.detect_flag_after =
            doc.usize_or("chaos", "detect_flag_after", c.detect_flag_after).max(1);
        c.detect_exclude_after = doc
            .usize_or("chaos", "detect_exclude_after", c.detect_exclude_after)
            .max(c.detect_flag_after);
        c.detect_probation_us =
            doc.usize_or("chaos", "detect_probation_us", c.detect_probation_us as usize) as u64;
        c.detect_warmup = doc.usize_or("chaos", "detect_warmup", c.detect_warmup);
        c
    }

    /// The full colluding attacker set: [`Self::byzantine_agents`] parsed
    /// as comma-separated indices, merged with [`Self::byzantine_agent`],
    /// sorted and deduped. A malformed entry is a config error, not a
    /// silently-shrunk attacker set.
    pub fn byzantine_set(&self) -> crate::Result<Vec<usize>> {
        let mut set: Vec<usize> = Vec::new();
        if let Some(k) = self.byzantine_agent {
            set.push(k);
        }
        for tok in self.byzantine_agents.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            let k: usize = tok.parse().map_err(|_| {
                crate::DdlError::Config(format!(
                    "chaos.byzantine_agents: bad agent index '{tok}' in '{}'",
                    self.byzantine_agents
                ))
            })?;
            set.push(k);
        }
        set.sort_unstable();
        set.dedup();
        Ok(set)
    }

    /// Materialize the executor-facing detection configuration: the
    /// score-law thresholds stay at the library defaults
    /// ([`crate::net::DetectionConfig::default`]); the ladder lengths,
    /// probation, and warmup come from this config.
    pub fn detection(&self) -> crate::net::DetectionConfig {
        crate::net::DetectionConfig {
            enabled: self.detect,
            flag_after: self.detect_flag_after,
            exclude_after: self.detect_exclude_after,
            probation_us: self.detect_probation_us,
            warmup_iters: self.detect_warmup,
            ..crate::net::DetectionConfig::default()
        }
    }

    /// Parse [`Self::pushsum`] into the executor's combine selector.
    pub fn combine_mode(&self) -> crate::Result<crate::net::CombineMode> {
        if let Some(f) = self.pushsum.strip_prefix("trimmed:") {
            let f: usize = f.parse().map_err(|_| {
                crate::DdlError::Config(format!(
                    "chaos.pushsum: bad trim parameter in '{}' (expected trimmed:<f>)",
                    self.pushsum
                ))
            })?;
            return Ok(crate::net::CombineMode::TrimmedMean(f));
        }
        match self.pushsum.as_str() {
            "auto" => Ok(crate::net::CombineMode::Auto),
            "on" => Ok(crate::net::CombineMode::PushSum),
            "off" => Ok(crate::net::CombineMode::Metropolis),
            "median" => Ok(crate::net::CombineMode::Median),
            other => Err(crate::DdlError::Config(format!(
                "chaos.pushsum: expected auto|on|off|median|trimmed:<f>, got '{other}'"
            ))),
        }
    }

    /// Parse [`Self::byzantine_policy`] into the executor's corruption
    /// policy (unit parameters: σ = 1, value = 1, magnitude = 1).
    pub fn corrupt_policy(&self) -> crate::Result<crate::net::CorruptPolicy> {
        use crate::net::CorruptPolicy;
        match self.byzantine_policy.as_str() {
            "sign-flip" => Ok(CorruptPolicy::SignFlip),
            "scaled-noise" => Ok(CorruptPolicy::ScaledNoise { sigma: 1.0 }),
            "constant" => Ok(CorruptPolicy::ConstantPsi { value: 1.0 }),
            "colluding-offset" => Ok(CorruptPolicy::ColludingOffset { magnitude: 1.0 }),
            other => Err(crate::DdlError::Config(format!(
                "chaos.byzantine_policy: expected \
                 sign-flip|scaled-noise|constant|colluding-offset, got '{other}'"
            ))),
        }
    }
}

/// Observability layer (`obs/`): virtual-clock tracing + trace export.
/// Loaded from the TOML section `[obs]`; the `--trace <path>` /
/// `--trace-format <fmt>` CLI flags override [`Self::trace_path`] and
/// [`Self::format`]. Tracing never perturbs a run (no RNG draws, no
/// clock advancement — `tests/obs_parity.rs`), so flipping these knobs
/// is always replay-safe.
#[derive(Clone, Debug)]
pub struct ObsConfig {
    /// Record events even without an export path (in-memory only; useful
    /// for programmatic [`crate::obs::ObsHandle::snapshot`] consumers).
    pub enabled: bool,
    /// Export destination; `None` disables export. Setting a path
    /// implies recording.
    pub trace_path: Option<String>,
    /// Export format: `auto` (by extension: `.jsonl` → JSONL, else
    /// Chrome) | `jsonl` | `chrome`.
    pub format: String,
    /// Ring-buffer capacity of the in-memory recorder; the oldest events
    /// are evicted (and counted) beyond this.
    pub ring_cap: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig { enabled: false, trace_path: None, format: "auto".into(), ring_cap: 262_144 }
    }
}

impl ObsConfig {
    /// Whether events should be recorded at all.
    pub fn active(&self) -> bool {
        self.enabled || self.trace_path.is_some()
    }

    /// Load from TOML (section `[obs]`), falling back to defaults.
    pub fn from_toml(doc: &TomlDoc) -> Self {
        let mut c = Self::default();
        c.enabled = doc.bool_or("obs", "enabled", c.enabled);
        if let Some(v) = doc.get("obs", "trace") {
            if let Some(s) = v.as_str() {
                c.trace_path = Some(s.to_string());
            }
        }
        c.format = doc.str_or("obs", "format", &c.format).to_string();
        c.ring_cap = doc.usize_or("obs", "ring_cap", c.ring_cap).max(1);
        c
    }
}

/// Asynchronous diffusion / straggler experiment (`ddl async`,
/// `net/async_exec.rs`). Loaded from the TOML section `[async]`; the
/// delay knobs feed [`crate::net::AsyncParams`] via [`Self::async_params`].
#[derive(Clone, Debug)]
pub struct AsyncConfig {
    pub seed: u64,
    /// Number of agents `N` (= atoms; one atom per agent, §IV-B).
    pub agents: usize,
    /// Data dimension `M`.
    pub dim: usize,
    /// Topology: `ring` | `grid` | `er` | `full`.
    pub topology: String,
    /// Neighbors per side for the ring topology.
    pub ring_k: usize,
    /// Edge probability for the `er` topology.
    pub edge_prob: f64,
    /// Staleness bound τ (`0` = barrier-synchronous, bitwise BSP).
    pub tau: usize,
    /// Compute-delay distribution: `zero` | `const` | `uniform` | `exp`.
    pub compute_dist: String,
    /// Compute-delay scale (mean / constant), µs.
    pub compute_us: u64,
    /// Link-delay distribution: `zero` | `const` | `uniform` | `exp`.
    pub link_dist: String,
    /// Link-delay scale (mean / constant), µs.
    pub link_us: u64,
    /// Straggler scenario: one slow agent; `None` = homogeneous network
    /// (spell it `slow_agent = -1` in TOML, or pass `--no-straggler`).
    pub slow_agent: Option<usize>,
    /// Compute-delay multiplier for the slow agent.
    pub slow_factor: f64,
    /// Drifting-straggler scenario: when > 0, the identity of the slow
    /// agent rotates deterministically every this many simulated µs
    /// (agent `⌊t/period⌋ mod N` is slow by [`Self::slow_factor`]),
    /// overriding the static `slow_agent`. `0` (default) = static
    /// scenario.
    pub drift_period_us: u64,
    /// Diffusion inference settings (μ, iters, elastic net; threads is
    /// ignored — the discrete-event simulation is single-threaded). The
    /// default horizon is past the ~`N/μ` cold-start build-up so the
    /// reported MSD gap compares converged runs, not transients
    /// (EXPERIMENTS.md §Async).
    pub infer: InferenceConfig,
    /// Sim-time checkpoints per run (MSD-vs-simulated-time table rows).
    pub checkpoints: usize,
    /// Feedback control plane (`[control]` TOML block, `--adaptive-tau`).
    pub control: ControlConfig,
    /// Deterministic fault injection (`[chaos]` TOML block, `ddl chaos`).
    pub chaos: ChaosConfig,
    /// Observability layer (`[obs]` TOML block, `--trace`).
    pub obs: ObsConfig,
}

impl Default for AsyncConfig {
    fn default() -> Self {
        AsyncConfig {
            seed: 0xA5_11C,
            agents: 100,
            dim: 64,
            topology: "ring".into(),
            ring_k: 2,
            edge_prob: 0.1,
            tau: 4,
            compute_dist: "exp".into(),
            compute_us: 100,
            link_dist: "exp".into(),
            link_us: 20,
            slow_agent: Some(0),
            slow_factor: 10.0,
            drift_period_us: 0,
            infer: InferenceConfig { mu: 0.5, iters: 1500, gamma: 0.1, delta: 0.5, threads: 1 },
            checkpoints: 4,
            control: ControlConfig::default(),
            chaos: ChaosConfig::default(),
            obs: ObsConfig::default(),
        }
    }
}

impl AsyncConfig {
    /// Load from TOML (section `[async]`), falling back to defaults.
    pub fn from_toml(doc: &TomlDoc) -> Self {
        let defaults = Self::default();
        let mut c = defaults;
        c.seed = doc.usize_or("async", "seed", c.seed as usize) as u64;
        c.agents = doc.usize_or("async", "agents", c.agents);
        c.dim = doc.usize_or("async", "dim", c.dim);
        c.topology = doc.str_or("async", "topology", &c.topology).to_string();
        c.ring_k = doc.usize_or("async", "ring_k", c.ring_k);
        c.edge_prob = doc.f32_or("async", "edge_prob", c.edge_prob as f32) as f64;
        c.tau = doc.usize_or("async", "tau", c.tau);
        c.compute_dist = doc.str_or("async", "compute_dist", &c.compute_dist).to_string();
        c.compute_us = doc.usize_or("async", "compute_us", c.compute_us as usize) as u64;
        c.link_dist = doc.str_or("async", "link_dist", &c.link_dist).to_string();
        c.link_us = doc.usize_or("async", "link_us", c.link_us as usize) as u64;
        if let Some(v) = doc.get("async", "slow_agent") {
            // `-1` is the documented "no straggler" spelling; a
            // non-integer value keeps the default rather than silently
            // disabling the scenario.
            if let Some(i) = v.as_i64() {
                c.slow_agent = if i < 0 { None } else { Some(i as usize) };
            }
        }
        c.slow_factor = doc.f32_or("async", "slow_factor", c.slow_factor as f32) as f64;
        c.drift_period_us =
            doc.usize_or("async", "drift_period_us", c.drift_period_us as usize) as u64;
        c.infer.mu = doc.f32_or("async", "mu", c.infer.mu);
        c.infer.iters = doc.usize_or("async", "iters", c.infer.iters);
        c.infer.gamma = doc.f32_or("async", "gamma", c.infer.gamma);
        c.infer.delta = doc.f32_or("async", "delta", c.infer.delta);
        c.checkpoints = doc.usize_or("async", "checkpoints", c.checkpoints).max(1);
        c.control = ControlConfig::from_toml(doc);
        c.chaos = ChaosConfig::from_toml(doc);
        c.obs = ObsConfig::from_toml(doc);
        c
    }

    /// Materialize the executor-facing [`crate::net::AsyncParams`]
    /// (delay-spec parsing can fail on an unknown distribution name).
    pub fn async_params(&self) -> crate::Result<crate::net::AsyncParams> {
        let mut p = crate::net::AsyncParams {
            tau: self.tau,
            compute: crate::net::DelayDist::parse(&self.compute_dist, self.compute_us)?,
            link: crate::net::DelayDist::parse(&self.link_dist, self.link_us)?,
            seed: self.seed,
            drift_period_us: self.drift_period_us,
            ..crate::net::AsyncParams::default()
        };
        if let Some(k) = self.slow_agent {
            p.slow_agents.push(k);
            p.slow_factor = self.slow_factor;
        }
        Ok(p)
    }
}

/// Residual loss selection for the novelty experiments (§IV-C).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ResidualKind {
    /// `f(u) = ½‖u‖²` (Fig. 6 / Table III).
    SquaredL2,
    /// `f(u) = Σ L(uₘ)`, Huber with parameter η (Fig. 7 / Table IV).
    Huber { eta: f32 },
}

/// Novel-document-detection experiment (Figs. 6–7, Tables III–IV).
#[derive(Clone, Debug)]
pub struct NoveltyConfig {
    pub seed: u64,
    /// Vocabulary size (paper TDT2: 19527; scaled default 800).
    pub vocab: usize,
    /// Total topics in the corpus (paper: 30).
    pub topics: usize,
    /// Documents per time-step batch (paper: 1000; scaled default 300).
    pub batch_docs: usize,
    /// Number of time steps (paper: 8).
    pub time_steps: usize,
    /// Initial dictionary atoms (paper: 10).
    pub init_atoms: usize,
    /// Atoms added per time step (paper: 10).
    pub atoms_per_step: usize,
    /// Residual metric.
    pub residual: ResidualKind,
    /// Elastic-net γ (paper: 0.05 sq-Euclid / 1.0 Huber).
    pub gamma: f32,
    /// Elastic-net δ (paper: 0.1).
    pub delta: f32,
    /// Distributed inference step size (paper: 0.05) and iterations
    /// (paper: 1000).
    pub dist_mu: f32,
    pub dist_iters: usize,
    /// Fully-connected inference step size (paper: 0.7) and iterations
    /// (paper: 100).
    pub fc_mu: f32,
    pub fc_iters: usize,
    /// Learning step size schedule μ_w(s) = mu_w_num / s (paper: 10/s).
    pub mu_w_num: f32,
    /// Edge probability for the per-step random topology (paper: 0.5).
    pub edge_prob: f64,
    /// Worker threads for inference and cost consensus (1 = serial).
    pub threads: usize,
}

impl NoveltyConfig {
    /// Scaled defaults for the squared-ℓ2 experiment (Fig. 6 / Table III).
    /// Paper scale: vocab 19527, 1000 docs/batch, 10+10 atoms/step,
    /// μ=0.05 with 1000 distributed iterations — restore via TOML when a
    /// bigger machine is available; the scaled run keeps μ·iters (the
    /// effective diffusion horizon) comparable.
    pub fn squared_l2() -> Self {
        NoveltyConfig {
            seed: 0x70D2,
            vocab: 600,
            topics: 30,
            batch_docs: 200,
            time_steps: 8,
            init_atoms: 6,
            atoms_per_step: 6,
            residual: ResidualKind::SquaredL2,
            gamma: 0.05,
            delta: 0.1,
            dist_mu: 0.1,
            dist_iters: 400,
            fc_mu: 0.7,
            fc_iters: 100,
            mu_w_num: 10.0,
            edge_prob: 0.5,
            threads: 1,
        }
    }

    /// Scaled defaults for the Huber experiment (Fig. 7 / Table IV).
    pub fn huber() -> Self {
        NoveltyConfig {
            residual: ResidualKind::Huber { eta: 0.2 },
            gamma: 1.0,
            ..Self::squared_l2()
        }
    }

    /// Load overrides from TOML section `[novelty]`.
    pub fn from_toml(doc: &TomlDoc, base: NoveltyConfig) -> Self {
        let mut c = base;
        c.seed = doc.usize_or("novelty", "seed", c.seed as usize) as u64;
        c.vocab = doc.usize_or("novelty", "vocab", c.vocab);
        c.topics = doc.usize_or("novelty", "topics", c.topics);
        c.batch_docs = doc.usize_or("novelty", "batch_docs", c.batch_docs);
        c.time_steps = doc.usize_or("novelty", "time_steps", c.time_steps);
        c.init_atoms = doc.usize_or("novelty", "init_atoms", c.init_atoms);
        c.atoms_per_step = doc.usize_or("novelty", "atoms_per_step", c.atoms_per_step);
        c.gamma = doc.f32_or("novelty", "gamma", c.gamma);
        c.delta = doc.f32_or("novelty", "delta", c.delta);
        c.dist_mu = doc.f32_or("novelty", "dist_mu", c.dist_mu);
        c.dist_iters = doc.usize_or("novelty", "dist_iters", c.dist_iters);
        c.fc_mu = doc.f32_or("novelty", "fc_mu", c.fc_mu);
        c.fc_iters = doc.usize_or("novelty", "fc_iters", c.fc_iters);
        c.mu_w_num = doc.f32_or("novelty", "mu_w_num", c.mu_w_num);
        c.edge_prob = doc.f32_or("novelty", "edge_prob", c.edge_prob as f32) as f64;
        c.threads = doc.usize_or("novelty", "threads", c.threads);
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn denoise_defaults_sane() {
        let c = DenoiseConfig::default();
        assert_eq!(c.patch * c.patch, 100); // M = 100
        assert_eq!(c.minibatch, 4);
        assert_eq!(c.train_infer.gamma, 45.0);
        assert!(c.informed.is_none());
    }

    #[test]
    fn paper_scale_matches_paper() {
        let c = DenoiseConfig::paper_scale();
        assert_eq!(c.agents, 196);
        assert_eq!(c.train_samples, 1_000_000);
        assert_eq!(c.train_infer.iters, 300);
        assert_eq!(c.denoise_infer.iters, 500);
        assert_eq!(c.mu_w, 5e-5);
    }

    #[test]
    fn novelty_defaults_match_paper_hparams() {
        let c = NoveltyConfig::squared_l2();
        assert_eq!(c.gamma, 0.05);
        assert_eq!(c.delta, 0.1);
        assert_eq!(c.fc_mu, 0.7);
        assert_eq!(c.mu_w_num, 10.0);
        let h = NoveltyConfig::huber();
        assert_eq!(h.gamma, 1.0);
        assert!(matches!(h.residual, ResidualKind::Huber { eta } if (eta - 0.2).abs() < 1e-7));
    }

    #[test]
    fn toml_overrides_apply() {
        let doc = TomlDoc::parse(
            "[denoise]\nagents = 16\ngamma = 30.0\nthreads = 4\n[novelty]\nvocab = 500\nthreads = 2\n",
        )
        .unwrap();
        let d = DenoiseConfig::from_toml(&doc);
        assert_eq!(d.agents, 16);
        assert_eq!(d.train_infer.gamma, 30.0);
        assert_eq!(d.denoise_infer.gamma, 30.0);
        assert_eq!(d.train_infer.threads, 4);
        assert_eq!(d.denoise_infer.threads, 4);
        let n = NoveltyConfig::from_toml(&doc, NoveltyConfig::squared_l2());
        assert_eq!(n.vocab, 500);
        assert_eq!(n.topics, 30);
        assert_eq!(n.threads, 2);
    }

    #[test]
    fn serve_defaults_sane() {
        let c = ServeConfig::default();
        assert_eq!(c.agents, 100);
        assert_eq!(c.topology, "grid");
        assert_eq!(c.batch, 8);
        assert_eq!(c.rate, 0.0);
        assert!(c.informed.is_none());
        assert_eq!(c.infer.threads, 1);
        assert!(!c.pipeline, "serial single-server loop stays the default");
        assert_eq!(c.pipeline_depth, 2);
    }

    /// Round trip for every serving knob exposed in the `[serve]` TOML
    /// block (the `--batch` / `--max-wait-us` CLI flags override the same
    /// fields).
    #[test]
    fn serve_toml_round_trip() {
        let doc = TomlDoc::parse(
            "[serve]\nseed = 99\nagents = 64\ndim = 36\ntopology = \"ring\"\nring_k = 3\n\
             edge_prob = 0.25\nbatch = 16\nmax_wait_us = 750\nsamples = 128\nrate = 2000.0\n\
             mu_w = 0.01\npipeline = true\npipeline_depth = 3\nmu = 0.5\niters = 80\n\
             gamma = 0.2\ndelta = 0.3\nthreads = 2\ninformed = 4\nqueue_capacity = 48\n\
             kill_slot = 1\nkill_at_batch = 3\n",
        )
        .unwrap();
        let c = ServeConfig::from_toml(&doc);
        assert_eq!(c.seed, 99);
        assert_eq!(c.agents, 64);
        assert_eq!(c.dim, 36);
        assert_eq!(c.topology, "ring");
        assert_eq!(c.ring_k, 3);
        assert!((c.edge_prob - 0.25).abs() < 1e-6);
        assert_eq!(c.batch, 16);
        assert_eq!(c.max_wait_us, 750);
        assert_eq!(c.samples, 128);
        assert!((c.rate - 2000.0).abs() < 1e-3);
        assert!((c.mu_w - 0.01).abs() < 1e-7);
        assert!(c.pipeline);
        assert_eq!(c.pipeline_depth, 3);
        assert!((c.infer.mu - 0.5).abs() < 1e-7);
        assert_eq!(c.infer.iters, 80);
        assert!((c.infer.gamma - 0.2).abs() < 1e-7);
        assert!((c.infer.delta - 0.3).abs() < 1e-7);
        assert_eq!(c.infer.threads, 2);
        assert_eq!(c.informed, Some(4));
        assert_eq!(c.queue_capacity, 48);
        assert_eq!(c.kill_slot, Some(1));
        assert_eq!(c.kill_at_batch, 3);
        // Absent section leaves defaults untouched.
        let empty = TomlDoc::parse("").unwrap();
        let d = ServeConfig::from_toml(&empty);
        assert_eq!(d.batch, ServeConfig::default().batch);
        assert_eq!(d.topology, ServeConfig::default().topology);
        assert_eq!(d.queue_capacity, 0, "unbounded admission by default");
        assert_eq!(d.kill_slot, None, "no worker death by default");
        // `kill_slot = -1` is the explicit "nobody dies" spelling.
        let alive =
            ServeConfig::from_toml(&TomlDoc::parse("[serve]\nkill_slot = -1\n").unwrap());
        assert_eq!(alive.kill_slot, None);
        // Workload-stream knobs ride in the same `[serve]` section.
        let w = ServeConfig::from_toml(
            &TomlDoc::parse(
                "[serve]\nstream = \"field\"\nshift_count = 5\nfield_sources = 4\n\
                 field_width = 0.2\nfield_noise = 0.05\n",
            )
            .unwrap(),
        );
        assert_eq!(w.stream, "field");
        assert_eq!(w.shift_count, 5);
        assert_eq!(w.field_sources, 4);
        assert!((w.field_width - 0.2).abs() < 1e-7);
        assert!((w.field_noise - 0.05).abs() < 1e-7);
        assert_eq!(d.stream, "planted", "planted stream by default");
    }

    /// Round trip for the `[convergence]` block; the detector must default
    /// to disabled (`tol = 0`) so existing serve configs are bit-for-bit
    /// untouched.
    #[test]
    fn convergence_toml_round_trip() {
        let c = ConvergenceConfig::default();
        assert!(!c.enabled(), "detector disabled by default");
        assert_eq!(c.window, 8);
        assert_eq!(c.max_no_improvement, 2);
        assert!((c.thaw_ratio - 1.5).abs() < 1e-9);
        assert_eq!(c.loss_window, 8);
        let doc = TomlDoc::parse(
            "[convergence]\ntol = 0.01\nwindow = 4\nmax_no_improvement = 3\n\
             thaw_ratio = 2.0\nloss_window = 6\n",
        )
        .unwrap();
        let c = ConvergenceConfig::from_toml(&doc);
        assert!(c.enabled());
        assert!((c.tol - 0.01).abs() < 1e-7);
        assert_eq!(c.window, 4);
        assert_eq!(c.max_no_improvement, 3);
        assert!((c.thaw_ratio - 2.0).abs() < 1e-9);
        assert_eq!(c.loss_window, 6);
        // Degenerate values clamp rather than divide by zero later.
        let z = ConvergenceConfig::from_toml(
            &TomlDoc::parse("[convergence]\nwindow = 0\nmax_no_improvement = 0\nloss_window = 0\n")
                .unwrap(),
        );
        assert_eq!(z.window, 1);
        assert_eq!(z.max_no_improvement, 1);
        assert_eq!(z.loss_window, 1);
        // Nested on ServeConfig via the same document.
        let s = ServeConfig::from_toml(&TomlDoc::parse("[convergence]\ntol = 0.5\n").unwrap());
        assert!(s.convergence.enabled());
    }

    #[test]
    fn async_defaults_sane() {
        let c = AsyncConfig::default();
        assert_eq!(c.agents, 100);
        assert_eq!(c.topology, "ring");
        assert_eq!(c.tau, 4);
        assert_eq!(c.slow_agent, Some(0));
        let p = c.async_params().unwrap();
        assert_eq!(p.tau, 4);
        assert_eq!(p.slow_agents, vec![0]);
        assert!((p.slow_factor - 10.0).abs() < 1e-12);
    }

    /// Round trip for every knob exposed in the `[async]` TOML block.
    #[test]
    fn async_toml_round_trip() {
        let doc = TomlDoc::parse(
            "[async]\nseed = 42\nagents = 30\ndim = 12\ntopology = \"grid\"\nring_k = 3\n\
             edge_prob = 0.4\ntau = 2\ncompute_dist = \"uniform\"\ncompute_us = 50\n\
             link_dist = \"const\"\nlink_us = 9\nslow_agent = 7\nslow_factor = 6.0\n\
             mu = 0.25\niters = 90\ngamma = 0.2\ndelta = 0.4\ncheckpoints = 8\n",
        )
        .unwrap();
        let c = AsyncConfig::from_toml(&doc);
        assert_eq!(c.seed, 42);
        assert_eq!(c.agents, 30);
        assert_eq!(c.dim, 12);
        assert_eq!(c.topology, "grid");
        assert_eq!(c.ring_k, 3);
        assert!((c.edge_prob - 0.4).abs() < 1e-6);
        assert_eq!(c.tau, 2);
        assert_eq!(c.compute_dist, "uniform");
        assert_eq!(c.compute_us, 50);
        assert_eq!(c.link_dist, "const");
        assert_eq!(c.link_us, 9);
        assert_eq!(c.slow_agent, Some(7));
        assert!((c.slow_factor - 6.0).abs() < 1e-9);
        assert!((c.infer.mu - 0.25).abs() < 1e-7);
        assert_eq!(c.infer.iters, 90);
        assert_eq!(c.checkpoints, 8);
        let p = c.async_params().unwrap();
        assert_eq!(p.compute, crate::net::DelayDist::Uniform { lo_us: 25, hi_us: 75 });
        assert_eq!(p.link, crate::net::DelayDist::Constant { us: 9 });
        // Absent section leaves defaults untouched; bad dist name errors.
        let empty = TomlDoc::parse("").unwrap();
        let d = AsyncConfig::from_toml(&empty);
        assert_eq!(d.tau, AsyncConfig::default().tau);
        // `slow_agent = -1` is the supported "no straggler" spelling; a
        // non-integer value keeps the default instead of silently
        // disabling the scenario.
        let off = AsyncConfig::from_toml(&TomlDoc::parse("[async]\nslow_agent = -1\n").unwrap());
        assert_eq!(off.slow_agent, None);
        assert!(off.async_params().unwrap().slow_agents.is_empty());
        let typo =
            AsyncConfig::from_toml(&TomlDoc::parse("[async]\nslow_agent = 0.5\n").unwrap());
        assert_eq!(typo.slow_agent, AsyncConfig::default().slow_agent);
        let bad = AsyncConfig { compute_dist: "gauss".into(), ..AsyncConfig::default() };
        assert!(bad.async_params().is_err());
    }

    #[test]
    fn control_defaults_disabled() {
        let c = ControlConfig::default();
        assert!(!c.enabled);
        assert!(!c.adaptive_tau);
        assert!(c.batch_min <= c.batch_max);
        assert!(c.wait_min_us <= c.wait_max_us);
        assert!(c.depth_min <= c.depth_max);
        assert!(c.tau_min <= c.tau_max);
        // Disabled by default on both experiment configs.
        assert!(!ServeConfig::default().control.enabled);
        assert!(!AsyncConfig::default().control.adaptive_tau);
        assert_eq!(ServeConfig::default().burst, 1);
        assert_eq!(AsyncConfig::default().drift_period_us, 0);
    }

    /// Round trip for every knob exposed in the `[control]` TOML block,
    /// plus the serve `burst` and async `drift_period_us` satellites.
    #[test]
    fn control_toml_round_trip() {
        let doc = TomlDoc::parse(
            "[serve]\nburst = 32\n[async]\ndrift_period_us = 5000\n[control]\nenabled = true\n\
             slo_p99_ms = 10.0\ntick_us = 1500\nbatch_min = 2\nbatch_max = 48\n\
             wait_min_us = 100\nwait_max_us = 9000\nwindow = 128\nsvc_base_us = 700\n\
             svc_per_sample_us = 120\nupd_per_sample_us = 40\ncalibrate = true\n\
             calib_batches = 6\ndepth_min = 1\ndepth_max = 3\n\
             epoch_batches = 8\nadaptive_tau = true\ntau_min = 1\ntau_max = 12\n\
             tau_epoch_us = 4000\ngate_wait_hi = 0.3\nmsd_drift_bound = 0.4\n",
        )
        .unwrap();
        let s = ServeConfig::from_toml(&doc);
        assert_eq!(s.burst, 32);
        assert!(s.control.enabled);
        assert!((s.control.slo_p99_ms - 10.0).abs() < 1e-6);
        assert_eq!(s.control.tick_us, 1500);
        assert_eq!(s.control.batch_min, 2);
        assert_eq!(s.control.batch_max, 48);
        assert_eq!(s.control.wait_min_us, 100);
        assert_eq!(s.control.wait_max_us, 9000);
        assert_eq!(s.control.window, 128);
        assert_eq!(s.control.svc_base_us, 700);
        assert_eq!(s.control.svc_per_sample_us, 120);
        assert_eq!(s.control.upd_per_sample_us, 40);
        assert!(s.control.calibrate);
        assert_eq!(s.control.calib_batches, 6);
        assert!(!ControlConfig::default().calibrate, "calibration must be opt-in");
        assert_eq!(s.control.depth_min, 1);
        assert_eq!(s.control.depth_max, 3);
        assert_eq!(s.control.epoch_batches, 8);
        let a = AsyncConfig::from_toml(&doc);
        assert_eq!(a.drift_period_us, 5000);
        assert!(a.control.adaptive_tau);
        assert_eq!(a.control.tau_min, 1);
        assert_eq!(a.control.tau_max, 12);
        assert_eq!(a.control.tau_epoch_us, 4000);
        assert!((a.control.gate_wait_hi - 0.3).abs() < 1e-6);
        assert!((a.control.msd_drift_bound - 0.4).abs() < 1e-6);
        assert_eq!(a.async_params().unwrap().drift_period_us, 5000);
        // Inverted bounds are sanitized to min ≤ max, not passed through.
        let bad = ControlConfig::from_toml(
            &TomlDoc::parse("[control]\nbatch_min = 16\nbatch_max = 4\ntau_min = 9\ntau_max = 2\n")
                .unwrap(),
        );
        assert!(bad.batch_min <= bad.batch_max);
        assert!(bad.tau_min <= bad.tau_max);
    }

    #[test]
    fn chaos_defaults_disabled_and_auto() {
        let c = ChaosConfig::default();
        assert!(!c.enabled, "chaos must be opt-in");
        assert!(c.crash_agent.is_none());
        assert_eq!(c.churn_windows, 0);
        assert_eq!(c.drop_prob, 0.0);
        assert_eq!(c.pushsum, "auto");
        assert_eq!(c.combine_mode().unwrap(), crate::net::CombineMode::Auto);
        assert!(!AsyncConfig::default().chaos.enabled);
    }

    /// Round trip for every knob exposed in the `[chaos]` TOML block.
    #[test]
    fn chaos_toml_round_trip() {
        let doc = TomlDoc::parse(
            "[chaos]\nenabled = true\nseed = 77\npartition_frac = 0.3\n\
             partition_start_frac = 0.25\npartition_len_frac = 0.1\ndrop_prob = 0.05\n\
             crash_agent = 4\nchurn_windows = 6\npushsum = \"on\"\nbyzantine_agent = 2\n\
             byzantine_policy = \"scaled-noise\"\n",
        )
        .unwrap();
        let c = ChaosConfig::from_toml(&doc);
        assert!(c.enabled);
        assert_eq!(c.seed, 77);
        assert!((c.partition_frac - 0.3).abs() < 1e-6);
        assert!((c.partition_start_frac - 0.25).abs() < 1e-6);
        assert!((c.partition_len_frac - 0.1).abs() < 1e-6);
        assert!((c.drop_prob - 0.05).abs() < 1e-6);
        assert_eq!(c.crash_agent, Some(4));
        assert_eq!(c.churn_windows, 6);
        assert_eq!(c.combine_mode().unwrap(), crate::net::CombineMode::PushSum);
        // The `[chaos]` block rides on AsyncConfig.
        let a = AsyncConfig::from_toml(&doc);
        assert!(a.chaos.enabled);
        assert_eq!(a.chaos.seed, 77);
        // `-1` = nobody crashes; `off` forces Metropolis; a typo'd
        // pushsum string is a config error, not a silent fallback.
        let off = ChaosConfig::from_toml(
            &TomlDoc::parse("[chaos]\ncrash_agent = -1\npushsum = \"off\"\n").unwrap(),
        );
        assert_eq!(off.crash_agent, None);
        assert_eq!(off.combine_mode().unwrap(), crate::net::CombineMode::Metropolis);
        let bad = ChaosConfig { pushsum: "maybe".into(), ..ChaosConfig::default() };
        assert!(bad.combine_mode().is_err());
        // Byzantine knobs round-trip; `-1` means "no attacker".
        assert_eq!(c.byzantine_agent, Some(2));
        assert_eq!(c.byzantine_policy, "scaled-noise");
        assert!(matches!(
            c.corrupt_policy().unwrap(),
            crate::net::CorruptPolicy::ScaledNoise { .. }
        ));
        let none = ChaosConfig::from_toml(
            &TomlDoc::parse("[chaos]\nbyzantine_agent = -1\n").unwrap(),
        );
        assert_eq!(none.byzantine_agent, None);
        assert_eq!(none.byzantine_policy, "sign-flip");
        assert_eq!(none.corrupt_policy().unwrap(), crate::net::CorruptPolicy::SignFlip);
        let bad_pol =
            ChaosConfig { byzantine_policy: "gremlin".into(), ..ChaosConfig::default() };
        assert!(bad_pol.corrupt_policy().is_err());
        // Resilient combine modes parse; a malformed trim count errors.
        let med = ChaosConfig { pushsum: "median".into(), ..ChaosConfig::default() };
        assert_eq!(med.combine_mode().unwrap(), crate::net::CombineMode::Median);
        let trim = ChaosConfig { pushsum: "trimmed:2".into(), ..ChaosConfig::default() };
        assert_eq!(trim.combine_mode().unwrap(), crate::net::CombineMode::TrimmedMean(2));
        let bad_trim = ChaosConfig { pushsum: "trimmed:x".into(), ..ChaosConfig::default() };
        assert!(bad_trim.combine_mode().is_err());
    }

    /// Round trip for the detection / collusion knobs: the colluding set
    /// parses, merges with the single-attacker spelling, and dedups; a
    /// malformed index is a typed config error; detection defaults off
    /// with the library score-law thresholds.
    #[test]
    fn chaos_detection_and_collusion_round_trip() {
        let d = ChaosConfig::default();
        assert!(!d.detect, "detection must be opt-in");
        assert_eq!(d.byzantine_agents, "");
        assert!(d.byzantine_set().unwrap().is_empty());
        assert!(!d.detection().enabled);
        assert_eq!(d.detection().flag_after, 6);
        assert_eq!(d.detection().exclude_after, 12);
        assert_eq!(d.detection().warmup_iters, 8);
        let doc = TomlDoc::parse(
            "[chaos]\nenabled = true\nbyzantine_agent = 7\nbyzantine_agents = \"3, 7,12\"\n\
             detect = true\ndetect_flag_after = 4\ndetect_exclude_after = 9\n\
             detect_probation_us = 5000\ndetect_warmup = 3\n",
        )
        .unwrap();
        let c = ChaosConfig::from_toml(&doc);
        assert_eq!(c.byzantine_set().unwrap(), vec![3, 7, 12], "merged, sorted, deduped");
        assert!(c.detect);
        let det = c.detection();
        assert!(det.enabled);
        assert_eq!(det.flag_after, 4);
        assert_eq!(det.exclude_after, 9);
        assert_eq!(det.probation_us, 5_000);
        assert_eq!(det.warmup_iters, 3);
        det.validate().unwrap();
        let bad =
            ChaosConfig { byzantine_agents: "3,x".into(), ..ChaosConfig::default() };
        assert!(bad.byzantine_set().is_err());
        // exclude_after is clamped to >= flag_after at load time.
        let clamped = ChaosConfig::from_toml(
            &TomlDoc::parse("[chaos]\ndetect_flag_after = 10\ndetect_exclude_after = 2\n")
                .unwrap(),
        );
        assert!(clamped.detect_exclude_after >= clamped.detect_flag_after);
    }

    /// Round trip for the serve poisoning knobs; poisoning defaults off
    /// and the screen defaults on (a `--poison` run is defended unless
    /// the screen is explicitly disabled).
    #[test]
    fn serve_poison_toml_round_trip() {
        let d = ServeConfig::default();
        assert!(!d.poison, "poisoning must be opt-in");
        assert!(d.poison_screen, "screen defends by default");
        assert!((d.poison_frac - 0.08).abs() < 1e-9);
        assert!((d.poison_scale - 25.0).abs() < 1e-6);
        assert!((d.poison_screen_z - 6.0).abs() < 1e-9);
        let doc = TomlDoc::parse(
            "[serve]\npoison = true\npoison_frac = 0.2\npoison_scale = 10.0\n\
             poison_screen = false\npoison_screen_z = 4.0\n",
        )
        .unwrap();
        let c = ServeConfig::from_toml(&doc);
        assert!(c.poison);
        assert!((c.poison_frac - 0.2).abs() < 1e-6);
        assert!((c.poison_scale - 10.0).abs() < 1e-6);
        assert!(!c.poison_screen);
        assert!((c.poison_screen_z - 4.0).abs() < 1e-6);
        // The fraction is clamped into [0, 1].
        let wild = ServeConfig::from_toml(
            &TomlDoc::parse("[serve]\npoison_frac = 7.0\n").unwrap(),
        );
        assert_eq!(wild.poison_frac, 1.0);
    }

    #[test]
    fn obs_defaults_off() {
        let c = ObsConfig::default();
        assert!(!c.enabled);
        assert!(c.trace_path.is_none());
        assert!(!c.active(), "no recording unless asked");
        assert_eq!(c.format, "auto");
        assert!(c.ring_cap >= 1);
        assert!(!ServeConfig::default().obs.active());
        assert!(!AsyncConfig::default().obs.active());
    }

    /// Round trip for every knob exposed in the `[obs]` TOML block, which
    /// rides on both ServeConfig and AsyncConfig.
    #[test]
    fn obs_toml_round_trip() {
        let doc = TomlDoc::parse(
            "[obs]\nenabled = true\ntrace = \"out/run.jsonl\"\nformat = \"jsonl\"\n\
             ring_cap = 1024\n",
        )
        .unwrap();
        let o = ObsConfig::from_toml(&doc);
        assert!(o.enabled);
        assert_eq!(o.trace_path.as_deref(), Some("out/run.jsonl"));
        assert_eq!(o.format, "jsonl");
        assert_eq!(o.ring_cap, 1024);
        assert!(o.active());
        assert!(ServeConfig::from_toml(&doc).obs.active());
        assert!(AsyncConfig::from_toml(&doc).obs.active());
        // A path alone implies recording; ring_cap is clamped to ≥ 1.
        let path_only = ObsConfig::from_toml(
            &TomlDoc::parse("[obs]\ntrace = \"t.json\"\nring_cap = 0\n").unwrap(),
        );
        assert!(!path_only.enabled);
        assert!(path_only.active());
        assert_eq!(path_only.ring_cap, 1);
    }

    #[test]
    fn threads_default_to_serial() {
        assert_eq!(DenoiseConfig::default().train_infer.threads, 1);
        assert_eq!(NoveltyConfig::squared_l2().threads, 1);
        assert_eq!(NoveltyConfig::huber().threads, 1);
    }
}
