//! Minimal TOML-subset parser for experiment configs.
//!
//! Supported: `[section]` headers, `key = value` with string / integer /
//! float / boolean / homogeneous arrays of numbers, `#` comments. That's
//! everything the `configs/*.toml` files use.

use crate::error::{DdlError, Result};
use std::collections::BTreeMap;

/// A TOML scalar or array value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    String(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Int(i) => Some(*i as f64),
            TomlValue::Float(f) => Some(*f),
            _ => None,
        }
    }
    pub fn as_f32(&self) -> Option<f32> {
        self.as_f64().map(|v| v as f32)
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::String(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed TOML document: sections of key-value pairs. Keys outside any
/// section live in the "" section.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    /// Parse a document.
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut current = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated section header"))?;
                current = name.trim().to_string();
                doc.sections.entry(current.clone()).or_default();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| err(lineno, "expected key = value"))?;
            let key = line[..eq].trim().to_string();
            if key.is_empty() {
                return Err(err(lineno, "empty key"));
            }
            let value = parse_value(line[eq + 1..].trim(), lineno)?;
            doc.sections
                .entry(current.clone())
                .or_default()
                .insert(key, value);
        }
        Ok(doc)
    }

    /// Load from a file path.
    pub fn load(path: &std::path::Path) -> Result<TomlDoc> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    /// Get `section.key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|m| m.get(key))
    }

    /// Typed getters with defaults (experiment configs are all-optional).
    pub fn f32_or(&self, section: &str, key: &str, default: f32) -> f32 {
        self.get(section, key).and_then(|v| v.as_f32()).unwrap_or(default)
    }
    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key).and_then(|v| v.as_usize()).unwrap_or(default)
    }
    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    /// Section names present.
    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }
}

fn err(lineno: usize, msg: &str) -> DdlError {
    DdlError::Config(format!("toml parse error on line {}: {}", lineno + 1, msg))
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, lineno: usize) -> Result<TomlValue> {
    if text.is_empty() {
        return Err(err(lineno, "empty value"));
    }
    if let Some(stripped) = text.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        return Ok(TomlValue::String(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let items = inner
            .split(',')
            .map(|s| parse_value(s.trim(), lineno))
            .collect::<Result<Vec<_>>>()?;
        return Ok(TomlValue::Array(items));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if !text.contains('.') && !text.contains('e') && !text.contains('E') {
        if let Ok(i) = text.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    text.parse::<f64>()
        .map(TomlValue::Float)
        .map_err(|_| err(lineno, &format!("cannot parse value '{text}'")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_document() {
        let doc = TomlDoc::parse(
            r#"
# experiment config
seed = 42

[denoise]
gamma = 45.0
delta = 0.1
agents = 64
paper_scale = false
label = "fig5"
sizes = [10, 10]
"#,
        )
        .unwrap();
        assert_eq!(doc.get("", "seed").unwrap().as_i64(), Some(42));
        assert_eq!(doc.f32_or("denoise", "gamma", 0.0), 45.0);
        assert_eq!(doc.usize_or("denoise", "agents", 0), 64);
        assert!(!doc.bool_or("denoise", "paper_scale", true));
        assert_eq!(doc.str_or("denoise", "label", ""), "fig5");
        match doc.get("denoise", "sizes").unwrap() {
            TomlValue::Array(a) => assert_eq!(a.len(), 2),
            _ => panic!("expected array"),
        }
    }

    #[test]
    fn defaults_on_missing() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.f32_or("x", "y", 1.5), 1.5);
        assert_eq!(doc.usize_or("x", "y", 7), 7);
    }

    #[test]
    fn comments_and_hash_in_string() {
        let doc = TomlDoc::parse("name = \"a#b\" # trailing").unwrap();
        assert_eq!(doc.str_or("", "name", ""), "a#b");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = \"unterminated").is_err());
        assert!(TomlDoc::parse("k = 12abc").is_err());
    }

    #[test]
    fn int_vs_float() {
        let doc = TomlDoc::parse("a = 3\nb = 3.5\nc = 1e-3").unwrap();
        assert_eq!(doc.get("", "a").unwrap(), &TomlValue::Int(3));
        assert_eq!(doc.get("", "b").unwrap(), &TomlValue::Float(3.5));
        assert_eq!(doc.get("", "c").unwrap(), &TomlValue::Float(1e-3));
    }
}
