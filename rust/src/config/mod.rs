//! Configuration substrate: minimal JSON and TOML parsers plus typed
//! experiment configs.
//!
//! `serde` is unavailable offline, so the repo ships a small recursive-
//! descent JSON parser (used for the AOT `artifacts/manifest.json`) and a
//! TOML-subset parser (used for experiment config files under `configs/`).

pub mod experiment;
pub mod json;
pub mod toml;

pub use json::JsonValue;
pub use toml::TomlDoc;
