//! Minimal JSON parser (RFC 8259 subset: no \u surrogate pairs beyond BMP).
//!
//! Parses the AOT artifact manifest written by `python/compile/aot.py`.

use crate::error::{DdlError, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<JsonValue>),
    Object(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<JsonValue> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64 if a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Number(n) => Some(*n),
            _ => None,
        }
    }

    /// As usize if a non-negative integral number.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            JsonValue::Number(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// As str if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::String(s) => Some(s),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Array(a) => Some(a),
            _ => None,
        }
    }

    /// As object map.
    pub fn as_object(&self) -> Option<&BTreeMap<String, JsonValue>> {
        match self {
            JsonValue::Object(m) => Some(m),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> DdlError {
        DdlError::Config(format!("json parse error at byte {}: {}", self.pos, msg))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<JsonValue> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::String(self.string()?)),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, lit: &str, v: JsonValue) -> Result<JsonValue> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn object(&mut self) -> Result<JsonValue> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Object(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => break,
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
        Ok(JsonValue::Object(m))
    }

    fn array(&mut self) -> Result<JsonValue> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Array(a));
        }
        loop {
            let v = self.value()?;
            a.push(v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => break,
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
        Ok(JsonValue::Array(a))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => break,
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Reassemble UTF-8 multibyte sequence.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("bad utf8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
        Ok(s)
    }

    fn number(&mut self) -> Result<JsonValue> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(JsonValue::Number)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(JsonValue::parse("null").unwrap(), JsonValue::Null);
        assert_eq!(JsonValue::parse("true").unwrap(), JsonValue::Bool(true));
        assert_eq!(JsonValue::parse("-3.5e2").unwrap(), JsonValue::Number(-350.0));
        assert_eq!(
            JsonValue::parse("\"a\\nb\"").unwrap(),
            JsonValue::String("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"artifacts": [{"name": "infer", "shape": [4, 8], "iters": 100}], "version": 1}"#;
        let v = JsonValue::parse(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let arts = v.get("artifacts").unwrap().as_array().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("infer"));
        let shape = arts[0].get("shape").unwrap().as_array().unwrap();
        assert_eq!(shape[1].as_usize(), Some(8));
    }

    #[test]
    fn rejects_malformed() {
        assert!(JsonValue::parse("{").is_err());
        assert!(JsonValue::parse("[1,]").is_err());
        assert!(JsonValue::parse("\"unterminated").is_err());
        assert!(JsonValue::parse("{} trailing").is_err());
        assert!(JsonValue::parse("tru").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            JsonValue::parse("\"\\u0041\"").unwrap(),
            JsonValue::String("A".into())
        );
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(
            JsonValue::parse("\"λ₂\"").unwrap(),
            JsonValue::String("λ₂".into())
        );
    }

    #[test]
    fn accessor_type_mismatches() {
        let v = JsonValue::parse("{\"a\": 1.5}").unwrap();
        assert_eq!(v.get("a").unwrap().as_usize(), None); // fractional
        assert_eq!(v.get("a").unwrap().as_str(), None);
        assert_eq!(v.get("missing"), None);
    }
}
