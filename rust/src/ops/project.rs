//! Projection operators.
//!
//! * [`project_unit_ball`] — Eq. 45, dictionary-atom constraint `‖w‖₂ ≤ 1`.
//! * [`project_nonneg_unit_ball`] — Eq. 47, NMF constraint `‖w‖₂ ≤ 1, w ⪰ 0`.
//! * [`clip_linf`] — Eq. 34, the `V_f` box for the Huber dual.
//! * [`project_l1_ball`] — Duchi et al. 2008, used by the ADMM [11] baseline
//!   whose atoms live in the ℓ1 ball.

use crate::math::vector::{norm2, scale};

/// Project onto the unit ℓ2 ball in place (Eq. 45).
pub fn project_unit_ball(w: &mut [f32]) {
    let n = norm2(w);
    if n > 1.0 {
        scale(1.0 / n, w);
    }
}

/// Project onto `{w : ‖w‖₂ ≤ 1, w ⪰ 0}` in place (Eq. 47): clamp negatives
/// to zero first, then scale into the ball.
pub fn project_nonneg_unit_ball(w: &mut [f32]) {
    for v in w.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
    project_unit_ball(w);
}

/// Entrywise clip to `[-bound, bound]` in place (Eq. 34 with bound 1 — the
/// projection onto `V_f = {‖ν‖_∞ ≤ 1}` for the Huber conjugate domain).
pub fn clip_linf(v: &mut [f32], bound: f32) {
    debug_assert!(bound >= 0.0);
    for x in v.iter_mut() {
        *x = x.clamp(-bound, bound);
    }
}

/// Project onto the ℓ1 ball of given `radius` (Duchi–Shalev-Shwartz–Singer
/// –Chandra 2008, O(n log n) sort variant). In place; no-op if already
/// inside.
pub fn project_l1_ball(w: &mut [f32], radius: f32) {
    assert!(radius > 0.0);
    let l1: f32 = w.iter().map(|v| v.abs()).sum();
    if l1 <= radius {
        return;
    }
    let mut mags: Vec<f32> = w.iter().map(|v| v.abs()).collect();
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap()); // descending
    let mut acc = 0.0f32;
    let mut theta = 0.0f32;
    for (i, &m) in mags.iter().enumerate() {
        acc += m;
        let t = (acc - radius) / (i as f32 + 1.0);
        if m - t <= 0.0 {
            break;
        }
        theta = t;
    }
    for v in w.iter_mut() {
        *v = super::threshold::soft_threshold(*v, theta);
    }
}

/// Project every column of a row-major `M x K` dictionary onto the unit
/// ball (the learning-side use of Eq. 45).
pub fn project_columns_unit_ball(w: &mut [f32], m: usize, k: usize) {
    debug_assert_eq!(w.len(), m * k);
    for c in 0..k {
        let mut nsq = 0.0f32;
        for r in 0..m {
            let v = w[r * k + c];
            nsq += v * v;
        }
        if nsq > 1.0 {
            let inv = 1.0 / nsq.sqrt();
            for r in 0..m {
                w[r * k + c] *= inv;
            }
        }
    }
}

/// Project every column onto the non-negative unit ball (Eq. 47).
pub fn project_columns_nonneg_unit_ball(w: &mut [f32], m: usize, k: usize) {
    debug_assert_eq!(w.len(), m * k);
    for c in 0..k {
        let mut nsq = 0.0f32;
        for r in 0..m {
            let v = w[r * k + c].max(0.0);
            w[r * k + c] = v;
            nsq += v * v;
        }
        if nsq > 1.0 {
            let inv = 1.0 / nsq.sqrt();
            for r in 0..m {
                w[r * k + c] *= inv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::vector::{norm1, norm2};

    #[test]
    fn unit_ball_inside_untouched() {
        let mut w = vec![0.3, 0.4];
        project_unit_ball(&mut w);
        assert_eq!(w, vec![0.3, 0.4]);
    }

    #[test]
    fn unit_ball_outside_scaled_to_boundary() {
        let mut w = vec![3.0, 4.0];
        project_unit_ball(&mut w);
        assert!((norm2(&w) - 1.0).abs() < 1e-6);
        assert!((w[0] - 0.6).abs() < 1e-6);
    }

    #[test]
    fn nonneg_ball_clamps_then_scales() {
        let mut w = vec![-5.0, 3.0, 4.0];
        project_nonneg_unit_ball(&mut w);
        assert_eq!(w[0], 0.0);
        assert!((norm2(&w) - 1.0).abs() < 1e-6);
        assert!(w.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn clip_linf_boxes() {
        let mut v = vec![-2.0, -0.5, 0.0, 0.5, 2.0];
        clip_linf(&mut v, 1.0);
        assert_eq!(v, vec![-1.0, -0.5, 0.0, 0.5, 1.0]);
    }

    #[test]
    fn l1_ball_inside_untouched() {
        let mut w = vec![0.2, -0.3];
        project_l1_ball(&mut w, 1.0);
        assert_eq!(w, vec![0.2, -0.3]);
    }

    #[test]
    fn l1_ball_projection_properties() {
        let mut w = vec![3.0, -1.0, 0.5, 0.0];
        let orig = w.clone();
        project_l1_ball(&mut w, 1.0);
        assert!((norm1(&w) - 1.0).abs() < 1e-5, "norm1 {}", norm1(&w));
        // Signs preserved, magnitudes shrunk.
        for (a, b) in w.iter().zip(&orig) {
            assert!(a.abs() <= b.abs() + 1e-6);
            assert!(a * b >= 0.0);
        }
    }

    /// The ℓ1 projection must be the closest point — check against a brute
    /// force search on a 2D grid.
    #[test]
    fn l1_ball_is_euclidean_projection_2d() {
        let target = [1.5f32, 0.7];
        let mut w = target;
        project_l1_ball(&mut w, 1.0);
        let d_proj = (w[0] - target[0]).powi(2) + (w[1] - target[1]).powi(2);
        // Brute-force over the ℓ1 sphere boundary.
        let mut best = f32::MAX;
        let steps = 4000;
        for i in 0..=steps {
            let a = i as f32 / steps as f32; // |x| = a, |y| = 1-a
            for (sx, sy) in [(1.0f32, 1.0f32), (1.0, -1.0), (-1.0, 1.0), (-1.0, -1.0)] {
                let (x, y) = (sx * a, sy * (1.0 - a));
                let d = (x - target[0]).powi(2) + (y - target[1]).powi(2);
                best = best.min(d);
            }
        }
        assert!(d_proj <= best + 1e-4, "proj dist {d_proj} vs brute {best}");
    }

    #[test]
    fn column_projection_matches_vector_projection() {
        let m = 4;
        let k = 3;
        let mut rng = crate::rng::Pcg64::new(5);
        let mut w: Vec<f32> = (0..m * k).map(|_| 2.0 * rng.next_normal()).collect();
        let mut cols: Vec<Vec<f32>> = (0..k)
            .map(|c| (0..m).map(|r| w[r * k + c]).collect())
            .collect();
        project_columns_unit_ball(&mut w, m, k);
        for (c, col) in cols.iter_mut().enumerate() {
            project_unit_ball(col);
            for r in 0..m {
                assert!((w[r * k + c] - col[r]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn nonneg_column_projection() {
        let m = 3;
        let k = 2;
        let mut w = vec![-1.0, 2.0, 3.0, 0.1, 4.0, -0.2];
        project_columns_nonneg_unit_ball(&mut w, m, k);
        for c in 0..k {
            let mut nsq = 0.0;
            for r in 0..m {
                assert!(w[r * k + c] >= 0.0);
                nsq += w[r * k + c] * w[r * k + c];
            }
            assert!(nsq <= 1.0 + 1e-6);
        }
    }
}
