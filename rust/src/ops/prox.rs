//! Proximal operators used by the dictionary update (Eq. 40–43).

use super::threshold::soft_threshold;

/// `prox_{λ‖·‖₁}(x)` — entrywise soft threshold (Eq. 42); the prox of the
/// bi-clustering regularizer `h_W(W) = β‖W‖₁` with λ = μ_w·β.
pub fn prox_l1(x: &mut [f32], lambda: f32) {
    for v in x.iter_mut() {
        *v = soft_threshold(*v, lambda);
    }
}

/// `prox_0(x) = x` — identity mapping (Eq. 43), for `h_W = 0`.
pub fn prox_zero(_x: &mut [f32]) {}

/// Proximal operator selector for the dictionary regularizers in Table I.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DictProx {
    /// `h_W = 0` (sparse SVD, NMF, Huber NMF rows of Table I).
    None,
    /// `h_W = β‖W‖₁` (bi-clustering row); the field is β.
    L1(f32),
}

impl DictProx {
    /// Apply `prox_{μ_w · h_W}` in place.
    pub fn apply(&self, x: &mut [f32], mu_w: f32) {
        match self {
            DictProx::None => {}
            DictProx::L1(beta) => prox_l1(x, mu_w * beta),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prox_l1_thresholds() {
        let mut x = vec![2.0, -0.5, 0.1];
        prox_l1(&mut x, 1.0);
        assert_eq!(x, vec![1.0, 0.0, 0.0]);
    }

    /// prox definition check: prox_h(x) minimizes h(u) + ½‖u−x‖² — compare
    /// against a grid search for the ℓ1 case.
    #[test]
    fn prox_l1_minimizes_objective() {
        let lambda = 0.7f32;
        for &x in &[-2.0f32, -0.6, 0.0, 0.4, 1.3] {
            let mut p = [x];
            prox_l1(&mut p, lambda);
            let obj = |u: f32| lambda * u.abs() + 0.5 * (u - x) * (u - x);
            let fp = obj(p[0]);
            let mut best = f32::MAX;
            for i in -400..=400 {
                best = best.min(obj(i as f32 * 0.01));
            }
            assert!(fp <= best + 1e-4, "x={x}: prox obj {fp} vs grid {best}");
        }
    }

    #[test]
    fn dict_prox_none_is_identity() {
        let mut x = vec![1.0, -2.0];
        DictProx::None.apply(&mut x, 0.5);
        assert_eq!(x, vec![1.0, -2.0]);
    }

    #[test]
    fn dict_prox_l1_scales_with_mu() {
        let mut x = vec![1.0, -2.0];
        DictProx::L1(2.0).apply(&mut x, 0.25); // λ = 0.5
        assert_eq!(x, vec![0.5, -1.5]);
    }
}
