//! Huber loss, gradient, and conjugate (paper Table I footnote c,
//! Appendix A Eqs. 71–73).

/// Scalar Huber loss
/// `L(u) = u²/(2η)` for `|u| < η`, else `|u| − η/2`.
#[inline]
pub fn huber(u: f32, eta: f32) -> f32 {
    if u.abs() < eta {
        u * u / (2.0 * eta)
    } else {
        u.abs() - eta / 2.0
    }
}

/// Gradient of the scalar Huber loss: `u/η` inside, `sgn(u)` outside.
#[inline]
pub fn huber_grad(u: f32, eta: f32) -> f32 {
    if u.abs() < eta {
        u / eta
    } else {
        u.signum()
    }
}

/// Sum of scalar Huber losses over a vector: `f(u) = Σ L(uₘ)`.
pub fn huber_sum(u: &[f32], eta: f32) -> f32 {
    u.iter().map(|&v| huber(v, eta) as f64).sum::<f64>() as f32
}

/// Conjugate of the summed Huber loss: `f*(ν) = (η/2)‖ν‖²` on the domain
/// `‖ν‖_∞ ≤ 1` (Eqs. 72–73).
pub fn huber_conjugate(nu: &[f32], eta: f32) -> f32 {
    debug_assert!(
        nu.iter().all(|&v| v.abs() <= 1.0 + 1e-5),
        "huber_conjugate evaluated outside its domain"
    );
    0.5 * eta * crate::math::vector::norm2_sq(nu)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_inside_linear_outside() {
        let eta = 0.2;
        assert!((huber(0.1, eta) - 0.1 * 0.1 / 0.4).abs() < 1e-7);
        assert!((huber(1.0, eta) - (1.0 - 0.1)).abs() < 1e-7);
        assert!((huber(-1.0, eta) - 0.9).abs() < 1e-7);
    }

    #[test]
    fn continuous_at_eta() {
        let eta = 0.5;
        let inside = huber(eta - 1e-6, eta);
        let outside = huber(eta + 1e-6, eta);
        assert!((inside - outside).abs() < 1e-5);
    }

    #[test]
    fn grad_matches_finite_difference() {
        let eta = 0.3;
        for &u in &[-1.0f32, -0.31, -0.1, 0.0, 0.15, 0.31, 2.0] {
            let h = 1e-3;
            let fd = (huber(u + h, eta) - huber(u - h, eta)) / (2.0 * h);
            assert!(
                (huber_grad(u, eta) - fd).abs() < 1e-2,
                "u={u}: grad {} vs fd {fd}",
                huber_grad(u, eta)
            );
        }
    }

    #[test]
    fn grad_bounded_by_one() {
        for &u in &[-100.0f32, -1.0, 0.0, 1.0, 100.0] {
            assert!(huber_grad(u, 0.2).abs() <= 1.0);
        }
    }

    /// Fenchel–Young: L(u) + L*(ν) >= u·ν, equality at ν = L'(u).
    #[test]
    fn fenchel_young_equality_at_gradient() {
        let eta = 0.2;
        for &u in &[-2.0f32, -0.15, 0.0, 0.1, 0.5, 3.0] {
            let nu = huber_grad(u, eta);
            let lhs = huber(u, eta) + 0.5 * eta * nu * nu;
            assert!((lhs - u * nu).abs() < 1e-5, "u={u}: {lhs} vs {}", u * nu);
        }
    }

    #[test]
    fn conjugate_sum_value() {
        let nu = [0.5f32, -0.5, 1.0];
        assert!((huber_conjugate(&nu, 0.2) - 0.5 * 0.2 * 1.5).abs() < 1e-6);
    }
}
