//! Proximal, conjugate, and projection operators from the paper.
//!
//! Implements Table II and Appendix A of Chen–Towfic–Sayed 2014:
//! soft-thresholding operators `T_λ` / `T⁺_λ`, the conjugate values
//! `S_{γ/δ}` / `S⁺_{γ/δ}` of the (non-negative) elastic net, the Huber
//! loss and its conjugate, and the projection operators used by the
//! dictionary update (Eqs. 45/47) and by projected diffusion (Eq. 34).

pub mod huber;
pub mod project;
pub mod prox;
pub mod threshold;

pub use huber::{huber, huber_conjugate, huber_grad, huber_sum};
pub use project::{clip_linf, project_l1_ball, project_nonneg_unit_ball, project_unit_ball};
pub use prox::{prox_l1, prox_zero};
pub use threshold::{s_conj, s_conj_plus, soft_threshold, soft_threshold_plus};
