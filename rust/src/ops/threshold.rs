//! Soft-thresholding operators and elastic-net conjugate values (paper
//! Table II footnotes a–d, Appendix A).

/// Two-sided soft threshold `[T_λ(x)]ₙ = (|xₙ| − λ)₊ · sgn(xₙ)` (Eq. 78).
#[inline]
pub fn soft_threshold(x: f32, lambda: f32) -> f32 {
    let a = x.abs() - lambda;
    if a > 0.0 {
        a * x.signum()
    } else {
        0.0
    }
}

/// One-sided soft threshold `[T⁺_λ(x)]ₙ = (xₙ − λ)₊` (Eq. 86) — the
/// non-negative (NMF / topic modeling) variant.
#[inline]
pub fn soft_threshold_plus(x: f32, lambda: f32) -> f32 {
    (x - lambda).max(0.0)
}

/// Vectorized two-sided threshold into `out`.
pub fn soft_threshold_vec(x: &[f32], lambda: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    for (o, &v) in out.iter_mut().zip(x) {
        *o = soft_threshold(v, lambda);
    }
}

/// Vectorized one-sided threshold into `out`.
pub fn soft_threshold_plus_vec(x: &[f32], lambda: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    for (o, &v) in out.iter_mut().zip(x) {
        *o = soft_threshold_plus(v, lambda);
    }
}

/// Elastic-net conjugate value `S_{γ/δ}(x)` (Table II footnote b):
///
/// `S_{γ/δ}(x) = −(δ/2)‖T(x)‖₂² − γ‖T(x)‖₁ + δ·xᵀT(x)` with `T = T_{γ/δ}`.
///
/// Equals `h*(δ·x)` for `h(y) = γ‖y‖₁ + (δ/2)‖y‖₂²` evaluated at `ν` with
/// `x = Wᵀν/δ`.
pub fn s_conj(x: &[f32], gamma: f32, delta: f32) -> f32 {
    let lam = gamma / delta;
    let mut acc = 0.0f64;
    for &v in x {
        let t = soft_threshold(v, lam);
        acc += (-0.5 * delta * t * t - gamma * t.abs() + delta * v * t) as f64;
    }
    acc as f32
}

/// Non-negative elastic-net conjugate value `S⁺_{γ/δ}(x)` (Table II
/// footnote d), with `T⁺ = T⁺_{γ/δ}`.
pub fn s_conj_plus(x: &[f32], gamma: f32, delta: f32) -> f32 {
    let lam = gamma / delta;
    let mut acc = 0.0f64;
    for &v in x {
        let t = soft_threshold_plus(v, lam);
        acc += (-0.5 * delta * t * t - gamma * t + delta * v * t) as f64;
    }
    acc as f32
}

/// Scalar conjugate of the elastic net evaluated directly by maximizing
/// `a·y − γ|y| − (δ/2)y²` over `y` (closed form). Used by property tests to
/// validate [`s_conj`].
pub fn elastic_net_conjugate_direct(a: f32, gamma: f32, delta: f32) -> f32 {
    // Optimal y = T_{γ/δ}(a/δ); value = a y − γ|y| − δ/2 y².
    let y = soft_threshold(a / delta, gamma / delta);
    a * y - gamma * y.abs() - 0.5 * delta * y * y
}

/// Scalar conjugate of the non-negative elastic net (direct evaluation).
pub fn nonneg_elastic_net_conjugate_direct(a: f32, gamma: f32, delta: f32) -> f32 {
    let y = soft_threshold_plus(a / delta, gamma / delta);
    a * y - gamma * y - 0.5 * delta * y * y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }

    #[test]
    fn soft_threshold_plus_cases() {
        assert_eq!(soft_threshold_plus(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold_plus(-3.0, 1.0), 0.0);
        assert_eq!(soft_threshold_plus(0.5, 1.0), 0.0);
    }

    /// `S_{γ/δ}(Wᵀν/δ)` must equal the direct supremum value of the
    /// conjugate — the identity the whole dual construction rests on.
    #[test]
    fn s_conj_matches_direct_supremum() {
        let (gamma, delta) = (0.7f32, 0.3f32);
        for &a in &[-3.0f32, -1.0, -0.1, 0.0, 0.2, 1.5, 4.0] {
            // s_conj takes x = a/δ per Table II convention.
            let via_s = s_conj(&[a / delta], gamma, delta);
            let direct = elastic_net_conjugate_direct(a, gamma, delta);
            assert!(
                (via_s - direct).abs() < 1e-5,
                "a={a}: S gives {via_s}, direct {direct}"
            );
        }
    }

    #[test]
    fn s_conj_plus_matches_direct_supremum() {
        let (gamma, delta) = (0.5f32, 0.2f32);
        for &a in &[-2.0f32, -0.3, 0.0, 0.4, 1.0, 3.0] {
            let via_s = s_conj_plus(&[a / delta], gamma, delta);
            let direct = nonneg_elastic_net_conjugate_direct(a, gamma, delta);
            assert!(
                (via_s - direct).abs() < 1e-5,
                "a={a}: S+ gives {via_s}, direct {direct}"
            );
        }
    }

    #[test]
    fn conjugates_are_nonnegative_at_zero_arg() {
        // h*(0) = -inf h >= -h(0) = 0, and h >= 0 with h(0)=0 => h*(0) = 0.
        assert!((s_conj(&[0.0], 1.0, 0.5)).abs() < 1e-7);
        assert!((s_conj_plus(&[0.0], 1.0, 0.5)).abs() < 1e-7);
    }

    #[test]
    fn vectorized_matches_scalar() {
        let x = [-2.0f32, -0.5, 0.0, 0.7, 3.0];
        let mut out = [0.0f32; 5];
        soft_threshold_vec(&x, 0.6, &mut out);
        for (i, &v) in x.iter().enumerate() {
            assert_eq!(out[i], soft_threshold(v, 0.6));
        }
        soft_threshold_plus_vec(&x, 0.6, &mut out);
        for (i, &v) in x.iter().enumerate() {
            assert_eq!(out[i], soft_threshold_plus(v, 0.6));
        }
    }
}
