//! Push-sum (ratio-of-sums) combination weights for directed or
//! time-varying live topologies.
//!
//! The Metropolis rule ([`super::metropolis`]) is doubly stochastic, which
//! is what makes plain diffusion average unbiasedly — but double
//! stochasticity needs *symmetric* connectivity. When the chaos layer
//! takes down one direction of an edge ([`crate::net::chaos`]), the live
//! graph is a digraph and no doubly-stochastic weight assignment may
//! exist. Push-sum (Nedić–Olshevsky subgradient-push; arXiv:1808.05933,
//! arXiv:1612.07335) only needs **column** stochasticity, which each
//! sender can guarantee locally: it splits its mass uniformly over its
//! live out-edges plus itself, `a_{ℓk} = 1/(d_k⁺ + 1)`. A parallel scalar
//! weight `w` runs through the same recursion and the unbiased estimate
//! is read off as the ratio `s/w`.

use super::Graph;
use crate::math::Mat;

/// Uniform push-sum weight matrix over the full graph:
/// `a_{ℓk} = 1/(d_k + 1)` for `ℓ ∈ N_k ∪ {k}`, zero otherwise
/// (column `k` = how agent `k` splits its mass). Column-stochastic by
/// construction; row sums differ on irregular graphs — this is *not* a
/// doubly-stochastic matrix and is not meant to be.
pub fn pushsum_weights(g: &Graph) -> Mat {
    pushsum_weights_live(g, |_, _| true)
}

/// Push-sum weights over the **live** out-edges only: `alive(k, l)` says
/// whether the directed link `k → l` currently transmits. Each column
/// stays exactly stochastic whatever the mask — the sender redistributes
/// over whatever is up (plus itself), which is the push-sum correction
/// the chaos executor applies at every send.
pub fn pushsum_weights_live(g: &Graph, alive: impl Fn(usize, usize) -> bool) -> Mat {
    let n = g.n();
    let mut a = Mat::zeros(n, n);
    for k in 0..n {
        let live: Vec<usize> =
            g.neighbors(k).iter().copied().filter(|&l| alive(k, l)).collect();
        let w = 1.0 / (live.len() + 1) as f32;
        for l in live {
            a.set(l, k, w);
        }
        a.set(k, k, w);
    }
    a
}

/// Check column stochasticity (`Aᵀ1 = 1`) and non-negativity — the whole
/// contract push-sum needs from its weights.
pub fn is_column_stochastic(a: &Mat, tol: f32) -> bool {
    let n = a.rows();
    if a.cols() != n {
        return false;
    }
    for k in 0..n {
        let mut col = 0.0;
        for l in 0..n {
            let v = a.get(l, k);
            if v < -tol {
                return false;
            }
            col += v;
        }
        if (col - 1.0).abs() > tol {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::metropolis::respects_topology;
    use crate::graph::{is_doubly_stochastic, Topology};
    use crate::rng::Pcg64;

    #[test]
    fn pushsum_is_column_stochastic_on_random_graphs() {
        for seed in 0..5 {
            let g = Graph::generate(20, &Topology::ErdosRenyi { p: 0.4 }, &mut Pcg64::new(seed));
            let a = pushsum_weights(&g);
            assert!(is_column_stochastic(&a, 1e-5), "seed {seed}");
            assert!(respects_topology(&a, &g, 1e-9), "seed {seed}");
        }
    }

    #[test]
    fn pushsum_is_not_doubly_stochastic_on_irregular_graphs() {
        // A star-ish ER graph has irregular degrees: rows cannot all sum
        // to one when columns do with uniform splits.
        let g = Graph::generate(15, &Topology::ErdosRenyi { p: 0.3 }, &mut Pcg64::new(3));
        let irregular =
            (0..15).any(|k| g.degree(k) != g.degree(0));
        assert!(irregular, "test graph should be irregular");
        let a = pushsum_weights(&g);
        assert!(!is_doubly_stochastic(&a, 1e-5));
    }

    #[test]
    fn live_mask_keeps_columns_stochastic() {
        let g = Graph::generate(12, &Topology::Ring { k: 2 }, &mut Pcg64::new(1));
        // Take down the directed links 0→1 and 3→5 (if present): the
        // senders redistribute, columns stay exactly stochastic.
        let a = pushsum_weights_live(&g, |k, l| !((k == 0 && l == 1) || (k == 3 && l == 5)));
        assert!(is_column_stochastic(&a, 1e-5));
        assert_eq!(a.get(1, 0), 0.0, "masked link carries no weight");
        // Column 0 split over one fewer recipient than column 2's.
        assert!(a.get(0, 0) > a.get(2, 2));
    }

    #[test]
    fn ratio_of_sums_consensus_is_exact_under_directed_mask() {
        // The defining property: iterating s ← As, w ← Aw from s = values,
        // w = 1 drives every ratio s_k/w_k to the true average, even with
        // a directed mask where plain row-normalized averaging is biased.
        let g = Graph::generate(10, &Topology::Ring { k: 2 }, &mut Pcg64::new(4));
        let a = pushsum_weights_live(&g, |k, l| !(k == 2 && l == 3));
        let n = 10usize;
        let values: Vec<f32> = (0..n).map(|k| k as f32).collect();
        let mean: f32 = values.iter().sum::<f32>() / n as f32;
        let mut s = values;
        let mut w = vec![1.0f32; n];
        for _ in 0..400 {
            let mut s2 = vec![0.0f32; n];
            let mut w2 = vec![0.0f32; n];
            for k in 0..n {
                for l in 0..n {
                    s2[l] += a.get(l, k) * s[k];
                    w2[l] += a.get(l, k) * w[k];
                }
            }
            s = s2;
            w = w2;
        }
        for k in 0..n {
            let z = s[k] / w[k];
            assert!((z - mean).abs() < 1e-3, "agent {k}: {z} vs {mean}");
        }
    }
}
