//! Undirected graph topologies over `N` agents.

use crate::rng::Pcg64;

/// Topology families used by the experiments and ablations.
#[derive(Clone, Debug, PartialEq)]
pub enum Topology {
    /// Erdős–Rényi `G(N, p)`, regenerated until connected (paper setting:
    /// `p = 0.5`).
    ErdosRenyi { p: f64 },
    /// Ring lattice where each agent links to `k` neighbors on each side.
    Ring { k: usize },
    /// 2D grid (row-major), 4-neighborhood.
    Grid,
    /// Complete graph (the paper's "fully connected" comparator).
    FullyConnected,
}

/// Undirected graph with adjacency lists. Self-loops are implicit: every
/// agent is always in its own neighborhood `N_k` (paper Fig. 1).
#[derive(Clone, Debug)]
pub struct Graph {
    n: usize,
    /// Sorted neighbor lists, *excluding* self.
    adj: Vec<Vec<usize>>,
}

impl Graph {
    /// Build a graph of the given topology; for `ErdosRenyi` the graph is
    /// resampled until connected (paper §IV-B protocol), up to 1000 tries.
    pub fn generate(n: usize, topology: &Topology, rng: &mut Pcg64) -> Graph {
        assert!(n > 0);
        match topology {
            Topology::ErdosRenyi { p } => {
                for _ in 0..1000 {
                    let g = Self::erdos_renyi(n, *p, rng);
                    if g.is_connected() {
                        return g;
                    }
                }
                panic!("failed to sample a connected G({n}, {p}) in 1000 tries");
            }
            Topology::Ring { k } => Self::ring(n, *k),
            Topology::Grid => Self::grid(n),
            Topology::FullyConnected => Self::complete(n),
        }
    }

    fn erdos_renyi(n: usize, p: f64, rng: &mut Pcg64) -> Graph {
        let mut adj = vec![Vec::new(); n];
        for i in 0..n {
            for j in i + 1..n {
                if rng.next_f64() < p {
                    adj[i].push(j);
                    adj[j].push(i);
                }
            }
        }
        Graph { n, adj }
    }

    fn ring(n: usize, k: usize) -> Graph {
        let mut adj = vec![Vec::new(); n];
        let k = k.max(1).min(n.saturating_sub(1) / 2 + 1);
        for i in 0..n {
            for d in 1..=k {
                let j = (i + d) % n;
                if i != j && !adj[i].contains(&j) {
                    adj[i].push(j);
                    adj[j].push(i);
                }
            }
        }
        for a in &mut adj {
            a.sort_unstable();
        }
        Graph { n, adj }
    }

    fn grid(n: usize) -> Graph {
        let side = (n as f64).sqrt().ceil() as usize;
        let mut adj = vec![Vec::new(); n];
        for i in 0..n {
            let (r, c) = (i / side, i % side);
            let link = |j: usize, adj: &mut Vec<Vec<usize>>| {
                if j < n {
                    adj[i].push(j);
                    adj[j].push(i);
                }
            };
            if c + 1 < side {
                link(i + 1, &mut adj);
            }
            if r + 1 < side.div_ceil(1) {
                link(i + side, &mut adj);
            }
        }
        for a in &mut adj {
            a.sort_unstable();
            a.dedup();
        }
        Graph { n, adj }
    }

    fn complete(n: usize) -> Graph {
        let adj = (0..n)
            .map(|i| (0..n).filter(|&j| j != i).collect())
            .collect();
        Graph { n, adj }
    }

    /// Build directly from adjacency lists (testing / hand-crafted
    /// topologies). Lists are normalized (sorted, deduped); symmetry is the
    /// caller's responsibility.
    pub fn from_adjacency(adj: Vec<Vec<usize>>) -> Graph {
        let n = adj.len();
        let mut adj = adj;
        for a in &mut adj {
            a.sort_unstable();
            a.dedup();
        }
        Graph { n, adj }
    }

    /// Number of agents.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Neighbors of `k`, excluding `k` itself.
    pub fn neighbors(&self, k: usize) -> &[usize] {
        &self.adj[k]
    }

    /// Degree of `k` excluding self.
    pub fn degree(&self, k: usize) -> usize {
        self.adj[k].len()
    }

    /// Total undirected edge count.
    pub fn edge_count(&self) -> usize {
        self.adj.iter().map(|a| a.len()).sum::<usize>() / 2
    }

    /// BFS connectivity check.
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut queue = std::collections::VecDeque::new();
        queue.push_back(0);
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            for &v in &self.adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    queue.push_back(v);
                }
            }
        }
        count == self.n
    }

    /// Grow the graph by `extra` new agents (novelty time-steps add 10
    /// nodes per step, §IV-C): each new agent wires to existing + new
    /// agents with probability `p`, retrying until the whole graph stays
    /// connected (guaranteed by forcing at least one link).
    pub fn grow(&mut self, extra: usize, p: f64, rng: &mut Pcg64) {
        let old_n = self.n;
        self.n += extra;
        self.adj.resize(self.n, Vec::new());
        for i in old_n..self.n {
            for j in 0..i {
                if rng.next_f64() < p {
                    self.adj[i].push(j);
                    self.adj[j].push(i);
                }
            }
            if self.adj[i].is_empty() {
                // Force connectivity with one uniformly chosen peer.
                let j = rng.next_below(i as u64) as usize;
                self.adj[i].push(j);
                self.adj[j].push(i);
            }
        }
        for a in &mut self.adj {
            a.sort_unstable();
            a.dedup();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erdos_renyi_connected_by_construction() {
        let mut rng = Pcg64::new(1);
        let g = Graph::generate(30, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
        assert!(g.is_connected());
        assert_eq!(g.n(), 30);
        // symmetry
        for i in 0..30 {
            for &j in g.neighbors(i) {
                assert!(g.neighbors(j).contains(&i));
            }
        }
    }

    #[test]
    fn ring_structure() {
        let g = Graph::generate(6, &Topology::Ring { k: 1 }, &mut Pcg64::new(2));
        assert!(g.is_connected());
        for i in 0..6 {
            assert_eq!(g.degree(i), 2, "node {i}");
        }
        assert_eq!(g.edge_count(), 6);
    }

    #[test]
    fn complete_graph() {
        let g = Graph::generate(5, &Topology::FullyConnected, &mut Pcg64::new(3));
        for i in 0..5 {
            assert_eq!(g.degree(i), 4);
        }
        assert_eq!(g.edge_count(), 10);
    }

    #[test]
    fn grid_connected() {
        let g = Graph::generate(12, &Topology::Grid, &mut Pcg64::new(4));
        assert!(g.is_connected());
        assert_eq!(g.n(), 12);
    }

    #[test]
    fn disconnected_detected() {
        // Hand-build two components.
        let g = Graph { n: 4, adj: vec![vec![1], vec![0], vec![3], vec![2]] };
        assert!(!g.is_connected());
    }

    #[test]
    fn grow_keeps_connected_and_symmetric() {
        let mut rng = Pcg64::new(5);
        let mut g = Graph::generate(10, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
        g.grow(10, 0.5, &mut rng);
        assert_eq!(g.n(), 20);
        assert!(g.is_connected());
        for i in 0..20 {
            for &j in g.neighbors(i) {
                assert!(g.neighbors(j).contains(&i), "{i}-{j} asymmetric");
            }
        }
    }

    #[test]
    fn grow_forced_link_when_p_zero() {
        let mut rng = Pcg64::new(6);
        let mut g = Graph::generate(5, &Topology::Ring { k: 1 }, &mut rng);
        g.grow(3, 0.0, &mut rng);
        assert!(g.is_connected());
    }
}
