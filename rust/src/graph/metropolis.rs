//! Combination matrices for diffusion (Eq. 32).
//!
//! The Metropolis(-Hastings) rule produces a symmetric doubly-stochastic
//! matrix from local degree information only — exactly what the paper uses
//! (§IV-B, "we use the Metropolis rule, which is known to be
//! doubly-stochastic"). The fully-connected comparator uses
//! `A = (1/N)·11ᵀ`.

use super::Graph;
use crate::math::{CsrMat, Mat};

/// Metropolis-rule combination matrix:
/// `a_{ℓk} = 1 / max(d_ℓ, d_k)` for neighbors `ℓ ≠ k`,
/// `a_{kk} = 1 − Σ_{ℓ≠k} a_{ℓk}`, zero otherwise. Symmetric and doubly
/// stochastic by construction; every diagonal entry is positive.
pub fn metropolis_weights(g: &Graph) -> Mat {
    let n = g.n();
    let mut a = Mat::zeros(n, n);
    for k in 0..n {
        let dk = g.degree(k) as f32;
        let mut off_sum = 0.0;
        for &l in g.neighbors(k) {
            let dl = g.degree(l) as f32;
            let w = 1.0 / (dk.max(dl) + 1.0); // +1: degrees counted incl. self
            a.set(l, k, w);
            off_sum += w;
        }
        a.set(k, k, 1.0 - off_sum);
    }
    a
}

/// Metropolis combination matrix built **directly in CSR**, never
/// materializing the dense `N×N` form. Returns the CSR of `Aᵀ` (row `k`
/// holds the weights `a_{ℓk}` flowing *into* agent `k`), which is exactly
/// the layout the combine step `V ← AᵀΨ` consumes; since the Metropolis
/// rule is symmetric this is also the CSR of `A` itself.
///
/// Weights match [`metropolis_weights`] bit-for-bit: the same per-neighbor
/// expression and the same accumulation order for the diagonal.
pub fn metropolis_csr(g: &Graph) -> CsrMat {
    let n = g.n();
    let mut indptr = Vec::with_capacity(n + 1);
    let mut indices = Vec::new();
    let mut values = Vec::new();
    indptr.push(0);
    for k in 0..n {
        let dk = g.degree(k) as f32;
        let nbrs = g.neighbors(k);
        let mut off_sum = 0.0;
        let mut row: Vec<(usize, f32)> = Vec::with_capacity(nbrs.len() + 1);
        for &l in nbrs {
            let dl = g.degree(l) as f32;
            let w = 1.0 / (dk.max(dl) + 1.0); // +1: degrees counted incl. self
            row.push((l, w));
            off_sum += w;
        }
        // Neighbor lists are sorted and exclude self: splice the diagonal in.
        let pos = row.partition_point(|&(l, _)| l < k);
        row.insert(pos, (k, 1.0 - off_sum));
        for (l, w) in row {
            indices.push(l);
            values.push(w);
        }
        indptr.push(indices.len());
    }
    CsrMat::from_parts(n, n, indptr, indices, values)
        .expect("metropolis CSR is valid by construction")
}

/// Uniform averaging matrix `A = (1/N)·11ᵀ` — the paper's fully-connected
/// configuration (§IV-C1).
pub fn uniform_weights(n: usize) -> Mat {
    Mat::full(n, n, 1.0 / n as f32)
}

/// Check double stochasticity (`A1 = Aᵀ1 = 1`), non-negativity, and zero
/// pattern consistency with the graph (entries only on edges + diagonal).
pub fn is_doubly_stochastic(a: &Mat, tol: f32) -> bool {
    let n = a.rows();
    if a.cols() != n {
        return false;
    }
    for i in 0..n {
        let mut row = 0.0;
        let mut col = 0.0;
        for j in 0..n {
            let v = a.get(i, j);
            if v < -tol {
                return false;
            }
            row += v;
            col += a.get(j, i);
        }
        if (row - 1.0).abs() > tol || (col - 1.0).abs() > tol {
            return false;
        }
    }
    true
}

/// Verify the sparsity pattern of `A` respects the graph: `a_{ℓk} > 0` only
/// if `ℓ = k` or `ℓ ∈ N_k`.
pub fn respects_topology(a: &Mat, g: &Graph, tol: f32) -> bool {
    let n = g.n();
    for k in 0..n {
        for l in 0..n {
            if a.get(l, k).abs() > tol && l != k && !g.neighbors(k).contains(&l) {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Topology;
    use crate::rng::Pcg64;

    #[test]
    fn metropolis_doubly_stochastic_on_random_graphs() {
        for seed in 0..5 {
            let g = Graph::generate(25, &Topology::ErdosRenyi { p: 0.5 }, &mut Pcg64::new(seed));
            let a = metropolis_weights(&g);
            assert!(is_doubly_stochastic(&a, 1e-5), "seed {seed}");
            assert!(respects_topology(&a, &g, 1e-9), "seed {seed}");
        }
    }

    #[test]
    fn metropolis_symmetric() {
        let g = Graph::generate(15, &Topology::ErdosRenyi { p: 0.4 }, &mut Pcg64::new(9));
        let a = metropolis_weights(&g);
        for i in 0..15 {
            for j in 0..15 {
                assert!((a.get(i, j) - a.get(j, i)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn metropolis_positive_diagonal() {
        let g = Graph::generate(20, &Topology::ErdosRenyi { p: 0.8 }, &mut Pcg64::new(11));
        let a = metropolis_weights(&g);
        for i in 0..20 {
            assert!(a.get(i, i) > 0.0, "diagonal {i} = {}", a.get(i, i));
        }
    }

    #[test]
    fn uniform_weights_are_doubly_stochastic() {
        let a = uniform_weights(7);
        assert!(is_doubly_stochastic(&a, 1e-6));
        assert!((a.get(3, 4) - 1.0 / 7.0).abs() < 1e-7);
    }

    #[test]
    fn detects_non_doubly_stochastic() {
        let mut a = uniform_weights(3);
        a.set(0, 0, 0.9);
        assert!(!is_doubly_stochastic(&a, 1e-6));
    }

    #[test]
    fn csr_matches_dense_metropolis_exactly() {
        for seed in 0..4 {
            let g = Graph::generate(
                22,
                &Topology::ErdosRenyi { p: 0.3 },
                &mut Pcg64::new(100 + seed),
            );
            let dense = metropolis_weights(&g);
            let csr = metropolis_csr(&g);
            assert_eq!(csr.rows(), 22);
            // Same values at every coordinate (Aᵀ row k == A column k; and
            // A is symmetric, so comparing against the transpose is exact).
            assert_eq!(csr.to_dense(), dense.transpose(), "seed {seed}");
            // Structural sparsity: diag + one entry per directed edge.
            assert_eq!(csr.nnz(), 22 + 2 * g.edge_count());
        }
    }

    #[test]
    fn csr_on_ring_has_bounded_degree() {
        let g = Graph::generate(30, &Topology::Ring { k: 2 }, &mut Pcg64::new(7));
        let csr = metropolis_csr(&g);
        assert_eq!(csr.nnz(), 30 * 5); // 4 neighbors + self per agent
        assert!(csr.density() < 0.2);
    }

    #[test]
    fn detects_topology_violation() {
        let g = Graph::generate(4, &Topology::Ring { k: 1 }, &mut Pcg64::new(13));
        let a = uniform_weights(4); // dense A cannot respect a ring
        assert!(!respects_topology(&a, &g, 1e-9));
    }
}
