//! Network topology substrate.
//!
//! The paper's experiments run over an Erdős–Rényi random graph with edge
//! probability 0.5, regenerated until connected (checked through the
//! algebraic connectivity of the graph Laplacian), with the Metropolis
//! rule supplying a doubly-stochastic combination matrix (Eq. 32 and §IV-B).
//! The [`pushsum`] module supplies the column-stochastic weights used when
//! the live topology loses symmetry (directed faults, `ddl chaos`).

pub mod laplacian;
pub mod metropolis;
pub mod pushsum;
pub mod topology;

pub use metropolis::{is_doubly_stochastic, metropolis_csr, metropolis_weights, uniform_weights};
pub use pushsum::{is_column_stochastic, pushsum_weights, pushsum_weights_live};
pub use topology::{Graph, Topology};
