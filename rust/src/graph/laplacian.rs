//! Graph Laplacian spectral analysis.
//!
//! The paper checks network connectivity "by inspecting the algebraic
//! connectivity of the graph Laplacian matrix" (§IV-B). We provide exactly
//! that: the Fiedler value λ₂(L), computed by power iteration on a shifted,
//! deflated Laplacian — no external eigensolver needed.

use super::Graph;
use crate::math::{solve::power_iteration, Mat};

/// Dense graph Laplacian `L = D − A`.
pub fn laplacian(g: &Graph) -> Mat {
    let n = g.n();
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        l.set(i, i, g.degree(i) as f32);
        for &j in g.neighbors(i) {
            l.set(i, j, -1.0);
        }
    }
    l
}

/// Algebraic connectivity λ₂ of the Laplacian (the Fiedler value).
/// Positive iff the graph is connected.
///
/// Method: λ_max from power iteration, then power-iterate `(λ_max I − L)`
/// with deflation of the all-ones kernel vector; λ₂ = λ_max − μ where μ is
/// the dominant eigenvalue of the deflated complement.
pub fn algebraic_connectivity(g: &Graph) -> f32 {
    let n = g.n();
    if n <= 1 {
        return 0.0;
    }
    let l = laplacian(g);
    let (lmax, _) = power_iteration(&l, 300, 0xF1ED);
    let lmax = lmax.max(1e-6);
    // B = λ_max I − L restricted to 1⊥: deflate by subtracting the mean.
    let mut b = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let v = if i == j { lmax } else { 0.0 } - l.get(i, j);
            b.set(i, j, v);
        }
    }
    // Power iteration with mean-deflation each step.
    let mut rng = crate::rng::Pcg64::new(0xF1ED2);
    let mut v: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
    deflate_mean(&mut v);
    crate::math::vector::normalize(&mut v);
    let mut mu = 0.0;
    let mut bv = vec![0.0f32; n];
    for _ in 0..500 {
        crate::math::blas::gemv(n, n, b.as_slice(), &v, &mut bv);
        deflate_mean(&mut bv);
        mu = crate::math::blas::dot(&v, &bv);
        let nn = crate::math::vector::norm2(&bv);
        if nn < 1e-12 {
            break;
        }
        for (vi, &bi) in v.iter_mut().zip(&bv) {
            *vi = bi / nn;
        }
    }
    (lmax - mu).max(0.0)
}

/// Spectral gap of a doubly-stochastic combination matrix `A`:
/// `1 − |λ₂(A)|`, which governs the diffusion mixing rate. Computed by
/// deflating the Perron vector (uniform, since A is doubly stochastic).
pub fn spectral_gap(a: &Mat) -> f32 {
    let n = a.rows();
    assert_eq!(a.cols(), n);
    let mut rng = crate::rng::Pcg64::new(0x5EC7);
    let mut v: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
    deflate_mean(&mut v);
    crate::math::vector::normalize(&mut v);
    let mut av = vec![0.0f32; n];
    let mut lam = 0.0f32;
    for _ in 0..500 {
        crate::math::blas::gemv(n, n, a.as_slice(), &v, &mut av);
        deflate_mean(&mut av);
        let nn = crate::math::vector::norm2(&av);
        if nn < 1e-12 {
            lam = 0.0;
            break;
        }
        lam = nn; // |λ₂| since v stays unit-norm in 1⊥
        for (vi, &ai) in v.iter_mut().zip(&av) {
            *vi = ai / nn;
        }
    }
    (1.0 - lam.abs()).clamp(0.0, 1.0)
}

fn deflate_mean(v: &mut [f32]) {
    let m = crate::math::vector::mean(v);
    for x in v.iter_mut() {
        *x -= m;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{metropolis_weights, Topology};
    use crate::rng::Pcg64;

    #[test]
    fn laplacian_rows_sum_to_zero() {
        let g = Graph::generate(12, &Topology::ErdosRenyi { p: 0.5 }, &mut Pcg64::new(1));
        let l = laplacian(&g);
        for i in 0..12 {
            let s: f32 = l.row(i).iter().sum();
            assert!(s.abs() < 1e-5);
        }
    }

    #[test]
    fn connected_graph_positive_fiedler() {
        let g = Graph::generate(16, &Topology::ErdosRenyi { p: 0.5 }, &mut Pcg64::new(2));
        let l2 = algebraic_connectivity(&g);
        assert!(l2 > 0.1, "λ₂ = {l2}");
    }

    #[test]
    fn disconnected_graph_zero_fiedler() {
        let g = Graph::from_adjacency(vec![vec![1], vec![0], vec![3], vec![2]]);
        let l2 = algebraic_connectivity(&g);
        assert!(l2 < 1e-2, "λ₂ = {l2}");
    }

    #[test]
    fn complete_graph_fiedler_is_n() {
        // K_n has λ₂ = n.
        let g = Graph::generate(8, &Topology::FullyConnected, &mut Pcg64::new(3));
        let l2 = algebraic_connectivity(&g);
        assert!((l2 - 8.0).abs() < 0.1, "λ₂ = {l2}");
    }

    #[test]
    fn ring_fiedler_matches_formula() {
        // Cycle C_n: λ₂ = 2(1 − cos(2π/n)).
        let n = 10;
        let g = Graph::generate(n, &Topology::Ring { k: 1 }, &mut Pcg64::new(4));
        let expect = 2.0 * (1.0 - (2.0 * std::f32::consts::PI / n as f32).cos());
        let l2 = algebraic_connectivity(&g);
        assert!((l2 - expect).abs() < 0.02, "λ₂ = {l2}, expect {expect}");
    }

    #[test]
    fn spectral_gap_larger_for_denser_graphs() {
        let mut rng = Pcg64::new(5);
        let ring = Graph::generate(20, &Topology::Ring { k: 1 }, &mut rng);
        let dense = Graph::generate(20, &Topology::ErdosRenyi { p: 0.7 }, &mut rng);
        let gap_ring = spectral_gap(&metropolis_weights(&ring));
        let gap_dense = spectral_gap(&metropolis_weights(&dense));
        assert!(
            gap_dense > gap_ring,
            "dense gap {gap_dense} should beat ring gap {gap_ring}"
        );
    }
}
