//! ATC diffusion over the dual problem — the paper's core algorithm
//! (Eqs. 31/35, specialized in Algs. 2–4).
//!
//! Per iteration, every agent `k` runs a local **adapt** step
//!
//! ```text
//! ψ_k = ν_k − μ·∇J_k(ν_k; x)
//!     = ν_k − μ·(c_f/N · ν_k − θ_k/|N_I| · x) − (μ/δ)·W_k thr_γ(W_kᵀ ν_k)
//! ```
//!
//! followed by the neighborhood **combine** `ν_k = Σ_ℓ a_{ℓk} ψ_ℓ`
//! (optionally projected onto `V_f` for the Huber task, Eq. 35b). The
//! engine stores the stacked iterates as `V ∈ R^{N×M}` so combine is one
//! gemm `V ← AᵀΨ` — the same layout the L1 Pallas kernel uses.
//!
//! Buffers are pre-allocated once; the per-iteration hot loop performs no
//! heap allocation (see EXPERIMENTS.md §Perf).

use crate::error::{DdlError, Result};
use crate::math::{blas, Mat};
use crate::model::{DistributedDictionary, TaskSpec};
use crate::ops::project::clip_linf;

/// Diffusion hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct DiffusionParams {
    /// Step size μ.
    pub mu: f32,
    /// Iteration count.
    pub iters: usize,
}

/// Reusable diffusion inference engine for a fixed network size.
pub struct DiffusionEngine {
    /// Stacked dual iterates `V` (`N × M`), row `k` = agent `k`'s ν.
    v: Mat,
    /// Adapt outputs `Ψ` (`N × M`).
    psi: Mat,
    /// Combination matrix transpose `Aᵀ` (`N × N`) — stored transposed so
    /// combine is a plain row-major gemm.
    at: Mat,
    /// Scratch: per-atom thresholded correlations (`K`).
    thr: Vec<f32>,
    /// Informed-agent mask θ (`N`), entries 1/|N_I| or 0 (Eq. 29).
    theta: Vec<f32>,
    /// Fast path: `A = (1/N)·11ᵀ` (fully connected) — combine collapses
    /// to a row average, O(N·M) instead of O(N²·M).
    uniform_a: bool,
    n: usize,
    m: usize,
}

impl DiffusionEngine {
    /// Create an engine for an `n`-agent network over data dimension `m`.
    ///
    /// `informed`: indices of the agents in `N_I` that observe the data
    /// sample (paper Fig. 1); pass `None` for "all agents informed".
    pub fn new(a: &Mat, m: usize, informed: Option<&[usize]>) -> Result<Self> {
        let n = a.rows();
        if a.cols() != n {
            return Err(DdlError::Shape("combination matrix must be square".into()));
        }
        let mut theta = vec![0.0f32; n];
        match informed {
            None => theta.fill(1.0 / n as f32),
            Some(idx) => {
                if idx.is_empty() {
                    return Err(DdlError::Config("at least one informed agent required".into()));
                }
                let w = 1.0 / idx.len() as f32;
                for &k in idx {
                    if k >= n {
                        return Err(DdlError::Config(format!("informed agent {k} out of range")));
                    }
                    theta[k] = w;
                }
            }
        }
        Ok(DiffusionEngine {
            v: Mat::zeros(n, m),
            psi: Mat::zeros(n, m),
            uniform_a: is_uniform(a),
            at: a.transpose(),
            thr: Vec::new(),
            theta,
            n,
            m,
        })
    }

    /// Replace the combination matrix (topology change between time-steps).
    pub fn set_combination(&mut self, a: &Mat) -> Result<()> {
        if a.rows() != self.n || a.cols() != self.n {
            return Err(DdlError::Shape("combination matrix shape mismatch".into()));
        }
        self.uniform_a = is_uniform(a);
        self.at = a.transpose();
        Ok(())
    }

    /// Reset all dual iterates to zero (cold start for a new sample).
    pub fn reset(&mut self) {
        self.v.as_mut_slice().fill(0.0);
    }

    /// Warm start: every *informed* agent initializes its dual iterate at
    /// `scale · x` locally (no communication — the agent already holds
    /// `x`). With `scale = 1/c_f` this jumps straight to the `y = 0`
    /// stationary point `ν = f'(x)`'s linear regime, skipping the slow
    /// O(N/(μ·c_f)) magnitude build-up that dominates cold-start Huber
    /// runs. Uninformed agents stay at zero and catch up via combine.
    pub fn reset_warm(&mut self, x: &[f32], scale: f32) {
        debug_assert_eq!(x.len(), self.m);
        for k in 0..self.n {
            let informed = self.theta[k] > 0.0;
            let row = self.v.row_mut(k);
            if informed {
                for (r, &xi) in row.iter_mut().zip(x) {
                    *r = scale * xi;
                }
            } else {
                row.fill(0.0);
            }
        }
    }

    /// Run `params.iters` diffusion iterations for data sample `x`.
    ///
    /// Returns after convergence; read results through [`Self::nu`],
    /// [`Self::consensus_nu`], or [`Self::recover_y`].
    pub fn run(
        &mut self,
        dict: &DistributedDictionary,
        task: &TaskSpec,
        x: &[f32],
        params: DiffusionParams,
    ) -> Result<()> {
        if x.len() != self.m {
            return Err(DdlError::Shape(format!(
                "sample length {} != engine dimension {}",
                x.len(),
                self.m
            )));
        }
        if dict.agents() != self.n {
            return Err(DdlError::Shape(format!(
                "dictionary has {} agents, engine {}",
                dict.agents(),
                self.n
            )));
        }
        if dict.m() != self.m {
            return Err(DdlError::Shape("dictionary row dimension mismatch".into()));
        }
        self.thr.resize(dict.k(), 0.0);
        let cf_over_n = task.conj_grad_scale() / self.n as f32;
        let inv_delta = 1.0 / task.delta();
        let mu = params.mu;
        let clip = task.dual_clip();

        for _ in 0..params.iters {
            // --- adapt (Eq. 31a): ψ_k = ν_k − μ ∇J_k(ν_k) ---
            for k in 0..self.n {
                let nu = self.v.row(k);
                // s = W_kᵀ ν_k, thresholded.
                dict.block_correlations(k, nu, &mut self.thr);
                let (start, len) = dict.block(k);
                for q in start..start + len {
                    self.thr[q] = task.threshold(self.thr[q]);
                }
                // ψ = ν − μ(c_f/N · ν − θ_k x)
                let theta_k = self.theta[k];
                let psi = self.psi.row_mut(k);
                let nu = self.v.row(k);
                for i in 0..self.m {
                    psi[i] = nu[i] - mu * (cf_over_n * nu[i] - theta_k * x[i]);
                }
                // ψ -= (μ/δ) Σ_q thr(s_q) w_q  — only agent k's atoms.
                for q in start..start + len {
                    self.thr[q] *= -mu * inv_delta;
                }
                dict.block_accumulate(k, &self.thr, self.psi.row_mut(k));
            }
            // --- combine (Eq. 31b): V ← Aᵀ Ψ ---
            if self.uniform_a {
                // Fully-connected fast path: every row of AᵀΨ equals the
                // column mean of Ψ — O(N·M) instead of O(N²·M).
                let inv_n = 1.0 / self.n as f32;
                let (v, psi) = (self.v.as_mut_slice(), self.psi.as_slice());
                v[..self.m].fill(0.0);
                for k in 0..self.n {
                    let row = &psi[k * self.m..(k + 1) * self.m];
                    for i in 0..self.m {
                        v[i] += row[i];
                    }
                }
                for i in 0..self.m {
                    v[i] *= inv_n;
                }
                let (first, rest) = v.split_at_mut(self.m);
                for k in 1..self.n {
                    rest[(k - 1) * self.m..k * self.m].copy_from_slice(first);
                }
            } else {
                blas::gemm(
                    self.n,
                    self.m,
                    self.n,
                    1.0,
                    self.at.as_slice(),
                    self.psi.as_slice(),
                    0.0,
                    self.v.as_mut_slice(),
                );
            }
            // --- projection onto V_f (Eq. 35b), Huber only ---
            if let Some(bound) = clip {
                clip_linf(self.v.as_mut_slice(), bound);
            }
        }
        Ok(())
    }

    /// Agent `k`'s current dual estimate `ν_{k,i}`.
    pub fn nu(&self, k: usize) -> &[f32] {
        self.v.row(k)
    }

    /// Network-average dual estimate (diagnostics; a real deployment reads
    /// any single agent after convergence).
    pub fn consensus_nu(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.m];
        for k in 0..self.n {
            crate::math::vector::axpy(1.0, self.v.row(k), &mut out);
        }
        crate::math::vector::scale(1.0 / self.n as f32, &mut out);
        out
    }

    /// Maximum pairwise disagreement `max_k ‖ν_k − ν̄‖` — a consensus
    /// diagnostic.
    pub fn disagreement(&self) -> f32 {
        let mean = self.consensus_nu();
        (0..self.n)
            .map(|k| crate::math::vector::dist_sq(self.v.row(k), &mean).sqrt())
            .fold(0.0f32, f32::max)
    }

    /// Primal recovery (Eq. 37 / Table II): `y_q = thr_γ(w_qᵀ ν_k)/δ` for
    /// each agent's own atoms, using each agent's **local** dual iterate —
    /// no extra communication, exactly as in Algs. 2–4.
    pub fn recover_y(&self, dict: &DistributedDictionary, task: &TaskSpec) -> Vec<f32> {
        let mut y = vec![0.0f32; dict.k()];
        let inv_delta = 1.0 / task.delta();
        let mut s = vec![0.0f32; dict.k()];
        for k in 0..self.n {
            dict.block_correlations(k, self.v.row(k), &mut s);
            let (start, len) = dict.block(k);
            for q in start..start + len {
                y[q] = task.threshold(s[q]) * inv_delta;
            }
        }
        y
    }

    /// Whether the fully-connected fast path is active.
    pub fn is_fully_connected(&self) -> bool {
        self.uniform_a
    }

    /// Number of agents.
    pub fn agents(&self) -> usize {
        self.n
    }

    /// Data dimension.
    pub fn dim(&self) -> usize {
        self.m
    }
}

/// Detect `A = (1/N)·11ᵀ` (all entries equal and doubly stochastic).
fn is_uniform(a: &Mat) -> bool {
    let n = a.rows();
    if n == 0 || a.cols() != n {
        return false;
    }
    let expect = 1.0 / n as f32;
    a.as_slice().iter().all(|&v| (v - expect).abs() <= 1e-7 * (1.0 + expect))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{metropolis_weights, uniform_weights, Graph, Topology};
    use crate::model::AtomConstraint;
    use crate::rng::Pcg64;

    fn setup(
        n: usize,
        m: usize,
        seed: u64,
    ) -> (DistributedDictionary, Mat, Vec<f32>) {
        let mut rng = Pcg64::new(seed);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
        let a = metropolis_weights(&g);
        let x: Vec<f32> = rng.normal_vec(m);
        (dict, a, x)
    }

    /// Consensus disagreement is O(μ): it must shrink proportionally as μ
    /// shrinks (the diffusion fixed-point property from [17]).
    #[test]
    fn iterates_converge_to_consensus() {
        let (dict, a, x) = setup(8, 12, 1);
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let mut eng = DiffusionEngine::new(&a, 12, None).unwrap();
        eng.run(&dict, &task, &x, DiffusionParams { mu: 0.2, iters: 3000 }).unwrap();
        let d_big = eng.disagreement();
        eng.reset();
        eng.run(&dict, &task, &x, DiffusionParams { mu: 0.02, iters: 30_000 }).unwrap();
        let d_small = eng.disagreement();
        assert!(d_small < 0.05, "disagreement at small μ: {d_small}");
        assert!(
            d_small < 0.25 * d_big,
            "disagreement must scale with μ: {d_big} → {d_small}"
        );
    }

    /// Fixed point must satisfy the dual optimality condition
    /// Σ_k ∇J_k(ν°) = 0, i.e. ν° − x + (1/δ) W thr(Wᵀν°) = 0 (sq-Euclid).
    #[test]
    fn fixed_point_satisfies_stationarity() {
        let (dict, a, x) = setup(6, 10, 2);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let mut eng = DiffusionEngine::new(&a, 10, None).unwrap();
        eng.run(&dict, &task, &x, DiffusionParams { mu: 0.02, iters: 30_000 }).unwrap();
        let nu = eng.consensus_nu();
        // grad = ν − x + (1/δ) Σ_q thr(w_qᵀν) w_q
        let s = dict.mat().matvec_t(&nu).unwrap();
        let coeff: Vec<f32> = s.iter().map(|&v| task.threshold(v) / task.delta()).collect();
        let wy = dict.mat().matvec(&coeff).unwrap();
        let mut grad = vec![0.0f32; 10];
        for i in 0..10 {
            grad[i] = nu[i] - x[i] + wy[i];
        }
        // The fixed point sits O(μ) from the optimum (constant step size).
        let gn = crate::math::vector::norm2(&grad);
        assert!(gn < 5e-2, "stationarity residual {gn}");
    }

    /// Eq. 53: at the optimum ν° = x − W y° for the squared-ℓ2 residual.
    #[test]
    fn nu_equals_residual_at_optimum() {
        let (dict, a, x) = setup(6, 10, 3);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let mut eng = DiffusionEngine::new(&a, 10, None).unwrap();
        eng.run(&dict, &task, &x, DiffusionParams { mu: 0.02, iters: 30_000 }).unwrap();
        let nu = eng.consensus_nu();
        let y = eng.recover_y(&dict, &task);
        let wy = dict.mat().matvec(&y).unwrap();
        for i in 0..10 {
            assert!(
                (nu[i] - (x[i] - wy[i])).abs() < 3e-2,
                "i={i}: ν {} vs residual {}",
                nu[i],
                x[i] - wy[i]
            );
        }
    }

    /// Single informed agent reaches the same solution as all-informed
    /// (the paper's headline distributed-data property).
    #[test]
    fn single_informed_agent_matches_all_informed() {
        let (dict, a, x) = setup(8, 12, 4);
        let task = TaskSpec::SparseCoding { gamma: 0.3, delta: 0.5 };
        // Both configurations share the same optimum; their O(μ) biases
        // differ, so compare at a small step size.
        let params = DiffusionParams { mu: 0.01, iters: 60_000 };
        let mut all = DiffusionEngine::new(&a, 12, None).unwrap();
        all.run(&dict, &task, &x, params).unwrap();
        let mut one = DiffusionEngine::new(&a, 12, Some(&[0])).unwrap();
        one.run(&dict, &task, &x, params).unwrap();
        let na = all.consensus_nu();
        let no = one.consensus_nu();
        crate::testutil::assert_close(&no, &na, 2e-2, 5e-2);
    }

    #[test]
    fn huber_iterates_stay_in_box() {
        let (dict, a, mut x) = setup(6, 10, 5);
        crate::math::vector::scale(5.0, &mut x); // make the box active
        let task = TaskSpec::HuberNmf { gamma: 0.1, delta: 0.5, eta: 0.2 };
        let mut eng = DiffusionEngine::new(&a, 10, None).unwrap();
        eng.run(&dict, &task, &x, DiffusionParams { mu: 0.3, iters: 500 }).unwrap();
        for k in 0..6 {
            assert!(crate::math::vector::norm_inf(eng.nu(k)) <= 1.0 + 1e-6);
        }
    }

    #[test]
    fn nmf_recovered_y_nonnegative() {
        let (dict, a, x) = setup(6, 10, 6);
        let task = TaskSpec::Nmf { gamma: 0.05, delta: 0.5 };
        let mut eng = DiffusionEngine::new(&a, 10, None).unwrap();
        eng.run(&dict, &task, &x, DiffusionParams { mu: 0.3, iters: 1000 }).unwrap();
        let y = eng.recover_y(&dict, &task);
        assert!(y.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn fully_connected_consensus_after_one_combine() {
        let (dict, _, x) = setup(5, 8, 7);
        let a = uniform_weights(5);
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let mut eng = DiffusionEngine::new(&a, 8, None).unwrap();
        assert!(eng.is_fully_connected());
        eng.run(&dict, &task, &x, DiffusionParams { mu: 0.3, iters: 1 }).unwrap();
        // After combine with A = 11ᵀ/N every row is identical.
        assert!(eng.disagreement() < 1e-6);
    }

    /// The FC fast path must match the generic gemm combine bit-for-bit
    /// in structure (same math, different order — allow f32 roundoff).
    #[test]
    fn fc_fast_path_matches_gemm_combine() {
        let (dict, _, x) = setup(6, 10, 9);
        let task = TaskSpec::SparseCoding { gamma: 0.15, delta: 0.4 };
        let params = DiffusionParams { mu: 0.3, iters: 37 };
        let a = uniform_weights(6);
        let mut fast = DiffusionEngine::new(&a, 10, None).unwrap();
        assert!(fast.is_fully_connected());
        fast.run(&dict, &task, &x, params).unwrap();
        // Force the slow path by perturbing A negligibly below the doubly-
        // stochastic tolerance but above the uniform-detection threshold.
        let mut a2 = a.clone();
        a2.set(0, 0, a2.get(0, 0) + 3e-6);
        a2.set(0, 1, a2.get(0, 1) - 3e-6);
        let mut slow = DiffusionEngine::new(&a2, 10, None).unwrap();
        assert!(!slow.is_fully_connected());
        slow.run(&dict, &task, &x, params).unwrap();
        for k in 0..6 {
            crate::testutil::assert_close(fast.nu(k), slow.nu(k), 2e-4, 2e-3);
        }
    }

    #[test]
    fn shape_errors_detected() {
        let (dict, a, x) = setup(5, 8, 8);
        let mut eng = DiffusionEngine::new(&a, 8, None).unwrap();
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let bad_x = vec![0.0; 7];
        assert!(eng.run(&dict, &task, &bad_x, DiffusionParams { mu: 0.1, iters: 1 }).is_err());
        assert!(DiffusionEngine::new(&a, 8, Some(&[9])).is_err());
        assert!(DiffusionEngine::new(&a, 8, Some(&[])).is_err());
        let _ = x;
    }
}
